"""Paper Fig. 7 + Fig. 8 + Table 4 analogue: running time of ε₁ filter
chains of increasing length, per dtype; single-program chain vs
per-filter dispatch (SMIL-like "naive") vs pixel pump (scalar
streaming); effective throughput (MPx/s).

Honest finding on this 1-core CPU host (EXPERIMENTS.md
§Paper-validation): XLA compiles each ε₁ into one fused vectorized pass,
so the per-filter path is already bandwidth-optimal per step, and the
fori_loop chain program is *slower* (while-loop buffer copies) — i.e. a
generic compiler does NOT fuse across filter iterations.  That is
precisely the gap the paper's technique (and our Pallas fused-chain
kernel, which keeps K steps VMEM-resident) closes; the TPU-side win is
quantified structurally in §Roofline (geodesic2d at 97% of the VPU
roofline).  The SIMD-vs-scalar axis of the paper's Fig. 8 IS directly
visible here: vectorized chains are ~450× the scalar pixel pump on char.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import DTYPES, timeit, timeit_host
from repro.baselines import naive, pixel_pump
from repro.data.images import blobs
from repro.kernels import ops


def run(quick: bool = True):
    size = 512 if quick else 1024
    lengths = [16, 64, 256] if quick else [16, 64, 256, 512, 1024, 1536]
    dtypes = ["char", "float"] if quick else list(DTYPES)
    rows = []
    for dname in dtypes:
        dt = DTYPES[dname]
        img = blobs(size, size, dt)
        f = jnp.asarray(img)
        naive.chain(f, 1, "erode")   # warm the per-filter jit caches
        for n in lengths:
            t_ours = timeit(lambda x: ops.morph_chain(x, n, "erode", "xla"), f)
            t_naive = timeit_host(lambda: naive.chain(f, n, "erode"),
                                  repeats=2)
            mpx = size * size * n / t_ours / 1e6
            rows.append({
                "name": f"chain/{dname}/{size}px/n{n}/chain_program",
                "us_per_call": t_ours * 1e6,
                "derived": f"{mpx:.0f}MPx/s vs_naive="
                           f"{t_naive/t_ours:.2f}x",
            })
            rows.append({
                "name": f"chain/{dname}/{size}px/n{n}/naive",
                "us_per_call": t_naive * 1e6,
                "derived": "",
            })
            if n <= 64:  # scalar python pump is slow; sample small chains
                t_pump = timeit_host(
                    lambda: pixel_pump.chain(img[:128, :128], n))
                scale = (size * size) / (128 * 128)
                rows.append({
                    "name": f"chain/{dname}/{size}px/n{n}/pixel_pump",
                    "us_per_call": t_pump * scale * 1e6,
                    "derived": f"extrapolated_from_128px "
                               f"speedup={t_pump*scale/t_ours:.0f}x",
                })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
