"""Paper §4.3/§5 crossover claim: chained 3×3 erosion beats the
O(1)-per-pixel streaming method (pixel pump; vHGW is its vectorized
equivalent here) for window sizes up to 183×183 (char) / 27×27 (double).

We sweep the half-size s and report the cost ratio chained/vHGW; the
measured crossover point on this substrate is the `derived` field of the
summary row.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import DTYPES, timeit
from repro.baselines import vhgw
from repro.data.images import blobs
from repro.kernels import ops


def run(quick: bool = True):
    size = 512 if quick else 1024
    sweep = [1, 4, 8, 16, 32, 64] if quick else [1, 4, 8, 16, 32, 64, 91]
    rows = []
    for dname in (["char", "double"] if not quick else ["char"]):
        dt = DTYPES[dname]
        f = jnp.asarray(blobs(size, size, dt))
        crossover = None
        for s in sweep:
            t_chain = timeit(
                lambda x: ops.morph_chain(x, s, "erode", "xla"), f)
            t_vhgw = timeit(lambda x: vhgw.erode(x, s), f)
            ratio = t_chain / t_vhgw
            if crossover is None and ratio > 1.0:
                crossover = s
            rows.append({
                "name": f"crossover/{dname}/s{s}",
                "us_per_call": t_chain * 1e6,
                "derived": f"vhgw={t_vhgw*1e6:.0f}us ratio={ratio:.2f}",
            })
        rows.append({
            "name": f"crossover/{dname}/summary",
            "us_per_call": 0.0,
            "derived":
                f"chained_faster_until_s={crossover or '>'+str(sweep[-1])}"
                       f" (window {(crossover or sweep[-1])*2+1}px)",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
