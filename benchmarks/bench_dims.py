"""Paper Fig. 9 analogue: performance dependency on image dimensions.

Square baseline vs width-varied (fixed H=128) vs height-varied (fixed
W=128) with a fixed 512-long ε₁ chain — the paper's probe of buffer-size
(width) vs synchronization (height) sensitivity.  In our TPU mapping
width sets the VMEM band size and height the number of grid bands.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import DTYPES, timeit
from repro.data.images import blobs
from repro.kernels import ops


def run(quick: bool = True):
    n = 128 if quick else 512
    sizes = [128, 512, 2048] if quick else [128, 512, 2048, 8192]
    rows = []
    dt = DTYPES["char"]
    for label, mk in [
        ("square", lambda s: (s, s)),
        ("width", lambda s: (128, s)),
        ("height", lambda s: (s, 128)),
    ]:
        for s in sizes:
            h, w = mk(s)
            f = jnp.asarray(blobs(h, w, dt))
            t = timeit(lambda x: ops.morph_chain(x, n, "erode", "xla"), f)
            rows.append({
                "name": f"dims/{label}/{h}x{w}/n{n}",
                "us_per_call": t * 1e6,
                "derived": f"{h*w*n/t/1e6:.0f}MPx/s",
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
