"""Generalised geodesic distance suite: wavefront requeue scheduling
vs the raster-scan sweep schedule vs the L1 quasi-distance baseline.

One image, one sparse seed set, three engines for the same fixpoint
(all bit-exact with ``repro.gdt.gdt_reference``):

* ``wavefront`` — the chunked activity-grid scheduler (the repo's
  requeue machinery, ``ChainPlan.schedule="wavefront"``); the derived
  column carries its chunk-weighted utilization (busy/capacity);
* ``raster`` — FastGeodis-style down/up/left/right sweeps iterated to
  fixpoint (``schedule="raster"``);
* ``xla`` — the pure-jnp Jacobi oracle;
* ``qdt_l1`` — the existing binary L1 quasi-distance kernel on the
  thresholded image, the λ=0 bridge (grey weights off, integer
  lattice): what gdt generalises.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro import api
from repro.core.chain import plan_chain
from repro.data.images import blobs
from repro.kernels import ops as K


def _case(size: int):
    img = (blobs(size, size, np.uint8).astype(np.float32) / 255.0) * 3.0
    rng = np.random.default_rng(7)
    seeds = (rng.random((size, size)) < 4.0 / size).astype(np.float32)
    seeds[size // 2, size // 2] = 1.0
    return jnp.asarray(img), jnp.asarray(seeds)


def run(quick: bool = True):
    size = 128 if quick else 512
    lamb, nu = 1.0, float(2 * size)
    img, seeds = _case(size)
    expr = api.E.gdt(api.E.input("image"), api.E.input("seeds"),
                     lamb=lamb, nu=nu)
    rows = []

    wave = api.compile(expr, img.shape, img.dtype, "pallas")
    t = timeit(lambda: wave(img, seeds), repeats=2)
    _, conv, busy, cap = wave.run_batch_stats(img[None], seeds[None])
    util = float(busy) / float(cap) if int(cap) else 1.0
    rows.append({
        "name": f"gdt/wavefront/{size}px",
        "us_per_call": t * 1e6,
        "derived": f"lamb={lamb} converged={bool(conv.all())} "
                   f"chunk_util={util:.2f}",
    })

    raster_plan = plan_chain(size, size, np.float32, None,
                             n_images_resident=3, n_images=1,
                             convergent=True, schedule="raster")
    raster = api.compile(expr, img.shape, img.dtype, "pallas",
                         plan=raster_plan)
    tr = timeit(lambda: raster(img, seeds), repeats=2)
    rows.append({
        "name": f"gdt/raster/{size}px",
        "us_per_call": tr * 1e6,
        "derived": f"lamb={lamb} vs_wavefront={t / tr:.2f}x",
    })

    xla = api.compile(expr, img.shape, img.dtype, "xla")
    tx = timeit(lambda: xla(img, seeds), repeats=2)
    rows.append({
        "name": f"gdt/xla/{size}px",
        "us_per_call": tx * 1e6,
        "derived": f"lamb={lamb} vs_wavefront={t / tx:.2f}x",
    })

    # λ=0 bridge baseline: binary L1 quasi-distance on the thresholded
    # image (the transform gdt reduces to when grey weights are off)
    binary = jnp.asarray(
        (np.asarray(img) > np.asarray(img).mean()).astype(np.uint8) * 255)
    tq = timeit(lambda: K.qdt_planes(binary, backend="pallas"), repeats=2)
    rows.append({
        "name": f"gdt/qdt_l1/{size}px",
        "us_per_call": tq * 1e6,
        "derived": f"binary_baseline vs_wavefront={t / tq:.2f}x",
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
