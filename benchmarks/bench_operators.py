"""Paper Table 5 analogue: end-to-end geodesic operators on synthetic
images with the paper's morphological statistics (blobs / basins /
border objects), char dtype.

Columns: ours (fused chains, XLA), hierarchical-queue reconstruction
(the SMIL single-threaded baseline), naive per-filter dispatch; plus the
reconstruction chain length (the paper reports average chain lengths).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit, timeit_host
from repro.baselines import queue_reconstruction as qr
from repro.core import morphology as M
from repro.core import operators as OPS
from repro.core.chain import plan_chain
from repro.data.images import basins, blobs, border_objects
from repro.kernels import ops as K


def run(quick: bool = True):
    size = 256 if quick else 1024
    male = blobs(size, size, np.uint8)
    airport = basins(size, size, np.uint8)
    airplane = border_objects(size, size, np.uint8)
    f = jnp.asarray(male)
    rows = []

    def bench(name, ours_fn, queue_fn=None, chain_len=None):
        t = timeit(ours_fn, repeats=2)
        derived = []
        if chain_len is not None:
            derived.append(f"chain={chain_len}")
        if queue_fn is not None:
            tq = timeit_host(queue_fn)
            derived.append(f"queue_recon={tq*1e6:.0f}us "
                           f"ratio={tq/t:.2f}x")
        rows.append({"name": f"operators/{name}/{size}px",
                     "us_per_call": t * 1e6,
                     "derived": " ".join(derived)})

    h = 40
    marker = np.asarray(OPS.sat_sub(f, h))
    _, iters = jax.jit(
        lambda a, b: M.dilate_reconstruct_with_iters(a, b))(
            jnp.asarray(marker), f)
    bench("HMAX", lambda: jax.jit(lambda x: OPS.hmax(x, h))(f),
          lambda: qr.dilate_reconstruct(marker, male),
          chain_len=int(iters))
    bench("DOME", lambda: jax.jit(lambda x: OPS.dome(x, h))(f))

    fa = jnp.asarray(airport)
    m_h = np.asarray(OPS.hfill_marker(fa))
    bench("HFILL", lambda: jax.jit(OPS.hfill)(fa),
          lambda: qr.erode_reconstruct(m_h, airport))

    fp = jnp.asarray(airplane)
    m_r = np.asarray(OPS.raobj_marker(fp))
    bench("RAOBJ", lambda: jax.jit(OPS.raobj)(fp),
          lambda: qr.dilate_reconstruct(m_r, airplane))

    s_open = 8 if quick else 75
    bench(f"OPENREC_s{s_open}",
          lambda: jax.jit(
              lambda x: OPS.opening_by_reconstruction(x, s_open))(f))

    bench("QDT", lambda: K.qdt_planes(f, backend="xla"))

    # sparse-marker reconstruction: exercises the active-band requeue
    # scheduler.  The mask is one horizontally extended object on a zero
    # background, so the reconstruction stays confined to a few bands —
    # everything else converges after the first chunk and is skipped
    # (and the driver compacts the survivors into a dense grid).
    sparse_mask = np.zeros((size, size), np.uint8)
    lo, hi = (3 * size) // 8, (4 * size) // 8
    sparse_mask[lo:hi, size // 16 : size - size // 16] = 200
    sparse = np.zeros((size, size), np.uint8)
    sparse[(lo + hi) // 2, size // 8] = 200
    sj, smj = jnp.asarray(sparse), jnp.asarray(sparse_mask)
    _, stats = jax.block_until_ready(
        K.reconstruct_with_stats(sj, smj, "dilate", "pallas"))
    frac = (int(stats.active_band_sum)
            / max(1, int(stats.total_bands) * int(stats.chunks)))
    bench("RECON_SPARSE_pallas",
          lambda: K.reconstruct(sj, smj, "dilate", "pallas"))
    rows[-1]["derived"] += (f" chunks={int(stats.chunks)}"
                            f" active_frac={frac:.2f}")

    # sparse *vertical* wavefront: the worst case for row-band
    # scheduling (every full-width band stays active while its slice of
    # the corridor converges) and the showcase for 2-D tiling — the
    # derived column compares tile-executions between the auto-tiled
    # plan and a row-only plan on the same input (row bands normalized
    # to tile-equivalents: one band spans n_tiles tiles of area).
    vsize = 640 if quick else 1024  # >= 5 tile columns so skipping shows
    vcol = vsize // 2 + vsize // 16  # inside one tile column
    vmask = np.zeros((vsize, vsize), np.uint8)
    vmask[8 : vsize - 8, vcol : vcol + 16] = 200
    vsparse = np.zeros((vsize, vsize), np.uint8)
    vsparse[8, vcol + 2] = 200
    vj, vmj = jnp.asarray(np.minimum(vsparse, vmask)), jnp.asarray(vmask)
    plan_2d = plan_chain(vsize, vsize, np.uint8, None, n_images_resident=2,
                         convergent=True)
    plan_1d = plan_chain(vsize, vsize, np.uint8, None, n_images_resident=2,
                         convergent=True, tile_w=0)
    _, st2 = jax.block_until_ready(K.reconstruct_with_stats(
        vj, vmj, "dilate", "pallas", plan=plan_2d))
    _, st1 = jax.block_until_ready(K.reconstruct_with_stats(
        vj, vmj, "dilate", "pallas", plan=plan_1d))
    bench(f"RECON_VWAVE_{vsize}v_tiled_pallas",
          lambda: K.reconstruct(vj, vmj, "dilate", "pallas", plan=plan_2d))
    tiles_2d = int(st2.active_band_sum)
    tiles_1d = int(st1.active_band_sum) * plan_2d.n_tiles
    rows[-1]["derived"] += (
        f" tiles_2d={tiles_2d} tiles_row={tiles_1d}"
        f" skip={tiles_1d / max(1, tiles_2d):.2f}x"
        f" grid={plan_2d.total_bands}x{plan_2d.n_tiles}")

    # batched front-end: one (N, H, W) stack through the fused kernels
    n_batch = 4
    fb = jnp.asarray(np.stack([male] * n_batch))
    bench(f"BATCH_ERODE_N{n_batch}_s8",
          lambda: K.erode(fb, 8, backend="pallas"))
    mb = jnp.asarray(np.stack([sparse] * n_batch))
    maskb = jnp.asarray(np.stack([sparse_mask] * n_batch))
    bench(f"BATCH_RECON_N{n_batch}",
          lambda: K.reconstruct(mb, maskb, "dilate", "pallas"))

    smax = 11
    bench(f"PS_0_{smax}",
          lambda: jax.jit(lambda x: OPS.pattern_spectrum(x, smax))(f),
          chain_len=sum(4 * k for k in range(1, smax + 1)))

    s_asf = 5 if quick else 11
    bench(f"ASF_{s_asf}", lambda: jax.jit(lambda x: OPS.asf(x, s_asf))(f),
          chain_len=OPS.asf_chain_length(s_asf))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
