"""Expression-pipeline benchmarks: what the compile step buys.

Two questions, answered in the standard ``name,us_per_call,derived``
row contract:

* **Fused vs unfused ASF** — the same ASF_s chain executed as one
  compiled expression (one pad, 2s+1 fused launches, masked refills
  between opposite-op runs) vs the legacy per-stage path (4s separate
  erode/dilate programs, each paying its own pad + launch + crop).  The
  derived column carries both static ``Executable.stats()`` counts, so
  the round-trip reduction is visible next to the wall-clock ratio.
* **Compile-cache hit rate** — the steady-state cost of routing every
  legacy sugar call through ``repro.api.compile`` (a cache lookup), and
  the hit rate over a replayed mixed operator workload.
* **Rewrites on vs off** — redundant composites (ASF over an opening,
  OBR∘OBR, a re-stabilized DOME) compiled with the expression optimizer
  enabled and disabled.  The derived column carries the static
  launches/pads saved by the algebraic rewrites next to the wall-clock
  ratio; outputs are asserted bit-exact before the row is emitted.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import timeit, timeit_host
from repro import api
from repro.data.images import blobs


def _stagewise_asf(f, s, backend):
    """Legacy path: one compiled program per elementary stage."""
    from repro.kernels.ops import morph_chain

    out = f
    for k in range(1, s + 1):
        out = morph_chain(out, k, "erode", backend)   # γ_k
        out = morph_chain(out, k, "dilate", backend)
        out = morph_chain(out, k, "dilate", backend)  # φ_k
        out = morph_chain(out, k, "erode", backend)
    return out


def run(quick: bool = True):
    size = 128 if quick else 512
    s = 2 if quick else 5
    f = jnp.asarray(blobs(size, size, np.uint8))
    rows = []

    for backend in ("xla", "pallas") if quick else ("pallas",):
        exe = api.compile(api.asf_expr(s), f.shape, f.dtype, backend)
        st = exe.stats()
        t_fused = timeit(exe, f, repeats=2)
        t_stage = timeit(lambda: _stagewise_asf(f, s, backend), repeats=2)
        rows.append({
            "name": f"pipeline/ASF{s}_fused_{backend}/{size}px",
            "us_per_call": t_fused * 1e6,
            "derived": (f"pads={st['pads']} launches={st['launches']} "
                        f"refills={st['refills']} "
                        f"chain={st['fused_chain_len']}"),
        })
        rows.append({
            "name": f"pipeline/ASF{s}_stagewise_{backend}/{size}px",
            "us_per_call": t_stage * 1e6,
            "derived": (f"pads={4 * s} launches={4 * s} "
                        f"ratio={t_stage / t_fused:.2f}x"),
        })

    # opening-by-reconstruction: chain + scheduler in one padded program
    exe = api.compile(api.opening_by_reconstruction_expr(8), f.shape,
                      f.dtype, "pallas")
    st = exe.stats()
    rows.append({
        "name": f"pipeline/OBR8_fused_pallas/{size}px",
        "us_per_call": timeit(exe, f, repeats=2) * 1e6,
        "derived": f"pads={st['pads']} launches={st['launches']}",
    })

    # optimizer: redundant composites with rewrites on vs off.  Each
    # pair must be bit-exact; the optimizer's win is the static
    # launches/pads delta (and whatever wall clock follows from it).
    E = api.E
    g = E.input("f")
    composites = {
        # ASF_2 stacked on an opening(1) the ASF's own γ_1 absorbs
        "ASF2_over_opening": api.asf_expr(s, E.opening(1, g)),
        # opening-by-reconstruction applied twice (γ_rec idempotence)
        "OBR4_twice": E.reconstruct(
            E.erode(4, E.reconstruct(E.erode(4, g), g, op="dilate")),
            g, op="dilate"),
        # DOME whose hmax was redundantly re-stabilized (Rec∘Rec)
        "DOME_restab": E.sub(g, E.reconstruct(
            E.reconstruct(E.sat_sub(g, 40), g, op="dilate"),
            g, op="dilate")),
    }
    for name, expr in composites.items():
        exe_on = api.compile(expr, f.shape, f.dtype, "pallas")
        exe_off = api.compile(expr, f.shape, f.dtype, "pallas",
                              rewrite=False)
        st_on, st_off = exe_on.stats(), exe_off.stats()
        out_on, out_off = exe_on(f), exe_off(f)
        assert np.array_equal(np.asarray(out_on), np.asarray(out_off)), \
            f"optimizer changed {name} output"
        t_on = timeit(exe_on, f, repeats=2)
        t_off = timeit(exe_off, f, repeats=2)
        d_launch = st_off["launches"] - st_on["launches"]
        d_pads = st_off["pads"] - st_on["pads"]
        rows.append({
            "name": f"pipeline/opt/{name}_rewritten_pallas/{size}px",
            "us_per_call": t_on * 1e6,
            "derived": (f"launches={st_on['launches']} "
                        f"pads={st_on['pads']} "
                        f"saved_launches={d_launch} "
                        f"saved_pads={d_pads} "
                        f"ratio={t_off / t_on:.2f}x"),
        })
        rows.append({
            "name": f"pipeline/opt/{name}_unrewritten_pallas/{size}px",
            "us_per_call": t_off * 1e6,
            "derived": (f"launches={st_off['launches']} "
                        f"pads={st_off['pads']}"),
        })

    # compile-cache steady state: replay a mixed workload through the
    # legacy sugar (every call routes through api.compile)
    api.clear_cache()
    workload = [api.hmax_expr(40.0), api.dome_expr(40.0),
                api.hfill_expr(), api.asf_expr(s),
                api.opening_by_reconstruction_expr(4)]
    for expr in workload:            # cold: compile misses
        api.compile(expr, f.shape, f.dtype, "xla")
    t_hit = timeit_host(
        lambda: [api.compile(e, f.shape, f.dtype, "xla") for e in workload],
        repeats=3,
    ) / len(workload)
    cs = api.cache_stats()
    rows.append({
        "name": "pipeline/compile_cache_lookup",
        "us_per_call": t_hit * 1e6,
        "derived": (f"hit_rate={cs['hit_rate']:.2f} hits={cs['hits']} "
                    f"misses={cs['misses']} entries={cs['entries']}"),
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
