"""Roofline terms per (arch × shape) from the dry-run artifacts
(results/dryrun).  Emits one row per cell: the bounding step time and
which term dominates.  Run the dry-run first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
from __future__ import annotations

import os

from repro.launch.roofline import enrich, load

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def run(quick: bool = True, directory: str = DEFAULT_DIR):
    rows = []
    if not os.path.isdir(directory):
        return [{"name": "roofline/missing", "us_per_call": 0.0,
                 "derived": f"run dryrun --all --out {directory} first"}]
    for r in load(directory):
        if not r.get("ok"):
            rows.append({"name": f"roofline/{r['arch']}/{r['shape']}/"
                                 f"{r['mesh']}",
                         "us_per_call": 0.0,
                         "derived": f"FAILED {r.get('error', '')[:50]}"})
            continue
        r = enrich(r)
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            "us_per_call": r["step_s_bound"] * 1e6,
            "derived": (f"dom={r['dominant']} "
                        f"c={r.get('compute_s_hlo', r['compute_s']):.3f}s "
                        f"m={r['memory_s']:.3f}s k={r['collective_s']:.3f}s "
                        f"frac={r.get('roofline_frac', 0):.1%} "
                        f"{r['bytes_per_device']/1e9:.1f}GB/dev"),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
