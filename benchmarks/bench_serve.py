"""Serving throughput: single-request latency vs micro-batched
throughput across bucket sizes, through the full ``repro.serve`` stack
(bucketing, compiled-plan cache, double-buffered executor) — plus an
**overload** section driving an open-loop arrival burst into a bounded
queue so the robustness counters (shed rate, retries, expiries) land in
the same ``run.py --json`` schema as the throughput rows.

Rows come straight from :meth:`ServeMetrics.bench_rows` /
:meth:`ServeMetrics.counter_rows`, so the derived column carries the
serving-native metrics (latency percentiles, batch occupancy, cache
hit-rate, FPS / MPx-per-s) and the lifecycle counters documented in
``docs/ROBUSTNESS.md``.

The **sustained** section (PR 9) replays one open-loop arrival
schedule at the same rate through the poll-based batch path and the
continuous slot-refill engine, emitting paired
``serve/sustained/{poll,continuous}`` rows (p99 + occupancy) and
asserting the slot-refilled outputs bit-exact against solo execution.
"""
from __future__ import annotations

import time

import numpy as np

from repro.data.images import blobs
from repro.kernels import ops as K
from repro.serve import QueueFullError, ServeError, Service
from repro.serve import faults as F

#: Ops benched per bucket size: one convergence-driven reconstruction,
#: one fixed chain.
_OPS = (("hmax", {"h": 40}), ("erode", {"s": 16}))


def _stream(service: Service, frames, n_round: int):
    tickets = [
        service.submit(op, f, params=params)
        for _ in range(n_round)
        for f in frames
        for op, params in _OPS
    ]
    service.flush()
    for t in tickets:
        t.result()


def _throughput(quick: bool) -> list[dict]:
    size = 128 if quick else 512
    backend = "xla" if quick else "pallas"
    batches = (1, 4) if quick else (1, 4, 8)
    n_frames = 4 if quick else 8
    rounds = 2 if quick else 3
    frames = [blobs(size, size, np.uint8, seed=i) for i in range(n_frames)]

    rows = []
    for max_batch in batches:
        service = Service(backend=backend, max_batch=max_batch,
                          max_delay_ms=1e6, pad_quantum=64)
        service.warmup(
            {"op": op, "params": params, "shape": (size, size),
             "dtype": np.uint8, "batch": max_batch}
            for op, params in _OPS
        )
        _stream(service, frames, rounds)
        for r in service.bench_rows():
            if "/counters/" in r["name"]:
                continue  # lifecycle counters: overload section only
            r["name"] = r["name"].replace("serve/", f"serve/b{max_batch}/")
            rows.append(r)
    return rows


def _overload(quick: bool) -> list[dict]:
    """Open-loop arrival burst against a bounded queue.

    Arrivals are independent of completions (no waiting on results mid
    burst), request shapes are spread across several buckets so no
    bucket fills to ``max_batch`` on its own, the queue is bounded, a
    per-request deadline is set, and one transient dispatch fault is
    injected — the service load-sheds what it must and completes the
    rest, and the counters (shed/expired/retried) plus the admitted
    requests' p99 become rows.
    """
    size = 64 if quick else 192
    n_burst = 32 if quick else 128
    n_shapes = 4
    # max_delay_ms is effectively infinite: during the burst nothing
    # drains, so admission control (max_queue) is what absorbs the
    # overload — the arrival rate is decoupled from completions.
    svc = Service(
        backend="xla", max_batch=8, max_delay_ms=1e6, pad_quantum=16,
        max_queue=16, default_deadline_ms=30e3,
        faults=F.parse("seed=1702;dispatch:n=1"),
    )
    frames = [blobs(size + 16 * j, size, np.uint8, seed=j)
              for j in range(n_shapes)]
    tickets = []
    shed = 0
    for i in range(n_burst):
        try:
            tickets.append(svc.submit("hmax", frames[i % n_shapes],
                                      params={"h": 40}))
        except QueueFullError:
            shed += 1
    svc.flush()
    completed = 0
    for t in tickets:
        try:
            t.result()
            completed += 1
        except ServeError:
            pass  # typed shed/expiry under overload: expected
    stats = svc.stats()
    counters = stats["counters"]
    p99 = stats["totals"]["latency"]["p99_ms"]
    rows = [{
        "name": "serve/overload/burst",
        "us_per_call": p99 * 1e3,
        "derived": (
            f"p99={p99:.1f}ms shed_rate={shed / n_burst:.2f} "
            f"retried={counters['retried']} expired={counters['expired']} "
            f"admitted={len(tickets)} completed={completed}"
        ),
    }]
    for r in svc.metrics.counter_rows():
        r["name"] = r["name"].replace("serve/", "serve/overload/")
        rows.append(r)
    return rows


def _sustained_cases(n_req: int, size: int) -> list[tuple]:
    """Reconstruction traffic with one serpentine straggler (request 4)
    in a stream of fast-converging requests — the straggler needs ~35x
    more scheduler chunks than its batch-mates, which is exactly the
    shape continuous refill exists for: freed slots take queued work
    while the straggler iterates, so one heavy request cannot poison
    the tail latency of the other 99%."""
    rng = np.random.default_rng(1702)
    cases = []
    for i in range(n_req):
        if i == 4:
            f = np.full((size, size), 0.1, np.float32)
            for r in range(0, size, 2):
                f[r, :] = 0.9
                if r + 1 < size:
                    f[r + 1, -1 if (r // 2) % 2 == 0 else 0] = 0.9
            m = np.full((size, size), 0.05, np.float32)
            m[0, 0] = 0.8
        else:
            f = rng.random((size, size)).astype(np.float32)
            m = (0.9 * f).astype(np.float32)
        cases.append((np.minimum(m, f), f))
    return cases


def _sustained_drive(svc: Service, cases, interval_s: float) -> list:
    """Open-loop arrival pacing: submissions follow the wall-clock
    schedule regardless of completions, with ``pump()`` keeping the
    event loop live *between* arrivals (timer flushes, engine rounds
    and drains all happen inside it).  Submissions never pump — when
    the service falls behind the schedule, arrivals land back-to-back
    and queue, exactly like an outside client."""
    tickets = []
    start = time.perf_counter()
    for i, (m, f) in enumerate(cases):
        while time.perf_counter() - start < i * interval_s:
            svc.pump()
        tickets.append(svc.submit("reconstruct", m, f))
    while svc.work_pending():
        svc.pump()
    svc.flush()
    return [t.result() for t in tickets]


def _sustained(quick: bool) -> list[dict]:
    """Equal-arrival-rate comparison: poll-based batch path vs the
    continuous slot-refill engine.

    The inter-arrival interval is calibrated from a warm solo run
    (1.4x the fast-request service time) so the offered load tracks
    the host's speed and sits just above the poll path's knee; both
    modes then replay the identical schedule.  Continuous outputs are
    asserted bit-exact against direct kernel execution (and against
    the poll path), so the occupancy/p99 win never comes at the cost
    of numerics.
    """
    size = 48 if quick else 96
    n_req = 100
    cases = _sustained_cases(n_req, size)

    # Calibrate: warm solo latency of a non-straggler request is the
    # fast-path service time; arrivals at 1.4x that keep the queue
    # shallow while the straggler is resident, which is where refill
    # (and poll's head-of-line blocking) shows.
    cal = Service(max_batch=1, max_delay_ms=0.0, pad_quantum=16)
    cal.submit("reconstruct", *cases[1])
    cal.flush()
    t0 = time.perf_counter()
    cal.submit("reconstruct", *cases[2])
    cal.flush()
    interval_s = max(1e-4, 1.4 * (time.perf_counter() - t0))

    rows, results = [], {}
    for mode, continuous in (("poll", False), ("continuous", True)):
        svc = Service(
            max_batch=4, max_delay_ms=2 * interval_s * 1e3,
            pad_quantum=16, continuous=continuous, refill_quantum=2,
        )
        # warm every partial fill: the poll path compiles one program
        # per canonical batch size it meets during the run
        svc.warmup([{"op": "reconstruct", "shape": (size, size),
                     "dtype": np.float32, "batch": b}
                    for b in (1, 2, 3, 4)])
        results[mode] = _sustained_drive(svc, cases, interval_s)
        stats = svc.stats()
        p99 = stats["totals"]["latency"]["p99_ms"]
        p50 = stats["totals"]["latency"]["p50_ms"]
        occ = stats["totals"]["work_occupancy"]
        counters = stats["counters"]
        rows.append({
            "name": f"serve/sustained/{mode}",
            "us_per_call": p99 * 1e3,
            "derived": (
                f"arrival_hz={1.0 / interval_s:.1f} p99={p99:.1f}ms "
                f"p50={p50:.1f}ms work_occ={occ:.2f} "
                f"refills={counters['refills']} "
                f"rounds={stats['totals']['rounds']}"
            ),
        })
    # Bit-exactness gate: every slot-refilled output must equal solo
    # kernel execution and the poll-path result, element for element.
    for (m, f), got, ref_poll in zip(cases, results["continuous"],
                                     results["poll"]):
        ref = np.asarray(K.reconstruct(m, f, op="dilate"))
        np.testing.assert_array_equal(np.asarray(got), ref)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref_poll))
    return rows


def run(quick: bool = True):
    return _throughput(quick) + _overload(quick) + _sustained(quick)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
