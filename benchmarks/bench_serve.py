"""Serving throughput: single-request latency vs micro-batched
throughput across bucket sizes, through the full ``repro.serve`` stack
(bucketing, compiled-plan cache, double-buffered executor) — plus an
**overload** section driving an open-loop arrival burst into a bounded
queue so the robustness counters (shed rate, retries, expiries) land in
the same ``run.py --json`` schema as the throughput rows.

Rows come straight from :meth:`ServeMetrics.bench_rows` /
:meth:`ServeMetrics.counter_rows`, so the derived column carries the
serving-native metrics (latency percentiles, batch occupancy, cache
hit-rate, FPS / MPx-per-s) and the lifecycle counters documented in
``docs/ROBUSTNESS.md``.
"""
from __future__ import annotations

import numpy as np

from repro.data.images import blobs
from repro.serve import QueueFullError, ServeError, Service
from repro.serve import faults as F

#: Ops benched per bucket size: one convergence-driven reconstruction,
#: one fixed chain.
_OPS = (("hmax", {"h": 40}), ("erode", {"s": 16}))


def _stream(service: Service, frames, n_round: int):
    tickets = [
        service.submit(op, f, params=params)
        for _ in range(n_round)
        for f in frames
        for op, params in _OPS
    ]
    service.flush()
    for t in tickets:
        t.result()


def _throughput(quick: bool) -> list[dict]:
    size = 128 if quick else 512
    backend = "xla" if quick else "pallas"
    batches = (1, 4) if quick else (1, 4, 8)
    n_frames = 4 if quick else 8
    rounds = 2 if quick else 3
    frames = [blobs(size, size, np.uint8, seed=i) for i in range(n_frames)]

    rows = []
    for max_batch in batches:
        service = Service(backend=backend, max_batch=max_batch,
                          max_delay_ms=1e6, pad_quantum=64)
        service.warmup(
            {"op": op, "params": params, "shape": (size, size),
             "dtype": np.uint8, "batch": max_batch}
            for op, params in _OPS
        )
        _stream(service, frames, rounds)
        for r in service.bench_rows():
            if "/counters/" in r["name"]:
                continue  # lifecycle counters: overload section only
            r["name"] = r["name"].replace("serve/", f"serve/b{max_batch}/")
            rows.append(r)
    return rows


def _overload(quick: bool) -> list[dict]:
    """Open-loop arrival burst against a bounded queue.

    Arrivals are independent of completions (no waiting on results mid
    burst), request shapes are spread across several buckets so no
    bucket fills to ``max_batch`` on its own, the queue is bounded, a
    per-request deadline is set, and one transient dispatch fault is
    injected — the service load-sheds what it must and completes the
    rest, and the counters (shed/expired/retried) plus the admitted
    requests' p99 become rows.
    """
    size = 64 if quick else 192
    n_burst = 32 if quick else 128
    n_shapes = 4
    # max_delay_ms is effectively infinite: during the burst nothing
    # drains, so admission control (max_queue) is what absorbs the
    # overload — the arrival rate is decoupled from completions.
    svc = Service(
        backend="xla", max_batch=8, max_delay_ms=1e6, pad_quantum=16,
        max_queue=16, default_deadline_ms=30e3,
        faults=F.parse("seed=1702;dispatch:n=1"),
    )
    frames = [blobs(size + 16 * j, size, np.uint8, seed=j)
              for j in range(n_shapes)]
    tickets = []
    shed = 0
    for i in range(n_burst):
        try:
            tickets.append(svc.submit("hmax", frames[i % n_shapes],
                                      params={"h": 40}))
        except QueueFullError:
            shed += 1
    svc.flush()
    completed = 0
    for t in tickets:
        try:
            t.result()
            completed += 1
        except ServeError:
            pass  # typed shed/expiry under overload: expected
    stats = svc.stats()
    counters = stats["counters"]
    p99 = stats["totals"]["latency"]["p99_ms"]
    rows = [{
        "name": "serve/overload/burst",
        "us_per_call": p99 * 1e3,
        "derived": (
            f"p99={p99:.1f}ms shed_rate={shed / n_burst:.2f} "
            f"retried={counters['retried']} expired={counters['expired']} "
            f"admitted={len(tickets)} completed={completed}"
        ),
    }]
    for r in svc.metrics.counter_rows():
        r["name"] = r["name"].replace("serve/", "serve/overload/")
        rows.append(r)
    return rows


def run(quick: bool = True):
    return _throughput(quick) + _overload(quick)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
