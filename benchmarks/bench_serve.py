"""Serving throughput: single-request latency vs micro-batched
throughput across bucket sizes, through the full ``repro.serve`` stack
(bucketing, compiled-plan cache, double-buffered executor).

Rows come straight from :meth:`ServeMetrics.bench_rows`, so the derived
column carries the serving-native metrics (latency percentiles, batch
occupancy, cache hit-rate, FPS / MPx-per-s) and ``run.py --json``
captures serving throughput alongside the kernel benchmarks.
"""
from __future__ import annotations

import numpy as np

from repro.data.images import blobs
from repro.serve import Service

#: Ops benched per bucket size: one convergence-driven reconstruction,
#: one fixed chain.
_OPS = (("hmax", {"h": 40}), ("erode", {"s": 16}))


def _stream(service: Service, frames, n_round: int):
    tickets = [
        service.submit(op, f, params=params)
        for _ in range(n_round)
        for f in frames
        for op, params in _OPS
    ]
    service.flush()
    for t in tickets:
        t.result()


def run(quick: bool = True):
    size = 128 if quick else 512
    backend = "xla" if quick else "pallas"
    batches = (1, 4) if quick else (1, 4, 8)
    n_frames = 4 if quick else 8
    rounds = 2 if quick else 3
    frames = [blobs(size, size, np.uint8, seed=i) for i in range(n_frames)]

    rows = []
    for max_batch in batches:
        service = Service(backend=backend, max_batch=max_batch,
                          max_delay_ms=1e6, pad_quantum=64)
        service.warmup(
            {"op": op, "params": params, "shape": (size, size),
             "dtype": np.uint8, "batch": max_batch}
            for op, params in _OPS
        )
        _stream(service, frames, rounds)
        for r in service.bench_rows():
            r["name"] = r["name"].replace("serve/", f"serve/b{max_batch}/")
            rows.append(r)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
