"""Paper Table 3 analogue: per-method working memory and per-pixel
comparison counts for the evaluated methods, instantiated with our
TPU-adapted parameters (fusion plan from core.chain instead of the
paper's T threads).

These are analytic (as in the paper's Table 3), not timed; the
``us_per_call`` column is 0 by construction and the payload is in
``derived``.
"""
from __future__ import annotations

import numpy as np

from repro.core.chain import plan_chain


def run(quick: bool = True):
    x = 1024                       # image width, paper's default
    rows = []
    for dname, dt in (("char", np.uint8), ("double", np.float64)):
        plan = plan_chain(1024, x, dt, 512)
        th, k = plan.band_h, plan.fuse_k
        b = np.dtype(dt).itemsize
        entries = {
            # proposed (ours): banded VMEM working set per grid step
            "proposed_fused": (
                f"cmp_per_px=4 "
                f"working_set={(3*(th+2*k)*plan.width_pad*b)//1024}KiB"
                f" (band {th}+2x{k} halo, VMEM) bandwidth_amp="
                f"{plan.bandwidth_amplification:.1f}x redundancy="
                f"{plan.redundant_compute_fraction:.1%}"
            ),
            # paper's proposed: 2X per filter x T filters
            "paper_cpu_pipeline": f"cmp_per_px=4 mem=2X*T={2*x}B*T",
            "pixel_pump": f"cmp_per_px=O(1) mem=(3X+3)*T={(3*x+3)}B*T",
            "smil_like_naive": f"cmp_per_px=4 mem=XY={x*x*b//1024}KiB "
                               "full image per filter",
            "vhgw": f"cmp_per_px=3 mem=2 prefix/suffix rows={2*x*b}B",
        }
        for name, derived in entries.items():
            rows.append({"name": f"table3/{dname}/{name}",
                         "us_per_call": 0.0, "derived": derived})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
