"""Timing helpers + the standard image/dtype matrix of the paper."""
from __future__ import annotations

import time

import jax
import numpy as np

DTYPES = {"char": np.uint8, "short": np.uint16, "float": np.float32,
          "double": np.float64}


def timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of wall time in seconds; blocks on jax outputs."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def timeit_host(fn, *args, repeats: int = 1) -> float:
    """For numpy/python baselines."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def emit(rows: list[dict]):
    """Print the runner's CSV contract: name,us_per_call,derived."""
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r.get('derived', '')}")
