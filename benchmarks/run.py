"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only chain,dims]
                                            [--json OUTDIR]

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).
With ``--json OUTDIR`` additionally writes one ``BENCH_<module>.json``
per module mapping row name → us_per_call, so the perf trajectory is
machine-readable across PRs.  The schema (including the serve suite's
metrics fields) and how to read the scheduler statistics are documented
in ``docs/BENCHMARKS.md``.

Modules:
  chain      paper Fig. 7/8 + Table 4 (chain length × dtype, speedups,
             throughput)
  dims       paper Fig. 9 (width/height dependency)
  operators  paper Table 5 (geodesic operators vs queue baselines)
  crossover  paper §4.3/§5 (chained 3×3 vs O(1)/px window crossover)
  roofline   §Roofline terms from the dry-run artifacts
  serve      repro.serve micro-batching: single-request latency vs
             batched throughput across bucket sizes (occupancy, cache
             hit-rate and FPS in the derived column)
  pipeline   repro.api expression pipeline: fused vs per-stage ASF
             (pad/launch round-trip counts from Executable.stats())
             and the compile-cache hit rate
  gdt        generalised geodesic distance: wavefront requeue vs
             raster-sweep schedules vs the binary L1 QDT baseline
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks import (bench_chain, bench_crossover, bench_dims,
                        bench_gdt, bench_operators, bench_pipeline,
                        bench_roofline, bench_serve, bench_table3)
from benchmarks.common import emit

MODULES = {
    "chain": bench_chain,
    "dims": bench_dims,
    "operators": bench_operators,
    "crossover": bench_crossover,
    "table3": bench_table3,
    "roofline": bench_roofline,
    "serve": bench_serve,
    "pipeline": bench_pipeline,
    "gdt": bench_gdt,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (1024², long chains)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--json", default=None, metavar="OUTDIR",
                    help="write BENCH_<module>.json files (name -> "
                         "us_per_call) into OUTDIR")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown suite(s) {', '.join(sorted(unknown))}; "
                 f"available: {', '.join(MODULES)}")
    outdir = None
    if args.json is not None:
        outdir = pathlib.Path(args.json)
        outdir.mkdir(parents=True, exist_ok=True)

    print("name,us_per_call,derived")
    for name in names:
        rows = MODULES[name].run(quick=not args.full)
        emit(rows)
        if outdir is not None:
            payload = {r["name"]: r["us_per_call"] for r in rows}
            path = outdir / f"BENCH_{name}.json"
            path.write_text(json.dumps(payload, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
