"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only chain,dims]

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).
Modules:
  chain      paper Fig. 7/8 + Table 4 (chain length × dtype, speedups,
             throughput)
  dims       paper Fig. 9 (width/height dependency)
  operators  paper Table 5 (geodesic operators vs queue baselines)
  crossover  paper §4.3/§5 (chained 3×3 vs O(1)/px window crossover)
  roofline   §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import argparse

from benchmarks import (bench_chain, bench_crossover, bench_dims,
                        bench_operators, bench_roofline, bench_table3)
from benchmarks.common import emit

MODULES = {
    "chain": bench_chain,
    "dims": bench_dims,
    "operators": bench_operators,
    "crossover": bench_crossover,
    "table3": bench_table3,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (1024², long chains)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(MODULES)
    print("name,us_per_call,derived")
    for name in names:
        emit(MODULES[name].run(quick=not args.full))


if __name__ == "__main__":
    main()
