"""Distributed geodesic reconstruction over a device mesh with halo
exchange — the paper's pipeline scaled out (DESIGN.md §6).

Run with fake devices to see the sharded path on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/distributed_morphology.py
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed as D
from repro.core import morphology as M
from repro.data.images import blobs

n = len(jax.devices())
rows = max(1, n // 2)
cols = n // rows
mesh = jax.make_mesh((rows, cols), ("r", "c"))
print(f"mesh: {rows}x{cols} over {n} devices")

img = blobs(512, 512, np.uint8)
f = jnp.asarray(img)
m = jnp.asarray(blobs(512, 512, np.uint8, seed=9))
marker = jnp.maximum(f, m)
put = lambda x: jax.device_put(x, NamedSharding(mesh, P("r", "c")))  # noqa: E731

# 64-step chain: halo exchanged once per 16 fused steps (4 exchanges)
chain = D.distributed_chain(mesh, "r", "c", n=64, op="erode",
                            backend="xla", fuse_k=16)
out = chain(put(f))
ref = M.erode(f, 64)
print("chain sharded == single-device:",
      bool(jnp.array_equal(out, ref)))

rec = D.distributed_reconstruct(mesh, "r", "c", op="erode",
                                backend="xla", fuse_k=16)
out = rec(put(marker), put(m))
ref = M.erode_reconstruct(marker, m)
print("reconstruct sharded == single-device:",
      bool(jnp.array_equal(out, ref)))
print("per-device shards:", out.sharding.shard_shape(out.shape))
