"""Quickstart: the paper's geodesic operators through the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import operators as OPS
from repro.data.images import blobs
from repro.kernels import ops

# a "Male"-like test image: smooth background + multi-scale blobs
img = blobs(256, 256, np.uint8)
f = jnp.asarray(img)

# elementary chains (the paper's core workload) — fused Pallas kernels
er64 = ops.erode(f, 64)            # 64 chained 3×3 erosions == 129×129
open16 = ops.opening(f, 16)
print("erode_64:   min", int(er64.min()), "max", int(er64.max()))
print("opening_16: mean", float(open16.mean()))

# geodesic reconstruction with kernel-fused convergence detection
rec = ops.reconstruct(jnp.maximum(f, 100), f, op="erode")
print("reconstruct: fixpoint reached, mean", float(rec.mean()))

# the operator family of paper §2
print("hmax_40:    maxima suppressed ->", int(OPS.hmax(f, 40).max()))
print("dome_40:    residue max       ->", int(OPS.dome(f, 40).max()))
print("hfill:      holes filled      ->", int(OPS.hfill(f).min()))
print("raobj:      border objs gone  ->", int(OPS.raobj(f).max()))
d = OPS.qdt(f)
print("qdt:        max distance      ->", int(d.max()))
ps = OPS.pattern_spectrum(f, 8)
print("pattern spectrum (s=0..7):", np.asarray(ps, np.int64))
print("asf_3:      tv-smoothed       ->", float(OPS.asf(f, 3).std()))
