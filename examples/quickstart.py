"""Quickstart: the paper's geodesic operators through the public API.

    PYTHONPATH=src python examples/quickstart.py

Two ways in: the *expression API* (compose a graph, compile once,
execute many times — composites fuse into one padded program) and the
classic operator sugar, which is thin wrappers over the same compiles.
"""
import numpy as np
import jax.numpy as jnp

from repro.api import E, asf_expr, compile, dome_expr, hmax_expr
from repro.core import operators as OPS
from repro.data.images import blobs
from repro.kernels import ops

# a "Male"-like test image: smooth background + multi-scale blobs
img = blobs(256, 256, np.uint8)
f = jnp.asarray(img)

# --- expression API: compose -> compile -> execute ----------------------
x = E.input("f")
er64 = compile(x >> E.erode(64), f.shape, f.dtype)(f)     # 129×129 erosion
print("erode_64:   min", int(er64.min()), "max", int(er64.max()))

open16 = compile(E.opening(16, x), f.shape, f.dtype)(f)
print("opening_16: mean", float(open16.mean()))

# geodesic reconstruction with kernel-fused convergence detection
rec_expr = E.reconstruct(E.input("marker"), E.input("mask"), op="erode")
rec = compile(rec_expr, f.shape, f.dtype)(jnp.maximum(f, 100), f)
print("reconstruct: fixpoint reached, mean", float(rec.mean()))

# composite graphs fuse end-to-end: ASF_3 is ONE padded program
asf3 = compile(asf_expr(3), f.shape, f.dtype)
print("asf_3:      tv-smoothed       ->", float(asf3(f).std()),
      "| program:", asf3.stats())

hm = compile(hmax_expr(40), f.shape, f.dtype)
dm = compile(dome_expr(40), f.shape, f.dtype)
print("hmax_40:    maxima suppressed ->", int(hm(f).max()))
print("dome_40:    residue max       ->", int(dm(f).max()))

# --- classic sugar (same compiles underneath) ---------------------------
print("hfill:      holes filled      ->", int(OPS.hfill(f).min()))
print("raobj:      border objs gone  ->", int(OPS.raobj(f).max()))
d = OPS.qdt(f)
print("qdt:        max distance      ->", int(d.max()))
ps = OPS.pattern_spectrum(f, 8)
print("pattern spectrum (s=0..7):", np.asarray(ps, np.int64))
er = ops.erode(f, 16)   # kernels sugar routes through the same cache
print("kernels.ops.erode(16): mean   ->", float(er.mean()))
