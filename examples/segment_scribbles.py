"""Interactive scribble segmentation served through ``repro.serve``:
the incremental marker-update pattern on the generalised geodesic
distance subsystem (``repro.gdt``).

The image is pinned on the service **once** (``service.pin``); every
round then submits only a cheap scribble-plane update, passing the
pinned name in place of the array — the cached-image path (watch the
``asset_hits`` counter climb).  Each round refines the previous one's
scribbles, the way an annotator would: a couple of seed taps first,
then corrective strokes where the last segmentation leaked.

Each ``seg_scribble`` request lowers to *two* gdt kernel segments over
the shared image (foreground + background distance maps) compared in
the finalize phase; a raw ``gdt`` distance request rides along to show
the single-kernel refillable path under the same service.

    PYTHONPATH=src python examples/segment_scribbles.py [--size 64]
        [--backend pallas|xla] [--rounds 3] [--continuous]
"""
import argparse

import numpy as np

from repro.data.images import blobs
from repro.serve import Service


def make_image(size: int) -> np.ndarray:
    """A float32 blob field — bright objects on a dark background, the
    grey-weighted cost's terrain."""
    return blobs(size, size, np.uint8, seed=3).astype(np.float32) / 255.0


def scribble_rounds(img: np.ndarray, rounds: int):
    """Progressively refined scribble planes (0 = unmarked, 1 = fg,
    2 = bg): round 0 taps one bright and one dark pixel; later rounds
    add strokes along a bright row / dark column, as an annotator
    correcting the boundary would."""
    h, w = img.shape
    flat = img.ravel()
    fg0 = np.unravel_index(int(flat.argmax()), img.shape)
    bg0 = np.unravel_index(int(flat.argmin()), img.shape)
    s = np.zeros(img.shape, np.float32)
    s[fg0], s[bg0] = 1.0, 2.0
    yield s.copy()
    for r in range(1, rounds):
        k = (r * h) // rounds
        row = np.clip(fg0[0] + (k - h // 2) // 4, 0, h - 1)
        col = np.clip(bg0[1] + (k - w // 2) // 4, 0, w - 1)
        s[row, w // 4: 3 * w // 4: 2] = 1.0   # stroke through the object
        s[:: 2, col] = 2.0                    # stroke over the background
        s[fg0], s[bg0] = 1.0, 2.0
        yield s.copy()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--backend", choices=("pallas", "xla"),
                    default="pallas")
    ap.add_argument("--continuous", action="store_true",
                    help="run refillable buckets on the continuous "
                         "slot-refill engine")
    args = ap.parse_args()

    img = make_image(args.size)
    lamb, nu = 1.0, float(2 * args.size)
    service = Service(backend=args.backend, max_batch=4, pad_quantum=16,
                      continuous=args.continuous)

    # Pin the (conceptually large, unchanging) image once; every round
    # below streams only the scribble update against the pinned name.
    service.pin("slice", img)

    print(f"scribble segmentation: {args.size}px float32, "
          f"{args.rounds} rounds, backend={args.backend}, "
          f"continuous={args.continuous}")
    for rnd, scrib in enumerate(scribble_rounds(img, args.rounds)):
        mask = service.submit(
            "seg_scribble", "slice", scrib,
            params={"lamb": lamb, "nu": nu}).result()
        n_fg = int(np.count_nonzero(scrib == 1.0))
        n_bg = int(np.count_nonzero(scrib == 2.0))
        print(f"  round {rnd}: {n_fg:4d} fg / {n_bg:4d} bg scribbles -> "
              f"foreground {float(np.asarray(mask).mean()):.1%}")

    # A raw distance request against the same pinned image: the
    # single-kernel gdt op is pad-safe and refillable, so with
    # --continuous this lands on the slot-refill engine.
    seeds = np.zeros(img.shape, np.float32)
    seeds[args.size // 2, args.size // 2] = 1.0
    dist = service.submit("gdt", "slice", seeds,
                          params={"lamb": lamb, "nu": nu}).result()
    print(f"  gdt from centre seed: max distance "
          f"{float(np.asarray(dist).max()):.1f}")

    stats = service.stats()
    hits = stats["counters"].get("asset_hits", 0)
    cache = stats["cache"]
    print(f"\npinned-asset hits: {hits} "
          f"({args.rounds} scribble rounds + 1 distance request)")
    print(f"cache: {cache['entries']} programs, "
          f"hit_rate={cache['hit_rate']:.2f}")
    service.close()


if __name__ == "__main__":
    main()
