"""End-to-end driver (the paper's kind is image processing, so serving):
a batched geodesic-operator service processing a stream of image
requests, with per-operator latency/throughput accounting and the >30
FPS-style headline metric of the paper's conclusion.

    PYTHONPATH=src python examples/serve_geodesic.py [--frames 24] [--size 512]
                                                     [--batch 4]

``--batch N`` additionally runs the batched (N, H, W) path: frames are
stacked and pushed through one compiled program per operator, so the
kernel grid covers the whole stack (and, for reconstruction, finished
images stop contributing band work while the rest iterate).
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import operators as OPS
from repro.data.images import basins, blobs, border_objects
from repro.kernels import ops


def build_service(quick_ops=True):
    """The service compiles one program per operator once, then streams."""
    return {
        "hmax40": jax.jit(lambda f: OPS.hmax(f, 40)),
        "dome40": jax.jit(lambda f: OPS.dome(f, 40)),
        "hfill": jax.jit(OPS.hfill),
        "raobj": jax.jit(OPS.raobj),
        "open_rec8": jax.jit(lambda f: OPS.opening_by_reconstruction(f, 8)),
        "asf3": jax.jit(lambda f: OPS.asf(f, 3)),
        "chain256": jax.jit(lambda f: ops.morph_chain(f, 256, "erode",
                                                      "xla")),
    }


def build_batched_service():
    """Batched front-end: one program per operator over (N, H, W) stacks.

    The reconstruction-based operators route through the Pallas fast
    path (active-band requeue scheduling) via ``backend="pallas"``."""
    return {
        "hmax40": jax.jit(lambda f: OPS.hmax(f, 40, backend="pallas")),
        "hfill": jax.jit(lambda f: OPS.hfill(f, backend="pallas")),
        "raobj": jax.jit(lambda f: OPS.raobj(f, backend="pallas")),
        "erode16": jax.jit(lambda f: ops.erode(f, 16)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=12)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--batch", type=int, default=0,
                    help="also run the batched (N, H, W) path with this "
                         "batch size")
    args = ap.parse_args()

    service = build_service()
    # request stream: alternating image kinds (different convergence
    # behaviour, like the paper's Male/Airport/Airplane)
    frames = [
        jnp.asarray({0: blobs, 1: basins, 2: border_objects}[i % 3](
            args.size, args.size, np.uint8, seed=i))
        for i in range(args.frames)
    ]

    print(f"geodesic service: {args.frames} frames @ "
          f"{args.size}x{args.size} u8")
    for name, fn in service.items():
        fn(frames[0]).block_until_ready()      # compile once
        t0 = time.perf_counter()
        for f in frames:
            fn(f).block_until_ready()
        dt = time.perf_counter() - t0
        fps = args.frames / dt
        mpx = args.frames * args.size**2 / dt / 1e6
        print(f"  {name:10s} {dt/args.frames*1e3:8.1f} ms/frame "
              f"{fps:7.1f} FPS  {mpx:8.1f} MPx/s")

    if args.batch > 1:
        n = min(args.batch, len(frames))
        stacks = [jnp.asarray(np.stack([np.asarray(f) for f in
                                        frames[i:i + n]]))
                  for i in range(0, len(frames) - n + 1, n)]
        dropped = len(frames) - len(stacks) * n
        print(f"batched path: {len(stacks)} stacks of {n} frames"
              + (f" ({dropped} leftover frames skipped)" if dropped else ""))
        for name, fn in build_batched_service().items():
            fn(stacks[0]).block_until_ready()  # compile once
            t0 = time.perf_counter()
            for s in stacks:
                fn(s).block_until_ready()
            dt = time.perf_counter() - t0
            n_frames = len(stacks) * n
            fps = n_frames / dt
            mpx = n_frames * args.size**2 / dt / 1e6
            print(f"  {name:10s} {dt/len(stacks)*1e3:8.1f} ms/stack "
                  f"{fps:7.1f} FPS  {mpx:8.1f} MPx/s")


if __name__ == "__main__":
    main()
