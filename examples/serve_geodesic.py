"""End-to-end driver (the paper's kind is image processing, so serving):
a batched geodesic-operator service processing a stream of image
requests, with per-operator latency/throughput accounting and the >30
FPS-style headline metric of the paper's conclusion.

    PYTHONPATH=src python examples/serve_geodesic.py [--frames 24] [--size 512]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import operators as OPS
from repro.data.images import basins, blobs, border_objects
from repro.kernels import ops


def build_service(quick_ops=True):
    """The service compiles one program per operator once, then streams."""
    return {
        "hmax40": jax.jit(lambda f: OPS.hmax(f, 40)),
        "dome40": jax.jit(lambda f: OPS.dome(f, 40)),
        "hfill": jax.jit(OPS.hfill),
        "raobj": jax.jit(OPS.raobj),
        "open_rec8": jax.jit(lambda f: OPS.opening_by_reconstruction(f, 8)),
        "asf3": jax.jit(lambda f: OPS.asf(f, 3)),
        "chain256": jax.jit(lambda f: ops.morph_chain(f, 256, "erode",
                                                      "xla")),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=12)
    ap.add_argument("--size", type=int, default=256)
    args = ap.parse_args()

    service = build_service()
    # request stream: alternating image kinds (different convergence
    # behaviour, like the paper's Male/Airport/Airplane)
    frames = [
        jnp.asarray({0: blobs, 1: basins, 2: border_objects}[i % 3](
            args.size, args.size, np.uint8, seed=i))
        for i in range(args.frames)
    ]

    print(f"geodesic service: {args.frames} frames @ "
          f"{args.size}x{args.size} u8")
    for name, fn in service.items():
        fn(frames[0]).block_until_ready()      # compile once
        t0 = time.perf_counter()
        for f in frames:
            fn(f).block_until_ready()
        dt = time.perf_counter() - t0
        fps = args.frames / dt
        mpx = args.frames * args.size**2 / dt / 1e6
        print(f"  {name:10s} {dt/args.frames*1e3:8.1f} ms/frame "
              f"{fps:7.1f} FPS  {mpx:8.1f} MPx/s")


if __name__ == "__main__":
    main()
