"""End-to-end serving demo on ``repro.serve``: a stream of heterogeneous
image requests flows through the shape-bucketed micro-batching service
— bucketing, compiled-plan caching, double-buffered execution and
demuxing all happen inside the subsystem (no hand-rolled batching
loop), and the run ends with the service's own metrics report
(per-bucket latency percentiles, batch occupancy, cache hit-rate, the
paper's FPS / MPx-per-s headline numbers).

    PYTHONPATH=src python examples/serve_geodesic.py [--frames 24]
        [--size 256] [--batch 4] [--backend pallas|xla] [--mixed-sizes]

The service is declared as data (``SERVICE``): operator names + params
resolved through the registry.  ``--mixed-sizes`` varies frame shapes to
exercise pad-to-bucket canonicalization; frames of different sizes that
round to the same bucket share one compiled program.  Buckets are keyed
on the *lowered run signature*, so HMAX, DOME and RAOBJ — all one
dilate-reconstruction after their prepare stages — co-batch into a
single ``rec:dilate`` bucket (cross-op packing; watch its occupancy in
the report).
"""
import argparse
import json

import numpy as np

from repro.data.images import basins, blobs, border_objects
from repro.serve import Service

#: The served operator mix, declared as data: (op name, params).
SERVICE = (
    ("hmax", {"h": 40}),
    ("dome", {"h": 40}),
    ("hfill", {}),
    ("raobj", {}),
    ("open_rec", {"s": 8}),
    ("erode", {"s": 16}),
    ("asf", {"s": 3}),
)

_KINDS = (blobs, basins, border_objects)


def make_frames(n, size, mixed_sizes):
    """Alternating image kinds (different convergence behaviour, like
    the paper's Male/Airport/Airplane), optionally ragged sizes."""
    frames = []
    for i in range(n):
        h = w = size
        if mixed_sizes:
            h = size - 16 * (i % 3)
            w = size - 8 * (i % 5)
        frames.append(_KINDS[i % 3](h, w, np.uint8, seed=i))
    return frames


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=12)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4,
                    help="max micro-batch size per bucket")
    ap.add_argument("--backend", choices=("pallas", "xla"), default="pallas")
    ap.add_argument("--max-delay-ms", type=float, default=50.0)
    ap.add_argument("--mixed-sizes", action="store_true",
                    help="vary frame shapes to exercise bucket padding")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the full metrics summary as JSON")
    args = ap.parse_args()

    service = Service(
        backend=args.backend,
        max_batch=args.batch,
        max_delay_ms=args.max_delay_ms,
        pad_quantum=64,
    )
    frames = make_frames(args.frames, args.size, args.mixed_sizes)

    # Warm-up prefill: compile one program per (op, bucket, batch size)
    # before traffic arrives, so the stream below measures steady-state.
    # Every canonical batch size (powers of two up to --batch) is warmed
    # so deadline flushes and leftover partial batches also hit.
    batch_sizes, b = {args.batch}, 1
    while b < args.batch:
        batch_sizes.add(b)
        b *= 2
    shapes = sorted({f.shape for f in frames})
    service.warmup(
        {"op": op, "params": params, "shape": s, "dtype": np.uint8,
         "batch": b}
        for op, params in SERVICE for s in shapes
        for b in sorted(batch_sizes)
    )

    print(f"geodesic serve: {args.frames} frames @ ~{args.size}px u8, "
          f"{len(SERVICE)} ops, max_batch={args.batch}, "
          f"backend={args.backend}")

    # The request stream: every frame fans out to every configured op.
    tickets = [
        service.submit(op, f, params=params)
        for f in frames for op, params in SERVICE
    ]
    service.flush()
    for t in tickets:          # surfaces any per-request failure
        t.result()

    stats = service.stats()
    print(f"\n{'bucket':44s} {'req':>4s} {'occ':>5s} {'p50ms':>8s} "
          f"{'p99ms':>8s} {'FPS':>7s} {'MPx/s':>8s}")
    for label, b in stats["buckets"].items():
        print(f"{label:44s} {b['requests']:4d} {b['batch_occupancy']:5.2f} "
              f"{b['latency']['p50_ms']:8.1f} {b['latency']['p99_ms']:8.1f} "
              f"{b['fps']:7.1f} {b['mpx_per_s']:8.2f}")
    tot, cache = stats["totals"], stats["cache"]
    print(f"\ntotals: {tot['requests']} requests, "
          f"occupancy={tot['batch_occupancy']:.2f}, "
          f"fps={tot['fps']:.1f}, mpx/s={tot['mpx_per_s']:.2f}")
    print(f"cache:  {cache['entries']} programs, "
          f"hit_rate={cache['hit_rate']:.2f} "
          f"({cache['hits']} hits / {cache['misses']} misses, "
          f"{cache['warm_builds']} warm)")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(stats, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
