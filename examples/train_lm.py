"""Train a reduced-config LM for a few hundred steps with checkpointing
and (optional) failure injection + recovery.

    PYTHONPATH=src python examples/train_lm.py --arch gemma-2b --steps 200
    PYTHONPATH=src python examples/train_lm.py --fail-at 90     # dies
    PYTHONPATH=src python examples/train_lm.py --restore        # resumes
"""
import argparse

from repro.configs.registry import ARCH_IDS, get_reduced
from repro.optim import adamw
from repro.train.loop import FailureInjector, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    tcfg = TrainerConfig(steps=args.steps, seq_len=64, global_batch=8,
                         checkpoint_every=50,
                         checkpoint_dir=args.checkpoint_dir, q_chunk=64,
                         log_every=20)
    trainer = Trainer(cfg, tcfg,
                      adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                                        total_steps=args.steps))
    injector = FailureInjector(args.fail_at) if args.fail_at else None
    _, hist = trainer.run(injector=injector, restore=args.restore)
    print(f"loss {hist[0]:.3f} -> {hist[-1]:.3f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
