"""Static program verifier for lowered morphology plans.

Proves invariants about :class:`~repro.api.expr.Expr` graphs, lowered
:class:`~repro.api.lower.Program`\\ s and
:class:`~repro.core.chain.ChainPlan` schedules **without executing
them** — five check classes (halo coverage, dtype safety, plan
constraints, cache-key completeness, index-map bounds), three entry
points (the ``verify=`` hook in ``repro.api.compile``, the
``python -m repro.analysis.lint`` CLI, and direct calls from the
mutation self-tests).  See ``docs/VERIFIER.md``.
"""
from repro.analysis.cachekeys import check_executable_key, check_plan_key
from repro.analysis.dtypes import (
    SUPPORTED_DTYPES,
    check_bucketer_fills,
    check_distance_plane,
    check_fill_value,
    check_qdt_accumulator,
)
from repro.analysis.findings import (
    CHECKS,
    ERROR,
    WARN,
    Finding,
    Report,
    VerificationError,
)
from repro.analysis.halo import check_coverage, check_program
from repro.analysis.indexmaps import (
    check_block_specs,
    check_partition,
    check_plan_index_maps,
)
from repro.analysis.plans import check_mosaic_readiness, check_plan
from repro.analysis.verifier import verify_executable, verify_on_compile

__all__ = [
    "CHECKS", "ERROR", "WARN", "Finding", "Report", "VerificationError",
    "SUPPORTED_DTYPES",
    "check_bucketer_fills", "check_distance_plane", "check_fill_value",
    "check_qdt_accumulator",
    "check_coverage", "check_program",
    "check_block_specs", "check_partition", "check_plan_index_maps",
    "check_mosaic_readiness", "check_plan",
    "check_executable_key", "check_plan_key",
    "verify_executable", "verify_on_compile",
]
