"""Cache-key completeness (check class d).

``Executable.key`` is simultaneously the compile-cache key and the
``repro.serve`` bucket/cache identity; ``ChainPlan.key`` is its
schedule component.  A key that ignores a lowering-relevant field
serves *stale programs*: two distinct compilations collide and one
silently answers for the other (the bug class ``serve/cache.py`` has
no other defence against).

The check is mutation-based but static: structurally perturb each
field that can change what a call computes — every ``ChainPlan``
dataclass field, every run-phase component of the lowered ``Program``
(segment kinds/params/srcs/dsts, fills, input slots, outputs) and
every binding of the ``Executable`` (shape, dtype, backend,
``max_chunks``, ``was_2d``, plan) — rebuild the key, and require it to
move.  Fields deliberately *outside* the run signature (the root
``expr``, prepare/finalize graphs) are not perturbed: excluding them is
what lets HMAX and DOME co-batch, and the compile cache keys on the
expression graph itself so they cannot go stale.

``key_of`` is injectable so the self-tests can hand in a broken key
function and assert the checker reports the gap.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.findings import ERROR, Finding

__all__ = ["check_plan_key", "check_executable_key",
           "perturb_plan", "perturb_program"]


def _bump(value):
    """A same-type structurally different value."""
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 1
    if isinstance(value, float):
        return value + 0.125 if value < 1.0 else value - 0.125
    if isinstance(value, str):
        return value + "_x"
    if isinstance(value, tuple):
        return (*value, "_x")
    return ("_perturbed", value)


def _forge_plan(plan, field: str):
    """A copy of ``plan`` with one field changed, bypassing
    ``__post_init__`` (the perturbed plan need not be valid — only its
    key must differ)."""
    cls = type(plan)
    mutant = object.__new__(cls)
    for f in dataclasses.fields(cls):
        value = getattr(plan, f.name)
        object.__setattr__(mutant, f.name,
                           _bump(value) if f.name == field else value)
    return mutant


def perturb_plan(plan):
    """Yield ``(field_name, mutant_plan)`` for every dataclass field —
    enumerated dynamically so a field added later is covered without
    touching this module."""
    for f in dataclasses.fields(type(plan)):
        yield f.name, _forge_plan(plan, f.name)


def check_plan_key(plan, key_of=None) -> list:
    key_of = key_of or (lambda p: p.key)
    base = key_of(plan)
    out = []
    for field, mutant in perturb_plan(plan):
        if key_of(mutant) == base:
            out.append(Finding(
                "cache-key", ERROR, "ChainPlan.key",
                f"insensitive to field {field!r} — two plans differing "
                "only there collide in every compiled-program cache"))
    return out


def _perturb_params(params: tuple):
    if not params:
        return (("_perturbed", 1),)
    name, value = params[0]
    swap = {"erode": "dilate", "dilate": "erode",
            "hi": "lo", "lo": "hi"}
    new = swap.get(value, _bump(value))
    return ((name, new), *params[1:])


def perturb_program(program):
    """Yield ``(description, mutant_program)`` covering every run-phase
    component.  Mutants are built with :func:`dataclasses.replace`, so
    they are real ``Program`` instances (possibly semantically invalid
    — irrelevant: only key sensitivity is under test)."""
    for i, seg in enumerate(program.segments):
        segs = list(program.segments)
        segs[i] = dataclasses.replace(seg, params=_perturb_params(seg.params))
        yield (f"segments[{i}].params",
               dataclasses.replace(program, segments=tuple(segs)))
        if seg.srcs:
            segs = list(program.segments)
            segs[i] = dataclasses.replace(
                seg, srcs=tuple(s + 1000 for s in seg.srcs))
            yield (f"segments[{i}].srcs",
                   dataclasses.replace(program, segments=tuple(segs)))
        if seg.dsts:
            segs = list(program.segments)
            segs[i] = dataclasses.replace(
                seg, dsts=tuple(d + 1000 for d in seg.dsts))
            yield (f"segments[{i}].dsts",
                   dataclasses.replace(program, segments=tuple(segs)))
        segs = list(program.segments)
        segs[i] = dataclasses.replace(
            seg, kind="geodesic" if seg.kind != "geodesic" else "chain")
        yield (f"segments[{i}].kind",
               dataclasses.replace(program, segments=tuple(segs)))
    if program.run_fills:
        flipped = ("lo" if program.run_fills[0] == "hi" else "hi",
                   *program.run_fills[1:])
        yield ("run_fills", dataclasses.replace(program, run_fills=flipped))
    if program.run_input_slots:
        shifted = (program.run_input_slots[0] + 1000,
                   *program.run_input_slots[1:])
        yield ("run_input_slots",
               dataclasses.replace(program, run_input_slots=shifted))
    if program.run_outputs:
        shifted = (program.run_outputs[0] + 1000, *program.run_outputs[1:])
        yield ("run_outputs",
               dataclasses.replace(program, run_outputs=shifted))


def check_executable_key(exe, key_of=None) -> list:
    """Perturb every lowering-relevant field feeding ``Executable.key``
    and assert the key changes."""
    from repro.api.executable import Executable

    key_of = key_of or (lambda e: e.key)
    shape3 = (exe.n_images, exe.height, exe.width)

    def rebuild(program=None, shape3_=None, dtype=None, backend=None,
                plan="same", max_chunks="same", was_2d=None):
        return Executable(
            program if program is not None else exe.program,
            shape3_ if shape3_ is not None else shape3,
            dtype if dtype is not None else exe.dtype,
            backend if backend is not None else exe.backend,
            exe.plan if plan == "same" else plan,
            exe.max_chunks if max_chunks == "same" else max_chunks,
            exe.was_2d if was_2d is None else was_2d,
        )

    base = key_of(rebuild())
    mutants = []
    for desc, prog in perturb_program(exe.program):
        mutants.append((f"program.{desc}", rebuild(program=prog)))
    for axis in range(3):
        s = tuple(v + (8 if i == axis else 0) for i, v in enumerate(shape3))
        mutants.append((f"shape3[{axis}]", rebuild(shape3_=s)))
    other_dt = "uint16" if str(exe.dtype) != "uint16" else "uint8"
    mutants.append(("dtype", rebuild(dtype=other_dt)))
    mutants.append(("backend",
                    rebuild(backend=exe.backend + "_x")))
    mutants.append(("was_2d", rebuild(was_2d=not exe.was_2d)))
    mutants.append(("max_chunks",
                    rebuild(max_chunks=(exe.max_chunks or 0) + 17)))
    if exe.plan is not None:
        for field, plan in perturb_plan(exe.plan):
            mutants.append((f"plan.{field}", rebuild(plan=plan)))

    out = []
    for desc, mutant in mutants:
        if key_of(mutant) == base:
            out.append(Finding(
                "cache-key", ERROR, "Executable.key",
                f"insensitive to {desc} — distinct compilations would "
                "collide in the compile cache and the serve "
                "compiled-program cache"))
    return out
