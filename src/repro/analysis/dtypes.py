"""Dtype-safety checks (check class b): absorbing fills + QDT overflow.

Two families of facts are proved per supported dtype (the paper's
char→double crossover set, §4):

* the serve bucketer's pad fill (``serve/bucketer.py:pad_fill``) must
  equal the lattice identity the kernels pin halos with
  (``kernels/common.py:ident_for``) and round-trip through the image
  dtype exactly — a fill one ULP off the lattice top is no longer
  absorbing for erosion and corrupts borders silently;
* the quasi-distance transform accumulates residuals
  ``f − ε₁(f)`` into ``kernels/common.py:qdt_acc_dtype``; the residual
  telescoping bound is the lattice range (one erosion can drop a pixel
  from top to bottom), so the accumulator must represent
  ``top − bottom``.  When the image dtype's own range cannot overflow
  the accumulator the fact is a proof (uint8…int16); when overflow
  needs pathological-but-representable inputs it is a WARN
  (int32 images in an int32 accumulator, float64 in float32).

Every check takes the *claimed* value as an argument with the
production default, so the mutation self-tests can seed a wrong fill or
an undersized accumulator and assert detection.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import ERROR, WARN, Finding

#: Supported image dtypes, uint8 through float64 (ISSUE 6 scope).
SUPPORTED_DTYPES = ("uint8", "uint16", "int16", "int32",
                    "float32", "float64")

#: pad-fill name → the op whose lattice identity it must be.
FILL_OP = {"hi": "erode", "lo": "dilate"}


def _lattice(dtype):
    """(top, bottom) of the dtype's complete lattice as numpy scalars."""
    dtype = np.dtype(dtype)
    if np.issubdtype(dtype, np.floating):
        return np.array(np.inf, dtype), np.array(-np.inf, dtype)
    info = np.iinfo(dtype)
    return np.array(info.max, dtype), np.array(info.min, dtype)


def check_fill_value(dtype, which: str, value) -> list:
    """Is ``value`` the absorbing identity ``which`` for ``dtype``?"""
    out = []
    subject = f"pad_fill({np.dtype(dtype).name}, {which!r})"
    top, bot = _lattice(dtype)
    expect = top if which == "hi" else bot
    got = np.asarray(value)
    if got.dtype != np.dtype(dtype):
        # a float fill for an int image (or vice versa) silently casts
        # at pad time; require the exact dtype round-trip
        cast = got.astype(np.dtype(dtype))
        if not np.array_equal(cast.astype(got.dtype), got, equal_nan=True):
            out.append(Finding(
                "dtype", ERROR, subject,
                f"fill {got!r} is not representable in {np.dtype(dtype)}"))
            return out
        got = cast
    if not np.array_equal(got, expect, equal_nan=True):
        out.append(Finding(
            "dtype", ERROR, subject,
            f"fill is {got!r}, but the absorbing identity for "
            f"{FILL_OP[which]} is {expect!r} — pad values would "
            "participate in the min/max and corrupt borders"))
    return out


def check_bucketer_fills(dtypes=SUPPORTED_DTYPES) -> list:
    """Audit ``serve.bucketer.pad_fill`` against the kernel identities."""
    from repro.kernels.common import ident_for
    from repro.serve.bucketer import pad_fill

    out = []
    for dt in dtypes:
        for which, op in FILL_OP.items():
            out += check_fill_value(dt, which, pad_fill(dt, which))
            # the serve fill and the in-kernel pin must agree too
            kern = np.asarray(ident_for(op, dt))
            serve = np.asarray(pad_fill(dt, which))
            if not np.array_equal(kern, serve, equal_nan=True):
                out.append(Finding(
                    "dtype", ERROR, f"pad_fill({dt}, {which!r})",
                    f"serve fill {serve!r} != kernel halo identity "
                    f"{kern!r} (ident_for)"))
    return out


def check_qdt_accumulator(image_dtype, acc_dtype=None) -> list:
    """Can ``acc_dtype`` hold QDT residuals of ``image_dtype`` images?

    The residual is ``f − ε₁(f)`` with both operands cast to the
    accumulator first; its tight bound is ``top − bottom`` of the image
    lattice.
    """
    if acc_dtype is None:
        from repro.kernels.common import qdt_acc_dtype
        acc_dtype = qdt_acc_dtype(image_dtype)
    img, acc = np.dtype(image_dtype), np.dtype(acc_dtype)
    subject = f"qdt acc ({img.name} image → {acc.name} accumulator)"
    out = []

    if np.issubdtype(img, np.floating):
        if not np.issubdtype(acc, np.floating):
            out.append(Finding(
                "dtype", ERROR, subject,
                "floating image accumulated in an integer dtype — "
                "fractional residuals truncate"))
            return out
        if np.finfo(img).max > np.finfo(acc).max:
            out.append(Finding(
                "dtype", WARN, subject,
                f"residual bound 2·{np.finfo(img).max:.3g} exceeds "
                f"{acc.name} max {np.finfo(acc).max:.3g}: residuals of "
                "full-range images saturate to inf (distance planes "
                "stay ordered, values lose precision)"))
        return out

    if np.issubdtype(acc, np.floating):
        # integer residuals are exact in an integer accumulator; a
        # float accumulator breaks bit-exactness above 2^mantissa
        mant = np.finfo(acc).nmant
        if int(np.iinfo(img).max) - int(np.iinfo(img).min) > 2 ** mant:
            out.append(Finding(
                "dtype", ERROR, subject,
                f"integer residual bound exceeds the {acc.name} "
                f"mantissa (2^{mant}) — accumulation is no longer "
                "bit-exact"))
        return out

    bound = int(np.iinfo(img).max) - int(np.iinfo(img).min)
    acc_max = int(np.iinfo(acc).max)
    if bound > acc_max:
        # provable within the dtype's normal domain for narrow images,
        # domain-conditional for >= 32-bit images
        severity = ERROR if np.iinfo(img).bits < 32 else WARN
        out.append(Finding(
            "dtype", severity, subject,
            f"residual bound top−bottom = {bound} exceeds {acc.name} "
            f"max {acc_max} — a single erosion step can overflow the "
            "masked-store accumulator"
            + ("" if severity == ERROR else
               " (requires images spanning more than the accumulator "
               "range; unreachable for uint8/uint16 sources)")))
    return out


def check_distance_plane(max_chunks: int, fuse_k: int) -> list:
    """The d-plane stores ``base + k`` elementary-step indices in int32."""
    out = []
    max_d = int(max_chunks) * int(fuse_k)
    if max_d > np.iinfo(np.int32).max:
        out.append(Finding(
            "dtype", ERROR, "qdt distance plane",
            f"max distance index {max_d} (max_chunks={max_chunks} × "
            f"fuse_k={fuse_k}) overflows the int32 d-plane"))
    return out


def check_executable_dtypes(exe) -> list:
    """Dtype facts bound to one executable: QDT accumulation for its
    image dtype and d-plane headroom for its chunk budget."""
    out = []
    dt = np.dtype(exe.dtype)
    if dt.name not in SUPPORTED_DTYPES:
        out.append(Finding(
            "dtype", WARN, f"dtype {dt.name}",
            f"outside the audited set {SUPPORTED_DTYPES}"))
    if any(s.kind == "qdt" for s in exe.program.segments):
        out += check_qdt_accumulator(dt)
        if exe.plan is not None:
            out += check_distance_plane(exe._max_chunks_qdt, exe.plan.fuse_k)
    if (dt.kind != "f"
            and any(s.kind == "gdt" for s in exe.program.segments)):
        out.append(Finding(
            "dtype", ERROR, f"gdt on {dt.name}",
            "the generalised geodesic distance plane is a float lattice "
            "(+inf pad identity, fractional grey weights) — integer "
            "images must be cast to a float dtype before compilation"))
    return out
