"""Finding/report model shared by every static check.

A check function returns a list of :class:`Finding`; the orchestration
in ``repro.analysis.verifier`` aggregates them into a :class:`Report`.
Severities:

``ERROR``
    a provable structural violation — the program/plan/key would
    compute wrong results, crash, or serve stale cache entries.  Lint
    exits non-zero and the compile-time hook raises
    :class:`VerificationError`.
``WARN``
    a domain-conditional hazard (e.g. int32 QDT residuals can overflow
    only for images spanning more than the int32 range) or a
    readiness diagnostic (e.g. halo blocks narrower than the 128-lane
    Mosaic tiling — ROADMAP item 3).  Reported, never fatal.
"""
from __future__ import annotations

import dataclasses

ERROR = "error"
WARN = "warn"

#: The check classes (ISSUE 6 + the rewrite soundness hook of
#: ISSUE 8); every Finding carries one.
CHECKS = ("halo", "dtype", "plan", "cache-key", "index-map", "rewrite")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verified fact about a program/plan/executable."""

    check: str      # one of CHECKS
    severity: str   # ERROR | WARN
    subject: str    # what was checked ("segment 2 (chain er4)", "plan", ...)
    message: str    # what is wrong, with the numbers that prove it

    def __str__(self):
        return f"[{self.severity.upper():5s}] {self.check}: " \
               f"{self.subject}: {self.message}"


@dataclasses.dataclass
class Report:
    """Aggregated findings of one verification run."""

    findings: list = dataclasses.field(default_factory=list)
    subject: str = ""

    def extend(self, findings) -> "Report":
        self.findings.extend(findings)
        return self

    def errors(self) -> list:
        return [f for f in self.findings if f.severity == ERROR]

    def warnings(self) -> list:
        return [f for f in self.findings if f.severity == WARN]

    @property
    def ok(self) -> bool:
        """No errors (warnings do not fail verification)."""
        return not self.errors()

    def raise_if_errors(self):
        errs = self.errors()
        if errs:
            raise VerificationError(self.subject, errs)

    def __str__(self):
        if not self.findings:
            return f"{self.subject or 'report'}: clean"
        lines = [f"{self.subject or 'report'}: "
                 f"{len(self.errors())} error(s), "
                 f"{len(self.warnings())} warning(s)"]
        lines += [f"  {f}" for f in self.findings]
        return "\n".join(lines)


class VerificationError(AssertionError):
    """A static check proved an ERROR-severity violation.

    Subclasses ``AssertionError`` on purpose: a failed proof about a
    compiled artifact is an internal-invariant failure, not bad user
    input.
    """

    def __init__(self, subject: str, errors: list):
        self.subject = subject
        self.errors = list(errors)
        msg = "\n".join(str(f) for f in self.errors)
        super().__init__(
            f"static verification failed for {subject or 'program'}:\n{msg}"
        )
