"""Halo-coverage proof over lowered run programs (check class a).

The padded engine's exactness rests on two disciplines the lowering and
planner are supposed to maintain; this module re-proves both from the
:class:`~repro.api.lower.Program` alone, **independently** of the
``_Lowerer`` bookkeeping that produced it:

**Pad-state discipline.**  Every kernel segment consumes its operands
with the pad region holding that op's absorbing identity ("hi" = +top
for erosion-family, "lo" = -bottom for dilation-family).  The checker
runs an abstract interpreter over the segment list: canonical inputs
start at their declared ``run_fills``; a masked ``refill`` segment
resets a slot's pad to a named identity; a kernel segment's output pad
is *evolved(ident)* — the identity-extension image evolved by the op,
which remains absorbing for further same-identity kernels but for
nothing else.  Consuming a slot whose pad state is neither the required
identity nor evolved(required identity) is an ERROR: values could leak
through the pad (exactly the bug class a dropped or wrong-fill refill
segment introduces).

**Reach coverage.**  A fused segment of ``n`` elementary filters has
Chebyshev reach ``n``.  Per kernel launch the schedule provides
``fuse_k`` halo rows/cols (the declared BlockSpec halo measured by
``repro.analysis.indexmaps``) and runs ``fuse_k`` elementary steps, so
per-launch reach never exceeds the halo; across launches the plan's
``n_chunks`` must cover the longest fixed chain
(``n_chunks · fuse_k ≥ n``).  The masked pad-refill segments between
kernel segments are part of the proof: they are what resets the pad
between identities so per-launch coverage composes.

Also proved here: program well-formedness (slot def-before-use, single
assignment, canonical input binding) — the invariant class that catches
input slots bound by position instead of by ``run_input_slots``.
"""
from __future__ import annotations

from repro.analysis.findings import ERROR, WARN, Finding

#: Absorbing identity each op requires in its operands' pad region —
#: re-derived from lattice algebra (erosion = min-op, absorbed by the
#: lattice top; dilation = max-op, absorbed by the bottom), on purpose
#: not imported from ``api.lower`` so the two derivations cross-check.
REQUIRED_FILL = {"erode": "hi", "dilate": "lo"}

_KINDS = ("chain", "geodesic", "reconstruct", "qdt", "gdt", "refill",
          "point")


def _evolved(fill: str) -> tuple:
    return ("evolved", fill)


def _seg_name(i: int, seg) -> str:
    return f"segment {i} ({seg.short()})"


def segment_reach(seg) -> int | None:
    """Chebyshev reach (pixels of influence) of one kernel segment;
    None for convergence-driven segments (reach = iterations to
    convergence, unbounded statically).  Raises on a kind this proof
    does not know — silently assuming 0 reach for a new segment kind
    would under-cover its halo."""
    if seg.kind == "chain":
        return int(seg.param("n"))
    if seg.kind == "geodesic":
        # the geodesic clamp is pointwise: reach equals the chain's
        return int(seg.param("n"))
    if seg.kind in ("reconstruct", "qdt", "gdt"):
        return None
    if seg.kind in ("refill", "point"):
        return 0  # pointwise: masked fill / elementwise expression
    raise ValueError(
        f"segment_reach: unknown segment kind {seg.kind!r} — teach the "
        "halo proof its reach before lowering it"
    )


def check_program(program) -> list:
    """Well-formedness + pad-state discipline of one lowered program."""
    out = []

    def err(subject, message):
        out.append(Finding("halo", ERROR, subject, message))

    fills = program.run_fills
    slots = program.run_input_slots
    if len(fills) != len(slots) or len(fills) != len(program.prepare):
        err("inputs",
            f"canonical input arity mismatch: {len(program.prepare)} "
            f"prepare exprs, {len(fills)} fills, {len(slots)} slots")
        return out
    if len(set(slots)) != len(slots):
        err("inputs", f"duplicate canonical input slots {slots}")
        return out

    # abstract pad state per defined slot
    state: dict[int, object] = {}
    for slot, fill in zip(slots, fills):
        if fill not in ("hi", "lo"):
            err("inputs", f"slot {slot}: unknown pad fill {fill!r}")
        state[slot] = fill

    for i, seg in enumerate(program.segments):
        name = _seg_name(i, seg)
        if seg.kind not in _KINDS:
            err(name, f"unknown segment kind {seg.kind!r}")
            continue
        for s in seg.srcs:
            if s not in state:
                err(name, f"reads slot {s} before any definition — "
                          "canonical inputs must bind through "
                          "run_input_slots")
        for d in seg.dsts:
            if d in state:
                err(name, f"writes slot {d}, which is already live "
                          "(single-assignment violated; a canonical "
                          "input or earlier segment output would be "
                          "clobbered)")
        if any(s not in state for s in seg.srcs):
            # cannot track pad state through an undefined read
            for d in seg.dsts:
                state[d] = None
            continue

        if seg.kind == "refill":
            fill = seg.param("fill")
            if fill not in ("hi", "lo"):
                err(name, f"refill to unknown identity {fill!r}")
            state[seg.dsts[0]] = fill
            continue

        if seg.kind == "point":
            if len(seg.dsts) != 1 or not seg.srcs:
                err(name, f"arity: expected ≥1 srcs/1 dst, got "
                          f"{len(seg.srcs)}/{len(seg.dsts)}")
            # elementwise on the padded planes: the pad region computes
            # from whatever fills the operands carry — poison the
            # output so a kernel consumer must refill first
            for d in seg.dsts:
                state[d] = None
            continue

        if seg.kind == "gdt":
            if len(seg.srcs) != 2 or len(seg.dsts) != 1:
                err(name, f"arity: expected 2 srcs/1 dst, got "
                          f"{len(seg.srcs)}/{len(seg.dsts)}")
            for s in seg.srcs:
                got = state.get(s)
                if got != "lo":
                    err(name,
                        f"operand slot {s} pad state is {got!r} but "
                        "gdt's pad detection keys on the exact "
                        "lattice-bottom fill 'lo' (−inf) — an evolved "
                        "or foreign pad would be misclassified as "
                        "image cells")
            # distance plane: pad holds +inf distances, absorbing for
            # nothing — poison it like the qdt outputs.
            for d in seg.dsts:
                state[d] = None
            continue

        if seg.kind == "qdt":
            need = "hi"  # QDT iterates erosion
            n_srcs, n_dsts = 1, 2
        elif seg.kind == "chain":
            need = REQUIRED_FILL.get(seg.param("op"))
            n_srcs, n_dsts = 1, 1
        else:  # geodesic / reconstruct
            need = REQUIRED_FILL.get(seg.param("op"))
            n_srcs, n_dsts = 2, 1
        if need is None:
            err(name, f"unknown op {seg.param('op')!r}")
            for d in seg.dsts:
                state[d] = None
            continue
        if len(seg.srcs) != n_srcs or len(seg.dsts) != n_dsts:
            err(name, f"arity: expected {n_srcs} srcs/{n_dsts} dsts, "
                      f"got {len(seg.srcs)}/{len(seg.dsts)}")
        if seg.kind == "chain" and int(seg.param("n")) < 1:
            err(name, f"chain length {seg.param('n')} < 1")
        for s in seg.srcs:
            got = state.get(s)
            if got != need and got != _evolved(need):
                err(name,
                    f"operand slot {s} pad state is {got!r} but the "
                    f"{seg.kind} requires the absorbing identity "
                    f"{need!r} — values can leak through the pad "
                    "(missing or wrong masked refill segment)")
        for d in seg.dsts:
            state[d] = _evolved(need)
        if seg.kind == "qdt":
            # d/r planes: pad holds distances/residuals, absorbing for
            # nothing — poison them so any downstream consumer errors.
            for d in seg.dsts:
                state[d] = None

    for s in program.run_outputs:
        if s not in state:
            out.append(Finding("halo", ERROR, "outputs",
                               f"run output slot {s} is never defined"))

    n_kernel = len(program.kernel_segments)
    if program.pad_safe != (n_kernel == 1):
        out.append(Finding(
            "halo", ERROR, "pad_safe",
            f"pad_safe={program.pad_safe} but the program has "
            f"{n_kernel} kernel segments — bucket padding would be "
            f"{'unsound' if program.pad_safe else 'needlessly exact-shape'}"
        ))
    return out


def check_coverage(program, plan, shape3=None, segments=None,
                   convergent=None) -> list:
    """Reach coverage of ``program`` under ``plan`` (pallas schedule).

    ``plan`` provides ``fuse_k`` halo rows per launch and runs
    ``fuse_k`` elementary steps per launch — per-launch reach is covered
    by construction; what can drift is the *cross-launch* accounting:
    the plan's ``n_chunks`` under-covering the longest fixed chain, or
    the plan not covering the bound image at all.

    ``segments``/``convergent`` restrict the check to one plan group of
    a specialized executable (``Executable.seg_plans``): the group's
    segment subset is proved against the group's own plan.  Defaults
    cover the whole program under its single shared plan.
    """
    out = []
    if plan is None:
        return out
    if segments is None:
        segments = program.segments
    if convergent is None:
        convergent = program.convergent
    if shape3 is not None:
        n, h, w = shape3
        if plan.n_images != n:
            out.append(Finding("halo", ERROR, "plan/shape",
                               f"plan.n_images={plan.n_images} != batch "
                               f"size {n}"))
        if plan.height_pad < h or plan.width_pad < w:
            out.append(Finding(
                "halo", ERROR, "plan/shape",
                f"plan pads ({plan.height_pad}, {plan.width_pad}) do not "
                f"cover the image ({h}, {w}) — the crop would read "
                "identity fill"))
    reaches = [r for s in segments
               if (r := segment_reach(s)) is not None and s.kind != "refill"]
    max_reach = max(reaches, default=0)
    if not convergent and max_reach:
        covered = plan.n_chunks * plan.fuse_k
        if covered < max_reach:
            out.append(Finding(
                "halo", WARN, "plan/chunks",
                f"plan.n_chunks={plan.n_chunks} × fuse_k={plan.fuse_k} "
                f"= {covered} < longest fixed chain {max_reach} — the "
                "advisory launch count under-covers the declared "
                "Chebyshev reach (stale plan for this program)"))
    # per-launch: steps per launch never exceed the declared halo
    per_launch = min(max_reach, plan.fuse_k) if max_reach else 0
    if per_launch > plan.fuse_k:  # pragma: no cover - min() forbids it
        out.append(Finding(
            "halo", ERROR, "plan/halo",
            f"{per_launch} elementary steps per launch exceed the "
            f"declared {plan.fuse_k}-row halo"))
    return out
