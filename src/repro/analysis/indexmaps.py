"""Index-map bounds analysis for the halo-assembly kernels
(check class e).

A Pallas ``BlockSpec`` maps a grid step to a *block index*; block ``b``
of size ``bs`` reads rows ``[b·bs, (b+1)·bs)``.  An index map that
steps outside the array is silent corruption in interpret mode and
undefined behaviour under Mosaic, so this module proves, for every
grid step of a plan's schedule, that every one of the real specs —
``kernels/common.py:row_specs`` (top/mid/bot row bands) and
``kernels/common.py:tile_specs`` (the nine 2-D halo blocks) — stays in
bounds.  The specs are imported and *evaluated*, not re-modelled: the
index maps are plain functions of the grid indices, so calling them on
every concrete grid point is a complete enumeration, and the bounds
the verifier proves are exactly the bounds the kernels launch with.

Two facts per schedule:

* **bounds** — for each spec, each grid step, each axis:
  ``0 ≤ b`` and ``(b+1)·bs ≤ dim``.  The clamped halo maps
  (``max(i·r−1, 0)``, ``min((i+1)·r, last)``) satisfy this by design;
  dropping a clamp is the seeded mutation;
* **partition** — the *centre* spec must visit every block of the
  array exactly once across the grid (a bijection), otherwise bands
  overlap (racy writes through the matching out_spec) or rows are
  never produced.  Halo specs are exempt: clamping deliberately
  re-reads border blocks.
"""
from __future__ import annotations

import itertools

from repro.analysis.findings import ERROR, Finding

__all__ = ["blocks_of", "check_block_specs", "check_partition",
           "check_plan_index_maps"]


def blocks_of(spec, grid):
    """Evaluate ``spec.index_map`` on every grid step → list of
    ``(grid_step, block_index)`` tuples of plain ints."""
    out = []
    for step in itertools.product(*(range(g) for g in grid)):
        idx = spec.index_map(*step)
        out.append((step, tuple(int(b) for b in idx)))
    return out


def check_block_specs(specs, grid, shape, subject="block-specs") -> list:
    """Bounds proof: every block of every spec lies inside ``shape``."""
    out = []
    for k, spec in enumerate(specs):
        bs = tuple(int(b) for b in spec.block_shape)
        if len(bs) != len(shape):
            out.append(Finding(
                "index-map", ERROR, subject,
                f"spec {k}: block rank {len(bs)} != array rank "
                f"{len(shape)}"))
            continue
        if any(b < 1 for b in bs):
            out.append(Finding(
                "index-map", ERROR, subject,
                f"spec {k}: non-positive block shape {bs}"))
            continue
        if any(d % b for d, b in zip(shape, bs)):
            out.append(Finding(
                "index-map", ERROR, subject,
                f"spec {k}: block shape {bs} does not divide the array "
                f"{shape} — the last block would read past the edge"))
            continue
        for step, blk in blocks_of(spec, grid):
            for axis, (b, s, d) in enumerate(zip(blk, bs, shape)):
                if b < 0:
                    out.append(Finding(
                        "index-map", ERROR, subject,
                        f"spec {k}, grid step {step}: negative block "
                        f"index {b} on axis {axis}"))
                elif (b + 1) * s > d:
                    out.append(Finding(
                        "index-map", ERROR, subject,
                        f"spec {k}, grid step {step}: block {b} of size "
                        f"{s} reads rows [{b * s}, {(b + 1) * s}) past "
                        f"axis-{axis} extent {d} (unclamped halo map?)"))
    return out


def check_partition(spec, grid, shape, subject="centre spec") -> list:
    """Bijection proof: the centre spec's blocks tile the array exactly
    once across the grid."""
    out = []
    bs = tuple(int(b) for b in spec.block_shape)
    if len(bs) != len(shape) or any(b < 1 for b in bs) \
            or any(d % b for d, b in zip(shape, bs)):
        return out  # bounds check already reports these
    want = set(itertools.product(*(range(d // b)
                                   for d, b in zip(shape, bs))))
    seen: dict[tuple, tuple] = {}
    for step, blk in blocks_of(spec, grid):
        if blk in seen:
            out.append(Finding(
                "index-map", ERROR, subject,
                f"grid steps {seen[blk]} and {step} both map to block "
                f"{blk} — overlapping writes race through the out_spec"))
        seen[blk] = step
    missing = want - set(seen)
    if missing:
        out.append(Finding(
            "index-map", ERROR, subject,
            f"{len(missing)} block(s) never visited (e.g. "
            f"{sorted(missing)[0]}) — those rows are never produced"))
    extra = set(seen) - want
    if extra:
        out.append(Finding(
            "index-map", ERROR, subject,
            f"block(s) outside the array visited: {sorted(extra)[:3]}"))
    return out


def check_plan_index_maps(plan) -> list:
    """Evaluate the real kernel specs over ``plan``'s full grids.

    The row-band schedule launches over ``(total_bands,)`` on the
    ``(n_images·height_pad, width_pad)`` stack; the 2-D tile schedule
    (when ``tile_w`` is set) over ``(total_bands, n_tiles)``.  Degenerate
    plans (reported by ``repro.analysis.plans``) are skipped — the specs
    are only meaningful on a structurally valid plan.
    """
    from repro.kernels.common import row_specs, tile_specs

    if (plan.fuse_k < 1 or plan.band_h < plan.fuse_k
            or plan.band_h % plan.fuse_k or plan.height_pad % plan.band_h
            or plan.width_pad < 1
            or (plan.tile_w and (plan.tile_w % plan.fuse_k
                                 or plan.width_pad % plan.tile_w))):
        return []

    h = plan.n_images * plan.height_pad
    w = plan.width_pad
    out = []

    grid = (h // plan.band_h,)
    specs = row_specs(plan.band_h, plan.fuse_k, h, w)
    out += check_block_specs(specs, grid, (h, w), "row_specs")
    out += check_partition(specs[1], grid, (h, w), "row_specs[mid]")

    if plan.tile_w:
        grid2 = (h // plan.band_h, w // plan.tile_w)
        specs2 = tile_specs(plan.band_h, plan.tile_w, plan.fuse_k, h, w)
        out += check_block_specs(specs2, grid2, (h, w), "tile_specs")
        out += check_partition(specs2[4], grid2, (h, w), "tile_specs[mid]")
    return out
