"""Exhaustive repo lint: ``python -m repro.analysis.lint``.

Sweeps every expression operator in the serve registry across a
dtype × shape × backend matrix, compiles each combination (verify
hook deferred — this CLI *is* the verifier) and runs the full-level
static checks: halo/pad-state proofs, plan constraints, numeric
index-map enumeration, cache-key mutation sweeps, dtype audits and
Mosaic-readiness diagnostics.  The serve bucketer's pad fills are
audited once against the kernel lattice identities on top.

Exit status: 1 when any ERROR-severity finding survives (or any WARN
under ``--strict``), 0 otherwise — the CI gate.  Nothing is executed:
a clean sweep is a set of static proofs about every program the
registry can currently lower.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import dtypes as dtype_checks
from repro.analysis.findings import Report, VerificationError
from repro.analysis.verifier import verify_executable

#: Default sweep matrix: the paper's char→double crossover dtypes, a
#: lane-aligned shape, a batched non-square shape and a ragged shape
#: (exercises the tile_w=0 fallback), on both engines.
DTYPES = ("uint8", "uint16", "float32", "float64")
SHAPES = ((1, 64, 64), (4, 48, 96), (1, 33, 70))
BACKENDS = ("pallas", "xla")


def _sample_params(spec) -> tuple:
    """Canonical sample params for one OpSpec (registration defaults)."""
    return tuple((name, spec.params[name].sample())
                 for name in sorted(spec.params))


def iter_registry_cases(ops=None, dtypes=DTYPES, shapes=SHAPES,
                        backends=BACKENDS):
    """Yield ``(label, expr, shape3, dtype, backend)`` for every
    expression op in the registry; custom (hand-written ``run``) specs
    have no lowered program to verify and are skipped."""
    from repro.serve import registry

    for name in ops or registry.names():
        spec = registry.get(name)
        if spec.expr_builder is None:
            continue
        expr = spec.build_expr(_sample_params(spec))
        for dtype in dtypes:
            for shape3 in shapes:
                for backend in backends:
                    yield (f"{name}[{dtype},{shape3},{backend}]",
                           expr, shape3, dtype, backend)


def run_lint(ops=None, dtypes=DTYPES, shapes=SHAPES, backends=BACKENDS,
             level="full", verbose=False, out=sys.stdout) -> Report:
    from repro.api.compile import compile as api_compile

    total = Report(subject="repro.analysis.lint")
    # the bucketer fill audit is global (all supported dtypes), not
    # restricted to the sweep matrix — it is cheap and shape-free
    total.extend(dtype_checks.check_bucketer_fills())
    n_cases = 0
    for label, expr, shape3, dtype, backend in iter_registry_cases(
            ops, dtypes, shapes, backends):
        n_cases += 1
        try:
            exe = api_compile(expr, shape3, dtype, backend, verify=False)
        except VerificationError as e:  # pragma: no cover - verify=False
            total.extend(e.errors)
            continue
        report = verify_executable(exe, level=level)
        if verbose or not report.ok:
            print(f"{label}: {len(report.errors())} error(s), "
                  f"{len(report.warnings())} warning(s)", file=out)
        total.extend(report.findings)
    print(f"lint: {n_cases} registry case(s) verified — "
          f"{len(total.errors())} error(s), "
          f"{len(total.warnings())} warning(s)", file=out)
    return total


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="statically verify every registry operator across a "
                    "dtype/shape/backend matrix",
    )
    p.add_argument("--ops", nargs="*", default=None,
                   help="restrict to these registry ops (default: all)")
    p.add_argument("--dtypes", nargs="*", default=list(DTYPES))
    p.add_argument("--shapes", nargs="*", default=None,
                   help="NxHxW triples, e.g. 4x48x96")
    p.add_argument("--backends", nargs="*", default=list(BACKENDS),
                   choices=["pallas", "xla"])
    p.add_argument("--level", default="full", choices=["fast", "full"])
    p.add_argument("--strict", action="store_true",
                   help="treat warnings as errors")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print every case, not only failing ones")
    args = p.parse_args(argv)

    shapes = SHAPES
    if args.shapes:
        shapes = tuple(tuple(int(v) for v in s.split("x"))
                       for s in args.shapes)
        if any(len(s) != 3 for s in shapes):
            p.error("shapes must be NxHxW triples")

    report = run_lint(ops=args.ops, dtypes=tuple(args.dtypes),
                      shapes=shapes, backends=tuple(args.backends),
                      level=args.level, verbose=args.verbose)
    for f in report.findings:
        print(f)
    failed = report.errors() or (args.strict and report.warnings())
    print("lint:", "FAILED" if failed else "ok")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
