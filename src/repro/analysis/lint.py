"""Exhaustive repo lint: ``python -m repro.analysis.lint``.

Sweeps every expression operator in the serve registry across a
dtype × shape × backend matrix, compiles each combination (verify
hook deferred — this CLI *is* the verifier) and runs the full-level
static checks: halo/pad-state proofs, plan constraints, numeric
index-map enumeration, cache-key mutation sweeps, dtype audits and
Mosaic-readiness diagnostics.  The serve bucketer's pad fills are
audited once against the kernel lattice identities on top.

Because the expression optimizer is on by default, every compiled
case is the *rewritten* program — a clean sweep asserts the rewritten
registry lints clean.  ``--rewrites`` additionally replays every
applied optimizer rule per op on randomized small inputs
(``repro.analysis.rewrites``), demanding bit-exactness against the
unrewritten graph — the CI program-lint job runs with it.

Exit status: 1 when any ERROR-severity finding survives (or any WARN
under ``--strict``), 0 otherwise — the CI gate.  Apart from the
``--rewrites`` replay (tiny oracle programs), nothing is executed: a
clean sweep is a set of static proofs about every program the
registry can currently lower.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.analysis import dtypes as dtype_checks
from repro.analysis.findings import Report, VerificationError
from repro.analysis.verifier import verify_executable

#: Default sweep matrix: the paper's char→double crossover dtypes, a
#: lane-aligned shape, a batched non-square shape and a ragged shape
#: (exercises the tile_w=0 fallback), on both engines.
DTYPES = ("uint8", "uint16", "float32", "float64")
SHAPES = ((1, 64, 64), (4, 48, 96), (1, 33, 70))
BACKENDS = ("pallas", "xla")


def _sample_params(spec) -> tuple:
    """Canonical sample params for one OpSpec (registration defaults)."""
    return tuple((name, spec.params[name].sample())
                 for name in sorted(spec.params))


def iter_registry_cases(ops=None, dtypes=DTYPES, shapes=SHAPES,
                        backends=BACKENDS):
    """Yield ``(label, expr, shape3, dtype, backend)`` for every
    expression op in the registry; custom (hand-written ``run``) specs
    have no lowered program to verify and are skipped."""
    from repro.serve import registry

    for name in ops or registry.names():
        spec = registry.get(name)
        if spec.expr_builder is None:
            continue
        expr = spec.build_expr(_sample_params(spec))
        for dtype in dtypes:
            if np.dtype(dtype).kind not in spec.dtypes:
                continue  # e.g. gdt ops are float-lattice only
            for shape3 in shapes:
                for backend in backends:
                    yield (f"{name}[{dtype},{shape3},{backend}]",
                           expr, shape3, dtype, backend)


def run_lint(ops=None, dtypes=DTYPES, shapes=SHAPES, backends=BACKENDS,
             level="full", rewrites=False, verbose=False,
             out=sys.stdout) -> Report:
    from repro.api.compile import compile as api_compile

    total = Report(subject="repro.analysis.lint")
    # the bucketer fill audit is global (all supported dtypes), not
    # restricted to the sweep matrix — it is cheap and shape-free
    total.extend(dtype_checks.check_bucketer_fills())
    n_cases = 0
    seen_exprs: dict = {}
    for label, expr, shape3, dtype, backend in iter_registry_cases(
            ops, dtypes, shapes, backends):
        n_cases += 1
        seen_exprs.setdefault(label.split("[")[0], expr)
        try:
            exe = api_compile(expr, shape3, dtype, backend, verify=False)
        except VerificationError as e:  # pragma: no cover - verify=False
            total.extend(e.errors)
            continue
        report = verify_executable(exe, level=level)
        if verbose or not report.ok:
            print(f"{label}: {len(report.errors())} error(s), "
                  f"{len(report.warnings())} warning(s)", file=out)
        total.extend(report.findings)
    n_rewritten = 0
    if rewrites:
        # optimizer soundness sweep: once per op (the trace and the
        # canonical graph do not depend on the shape/backend matrix)
        from repro.analysis.rewrites import check_rewrites
        from repro.opt import rewrite_traced

        for name, expr in sorted(seen_exprs.items()):
            result = rewrite_traced(expr)
            findings = check_rewrites(expr)
            if result.changed:
                n_rewritten += 1
            if verbose or findings:
                rules = ",".join(a.rule for a in result.trace) or "-"
                print(f"rewrites[{name}]: {result.n_applied} applied "
                      f"({rules}), {len(findings)} finding(s)", file=out)
            total.extend(findings)
    msg = (f"lint: {n_cases} registry case(s) verified — "
           f"{len(total.errors())} error(s), "
           f"{len(total.warnings())} warning(s)")
    if rewrites:
        msg += (f"; rewrite soundness replayed on {len(seen_exprs)} op(s) "
                f"({n_rewritten} rewritten)")
    print(msg, file=out)
    return total


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="statically verify every registry operator across a "
                    "dtype/shape/backend matrix",
    )
    p.add_argument("--ops", nargs="*", default=None,
                   help="restrict to these registry ops (default: all)")
    p.add_argument("--dtypes", nargs="*", default=list(DTYPES))
    p.add_argument("--shapes", nargs="*", default=None,
                   help="NxHxW triples, e.g. 4x48x96")
    p.add_argument("--backends", nargs="*", default=list(BACKENDS),
                   choices=["pallas", "xla"])
    p.add_argument("--level", default="full",
                   choices=["fast", "full", "sound"])
    p.add_argument("--rewrites", action="store_true",
                   help="additionally replay the expression optimizer's "
                        "rewrites on every registry op (numeric "
                        "bit-exactness, randomized small inputs)")
    p.add_argument("--strict", action="store_true",
                   help="treat warnings as errors")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print every case, not only failing ones")
    args = p.parse_args(argv)

    shapes = SHAPES
    if args.shapes:
        shapes = tuple(tuple(int(v) for v in s.split("x"))
                       for s in args.shapes)
        if any(len(s) != 3 for s in shapes):
            p.error("shapes must be NxHxW triples")

    report = run_lint(ops=args.ops, dtypes=tuple(args.dtypes),
                      shapes=shapes, backends=tuple(args.backends),
                      level=args.level, rewrites=args.rewrites,
                      verbose=args.verbose)
    for f in report.findings:
        print(f)
    failed = report.errors() or (args.strict and report.warnings())
    print("lint:", "FAILED" if failed else "ok")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
