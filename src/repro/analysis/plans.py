"""ChainPlan constraint checking + Mosaic-readiness diagnostics
(check class c).

Re-derives the planner/kernel contract from first principles and
checks a plan against it — deliberately *not* by calling
``ChainPlan.__post_init__`` (mutation tests forge plans past it with
``object.__new__``, which is also what a deserialized or hand-built
plan could do):

* band decomposition: ``band_h % fuse_k == 0`` (the kernel runs
  ``fuse_k`` elementary steps on a ``band_h + 2·fuse_k`` stack),
  ``height_pad % band_h == 0``, ``n_bands·band_h == height_pad``;
* ragged-width fallback: ``tile_w`` is 0 (row-only) or tiles the padded
  width in ``fuse_k`` multiples — a ragged column tile would shift
  every halo index map off the block grid;
* requeue exactness: influence propagates at most ``fuse_k`` px per
  chunk (Chebyshev), so ``fuse_k ≤ requeue_halo · band_h`` and, when
  column-tiled, ``fuse_k ≤ requeue_halo · tile_w`` — otherwise a
  wavefront outruns the re-activated neighbourhood and convergence is
  detected too early;
* compaction capacity within the activity grid.

Mosaic-readiness (WARN, ROADMAP item 3): interpret-mode Pallas accepts
any block geometry, but on-TPU Mosaic wants last-dim tiles in 128-lane
multiples and sublane counts per dtype.  The diagnostics flag every
block the 2-D tile kernels would feed Mosaic that violates that —
``fuse_k``-wide corner/side halos, non-lane-multiple ``tile_w``/
``width_pad``, patch widths ``tile_w + 2·fuse_k``.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import ERROR, WARN, Finding
from repro.core.chain import LANES, SUBLANES

__all__ = ["check_plan", "check_mosaic_readiness"]


def check_plan(plan, shape3=None) -> list:
    """Structural constraints of one :class:`ChainPlan`."""
    out = []

    def err(msg):
        out.append(Finding("plan", ERROR, "plan", msg))

    if plan.fuse_k < 1:
        err(f"fuse_k={plan.fuse_k} < 1")
        return out
    if plan.band_h < plan.fuse_k:
        err(f"band_h={plan.band_h} < fuse_k={plan.fuse_k}: the band "
            "cannot carry one launch's halo")
    if plan.band_h % plan.fuse_k:
        err(f"band_h={plan.band_h} not a multiple of fuse_k="
            f"{plan.fuse_k}: halo blocks would straddle band borders")
    if plan.height_pad < 1 or plan.height_pad % plan.band_h:
        err(f"height_pad={plan.height_pad} not a positive multiple of "
            f"band_h={plan.band_h}")
    elif plan.n_bands != plan.height_pad // plan.band_h:
        err(f"n_bands={plan.n_bands} != height_pad/band_h="
            f"{plan.height_pad // plan.band_h}")
    if plan.width_pad < 1:
        err(f"width_pad={plan.width_pad} < 1")
    if plan.n_images < 1:
        err(f"n_images={plan.n_images} < 1")
    if plan.n_chunks < 1:
        err(f"n_chunks={plan.n_chunks} < 1")
    # re-derived from core.chain.SCHEDULES by value, not by import, so
    # a forged plan with a typo'd schedule is caught here too
    if getattr(plan, "schedule", "wavefront") not in ("wavefront",
                                                      "raster"):
        err(f"schedule={plan.schedule!r} is not a known schedule "
            "('wavefront' | 'raster') — the executable would fall "
            "through to the wavefront path silently")

    if plan.tile_w < 0:
        err(f"tile_w={plan.tile_w} < 0")
    elif plan.tile_w:
        if plan.tile_w % plan.fuse_k:
            err(f"tile_w={plan.tile_w} not a multiple of fuse_k="
                f"{plan.fuse_k} (ragged-width plans must fall back to "
                "tile_w=0 row bands)")
        if plan.width_pad % plan.tile_w:
            err(f"width_pad={plan.width_pad} not a multiple of tile_w="
                f"{plan.tile_w} (ragged last tile; the fallback "
                "contract is tile_w=0)")

    if plan.requeue_halo < 1:
        err(f"requeue_halo={plan.requeue_halo} < 1: changed cells "
            "would not re-activate their neighbours")
    else:
        reach = plan.fuse_k  # Chebyshev influence per K-chunk
        if reach > plan.requeue_halo * plan.band_h:
            err(f"fuse_k={plan.fuse_k} exceeds requeue_halo·band_h="
                f"{plan.requeue_halo * plan.band_h}: per-chunk influence "
                "outruns the re-activated rows — convergence would be "
                "detected early")
        if plan.tile_w and reach > plan.requeue_halo * plan.tile_w:
            err(f"fuse_k={plan.fuse_k} exceeds requeue_halo·tile_w="
                f"{plan.requeue_halo * plan.tile_w}: per-chunk influence "
                "outruns the re-activated columns")

    if not 0.0 <= plan.compact_threshold <= 1.0:
        err(f"compact_threshold={plan.compact_threshold} outside [0, 1]")
    elif plan.compact_threshold and plan.band_h and plan.width_pad:
        try:
            cap = plan.compact_capacity
        except Exception:  # degenerate fields above already reported
            cap = None
        if cap is not None and not 1 <= cap <= max(1, plan.total_tiles):
            err(f"compact_capacity={cap} outside [1, total_tiles="
                f"{plan.total_tiles}]")

    if shape3 is not None:
        n, h, w = shape3
        if plan.n_images != n:
            out.append(Finding("plan", ERROR, "plan/shape",
                               f"n_images={plan.n_images} != batch {n}"))
        if plan.height_pad < h:
            out.append(Finding("plan", ERROR, "plan/shape",
                               f"height_pad={plan.height_pad} < image "
                               f"height {h}"))
        if plan.width_pad < w:
            out.append(Finding("plan", ERROR, "plan/shape",
                               f"width_pad={plan.width_pad} < image "
                               f"width {w}"))
    return out


def check_mosaic_readiness(plan, dtype=None) -> list:
    """WARN-level diagnostics for on-TPU (interpret=False) lowering —
    the known PR 4 blocker tracked as ROADMAP item 3."""
    out = []

    def warn(subject, msg):
        out.append(Finding("plan", WARN, subject, msg))

    if plan.width_pad % LANES:
        warn("mosaic/width",
             f"width_pad={plan.width_pad} is not a {LANES}-lane multiple")
    if plan.tile_w:
        if plan.tile_w % LANES:
            warn("mosaic/tile",
                 f"tile_w={plan.tile_w} is not a {LANES}-lane multiple "
                 "(centre blocks of the 2-D tile kernels)")
        if plan.fuse_k % LANES:
            warn("mosaic/halo",
                 f"corner/side halo blocks are fuse_k={plan.fuse_k} "
                 f"lanes wide — narrower than the {LANES}-lane tiling "
                 "Mosaic wants (tile_specs NOTE; widen or re-fetch for "
                 "interpret=False)")
        if (plan.tile_w + 2 * plan.fuse_k) % LANES:
            warn("mosaic/patch",
                 f"compact patch width tile_w+2K="
                 f"{plan.tile_w + 2 * plan.fuse_k} is not a {LANES}-lane "
                 "multiple (gathered workspace of the compact kernels)")
    if dtype is not None:
        sub = SUBLANES.get(np.dtype(dtype).itemsize, 8)
        if plan.fuse_k % sub:
            warn("mosaic/sublane",
                 f"fuse_k={plan.fuse_k} not a multiple of the "
                 f"{np.dtype(dtype).name} sublane count {sub} (halo "
                 "blocks straddle sublane tiles)")
        if plan.band_h % sub:
            warn("mosaic/sublane",
                 f"band_h={plan.band_h} not a multiple of the "
                 f"{np.dtype(dtype).name} sublane count {sub}")
    return out
