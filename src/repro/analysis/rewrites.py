"""Rewrite soundness hook (check class f): numeric replay of the
expression optimizer's applied rules.

The optimizer (``repro.opt``) only ships exactness-*provable* rules,
but a proof about the algebra is not a proof about the implementation:
a pattern that binds the wrong operand, a guard that under-constrains,
or a build that swaps arguments would all survive the static checks
(the rewritten program is still structurally valid — it just computes
the wrong thing).  This module closes that gap dynamically:

* :func:`replay_applied` re-executes one :class:`~repro.opt.engine.
  Applied` step — the rule's ``before`` and ``after`` sub-graphs,
  compiled **unrewritten** on the jnp oracle backend — on randomized
  small inputs and demands bit-equality.  Because every rule is
  locally exact, each step is checkable in isolation; the composition
  of bit-exact steps is bit-exact, so a clean trace proves the whole
  rewrite.
* :func:`check_rewrites` drives the end-to-end contract for one
  source expression: replays every trace step, re-runs the structural
  halo/pad-state proof on the rewritten program, and additionally
  executes ``source`` vs ``canonical`` whole-graph on random inputs
  (belt and braces — it would only fire if the per-step argument
  itself were wrong).

Wired in at two levels: ``verify_executable(level="sound")`` replays
the trace an executable was compiled with, and ``python -m
repro.analysis.lint --rewrites`` sweeps the serve registry's source
expressions through :func:`check_rewrites`.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import ERROR, WARN, Finding

__all__ = ["replay_applied", "check_trace", "check_rewrites",
           "random_inputs", "REPLAY_SHAPE3", "REPLAY_DTYPES"]

#: Replay geometry: small enough that the jnp oracle converges fast,
#: batched and ragged enough to exercise per-image reductions.
REPLAY_SHAPE3 = (2, 24, 33)

#: Dtypes replayed by default: the paper's integer lattice and a float
#: lattice (saturation and identity values differ between them).
REPLAY_DTYPES = ("uint8", "float32")


def random_inputs(names, shape3, dtype, seed: int):
    """One random array per input leaf, dtype-appropriate range."""
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    out = {}
    for i, name in enumerate(names):
        if dt.kind in "ui":
            hi = min(255, np.iinfo(dt).max)
            arr = rng.integers(0, hi, size=shape3, endpoint=True, dtype=dt)
        else:
            arr = rng.random(size=shape3).astype(dt)
        out[name] = arr
    return out


def _execute(expr, inputs: dict, shape3, dtype):
    """Evaluate ``expr`` verbatim (optimizer off) on the jnp oracle."""
    from repro.api.compile import compile as api_compile
    from repro.api.lower import _input_names

    exe = api_compile(expr, shape3, dtype, "xla", verify=False,
                      rewrite=False)
    outs = exe(*(inputs[n] for n in _input_names(expr)))
    return outs if isinstance(outs, tuple) else (outs,)


def replay_applied(step, shape3=REPLAY_SHAPE3, dtypes=REPLAY_DTYPES,
                   n_samples: int = 2, seed: int = 0) -> list:
    """Numerically replay one applied rule; bit-inequality is an ERROR.

    Both sides run with ``rewrite=False`` so the replay cannot be
    masked by the very engine under test.
    """
    from repro.api.lower import LoweringError, _input_names

    out = []
    names = _input_names(step.before)
    for dtype in dtypes:
        for k in range(n_samples):
            inputs = random_inputs(names, shape3, dtype,
                                   seed + 7919 * k)
            try:
                got_before = _execute(step.before, inputs, shape3, dtype)
                got_after = _execute(step.after, inputs, shape3, dtype)
            except LoweringError as e:
                # a mid-rewrite sub-graph need not be a standalone
                # program (e.g. a picked QDT plane); nothing to replay
                out.append(Finding(
                    "rewrite", WARN, f"rule {step.rule}",
                    f"sub-graph not replayable in isolation: {e}"))
                return out
            if len(got_before) != len(got_after):
                out.append(Finding(
                    "rewrite", ERROR, f"rule {step.rule}",
                    f"output arity changed: {len(got_before)} → "
                    f"{len(got_after)}"))
                return out
            for i, (a, b) in enumerate(zip(got_before, got_after)):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    diff = int(np.sum(np.asarray(a) != np.asarray(b)))
                    out.append(Finding(
                        "rewrite", ERROR, f"rule {step.rule}",
                        f"not bit-exact on {dtype} sample {k} (output "
                        f"{i}): {diff} differing pixel(s) — "
                        f"{step.before.label()} vs {step.after.label()}"))
                    return out
    return out


def check_trace(trace, shape3=REPLAY_SHAPE3, dtypes=REPLAY_DTYPES,
                n_samples: int = 2, seed: int = 0) -> list:
    """Replay every step of a rewrite trace (each rule in isolation)."""
    out = []
    for step in trace:
        out.extend(replay_applied(step, shape3, dtypes, n_samples, seed))
    return out


def check_rewrites(expr, shape3=REPLAY_SHAPE3, dtypes=REPLAY_DTYPES,
                   n_samples: int = 2, seed: int = 0) -> list:
    """Full soundness check of the optimizer on one source expression:
    per-step replay + structural re-proof + whole-graph equality."""
    from repro.api.lower import LoweringError, _input_names, lower
    from repro.analysis import halo
    from repro.opt import rewrite_traced

    result = rewrite_traced(expr)
    out = check_trace(result.trace, shape3, dtypes, n_samples, seed)
    if not result.changed:
        return out

    # the rewritten program must still satisfy the pad-state proof
    try:
        out.extend(halo.check_program(lower(result.expr)))
    except LoweringError as e:
        out.append(Finding(
            "rewrite", ERROR, "canonical graph",
            f"source lowers but its canonical form does not: {e}"))
        return out

    names = _input_names(expr)
    if _input_names(result.expr) != names:
        out.append(Finding(
            "rewrite", ERROR, "canonical graph",
            f"input signature changed: {names} → "
            f"{_input_names(result.expr)}"))
        return out
    for dtype in dtypes:
        for k in range(n_samples):
            inputs = random_inputs(names, shape3, dtype, seed + 104729 * k)
            got_src = _execute(expr, inputs, shape3, dtype)
            got_can = _execute(result.expr, inputs, shape3, dtype)
            for i, (a, b) in enumerate(zip(got_src, got_can)):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    out.append(Finding(
                        "rewrite", ERROR, "canonical graph",
                        f"execute(rewrite(g)) != execute(g) on {dtype} "
                        f"sample {k} (output {i}) after "
                        f"{result.n_applied} rule application(s)"))
                    return out
    return out
