"""Verification orchestration: one executable in, one report out.

Three levels:

``fast``
    the always-on compile hook (``api/compile.py`` runs it on every
    cache-miss build when ``REPRO_VERIFY`` is enabled — the test suite
    turns it on in ``conftest.py``).  Pure-Python structural proofs
    only: program well-formedness + pad-state discipline, plan
    constraints and reach coverage (per plan group when the executable
    is specialized), executable-bound dtype facts.  Micro-seconds per
    compile; no spec evaluation, no key mutation.
``full``
    everything ``fast`` proves, plus numeric index-map enumeration over
    every plan's whole grid, cache-key mutation sweeps, and the
    Mosaic-readiness diagnostics.  This is what the lint CLI and the
    mutation self-tests run.
``sound``
    everything ``full`` proves, plus the rewrite soundness hook
    (``repro.analysis.rewrites``): every optimizer rule application the
    executable was compiled with is replayed on randomized small
    inputs and must be bit-exact.  The one level that *executes*
    anything — and only tiny oracle programs, never the compiled
    kernels under test.

Below ``sound``, the functions never execute the compiled program —
every fact is read off the lowered ``Program``, the ``ChainPlan`` and
the ``BlockSpec`` geometry.
"""
from __future__ import annotations

import os

from repro.analysis import cachekeys, dtypes, halo, indexmaps, plans
from repro.analysis.findings import Report

__all__ = ["verify_executable", "verify_on_compile", "LEVELS"]

LEVELS = ("fast", "full", "sound")


def verify_executable(exe, level: str = "fast") -> Report:
    """Statically verify one :class:`~repro.api.executable.Executable`."""
    if level not in LEVELS:
        raise ValueError(f"level must be one of {LEVELS}, got {level!r}")
    shape3 = (exe.n_images, exe.height, exe.width)
    report = Report(subject=repr(exe))

    report.extend(halo.check_program(exe.program))
    report.extend(dtypes.check_executable_dtypes(exe))
    if exe.seg_plans is not None:
        segs = exe.program.segments
        for idxs, plan in exe.seg_plans:
            group = tuple(segs[i] for i in idxs)
            conv = any(s.kind in ("reconstruct", "qdt", "gdt")
                       for s in group)
            report.extend(plans.check_plan(plan, shape3))
            report.extend(halo.check_coverage(
                exe.program, plan, shape3, segments=group, convergent=conv))
    elif exe.plan is not None:
        report.extend(plans.check_plan(exe.plan, shape3))
        report.extend(halo.check_coverage(exe.program, exe.plan, shape3))

    if level in ("full", "sound"):
        for plan in exe.all_plans:
            report.extend(indexmaps.check_plan_index_maps(plan))
            report.extend(plans.check_mosaic_readiness(plan, exe.dtype))
            report.extend(cachekeys.check_plan_key(plan))
        report.extend(cachekeys.check_executable_key(exe))

    if level == "sound" and exe.rewrite_trace:
        from repro.analysis import rewrites

        report.extend(rewrites.check_trace(exe.rewrite_trace))
    return report


def verify_on_compile() -> bool:
    """Is the compile-time hook enabled?  Controlled by ``REPRO_VERIFY``
    (unset/"0"/"off"/"false" → disabled).  ``tests/conftest.py`` enables
    it for the whole suite, so every executable any test compiles is
    verified for free."""
    return os.environ.get("REPRO_VERIFY", "0").lower() \
        not in ("0", "", "off", "false", "no")
