"""repro.api — the unified morphology expression API.

compose → plan → compile → execute::

    from repro.api import E, compile

    f    = E.input("f")
    expr = E.reconstruct(E.sat_sub(f, 40), f, op="dilate")   # HMAX_40
    exe  = compile(expr, image.shape, image.dtype, "pallas")
    out  = exe(image)            # (H, W) or (N, H, W), bit-exact
    exe.stats()                  # pads / launches / refills / plan

Layers (each module's docstring carries its local contract):

- ``expr`` — composable graph nodes (``E.erode``, ``E.reconstruct``,
  marker derivations, pointwise arithmetic, ``>>`` piping).
- ``lower`` — graph → three-phase :class:`~repro.api.lower.Program`
  (prepare / padded run segments with chain fusion / finalize).
- ``compile`` — binds a program to (shape, dtype, backend) under one
  shared :class:`~repro.core.chain.ChainPlan`, LRU-cached on the graph.
- ``executable`` — runs the program: one pad, fused segments, one crop.

The legacy surfaces are sugar over this: ``core/operators.py`` builds
these graphs, ``kernels/ops.py``'s public wrappers route through
``compile``, and ``repro.serve`` derives its pipeline stages and bucket
keys from the lowered programs.
"""
from repro.api.compile import cache_stats, clear_cache, compile
from repro.api.executable import Executable
from repro.api.expr import (E, Expr, Pipe, asf_expr, dome_expr, hfill_expr,
                            hmax_expr, opening_by_reconstruction_expr,
                            qdt_l1_expr, raobj_expr)
from repro.api.lower import Program, lower

__all__ = [
    "E", "Expr", "Pipe", "Program", "Executable",
    "compile", "lower", "cache_stats", "clear_cache",
    "hmax_expr", "dome_expr", "hfill_expr", "raobj_expr",
    "opening_by_reconstruction_expr", "asf_expr", "qdt_l1_expr",
]
