"""``compile(expr, shape, dtype, backend)`` — the one entry that turns
an expression graph into an :class:`~repro.api.executable.Executable`.

Compilation is lowering (``repro.api.lower``) plus schedule binding:
one :class:`~repro.core.chain.ChainPlan` is derived for the whole
program — convergent when any reconstruction/QDT segment is present,
with the residency of the hungriest segment — so every segment of a
composite operator (ASF's fused chains, opening-by-reconstruction's
erosion + reconstruction) shares one padded layout.

Compiled executables are cached in a module-level LRU keyed on the
expression graph itself plus the binding ``(shape, dtype, backend,
plan, max_chunks)`` — an :class:`~repro.api.expr.Expr` is a frozen
hashable dataclass, so the graph *is* the key.  ``cache_stats()``
exposes hit/miss counters (surfaced by ``benchmarks/run.py --only
pipeline``); the legacy operator sugar in ``core/operators.py`` and
``kernels/ops.py`` goes through this cache on every call, which is what
makes the thin-wrapper rebuild free in steady state.
"""
from __future__ import annotations

import collections
import threading

import jax.numpy as jnp

from repro.api.executable import Executable
from repro.api.expr import Expr, Pipe
from repro.api.lower import lower
from repro.core.backend import canonicalize_backend
from repro.core.chain import plan_chain

#: Executables kept resident; enough for every (op, bucket) pair of a
#: busy service plus direct-use traffic.
CACHE_CAPACITY = 512

_cache: collections.OrderedDict = collections.OrderedDict()
_lock = threading.Lock()
_hits = 0
_misses = 0


def compile(expr: Expr, shape, dtype, backend: str | None = None, *,
            plan=None, max_chunks: int | None = None,
            verify: bool | None = None) -> Executable:
    """Lower ``expr`` and bind it to a concrete (shape, dtype, backend).

    ``shape`` is ``(H, W)`` (the executable then takes and returns 2-D
    arrays) or ``(N, H, W)`` for batched execution.  ``plan`` overrides
    the derived :class:`~repro.core.chain.ChainPlan` (Pallas backend
    only; validated against the shape); ``max_chunks`` caps the
    convergence-driven segments' K-chunk iterations.

    ``verify`` controls the static verifier hook
    (``repro.analysis.verifier:verify_executable`` at the cheap "fast"
    level, cache-miss builds only): ``None`` defers to the
    ``REPRO_VERIFY`` environment toggle (the test suite turns it on),
    ``True``/``False`` force it.  An ERROR-severity finding raises
    ``repro.analysis.findings:VerificationError`` before the executable
    enters the cache.
    """
    if isinstance(expr, Pipe):
        raise TypeError(
            "got an unapplied pipe — apply it to an input first, e.g. "
            "E.input('f') >> E.erode(4)"
        )
    if not isinstance(expr, Expr):
        raise TypeError(f"expected an Expr, got {type(expr).__name__}")
    backend = canonicalize_backend(backend)
    shape = tuple(int(s) for s in shape)
    if len(shape) == 2:
        shape3, was_2d = (1, *shape), True
    elif len(shape) == 3:
        shape3, was_2d = shape, False
    else:
        raise ValueError(f"shape must be (H, W) or (N, H, W), got {shape}")
    dtype = jnp.dtype(dtype)

    global _hits, _misses
    key = (expr, shape3, was_2d, str(dtype), backend, plan, max_chunks)
    with _lock:
        exe = _cache.get(key)
        if exe is not None:
            _hits += 1
            _cache.move_to_end(key)
            return exe
        _misses += 1

    exe = _build(expr, shape3, was_2d, dtype, backend, plan, max_chunks)
    if verify or verify is None:
        # local import: analysis sits above api in the layering
        from repro.analysis.verifier import (
            verify_executable,
            verify_on_compile,
        )

        if verify or verify_on_compile():
            verify_executable(exe, level="fast").raise_if_errors()
    with _lock:
        _cache[key] = exe
        while len(_cache) > CACHE_CAPACITY:
            _cache.popitem(last=False)
    return exe


def _build(expr, shape3, was_2d, dtype, backend, plan, max_chunks):
    program = lower(expr)
    n, h, w = shape3
    if plan is not None:
        # validate an explicit plan against the bound shape regardless
        # of backend — a mismatched schedule is a caller bug even when
        # the jnp engine would not use it
        if plan.n_images != n:
            raise ValueError(
                f"plan.n_images={plan.n_images} != batch size {n}"
            )
        if plan.height_pad < h or plan.width_pad < w:
            raise ValueError(
                f"plan pads ({plan.height_pad}, {plan.width_pad}) "
                f"smaller than image ({h}, {w})"
            )
    if backend == "pallas" and program.kernel_segments:
        if plan is None:
            lens = [s.param("n") for s in program.segments
                    if s.kind in ("chain", "geodesic")]
            plan = plan_chain(
                h, w, dtype,
                None if program.convergent else (max(lens) if lens else None),
                n_images_resident=program.n_resident,
                n_images=n,
                convergent=program.convergent,
            )
    else:
        plan = None  # the jnp oracle engine runs unpadded
    return Executable(program, shape3, dtype, backend, plan, max_chunks,
                      was_2d)


def cache_stats() -> dict:
    """Compile-cache counters (the pipeline benchmark's hit-rate row)."""
    with _lock:
        total = _hits + _misses
        return {
            "entries": len(_cache),
            "capacity": CACHE_CAPACITY,
            "hits": _hits,
            "misses": _misses,
            "hit_rate": _hits / total if total else 0.0,
        }


def clear_cache() -> None:
    global _hits, _misses
    with _lock:
        _cache.clear()
        _hits = 0
        _misses = 0
