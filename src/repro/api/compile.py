"""``compile(expr, shape, dtype, backend)`` — the one entry that turns
an expression graph into an :class:`~repro.api.executable.Executable`.

Compilation is now three stages:

1. **Rewrite** (on by default, ``rewrite=False`` to skip): the
   expression optimizer (``repro.opt``) canonicalizes the graph with
   its exactness-provable algebraic rules — idempotent openings
   collapse, adjacent chains merge, dead convergent segments are
   pruned.  The *canonical* graph is what gets lowered and what the
   LRU keys on, so source graphs that are algebraically equal share
   one compiled program (``cache_stats()`` reports those as
   ``shared_hits``, distinct from ``structural_hits`` on the very same
   source graph).
2. **Lowering** (``repro.api.lower``) into the three-phase program.
3. **Schedule binding**: one :class:`~repro.core.chain.ChainPlan` per
   *plan group*.  A single-class program (all fixed chains, or all
   convergent) keeps today's single shared plan; a mixed program — a
   fixed 2s-chain feeding a convergent reconstruction — is
   *specialized* (``specialize=None`` auto, ``True``/``False`` force):
   each contiguous fixed/convergent segment group gets its own plan
   tuned to its chain length and residency, with a re-band boundary
   between groups (see ``Executable.seg_plans``).

Compiled executables are cached in a module-level LRU keyed on the
canonical expression graph plus the binding ``(shape, dtype, backend,
plan, max_chunks, specialize)`` — an :class:`~repro.api.expr.Expr` is a
frozen hashable dataclass, so the graph *is* the key.  ``cache_stats()``
exposes hit/miss counters (surfaced by ``benchmarks/run.py --only
pipeline``); the legacy operator sugar in ``core/operators.py`` and
``kernels/ops.py`` goes through this cache on every call, which is what
makes the thin-wrapper rebuild free in steady state.
"""
from __future__ import annotations

import collections
import threading

import jax.numpy as jnp

from repro.api.executable import Executable
from repro.api.expr import Expr, Pipe
from repro.api.lower import _RESIDENT, lower
from repro.core.backend import canonicalize_backend
from repro.core.chain import plan_chain

#: Executables kept resident; enough for every (op, bucket) pair of a
#: busy service plus direct-use traffic.
CACHE_CAPACITY = 512

#: Segment kinds whose work is convergence-driven (vs fixed-length).
_CONVERGENT_KINDS = ("reconstruct", "qdt", "gdt")

_cache: collections.OrderedDict = collections.OrderedDict()
_sources: dict = {}  # cache key → set of source Exprs that mapped to it
_lock = threading.Lock()
_hits = 0
_misses = 0
_structural_hits = 0
_shared_hits = 0


def compile(expr: Expr, shape, dtype, backend: str | None = None, *,
            plan=None, max_chunks: int | None = None,
            verify: bool | None = None, rewrite: bool = True,
            specialize: bool | None = None) -> Executable:
    """Lower ``expr`` and bind it to a concrete (shape, dtype, backend).

    ``shape`` is ``(H, W)`` (the executable then takes and returns 2-D
    arrays) or ``(N, H, W)`` for batched execution.  ``plan`` overrides
    the derived :class:`~repro.core.chain.ChainPlan` (Pallas backend
    only; validated against the shape; disables per-group
    specialization); ``max_chunks`` caps the convergence-driven
    segments' K-chunk iterations.

    ``rewrite`` (default on) runs the expression optimizer first; the
    escape hatch ``rewrite=False`` compiles the source graph verbatim.
    ``specialize`` controls per-segment plan specialization: ``None``
    specializes exactly the mixed fixed+convergent programs, ``True``/
    ``False`` force it on/off (``True`` on a single-group program is a
    no-op).

    ``verify`` controls the static verifier hook
    (``repro.analysis.verifier:verify_executable`` at the cheap "fast"
    level, cache-miss builds only): ``None`` defers to the
    ``REPRO_VERIFY`` environment toggle (the test suite turns it on),
    ``True``/``False`` force it.  An ERROR-severity finding raises
    ``repro.analysis.findings:VerificationError`` before the executable
    enters the cache.
    """
    if isinstance(expr, Pipe):
        raise TypeError(
            "got an unapplied pipe — apply it to an input first, e.g. "
            "E.input('f') >> E.erode(4)"
        )
    if not isinstance(expr, Expr):
        raise TypeError(f"expected an Expr, got {type(expr).__name__}")
    backend = canonicalize_backend(backend)
    shape = tuple(int(s) for s in shape)
    if len(shape) == 2:
        shape3, was_2d = (1, *shape), True
    elif len(shape) == 3:
        shape3, was_2d = shape, False
    else:
        raise ValueError(f"shape must be (H, W) or (N, H, W), got {shape}")
    dtype = jnp.dtype(dtype)

    if rewrite:
        # local import: repro.opt sits between api.expr and api.lower
        # in the layering but imports lower's graph walkers
        from repro.opt import rewrite_traced

        rewritten = rewrite_traced(expr)
        canonical, trace = rewritten.expr, rewritten.trace
    else:
        canonical, trace = expr, ()

    global _hits, _misses, _structural_hits, _shared_hits
    key = (canonical, shape3, was_2d, str(dtype), backend, plan, max_chunks,
           specialize)
    with _lock:
        exe = _cache.get(key)
        if exe is not None:
            _hits += 1
            seen = _sources.setdefault(key, set())
            if expr in seen:
                _structural_hits += 1
            else:
                _shared_hits += 1
                seen.add(expr)
            _cache.move_to_end(key)
            return exe
        _misses += 1

    exe = _build(canonical, shape3, was_2d, dtype, backend, plan, max_chunks,
                 specialize, trace)
    if verify or verify is None:
        # local import: analysis sits above api in the layering
        from repro.analysis.verifier import (
            verify_executable,
            verify_on_compile,
        )

        if verify or verify_on_compile():
            verify_executable(exe, level="fast").raise_if_errors()
    with _lock:
        _cache[key] = exe
        _sources.setdefault(key, set()).add(expr)
        while len(_cache) > CACHE_CAPACITY:
            old_key, _ = _cache.popitem(last=False)
            _sources.pop(old_key, None)
    return exe


def segment_groups(program) -> tuple:
    """Partition ``program.segments`` into contiguous plan groups.

    Each group is ``(segment_indices, convergent)``: a maximal run of
    kernel segments of one work class — fixed-length (chain/geodesic)
    or convergence-driven (reconstruct/qdt/gdt) — plus the refill and
    ``point`` segments that prepare operands for it (both attach to the
    *next* kernel segment; trailing ones join the last group).
    """
    groups: list = []
    current: list = []
    current_conv: bool | None = None
    pending: list = []  # refills/points awaiting their consumer's class
    for i, seg in enumerate(program.segments):
        if seg.kind in ("refill", "point"):
            pending.append(i)
            continue
        conv = seg.kind in _CONVERGENT_KINDS
        if current_conv is None or conv == current_conv:
            current.extend(pending)
            current.append(i)
            current_conv = conv
        else:
            groups.append((tuple(current), current_conv))
            current = [*pending, i]
            current_conv = conv
        pending = []
    if pending:
        current.extend(pending)
    if current:
        groups.append((tuple(current), bool(current_conv)))
    return tuple(groups)


def _group_plan(program, idxs, h, w, dtype, n, convergent):
    """One ChainPlan tuned to a single plan group's segments."""
    segs = [program.segments[i] for i in idxs]
    lens = [s.param("n") for s in segs if s.kind in ("chain", "geodesic")]
    resident = max((_RESIDENT.get(s.kind, 1) for s in segs), default=1)
    return plan_chain(
        h, w, dtype,
        None if convergent else (max(lens) if lens else None),
        n_images_resident=resident,
        n_images=n,
        convergent=convergent,
    )


def _build(expr, shape3, was_2d, dtype, backend, plan, max_chunks,
           specialize, trace):
    program = lower(expr)
    n, h, w = shape3
    if (dtype.kind != "f"
            and any(s.kind == "gdt" for s in program.segments)):
        raise TypeError(
            f"gdt requires a float dtype (the distance plane is a float "
            f"lattice), got {dtype}"
        )
    if plan is not None:
        # validate an explicit plan against the bound shape regardless
        # of backend — a mismatched schedule is a caller bug even when
        # the jnp engine would not use it
        if plan.n_images != n:
            raise ValueError(
                f"plan.n_images={plan.n_images} != batch size {n}"
            )
        if plan.height_pad < h or plan.width_pad < w:
            raise ValueError(
                f"plan pads ({plan.height_pad}, {plan.width_pad}) "
                f"smaller than image ({h}, {w})"
            )
    seg_plans = None
    if backend == "pallas" and program.kernel_segments:
        if plan is None:
            groups = segment_groups(program)
            if len(groups) > 1 and specialize is not False:
                seg_plans = tuple(
                    (idxs, _group_plan(program, idxs, h, w, dtype, n, conv))
                    for idxs, conv in groups
                )
                plan = seg_plans[0][1]
            else:
                lens = [s.param("n") for s in program.segments
                        if s.kind in ("chain", "geodesic")]
                plan = plan_chain(
                    h, w, dtype,
                    None if program.convergent
                    else (max(lens) if lens else None),
                    n_images_resident=program.n_resident,
                    n_images=n,
                    convergent=program.convergent,
                )
    else:
        plan = None  # the jnp oracle engine runs unpadded
    return Executable(program, shape3, dtype, backend, plan, max_chunks,
                      was_2d, seg_plans=seg_plans, rewrite_trace=trace)


def cache_stats() -> dict:
    """Compile-cache counters (the pipeline benchmark's hit-rate row).

    ``hits`` splits into ``structural_hits`` — the very same source
    graph was compiled before — and ``shared_hits`` — a *different*
    source graph canonicalized to an already-compiled program (the
    optimizer's cross-graph sharing; never counted as a miss)."""
    with _lock:
        total = _hits + _misses
        return {
            "entries": len(_cache),
            "capacity": CACHE_CAPACITY,
            "hits": _hits,
            "structural_hits": _structural_hits,
            "shared_hits": _shared_hits,
            "misses": _misses,
            "hit_rate": _hits / total if total else 0.0,
        }


def clear_cache() -> None:
    global _hits, _misses, _structural_hits, _shared_hits
    with _lock:
        _cache.clear()
        _sources.clear()
        _hits = 0
        _misses = 0
        _structural_hits = 0
        _shared_hits = 0
