"""Executable: a compiled expression bound to (shape, dtype, backend).

The run phase executes the lowered :class:`~repro.api.lower.Program`
as **one padded program**: every canonical input is padded to the
shared :class:`~repro.core.chain.ChainPlan` exactly once, all kernel
segments run on the vertically stacked ``(N·H_pad, W_pad)`` working
arrays (chains via ``chain_step`` scans, convergence-driven segments
via the requeue scheduler in ``kernels/ops.py``), and outputs are
cropped exactly once.  Between segments that need a different absorbing
identity in the pad region, the lowered ``refill`` segments apply a
masked fill in place of the legacy crop → re-pad → re-plan round-trip.

``backend="xla"`` executes the same program with the pure-jnp oracle
bodies on unpadded arrays — bit-exact with the Pallas path by the
repo's exactness convention (see ``docs/ARCHITECTURE.md``).

``Executable.key`` — the lowered run signature + bound shape/dtype/
backend + ``plan.key`` — is simultaneously the compile-cache key and
the ``repro.serve`` bucket/cache identity, which is what lets different
operators with identical run phases (HMAX vs DOME) share one compiled
bucket program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api.lower import Program, eval_pointwise
from repro.core import morphology as M
from repro.core import operators as OPS
from repro.kernels.common import ident_for
from repro.kernels.erode_chain import chain_step
from repro.kernels.geodesic_chain import geodesic_chain_step

#: pad-fill name → the op whose lattice identity it is
_FILL_OP = {"hi": "erode", "lo": "dilate"}


def _fill_value(fill: str, dtype):
    return ident_for(_FILL_OP[fill], dtype)


class Executable:
    """A lowered program bound to a concrete (N, H, W)/dtype/backend.

    Call it with the expression's input arrays (in
    ``program.input_names`` order) to run prepare → run → finalize;
    ``run_batch`` runs the run phase alone on canonical inputs (the
    serve executor's per-bucket program).  ``stats()`` reports the
    static pad/launch/refill accounting of the compiled program — the
    fusion wins of the expression API are visible there.
    """

    def __init__(self, program: Program, shape3: tuple, dtype, backend: str,
                 plan, max_chunks: int | None, was_2d: bool):
        self.program = program
        self.n_images, self.height, self.width = shape3
        self.dtype = jnp.dtype(dtype)
        self.backend = backend
        self.plan = plan
        self.max_chunks = max_chunks
        self.was_2d = was_2d
        if plan is not None:
            k = plan.fuse_k
            self._max_chunks_rec = (
                max_chunks if max_chunks is not None
                else (self.height * self.width) // k + 2
            )
            self._max_chunks_qdt = (
                max_chunks if max_chunks is not None
                else max(self.height, self.width) // k + 2
            )
        # Every field that can change what a call computes or returns
        # must appear here — ``repro.analysis.cachekeys`` perturbs each
        # one and asserts the key moves (``max_chunks`` truncates
        # convergent segments; ``was_2d`` changes the output rank).
        self.key = (
            program.run_sig, shape3, str(self.dtype), backend,
            plan.key if plan is not None else None,
            max_chunks, was_2d,
        )

    # -- public ------------------------------------------------------------

    def __call__(self, *arrays, **named):
        names = self.program.input_names
        if named:
            if arrays:
                raise TypeError("pass inputs positionally or by name, "
                                "not both")
            try:
                arrays = tuple(named.pop(n) for n in names)
            except KeyError as e:
                raise TypeError(f"missing input {e.args[0]!r}") from None
            if named:
                raise TypeError(f"unknown inputs {sorted(named)} "
                                f"(expected {list(names)})")
        if len(arrays) != len(names):
            raise TypeError(
                f"expression takes {len(names)} input(s) {list(names)}, "
                f"got {len(arrays)}"
            )
        arrays = tuple(self._check(jnp.asarray(a)) for a in arrays)
        outs = self._call_fn(*arrays)
        return outs[0] if self.program.n_outputs == 1 else outs

    def run_batch(self, *canonical):
        """Run phase only: canonical (N, H, W) inputs → cropped run
        outputs (always a tuple) — the serve bucket entry point."""
        return self._run_fn(*canonical)

    def run_batch_stats(self, *canonical):
        """Run phase plus the convergence watchdog's verdict:
        ``(outputs, converged)`` where ``converged`` is a (N,) bool
        vector, False for images whose convergence-driven segments
        exhausted the chunk budget (``ReconstructStats.converged``
        per image, AND-ed across segments).  The serve executor demuxes
        it into per-request degraded flags; programs without convergent
        segments (and the jnp oracle engine, which iterates to its own
        fixpoint) report all-True."""
        return self._run_stats_fn(*canonical)

    def stats(self) -> dict:
        """Static accounting of the compiled program (pads, launches,
        refills): what the fusion tests and the pipeline benchmarks
        count.  ``pads``/``crops`` are the pad/crop round-trips of one
        execution; the legacy per-stage path pays one of each per
        elementary operator stage.  ``convergent``/``chunk_budget_rec``
        /``chunk_budget_qdt`` describe the watchdog configuration the
        convergence-driven segments run under; the *runtime* verdict
        for a particular execution comes from :meth:`run_batch_stats`
        (or ``ReconstructStats.converged`` on the engine entry
        points)."""
        prog = self.program
        return {
            "backend": self.backend,
            "pads": len(prog.run_fills) if self.plan is not None else 0,
            "crops": len(prog.run_outputs) if self.plan is not None else 0,
            "launches": len(prog.kernel_segments),
            "refills": sum(1 for s in prog.segments if s.kind == "refill"),
            "fused_chain_len": prog.fused_chain_len,
            "plan_key": self.plan.key if self.plan is not None else None,
            "convergent": prog.convergent,
            "chunk_budget_rec": (self._max_chunks_rec
                                 if self.plan is not None else None),
            "chunk_budget_qdt": (self._max_chunks_qdt
                                 if self.plan is not None else None),
        }

    def __repr__(self):
        return (f"Executable({self.program.sig_label()}, "
                f"shape=({self.n_images}, {self.height}, {self.width}), "
                f"dtype={self.dtype}, backend={self.backend!r})")

    # -- internals ---------------------------------------------------------

    def _check(self, a):
        # 2-D executables keep 2-D arrays end-to-end (XLA:CPU handles a
        # leading unit dim poorly); the pallas engine promotes privately.
        want = ((self.height, self.width) if self.was_2d
                else (self.n_images, self.height, self.width))
        if tuple(a.shape) != want:
            raise ValueError(
                f"input shape {a.shape} does not match the compiled "
                f"shape {want}"
            )
        if a.dtype != self.dtype:
            raise ValueError(
                f"input dtype {a.dtype} does not match the compiled "
                f"dtype {self.dtype}"
            )
        return a

    @functools.cached_property
    def _call_fn(self):
        return jax.jit(self._pipeline)

    @functools.cached_property
    def _run_fn(self):
        return jax.jit(self._run_segments)

    @functools.cached_property
    def _run_stats_fn(self):
        return jax.jit(self._run_segments_stats)

    def _pipeline(self, *inputs3):
        prog = self.program
        env = dict(zip(prog.input_names, inputs3))
        canonical = [eval_pointwise(e, env, {}, {}) for e in prog.prepare]
        cropped = self._run_segments(*canonical)
        kernel_vals = {
            (node, i): cropped[j]
            for j, (node, i, _) in enumerate(prog.kernel_outputs)
        }
        memo = {}
        return tuple(eval_pointwise(e, env, kernel_vals, memo)
                     for e in prog.result_exprs())

    def _run_segments(self, *canonical):
        if self.plan is None:
            return self._run_xla(canonical)
        return self._run_padded(canonical)

    def _run_segments_stats(self, *canonical):
        """Run phase + (N,) convergence vector (see run_batch_stats)."""
        all_ok = jnp.ones((self.n_images,), jnp.bool_)
        if self.plan is None:
            # the jnp oracle bodies iterate to their own fixpoint
            return self._run_xla(canonical), all_ok
        conv: list = []
        outs = self._run_padded(canonical, conv)
        for vec in conv:
            all_ok = jnp.logical_and(all_ok, vec)
        return outs, all_ok

    # -- xla engine: the jnp oracle bodies, unpadded -----------------------

    def _run_xla(self, canonical):
        vals = {}
        for slot, x3 in zip(self.program.run_input_slots, canonical):
            vals[slot] = x3
        for seg in self.program.segments:
            if seg.kind == "refill":       # no padding exists to refill
                vals[seg.dsts[0]] = vals[seg.srcs[0]]
            elif seg.kind == "chain":
                body = (M.erode3 if seg.param("op") == "erode"
                        else M.dilate3)
                vals[seg.dsts[0]] = jax.lax.fori_loop(
                    0, seg.param("n"), lambda _, y, b=body: b(y),
                    vals[seg.srcs[0]],
                )
            elif seg.kind == "geodesic":
                step = (M.geodesic_erode if seg.param("op") == "erode"
                        else M.geodesic_dilate)
                vals[seg.dsts[0]] = step(vals[seg.srcs[0]],
                                         vals[seg.srcs[1]], seg.param("n"))
            elif seg.kind == "reconstruct":
                rec = (M.erode_reconstruct if seg.param("op") == "erode"
                       else M.dilate_reconstruct)
                vals[seg.dsts[0]] = rec(vals[seg.srcs[0]], vals[seg.srcs[1]])
            elif seg.kind == "qdt":
                d, r = OPS.qdt_raw(vals[seg.srcs[0]])
                vals[seg.dsts[0]], vals[seg.dsts[1]] = d, r
            else:  # pragma: no cover
                raise AssertionError(seg.kind)
        return tuple(vals[s] for s in self.program.run_outputs)

    # -- pallas engine: one padded program ---------------------------------

    @functools.cached_property
    def _image_mask(self):
        """(TOTAL_H, W_pad) bool: True inside the real image regions."""
        plan = self.plan
        rows = (jnp.arange(plan.n_images * plan.height_pad)
                % plan.height_pad) < self.height
        cols = jnp.arange(plan.width_pad) < self.width
        return rows[:, None] & cols[None, :]

    def _run_padded(self, canonical, conv: list | None = None):
        from repro.kernels.ops import _pad, _stacked

        plan = self.plan
        vals = {}
        for slot, x, fill in zip(self.program.run_input_slots, canonical,
                                 self.program.run_fills):
            x3 = x[None] if x.ndim == 2 else x
            vals[slot] = _stacked(_pad(x3, plan, _fill_value(fill, x.dtype)))
        for seg in self.program.segments:
            self._pallas_seg(seg, vals, conv)
        return tuple(self._crop2(vals[s]) for s in self.program.run_outputs)

    def _pallas_seg(self, seg, vals, conv: list | None = None):
        from repro.kernels.ops import _scheduled_qdt, _scheduled_reconstruct

        plan = self.plan
        if seg.kind == "refill":
            x2 = vals[seg.srcs[0]]
            vals[seg.dsts[0]] = jnp.where(
                self._image_mask, x2,
                _fill_value(seg.param("fill"), x2.dtype),
            )
        elif seg.kind == "chain":
            vals[seg.dsts[0]] = self._chain2(
                vals[seg.srcs[0]], seg.param("op"), seg.param("n"))
        elif seg.kind == "geodesic":
            vals[seg.dsts[0]] = self._geodesic2(
                vals[seg.srcs[0]], vals[seg.srcs[1]],
                seg.param("op"), seg.param("n"))
        elif seg.kind == "reconstruct":
            out, _, _, _, img_conv = _scheduled_reconstruct(
                vals[seg.srcs[0]], vals[seg.srcs[1]], plan,
                seg.param("op"), self._max_chunks_rec, False,
            )
            vals[seg.dsts[0]] = out
            if conv is not None:
                conv.append(img_conv)
        elif seg.kind == "qdt":
            _, r, d, img_conv = _scheduled_qdt(vals[seg.srcs[0]], plan,
                                               self._max_chunks_qdt)
            vals[seg.dsts[0]], vals[seg.dsts[1]] = d, r
            if conv is not None:
                conv.append(img_conv)
        else:  # pragma: no cover
            raise AssertionError(seg.kind)

    def _chain2(self, x2, op, n):
        from repro.kernels.ops import _INTERPRET, _stacked, _unstacked

        plan = self.plan
        full, rem = divmod(n, plan.fuse_k)
        if full:
            def chunk(x, _):
                return chain_step(
                    x, op=op, fuse_k=plan.fuse_k, band_h=plan.band_h,
                    interpret=_INTERPRET, bands_per_image=plan.n_bands,
                ), None
            x2, _ = jax.lax.scan(chunk, x2, None, length=full)
        if rem:
            # jnp tail on the 3-D view: axis-polymorphic per image, and
            # the pad region continues the identity-padded semantics.
            body = M.erode3 if op == "erode" else M.dilate3
            x3 = jax.lax.fori_loop(
                0, rem, lambda _, y, b=body: b(y),
                _unstacked(x2, self.n_images),
            )
            x2 = _stacked(x3)
        return x2

    def _geodesic2(self, f2, m2, op, n):
        from repro.kernels.ops import _INTERPRET, _stacked, _unstacked

        plan = self.plan
        full, rem = divmod(n, plan.fuse_k)
        if full:
            def chunk(x, _):
                y, _ = geodesic_chain_step(
                    x, m2, op=op, fuse_k=plan.fuse_k, band_h=plan.band_h,
                    interpret=_INTERPRET, bands_per_image=plan.n_bands,
                )
                return y, None
            f2, _ = jax.lax.scan(chunk, f2, None, length=full)
        if rem:
            step = (M.geodesic_erode1 if op == "erode"
                    else M.geodesic_dilate1)
            m3 = _unstacked(m2, self.n_images)
            f3 = jax.lax.fori_loop(
                0, rem, lambda _, y: step(y, m3),
                _unstacked(f2, self.n_images),
            )
            f2 = _stacked(f3)
        return f2

    def _crop2(self, x2):
        from repro.kernels.ops import _unstacked

        x3 = _unstacked(x2, self.n_images)
        out = x3[:, : self.height, : self.width]
        return out[0] if self.was_2d else out
