"""Executable: a compiled expression bound to (shape, dtype, backend).

The run phase executes the lowered :class:`~repro.api.lower.Program`
as **one padded program per plan group**: by default every canonical
input is padded to the shared :class:`~repro.core.chain.ChainPlan`
exactly once, all kernel segments run on the vertically stacked
``(N·H_pad, W_pad)`` working arrays (chains via ``chain_step`` scans,
convergence-driven segments via the requeue scheduler in
``kernels/ops.py``), and outputs are cropped exactly once.  Between
segments that need a different absorbing identity in the pad region,
the lowered ``refill`` segments apply a masked fill in place of the
legacy crop → re-pad → re-plan round-trip.

When the compiler specializes a mixed program (``compile(...,
specialize=...)``), the segment list is partitioned into contiguous
*plan groups* — fixed-length chain groups and convergent
reconstruction/QDT groups — each with its own ``ChainPlan``
(``seg_plans``).  Values crossing a group boundary take a *re-band*
round-trip: cropped out of the producer group's band layout and
re-padded with the pad identity the consumer group's lowering expects,
so the halo-exactness argument of each group composes unchanged.

``backend="xla"`` executes the same program with the pure-jnp oracle
bodies on unpadded arrays — bit-exact with the Pallas path by the
repo's exactness convention (see ``docs/ARCHITECTURE.md``).

``Executable.key`` — the lowered run signature + bound shape/dtype/
backend + ``plan.key`` (+ the per-group plan keys when specialized) —
is simultaneously the compile-cache key and the ``repro.serve``
bucket/cache identity, which is what lets different operators with
identical run phases (HMAX vs DOME) share one compiled bucket program.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.api.lower import Program, eval_pointwise
from repro.core import morphology as M
from repro.core import operators as OPS
from repro.kernels.common import ident_for
from repro.kernels.erode_chain import chain_step
from repro.kernels.geodesic_chain import geodesic_chain_step

#: pad-fill name → the op whose lattice identity it is
_FILL_OP = {"hi": "erode", "lo": "dilate"}

#: op → the absorbing pad identity its operands need (dual of _FILL_OP)
_NEED_FILL = {"erode": "hi", "dilate": "lo"}


def _fill_value(fill: str, dtype):
    return ident_for(_FILL_OP[fill], dtype)


class SlotSession(NamedTuple):
    """Jitted entry points for continuous batching over one resident
    device-state *session* (see :meth:`Executable.slot_session`).

    The session owns a persistent padded stack whose ``n_slots`` row
    blocks are independent images under the requeue scheduler; slots
    park (activity cleared → zero work) and are re-armed in place.
    All callables are pure: they take the session state and return the
    next one.

    ``init()``
        fresh state: every slot parked, planes filled with the
        program's absorbing pad identities.
    ``admit(state, slot, *canonical) -> state``
        write one request's canonical (H, W) inputs into ``slot``'s
        row block (padded with the program's fills), re-arm its
        activity rows, and zero its chunk counter — exactly the
        initial condition a solo run of that image starts from.
    ``round(state) -> (state, finished, exhausted)``
        run at most ``n_chunks`` scheduler chunks over every active
        slot.  ``finished`` is (n_slots,) bool — the slot's active set
        is empty (converged, budget-truncated, or parked); a finished
        *occupied* slot is ready to harvest and refill.  ``exhausted``
        flags slots cut off by the per-image chunk budget (degraded
        partial fixpoints).
    ``extract(state) -> outputs``
        cropped (n_slots, H, W) run outputs (program output order).
    ``chunks_of(state) -> (n_slots,) int32``
        cumulative scheduler chunks each slot's image has consumed —
        the raw material for chunk-weighted work-occupancy accounting
        (round-over-round deltas are what a slot actually did).
    """

    n_slots: int
    n_chunks: int
    init: Any
    admit: Any
    round: Any
    extract: Any
    chunks_of: Any


def _seg_need_fill(seg) -> str:
    """Pad identity ``seg`` expects of an operand re-entering padded
    form at a group boundary."""
    if seg.kind == "refill":
        # the masked fill overwrites the pad region anyway
        return seg.param("fill")
    if seg.kind == "qdt":
        return "hi"  # the QDT iterates erosion
    if seg.kind in ("gdt", "point"):
        # gdt stages its own planes from −inf-marked operands; point
        # outputs are re-masked by a refill before any kernel consumer
        return "lo"
    return _NEED_FILL[seg.param("op")]


class Executable:
    """A lowered program bound to a concrete (N, H, W)/dtype/backend.

    Call it with the expression's input arrays (in
    ``program.input_names`` order) to run prepare → run → finalize;
    ``run_batch`` runs the run phase alone on canonical inputs (the
    serve executor's per-bucket program).  ``stats()`` reports the
    static pad/launch/refill accounting of the compiled program — the
    fusion wins of the expression API are visible there.

    ``seg_plans`` (keyword-only) activates per-segment plan
    specialization: a tuple of ``(segment_indices, ChainPlan)`` groups
    covering ``program.segments`` in order.  ``plan`` then remains the
    primary (first-group) plan for introspection; ``all_plans`` lists
    every group's.  ``rewrite_trace`` carries the optimizer's
    :class:`~repro.opt.engine.Applied` steps for this program (empty
    when compiled with ``rewrite=False`` or nothing fired) — the
    soundness hook in ``repro.analysis.rewrites`` replays it.
    """

    def __init__(self, program: Program, shape3: tuple, dtype, backend: str,
                 plan, max_chunks: int | None, was_2d: bool, *,
                 seg_plans=None, rewrite_trace=()):
        self.program = program
        self.n_images, self.height, self.width = shape3
        self.dtype = jnp.dtype(dtype)
        self.backend = backend
        self.plan = plan
        self.max_chunks = max_chunks
        self.was_2d = was_2d
        self.seg_plans = tuple(seg_plans) if seg_plans else None
        self.rewrite_trace = tuple(rewrite_trace)
        self._mask_cache: dict = {}
        self._sessions: dict = {}
        if plan is not None:
            self._max_chunks_rec = self._budget_rec(plan)
            self._max_chunks_qdt = self._budget_qdt(plan)
        # Every field that can change what a call computes or returns
        # must appear here — ``repro.analysis.cachekeys`` perturbs each
        # one and asserts the key moves (``max_chunks`` truncates
        # convergent segments; ``was_2d`` changes the output rank).
        # ``rewrite_trace`` is deliberately absent: it is provenance,
        # not behaviour — the program it produced is already keyed.
        seg_key = (tuple((idxs, p.key) for idxs, p in self.seg_plans)
                   if self.seg_plans is not None else None)
        self.key = (
            program.run_sig, shape3, str(self.dtype), backend,
            plan.key if plan is not None else None,
            max_chunks, was_2d, seg_key,
        )

    # -- public ------------------------------------------------------------

    def __call__(self, *arrays, **named):
        names = self.program.input_names
        if named:
            if arrays:
                raise TypeError("pass inputs positionally or by name, "
                                "not both")
            try:
                arrays = tuple(named.pop(n) for n in names)
            except KeyError as e:
                raise TypeError(f"missing input {e.args[0]!r}") from None
            if named:
                raise TypeError(f"unknown inputs {sorted(named)} "
                                f"(expected {list(names)})")
        if len(arrays) != len(names):
            raise TypeError(
                f"expression takes {len(names)} input(s) {list(names)}, "
                f"got {len(arrays)}"
            )
        arrays = tuple(self._check(jnp.asarray(a)) for a in arrays)
        outs = self._call_fn(*arrays)
        return outs[0] if self.program.n_outputs == 1 else outs

    def run_batch(self, *canonical):
        """Run phase only: canonical (N, H, W) inputs → cropped run
        outputs (always a tuple) — the serve bucket entry point."""
        return self._run_fn(*canonical)

    def run_batch_stats(self, *canonical):
        """Run phase plus the convergence watchdog's verdict and chunk
        utilization: ``(outputs, converged, busy_chunks, cap_chunks)``.
        ``converged`` is a (N,) bool vector, False for images whose
        convergence-driven segments exhausted the chunk budget
        (``ReconstructStats.converged`` per image, AND-ed across
        segments).  The serve executor demuxes it into per-request
        degraded flags; programs without convergent segments (and the
        jnp oracle engine, which iterates to its own fixpoint) report
        all-True.  ``busy_chunks``/``cap_chunks`` are int32 scalars:
        scheduler chunks the images actually consumed vs the chunks the
        batch held every slot for (summed across convergence-driven
        segments; both 0 when there are none) — the serving layer's
        chunk-weighted work-occupancy accounting, which exposes the
        dead capacity of early-converged slots parked behind a
        straggler."""
        return self._run_stats_fn(*canonical)

    @property
    def refillable(self) -> bool:
        """True when this program can run as a continuous-batching slot
        session: a single convergence-driven segment (reconstruct/QDT/
        gdt) under one pallas plan, compiled for a 3-D batch.
        Fixed-length chains gain nothing from refill (no stragglers to
        wait behind), multi-segment/specialized programs re-band
        between plans, which has no per-slot resumable state, and the
        raster gdt schedule sweeps whole images (no per-slot activity
        grid to park and resume)."""
        prog = self.program
        return (self.plan is not None
                and self.seg_plans is None
                and not self.was_2d
                and len(prog.segments) == 1
                and prog.segments[0].kind in ("reconstruct", "qdt", "gdt")
                and self.plan.schedule == "wavefront")

    def slot_session(self, n_chunks: int) -> SlotSession:
        """Build (or fetch) the :class:`SlotSession` entry points for
        continuous batching with rounds of ``n_chunks`` scheduler
        chunks.  Requires :attr:`refillable`.

        Bit-exactness: a slot admitted mid-flight starts from exactly
        the state a fresh solo batch would stage for it (same absorbing
        pads, all-active rows, zero chunk counter), and the scheduler's
        per-image independence (image-pinned halos + inactive-cell
        skip) means later rounds apply the same chunk sequence a solo
        run would — so harvested outputs equal solo execution bit for
        bit.  Budget-truncated slots are flagged exhausted and match a
        solo run under ``max_chunks=budget`` (see ``_drive_scheduler``).
        """
        cached = self._sessions.get(n_chunks)
        if cached is not None:
            return cached
        if not self.refillable:
            raise ValueError(
                f"{self!r} is not refillable (continuous batching needs a "
                "single convergent segment on the pallas backend)")
        if n_chunks < 1:
            raise ValueError("n_chunks must be >= 1")
        from repro.kernels.common import qdt_acc_dtype
        from repro.kernels.gdt_chain import D_IDENT, I_IDENT, S_IDENT
        from repro.kernels.ops import (_crop3, _scheduled_gdt,
                                       _scheduled_qdt,
                                       _scheduled_reconstruct, gdt_stage)

        prog = self.program
        seg = prog.segments[0]
        plan = self.plan
        n, h, w = self.n_images, self.height, self.width
        hp, wp = plan.height_pad, plan.width_pad
        fills = dict(self._exec_groups[0][2])  # slot -> pad fill name

        def plane(fill: str, dtype):
            return jnp.full((n * hp, wp), _fill_value(fill, dtype), dtype)

        def write(p, slot, img, fill: str):
            tile = jnp.pad(img, ((0, hp - h), (0, wp - w)),
                           constant_values=_fill_value(fill, img.dtype))
            return jax.lax.dynamic_update_slice(p, tile, (slot * hp, 0))

        def zero_rows(p, slot):
            return jax.lax.dynamic_update_slice(
                p, jnp.zeros((hp, wp), p.dtype), (slot * hp, 0))

        def arm(sched, slot):
            active, chunks, exhausted = sched
            active = jax.lax.dynamic_update_slice(
                active, jnp.ones((plan.n_bands, plan.n_tiles), jnp.int32),
                (slot * plan.n_bands, 0))
            chunks = jax.lax.dynamic_update_slice(
                chunks, jnp.zeros((1,), jnp.int32), (slot,))
            exhausted = jax.lax.dynamic_update_slice(
                exhausted, jnp.zeros((1,), jnp.bool_), (slot,))
            return active, chunks, exhausted

        def sched0():
            # all slots parked: no active cells, nothing costs work
            return (jnp.zeros((plan.total_bands, plan.n_tiles), jnp.int32),
                    jnp.zeros((n,), jnp.int32),
                    jnp.zeros((n,), jnp.bool_))

        def crops(vals: dict):
            return tuple(_crop3(vals[s], n, h, w)
                         for s in prog.run_outputs)

        if seg.kind == "reconstruct":
            op = seg.param("op")
            budget = self._budget_rec(plan)
            f_slot, m_slot = seg.srcs

            def init():
                return (plane(fills[f_slot], self.dtype),
                        plane(fills[m_slot], self.dtype), *sched0())

            def admit(state, slot, marker, mask):
                fp, mp, *sched = state
                fp = write(fp, slot, marker, fills[f_slot])
                mp = write(mp, slot, mask, fills[m_slot])
                return (fp, mp, *arm(tuple(sched), slot))

            def round_(state):
                fp, mp, *sched = state
                fp, _, _, _, finished, sched = _scheduled_reconstruct(
                    fp, mp, plan, op, n_chunks, False,
                    resume=tuple(sched), budget=budget)
                return (fp, mp, *sched), finished, sched[2]

            def extract(state):
                return crops({seg.dsts[0]: state[0]})

            def chunks_of(state):
                return state[3]

        elif seg.kind == "gdt":
            budget = self._budget_rec(plan)
            i_slot, s_slot = seg.srcs
            lamb, nu = seg.param("lamb"), seg.param("nu")

            def ident_plane(v):
                return jnp.full((n * hp, wp), jnp.asarray(v, self.dtype),
                                self.dtype)

            def init():
                # parked slots hold the kernel's halo identities: +inf
                # distance, zero image, −1 seed marker (clamped region)
                return (ident_plane(D_IDENT), ident_plane(I_IDENT),
                        ident_plane(S_IDENT), *sched0())

            def admit(state, slot, image, seeds):
                d, ip, sp, *sched = state
                img_t = jnp.pad(
                    image, ((0, hp - h), (0, wp - w)),
                    constant_values=_fill_value(fills[i_slot], image.dtype))
                sd_t = jnp.pad(
                    seeds, ((0, hp - h), (0, wp - w)),
                    constant_values=_fill_value(fills[s_slot], seeds.dtype))
                d0, i_t, s_t = gdt_stage(img_t, sd_t, nu)
                at = (slot * hp, 0)
                d = jax.lax.dynamic_update_slice(d, d0, at)
                ip = jax.lax.dynamic_update_slice(ip, i_t, at)
                sp = jax.lax.dynamic_update_slice(sp, s_t, at)
                return (d, ip, sp, *arm(tuple(sched), slot))

            def round_(state):
                d, ip, sp, *sched = state
                d, finished, sched = _scheduled_gdt(
                    d, ip, sp, plan, lamb, n_chunks,
                    resume=tuple(sched), budget=budget)
                return (d, ip, sp, *sched), finished, sched[2]

            def extract(state):
                return crops({seg.dsts[0]: state[0]})

            def chunks_of(state):
                return state[4]

        else:  # qdt
            budget = self._budget_qdt(plan)
            x_slot = seg.srcs[0]
            acc = qdt_acc_dtype(self.dtype)

            def init():
                return (plane(fills[x_slot], self.dtype),
                        jnp.zeros((n * hp, wp), acc),
                        jnp.zeros((n * hp, wp), jnp.int32), *sched0())

            def admit(state, slot, f):
                x, r, d, *sched = state
                x = write(x, slot, f, fills[x_slot])
                r = zero_rows(r, slot)
                d = zero_rows(d, slot)
                return (x, r, d, *arm(tuple(sched), slot))

            def round_(state):
                x, r, d, *sched = state
                x, r, d, finished, sched = _scheduled_qdt(
                    x, plan, n_chunks, rp=r, dp=d,
                    resume=tuple(sched), budget=budget)
                return (x, r, d, *sched), finished, sched[2]

            def extract(state):
                return crops({seg.dsts[0]: state[2], seg.dsts[1]: state[1]})

            def chunks_of(state):
                return state[4]

        session = SlotSession(
            n_slots=n, n_chunks=n_chunks, init=jax.jit(init),
            admit=jax.jit(admit), round=jax.jit(round_),
            extract=jax.jit(extract), chunks_of=chunks_of,
        )
        self._sessions[n_chunks] = session
        return session

    @property
    def all_plans(self) -> tuple:
        """Every ChainPlan this executable runs under (primary first)."""
        if self.seg_plans is not None:
            return tuple(p for _, p in self.seg_plans)
        return (self.plan,) if self.plan is not None else ()

    def stats(self) -> dict:
        """Static accounting of the compiled program (pads, launches,
        refills): what the fusion tests and the pipeline benchmarks
        count.  ``pads``/``crops`` are the pad/crop round-trips of one
        execution (including the re-band round-trips at specialized
        group boundaries); the legacy per-stage path pays one of each
        per elementary operator stage.  ``plans`` counts the plan
        groups, ``rebands`` the group boundaries values re-band
        across.  ``convergent``/``chunk_budget_rec``/
        ``chunk_budget_qdt`` describe the watchdog configuration the
        convergence-driven segments run under; the *runtime* verdict
        for a particular execution comes from :meth:`run_batch_stats`
        (or ``ReconstructStats.converged`` on the engine entry
        points)."""
        prog = self.program
        groups = self._exec_groups
        return {
            "backend": self.backend,
            "pads": sum(len(pads) for _, _, pads, _ in groups),
            "crops": sum(len(crops) for _, _, _, crops in groups),
            "launches": len(prog.kernel_segments),
            "refills": sum(1 for s in prog.segments if s.kind == "refill"),
            "fused_chain_len": prog.fused_chain_len,
            "plan_key": self.plan.key if self.plan is not None else None,
            "plans": len(groups),
            "rebands": max(0, len(groups) - 1),
            "convergent": prog.convergent,
            "chunk_budget_rec": (self._max_chunks_rec
                                 if self.plan is not None else None),
            "chunk_budget_qdt": (self._max_chunks_qdt
                                 if self.plan is not None else None),
        }

    def __repr__(self):
        return (f"Executable({self.program.sig_label()}, "
                f"shape=({self.n_images}, {self.height}, {self.width}), "
                f"dtype={self.dtype}, backend={self.backend!r})")

    # -- internals ---------------------------------------------------------

    def _check(self, a):
        # 2-D executables keep 2-D arrays end-to-end (XLA:CPU handles a
        # leading unit dim poorly); the pallas engine promotes privately.
        want = ((self.height, self.width) if self.was_2d
                else (self.n_images, self.height, self.width))
        if tuple(a.shape) != want:
            raise ValueError(
                f"input shape {a.shape} does not match the compiled "
                f"shape {want}"
            )
        if a.dtype != self.dtype:
            raise ValueError(
                f"input dtype {a.dtype} does not match the compiled "
                f"dtype {self.dtype}"
            )
        return a

    def _budget_rec(self, plan) -> int:
        return (self.max_chunks if self.max_chunks is not None
                else (self.height * self.width) // plan.fuse_k + 2)

    def _budget_qdt(self, plan) -> int:
        return (self.max_chunks if self.max_chunks is not None
                else max(self.height, self.width) // plan.fuse_k + 2)

    @functools.cached_property
    def _call_fn(self):
        return jax.jit(self._pipeline)

    @functools.cached_property
    def _run_fn(self):
        return jax.jit(self._run_segments)

    @functools.cached_property
    def _run_stats_fn(self):
        return jax.jit(self._run_segments_stats)

    def _pipeline(self, *inputs3):
        prog = self.program
        env = dict(zip(prog.input_names, inputs3))
        canonical = [eval_pointwise(e, env, {}, {}) for e in prog.prepare]
        cropped = self._run_segments(*canonical)
        kernel_vals = {
            (node, i): cropped[j]
            for j, (node, i, _) in enumerate(prog.kernel_outputs)
        }
        memo = {}
        return tuple(eval_pointwise(e, env, kernel_vals, memo)
                     for e in prog.result_exprs())

    def _run_segments(self, *canonical):
        if self.plan is None:
            return self._run_xla(canonical)
        return self._run_padded(canonical)

    def _run_segments_stats(self, *canonical):
        """Run phase + (N,) convergence vector + chunk utilization
        (see run_batch_stats)."""
        all_ok = jnp.ones((self.n_images,), jnp.bool_)
        zero = jnp.zeros((), jnp.int32)
        if self.plan is None:
            # the jnp oracle bodies iterate to their own fixpoint
            return self._run_xla(canonical), all_ok, zero, zero
        conv: list = []
        util: list = []
        outs = self._run_padded(canonical, conv, util)
        for vec in conv:
            all_ok = jnp.logical_and(all_ok, vec)
        busy, cap = zero, zero
        for b, c in util:
            busy = busy + b
            cap = cap + c
        return outs, all_ok, busy, cap

    # -- xla engine: the jnp oracle bodies, unpadded -----------------------

    def _run_xla(self, canonical):
        vals = {}
        for slot, x3 in zip(self.program.run_input_slots, canonical):
            vals[slot] = x3
        for seg in self.program.segments:
            if seg.kind == "refill":       # no padding exists to refill
                vals[seg.dsts[0]] = vals[seg.srcs[0]]
            elif seg.kind == "chain":
                body = (M.erode3 if seg.param("op") == "erode"
                        else M.dilate3)
                vals[seg.dsts[0]] = jax.lax.fori_loop(
                    0, seg.param("n"), lambda _, y, b=body: b(y),
                    vals[seg.srcs[0]],
                )
            elif seg.kind == "geodesic":
                step = (M.geodesic_erode if seg.param("op") == "erode"
                        else M.geodesic_dilate)
                vals[seg.dsts[0]] = step(vals[seg.srcs[0]],
                                         vals[seg.srcs[1]], seg.param("n"))
            elif seg.kind == "reconstruct":
                rec = (M.erode_reconstruct if seg.param("op") == "erode"
                       else M.dilate_reconstruct)
                vals[seg.dsts[0]] = rec(vals[seg.srcs[0]], vals[seg.srcs[1]])
            elif seg.kind == "qdt":
                d, r = OPS.qdt_raw(vals[seg.srcs[0]])
                vals[seg.dsts[0]], vals[seg.dsts[1]] = d, r
            elif seg.kind == "gdt":
                from repro.kernels.ops import gdt_fixpoint_xla

                # Jacobi advances every shortest path by ≥1 edge per
                # iteration; H·W bounds any simple path's length.
                vals[seg.dsts[0]] = gdt_fixpoint_xla(
                    vals[seg.srcs[0]], vals[seg.srcs[1]],
                    seg.param("lamb"), seg.param("nu"),
                    self.height * self.width + 2,
                )
            elif seg.kind == "point":
                env = {f"__p{j}": vals[s]
                       for j, s in enumerate(seg.srcs)}
                vals[seg.dsts[0]] = eval_pointwise(
                    seg.param("expr"), env, {}, {})
            else:  # pragma: no cover
                raise AssertionError(seg.kind)
        return tuple(vals[s] for s in self.program.run_outputs)

    # -- pallas engine: one padded program per plan group ------------------

    @property
    def _groups(self) -> tuple:
        """``(segment_indices, plan)`` plan groups, in execution order."""
        if self.seg_plans is not None:
            return self.seg_plans
        if self.plan is None:
            return ()
        return ((tuple(range(len(self.program.segments))), self.plan),)

    @functools.cached_property
    def _exec_groups(self) -> tuple:
        """Static execution schedule: per group, the ``(slot, fill)``
        pads to apply on entry (first-consume order) and the dst slots
        to crop back to unpadded form on exit (consumed by a later
        group, or a run output)."""
        prog = self.program
        segs = prog.segments
        groups = self._groups
        # abstract pad state a slot's cropped value must be re-padded
        # with: inputs carry their declared fill, refill outputs their
        # target fill; kernel outputs are dirty (None) — only a masked
        # refill may consume them across a boundary, and its own fill
        # is then used (the mask overwrites the pad region regardless).
        fill_state: dict = dict(zip(prog.run_input_slots, prog.run_fills))
        for seg in segs:
            for d in seg.dsts:
                fill_state[d] = (seg.param("fill") if seg.kind == "refill"
                                 else None)
        out = []
        for gi, (idxs, plan) in enumerate(groups):
            local: set = set()
            pad_map: dict = {}
            for i in idxs:
                seg = segs[i]
                for s in seg.srcs:
                    if s in local or s in pad_map:
                        continue
                    pad_map[s] = fill_state.get(s) or _seg_need_fill(seg)
                local.update(seg.dsts)
            later: set = set(prog.run_outputs)
            for idxs2, _ in groups[gi + 1:]:
                for i in idxs2:
                    later.update(segs[i].srcs)
            crops = tuple(d for i in idxs for d in segs[i].dsts
                          if d in later)
            out.append((tuple(idxs), plan, tuple(pad_map.items()), crops))
        return tuple(out)

    def _image_mask(self, plan):
        """(TOTAL_H, W_pad) bool: True inside the real image regions."""
        mask = self._mask_cache.get(plan.key)
        if mask is None:
            rows = (jnp.arange(plan.n_images * plan.height_pad)
                    % plan.height_pad) < self.height
            cols = jnp.arange(plan.width_pad) < self.width
            mask = rows[:, None] & cols[None, :]
            self._mask_cache[plan.key] = mask
        return mask

    def _run_padded(self, canonical, conv: list | None = None,
                    util: list | None = None):
        from repro.kernels.ops import _crop3, _pad, _stacked

        prog = self.program
        vals3 = {
            slot: (x[None] if x.ndim == 2 else x)
            for slot, x in zip(prog.run_input_slots, canonical)
        }
        for idxs, plan, pads, crops in self._exec_groups:
            vals2 = {}
            for s, fill in pads:
                x3 = vals3[s]
                vals2[s] = _stacked(_pad(x3, plan,
                                         _fill_value(fill, x3.dtype)))
            for i in idxs:
                self._pallas_seg(prog.segments[i], vals2, plan, conv,
                                 util)
            for d in crops:
                vals3[d] = _crop3(vals2[d], self.n_images, self.height,
                                  self.width)
        outs = tuple(vals3[s] for s in prog.run_outputs)
        return tuple(o[0] if self.was_2d else o for o in outs)

    def _pallas_seg(self, seg, vals, plan, conv: list | None = None,
                    util: list | None = None):
        from repro.kernels.ops import (_raster_gdt, _scheduled_gdt,
                                       _scheduled_qdt,
                                       _scheduled_reconstruct, gdt_stage)

        if seg.kind == "refill":
            x2 = vals[seg.srcs[0]]
            vals[seg.dsts[0]] = jnp.where(
                self._image_mask(plan), x2,
                _fill_value(seg.param("fill"), x2.dtype),
            )
        elif seg.kind == "chain":
            vals[seg.dsts[0]] = self._chain2(
                vals[seg.srcs[0]], seg.param("op"), seg.param("n"), plan)
        elif seg.kind == "geodesic":
            vals[seg.dsts[0]] = self._geodesic2(
                vals[seg.srcs[0]], vals[seg.srcs[1]],
                seg.param("op"), seg.param("n"), plan)
        elif seg.kind == "reconstruct":
            out, it, _, _, img_conv, state = _scheduled_reconstruct(
                vals[seg.srcs[0]], vals[seg.srcs[1]], plan,
                seg.param("op"), self._budget_rec(plan), False,
            )
            vals[seg.dsts[0]] = out
            if conv is not None:
                conv.append(img_conv)
            if util is not None:
                # busy = chunks each image actually consumed; capacity =
                # chunks the batch held every slot for (chunk-weighted
                # work occupancy — parked converged slots are waste)
                util.append((jnp.sum(state[1]),
                             it * jnp.int32(plan.n_images)))
        elif seg.kind == "qdt":
            _, r, d, img_conv, state = _scheduled_qdt(
                vals[seg.srcs[0]], plan, self._budget_qdt(plan))
            vals[seg.dsts[0]], vals[seg.dsts[1]] = d, r
            if conv is not None:
                conv.append(img_conv)
            if util is not None:
                util.append((jnp.sum(state[1]),
                             jnp.max(state[1]) * jnp.int32(plan.n_images)))
        elif seg.kind == "gdt":
            d0, ip, sp = gdt_stage(vals[seg.srcs[0]], vals[seg.srcs[1]],
                                   seg.param("nu"))
            budget = self._budget_rec(plan)
            if plan.schedule == "raster":
                d, rounds, img_conv = _raster_gdt(
                    d0, ip, sp, plan, seg.param("lamb"), budget)
                if util is not None:
                    # the sweeps run every image every round — full
                    # occupancy by construction, no parked-slot slack
                    swept = rounds * jnp.int32(plan.n_images)
                    util.append((swept, swept))
            else:
                d, img_conv, state = _scheduled_gdt(
                    d0, ip, sp, plan, seg.param("lamb"), budget)
                if util is not None:
                    util.append((jnp.sum(state[1]),
                                 jnp.max(state[1])
                                 * jnp.int32(plan.n_images)))
            vals[seg.dsts[0]] = d
            if conv is not None:
                conv.append(img_conv)
        elif seg.kind == "point":
            env = {f"__p{j}": vals[s] for j, s in enumerate(seg.srcs)}
            vals[seg.dsts[0]] = eval_pointwise(seg.param("expr"), env, {}, {})
        else:  # pragma: no cover
            raise AssertionError(seg.kind)

    def _chain2(self, x2, op, n, plan):
        from repro.kernels.ops import _INTERPRET, _stacked, _unstacked

        full, rem = divmod(n, plan.fuse_k)
        if full:
            def chunk(x, _):
                return chain_step(
                    x, op=op, fuse_k=plan.fuse_k, band_h=plan.band_h,
                    interpret=_INTERPRET, bands_per_image=plan.n_bands,
                ), None
            x2, _ = jax.lax.scan(chunk, x2, None, length=full)
        if rem:
            # jnp tail on the 3-D view: axis-polymorphic per image, and
            # the pad region continues the identity-padded semantics.
            body = M.erode3 if op == "erode" else M.dilate3
            x3 = jax.lax.fori_loop(
                0, rem, lambda _, y, b=body: b(y),
                _unstacked(x2, self.n_images),
            )
            x2 = _stacked(x3)
        return x2

    def _geodesic2(self, f2, m2, op, n, plan):
        from repro.kernels.ops import _INTERPRET, _stacked, _unstacked

        full, rem = divmod(n, plan.fuse_k)
        if full:
            def chunk(x, _):
                y, _ = geodesic_chain_step(
                    x, m2, op=op, fuse_k=plan.fuse_k, band_h=plan.band_h,
                    interpret=_INTERPRET, bands_per_image=plan.n_bands,
                )
                return y, None
            f2, _ = jax.lax.scan(chunk, f2, None, length=full)
        if rem:
            step = (M.geodesic_erode1 if op == "erode"
                    else M.geodesic_dilate1)
            m3 = _unstacked(m2, self.n_images)
            f3 = jax.lax.fori_loop(
                0, rem, lambda _, y: step(y, m3),
                _unstacked(f2, self.n_images),
            )
            f2 = _stacked(f3)
        return f2
