"""Morphology expression graphs: every operator as a composable node.

An :class:`Expr` is an immutable, hashable DAG node.  Leaves are named
inputs (``E.input("f")``); interior nodes are either *kernel* nodes —
erode/dilate chains, geodesic chains, reconstruction, the QDT — or
*pointwise* nodes (saturating arithmetic, residuals, marker
derivations, the QDT η-regularization).  The paper's composite
operators are then plain graph constructions::

    f = E.input("f")
    hmax   = E.reconstruct(E.sat_sub(f, 40), f, op="dilate")
    dome   = E.sub(f, hmax)
    obr    = E.reconstruct(f >> E.erode(4), f, op="dilate")
    asf2   = f >> E.erode(1) >> E.dilate(1) >> E.dilate(1) >> E.erode(1) \
               >> E.erode(2) >> E.dilate(2) >> E.dilate(2) >> E.erode(2)

``>>`` pipes a value through a unary constructor; unary constructors
called without their operand return a :class:`Pipe` so they compose
point-free (``E.erode(2) >> E.dilate(2)``).  Expressions carry no
shapes, dtypes or backends — those bind at :func:`repro.api.compile`
time, which lowers the graph (``repro.api.lower``) into one padded
program per compiled :class:`~repro.api.executable.Executable`.

Because an ``Expr`` is a frozen dataclass of hashables, it *is* the
cache key of the compile layer, and its lowered run-phase signature is
what ``repro.serve`` buckets on.
"""
from __future__ import annotations

import dataclasses

#: Node kinds executed inside the padded kernel program.
KERNEL_KINDS = ("erode", "dilate", "geodesic", "reconstruct", "qdt", "gdt")

#: Pointwise / per-image nodes, evaluated unpadded (prepare or finalize).
POINTWISE_KINDS = ("input", "sat_sub", "sat_add", "sub", "ge", "hfill_marker",
                   "raobj_marker", "qdt_regularize", "pick")

#: Outputs per node kind (1 unless listed).
OUT_ARITY = {"qdt": 2}


@dataclasses.dataclass(frozen=True)
class Expr:
    """One node of a morphology expression DAG.

    ``kind`` names the operation, ``args`` the child expressions and
    ``params`` the scalar parameters as sorted ``(name, value)`` pairs.
    Hashable by construction — equality is structural, which is exactly
    what the compile cache and the serve bucketer key on.
    """

    kind: str
    args: tuple = ()
    params: tuple = ()

    def __post_init__(self):
        if self.kind not in KERNEL_KINDS + POINTWISE_KINDS:
            raise ValueError(f"unknown expression kind {self.kind!r}")
        for a in self.args:
            if not isinstance(a, Expr):
                raise TypeError(
                    f"{self.kind}: expression arguments must be Expr, "
                    f"got {type(a).__name__}"
                )

    # -- sugar -------------------------------------------------------------

    def __rshift__(self, other):
        """``expr >> E.erode(2)``: pipe this value into a unary stage."""
        if isinstance(other, Pipe):
            return other(self)
        return NotImplemented

    def __sub__(self, other):
        if isinstance(other, Expr):
            return E.sub(self, other)
        return NotImplemented

    @property
    def n_outputs(self) -> int:
        return OUT_ARITY.get(self.kind, 1)

    def param(self, name):
        return dict(self.params)[name]

    def label(self) -> str:
        """Compact human-readable form (metrics / repr)."""
        p = ",".join(f"{k}={v}" for k, v in self.params)
        if self.kind == "input":
            return f"%{self.param('name')}"
        inner = ",".join(a.label() for a in self.args)
        sep = ";" if inner and p else ""
        return f"{self.kind}({inner}{sep}{p})"


@dataclasses.dataclass(frozen=True)
class Pipe:
    """A unary stage awaiting its operand (point-free composition)."""

    stages: tuple  # of callables Expr -> Expr, applied left to right

    def __call__(self, x: Expr) -> Expr:
        for stage in self.stages:
            x = stage(x)
        return x

    def __rshift__(self, other):
        if isinstance(other, Pipe):
            return Pipe(self.stages + other.stages)
        return NotImplemented


def _params(**kw) -> tuple:
    return tuple(sorted(kw.items()))


def _check_op(op: str) -> str:
    if op not in ("erode", "dilate"):
        raise ValueError(f"op must be 'erode' or 'dilate', got {op!r}")
    return op


class E:
    """Expression constructors — the public vocabulary of the API."""

    # -- leaves ------------------------------------------------------------

    @staticmethod
    def input(name: str = "f") -> Expr:
        return Expr("input", params=_params(name=str(name)))

    # -- kernel nodes ------------------------------------------------------

    @staticmethod
    def erode(s: int, x: Expr | None = None):
        """ε_s as a chain of s elementary 3×3 erosions (paper Eq. 4)."""
        if s < 0:
            raise ValueError(f"chain length must be >= 0, got {s}")
        if x is None:
            return Pipe((lambda v, s=s: E.erode(s, v),))
        return Expr("erode", (x,), _params(s=int(s))) if s else x

    @staticmethod
    def dilate(s: int, x: Expr | None = None):
        if s < 0:
            raise ValueError(f"chain length must be >= 0, got {s}")
        if x is None:
            return Pipe((lambda v, s=s: E.dilate(s, v),))
        return Expr("dilate", (x,), _params(s=int(s))) if s else x

    @staticmethod
    def opening(s: int, x: Expr | None = None):
        """γ_s = δ_s ∘ ε_s (a two-segment sub-graph, not a new kind)."""
        if x is None:
            return Pipe((lambda v, s=s: E.opening(s, v),))
        return E.dilate(s, E.erode(s, x))

    @staticmethod
    def closing(s: int, x: Expr | None = None):
        if x is None:
            return Pipe((lambda v, s=s: E.closing(s, v),))
        return E.erode(s, E.dilate(s, x))

    @staticmethod
    def geodesic(marker: Expr, mask: Expr, n: int, op: str = "erode") -> Expr:
        """n elementary geodesic steps (fixed length, Eq. 4)."""
        if n < 1:
            raise ValueError(f"geodesic chain length must be >= 1, got {n}")
        return Expr("geodesic", (marker, mask),
                    _params(n=int(n), op=_check_op(op)))

    @staticmethod
    def reconstruct(marker: Expr | None = None, mask: Expr | None = None,
                    op: str = "dilate"):
        """ε_rec / δ_rec to convergence (Eq. 5, Alg. 4).

        Fully applied with (marker, mask); with ``marker`` omitted it
        returns a pipe taking the marker: ``expr >> E.reconstruct(
        mask=f, op="dilate")``.
        """
        _check_op(op)
        if marker is None:
            if mask is None:
                raise ValueError("reconstruct needs at least a mask")
            return Pipe((lambda v, m=mask, o=op: E.reconstruct(v, m, o),))
        if mask is None:
            raise ValueError("reconstruct needs an explicit mask")
        return Expr("reconstruct", (marker, mask), _params(op=op))

    @staticmethod
    def qdt(x: Expr | None = None):
        """Raw quasi-distance planes d(f), r(f) (Eq. 13) — two outputs."""
        if x is None:
            return Pipe((lambda v: E.qdt(v),))
        return Expr("qdt", (x,))

    @staticmethod
    def gdt(image: Expr, seeds: Expr, lamb=1.0, nu=1e6) -> Expr:
        """Generalised geodesic distance transform (FastGeodis-style).

        The fixpoint of the grey-weighted relaxation over the 8-connected
        neighbourhood with additive DTOCS cost ``w(p, q) = 1 +
        lamb·|I(p) − I(q)|``, initialised from soft seeds ``S ∈ [0, 1]``
        as ``D₀ = nu·(1 − S)``.  ``lamb = 0`` degrades to the Chebyshev
        distance to the seed set; ``nu`` bounds the unseeded plateau.
        Float dtypes only (the distance plane is a float lattice).
        """
        if lamb < 0:
            raise ValueError(f"lamb must be >= 0, got {lamb}")
        if nu <= 0:
            raise ValueError(f"nu must be > 0, got {nu}")
        return Expr("gdt", (image, seeds),
                    _params(lamb=float(lamb), nu=float(nu)))

    # -- pointwise nodes ---------------------------------------------------

    @staticmethod
    def sat_sub(x: Expr, h) -> Expr:
        """x - h clamped to the dtype's range."""
        return Expr("sat_sub", (x,), _params(h=float(h)))

    @staticmethod
    def sat_add(x: Expr, h) -> Expr:
        return Expr("sat_add", (x,), _params(h=float(h)))

    @staticmethod
    def sub(a: Expr, b: Expr) -> Expr:
        """a - b (plain dtype arithmetic, e.g. DOME's residual)."""
        return Expr("sub", (a, b))

    @staticmethod
    def ge(x: Expr, t) -> Expr:
        """(x >= t) as 0/1 in x's dtype (thresholding / mask derivation)."""
        return Expr("ge", (x,), _params(t=float(t)))

    @staticmethod
    def hfill_marker(x: Expr) -> Expr:
        """m_HFILL (Eq. 9) — per-image reduction, unpadded by contract."""
        return Expr("hfill_marker", (x,))

    @staticmethod
    def raobj_marker(x: Expr) -> Expr:
        """m_RAOBJ (Eq. 11) — per-image reduction, unpadded by contract."""
        return Expr("raobj_marker", (x,))

    @staticmethod
    def qdt_regularize(d: Expr) -> Expr:
        """η-iteration (Eq. 14) until 1-Lipschitz (Eq. 15)."""
        return Expr("qdt_regularize", (d,))

    @staticmethod
    def pick(x: Expr, i: int) -> Expr:
        """Select output ``i`` of a multi-output node (the QDT planes).

        Normalizing: picking the only output of a single-output node is
        the node itself, so ``pick(pick(qdt(f), 0), 0)`` collapses and
        every consumer sees one canonical graph.
        """
        if not 0 <= i < x.n_outputs:
            raise ValueError(
                f"pick({i}) out of range for {x.kind} ({x.n_outputs} outputs)"
            )
        if x.n_outputs == 1:
            return x
        return Expr("pick", (x,), _params(i=int(i)))


# ---------------------------------------------------------------------------
# composite builders (operator sugar used by core.operators / repro.serve)
# ---------------------------------------------------------------------------


def hmax_expr(h, f: Expr | None = None) -> Expr:
    f = E.input("f") if f is None else f
    return E.reconstruct(E.sat_sub(f, h), f, op="dilate")


def dome_expr(h, f: Expr | None = None) -> Expr:
    f = E.input("f") if f is None else f
    return E.sub(f, hmax_expr(h, f))


def hfill_expr(f: Expr | None = None) -> Expr:
    f = E.input("f") if f is None else f
    return E.reconstruct(E.hfill_marker(f), f, op="erode")


def raobj_expr(f: Expr | None = None) -> Expr:
    f = E.input("f") if f is None else f
    return E.sub(f, E.reconstruct(E.raobj_marker(f), f, op="dilate"))


def opening_by_reconstruction_expr(s: int, f: Expr | None = None) -> Expr:
    """γ_rec^s: the erosion chain and the reconstruction share one
    padded program when compiled (the tentpole fusion case)."""
    f = E.input("f") if f is None else f
    return E.reconstruct(E.erode(s, f), f, op="dilate")


def asf_expr(s: int, f: Expr | None = None) -> Expr:
    """ASF_s (Eq. 20): alternating γ_k/φ_k — a 4s-stage chain whose
    adjacent same-op runs fuse into 2s+1 launches when lowered."""
    if s < 1:
        raise ValueError(f"ASF scale must be >= 1, got {s}")
    out = E.input("f") if f is None else f
    for k in range(1, s + 1):
        out = E.closing(k, E.opening(k, out))
    return out


def qdt_l1_expr(f: Expr | None = None) -> Expr:
    """L1-regularized quasi-distance transform d_L1(f)."""
    f = E.input("f") if f is None else f
    return E.qdt_regularize(E.pick(E.qdt(f), 0))
