"""Lowering: expression DAG → three-phase :class:`Program`.

The lowered form mirrors the serve pipeline's stage split (and is what
``repro.serve.registry`` now derives its ``OpSpec`` stages from):

``prepare``
    pointwise / per-image sub-expressions whose transitive dependencies
    are input leaves only — marker derivation.  Evaluated *unpadded*
    (per-image reductions like ``hfill_marker`` must never see
    padding), producing the program's canonical run inputs.
``run``
    the padded kernel program: a linear list of :class:`RunSeg`
    register-machine segments over padded, vertically stacked slots.
    Adjacent same-op erode/dilate runs are fused into one ``chain``
    segment; intermediates stay padded across segments — when a
    consumer needs a different absorbing identity in the pad region
    than the producer left there, a cheap masked ``refill`` segment is
    inserted instead of a crop/re-pad round-trip.  One
    :class:`~repro.core.chain.ChainPlan` schedules every segment.
``finalize``
    the pointwise remainder of the graph, evaluated on the *cropped*
    run outputs plus the original inputs (residuals like DOME's
    ``f - hmax``, the QDT η-regularization).

``Program.run_sig`` is the hashable identity of the run phase alone —
two operators whose run phases lower identically (e.g. HMAX and DOME,
whose difference is pure prepare/finalize) share it, which is what lets
the serve bucketer co-batch them on ``Executable.key``.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.api.expr import E, Expr, KERNEL_KINDS
from repro.core import operators as OPS

#: Absorbing pad identity each kernel consumer requires of an operand.
_IDENT = {"erode": "hi", "dilate": "lo"}

#: Same-shaped operand planes each segment kind keeps resident in VMEM
#: (drives the shared ChainPlan's ``n_images_resident``).
_RESIDENT = {"chain": 1, "geodesic": 2, "reconstruct": 2, "qdt": 3,
             "gdt": 3, "point": 1}

#: Pointwise kinds a ``point`` run segment may contain: strictly
#: elementwise maps, safe to evaluate on padded slots (the pad region
#: comes out dirty and the dst's ``None`` pad state forces a refill
#: before any kernel consumer).  Per-image reductions
#: (``hfill_marker``/``raobj_marker``) and crop-contract nodes
#: (``qdt_regularize``) stay un-lowerable between kernels.
_POINT_KINDS = ("sat_sub", "sat_add", "sub", "ge")


@dataclasses.dataclass(frozen=True)
class RunSeg:
    """One run-phase segment: reads ``srcs`` slots, writes ``dsts``."""

    kind: str       # "chain" | "geodesic" | "reconstruct" | "qdt" | "gdt"
                    # | "point" | "refill"
    srcs: tuple
    dsts: tuple
    params: tuple   # sorted (name, value) pairs

    def param(self, name):
        return dict(self.params)[name]

    def short(self) -> str:
        p = dict(self.params)
        if self.kind == "chain":
            return f"{p['op'][:2]}{p['n']}"
        if self.kind == "refill":
            return f"rf:{p['fill']}"
        if self.kind == "point":
            return "pt"
        tag = ":".join(str(v) for _, v in self.params)
        return f"{self.kind[:3]}{':' + tag if tag else ''}"


@dataclasses.dataclass(frozen=True)
class Program:
    """A lowered expression: prepare exprs, run segments, finalize root."""

    expr: Expr                       # the root expression (finalize walks it)
    input_names: tuple               # user-facing leaves, DFS-preorder
    prepare: tuple                   # pre-Expr per canonical run input
    run_fills: tuple                 # "hi"/"lo" per canonical run input
    run_input_slots: tuple           # slot id per canonical run input
    segments: tuple                  # RunSeg, in execution order
    run_outputs: tuple               # slot ids cropped and handed to finalize
    kernel_outputs: tuple            # ((kernel Expr, out_idx, slot), ...)
    n_outputs: int

    @property
    def run_sig(self) -> tuple:
        """Hashable identity of the run phase (bucket/cache keying)."""
        return (
            ("in", self.run_input_slots, self.run_fills),
            *((s.kind, s.params, s.srcs, s.dsts) for s in self.segments),
            ("out", self.run_outputs),
        )

    @property
    def kernel_segments(self) -> tuple:
        """True padded-kernel segments: refills are plumbing and
        ``point`` segments are exact on the real region by construction
        (strictly elementwise), so neither counts against pad safety."""
        return tuple(s for s in self.segments
                     if s.kind not in ("refill", "point"))

    @property
    def pad_safe(self) -> bool:
        """Whether enlarging the image with each canonical input's fill
        is exact end-to-end: true exactly for single-phase programs (one
        kernel segment); multi-phase programs mix identities, so no
        single bucket fill is absorbing across them."""
        return len(self.kernel_segments) == 1

    @property
    def convergent(self) -> bool:
        return any(s.kind in ("reconstruct", "qdt", "gdt")
                   for s in self.segments)

    @property
    def n_resident(self) -> int:
        return max((_RESIDENT.get(s.kind, 1) for s in self.segments),
                   default=1)

    @property
    def max_chain_len(self) -> int | None:
        lens = [s.param("n") for s in self.segments if s.kind == "chain"]
        return max(lens) if lens else None

    @property
    def fused_chain_len(self) -> int:
        """Total elementary fixed-chain filters across chain segments."""
        return sum(s.param("n") for s in self.segments if s.kind == "chain")

    def sig_label(self) -> str:
        """Compact human-readable run signature (metrics bucket labels)."""
        segs = [s.short() for s in self.segments if s.kind != "refill"]
        if not segs:
            return "pointwise"
        if len(segs) > 4:
            segs = segs[:3] + [f"+{len(segs) - 3}"]
        return "-".join(segs)

    def result_exprs(self) -> tuple:
        """The root split into single-output expressions."""
        if self.expr.kind in KERNEL_KINDS and self.expr.n_outputs > 1:
            return tuple(E.pick(self.expr, i)
                         for i in range(self.expr.n_outputs))
        return (self.expr,)


class LoweringError(ValueError):
    """The expression cannot be split into prepare → run → finalize."""


def _consumer_counts(root: Expr) -> dict:
    counts: dict[Expr, int] = {}
    seen = set()
    stack = [root]
    while stack:
        node = stack.pop()
        for a in node.args:
            counts[a] = counts.get(a, 0) + 1
            if a not in seen:
                seen.add(a)
                stack.append(a)
    return counts


def _input_names(root: Expr) -> tuple:
    names, seen = [], set()

    def walk(node):
        if node in seen:
            return
        seen.add(node)
        if node.kind == "input":
            name = node.param("name")
            if name not in names:
                names.append(name)
        for a in node.args:
            walk(a)

    walk(root)
    return tuple(names)


@functools.lru_cache(maxsize=1024)
def _is_pre(node: Expr) -> bool:
    """True when the node is pointwise over input leaves only."""
    if node.kind in KERNEL_KINDS:
        return False
    return all(_is_pre(a) for a in node.args)


class _Lowerer:
    def __init__(self, root: Expr):
        self.root = root
        self.counts = _consumer_counts(root)
        self.segments: list[RunSeg] = []
        self.prepare: list[Expr] = []
        self.fills: list[str] = []
        self.input_slots: list[int] = []
        self.pre_slot: dict[Expr, int] = {}
        self.kernel_slots: dict[Expr, tuple] = {}
        self.point_slots: dict[Expr, int] = {}
        self.pad_state: dict[int, str | None] = {}
        self.refilled: dict[tuple, int] = {}
        self.next_slot = 0

    def _alloc(self, state):
        slot = self.next_slot
        self.next_slot += 1
        self.pad_state[slot] = state
        return slot

    def _operand(self, node: Expr, fill: str) -> int:
        """Slot holding ``node``'s value with pad region == ``fill``."""
        if _is_pre(node):
            slot = self.pre_slot.get(node)
            if slot is None:
                # NB: prepare slots are *not* guaranteed to be 0..n-1 —
                # a fresh prepare leaf first requested after a kernel
                # allocation (e.g. the mask of geodesic(erode(a), b))
                # lands on a later slot id, which is why the executable
                # binds canonical inputs through ``run_input_slots``.
                slot = self._alloc(fill)
                self.pre_slot[node] = slot
                self.prepare.append(node)
                self.fills.append(fill)
                self.input_slots.append(slot)
        elif node.kind in KERNEL_KINDS:
            slot = self._kernel(node)[0]
        elif node.kind == "pick" and node.args[0].kind in KERNEL_KINDS:
            slot = self._kernel(node.args[0])[node.param("i")]
        else:
            slot = self._point(node)
        if self.pad_state[slot] == fill:
            return slot
        refill = self.refilled.get((slot, fill))
        if refill is None:
            refill = self._alloc(fill)
            self.refilled[(slot, fill)] = refill
            self.segments.append(
                RunSeg("refill", (slot,), (refill,), (("fill", fill),))
            )
        return refill

    def _kernel(self, node: Expr) -> tuple:
        """Lower a kernel node (memoized); returns its output slots."""
        slots = self.kernel_slots.get(node)
        if slots is not None:
            return slots
        kind = node.kind
        if kind in ("erode", "dilate"):
            # fuse the run of same-op ancestors this node tops, as long
            # as each intermediate has no other consumer
            total, child = node.param("s"), node.args[0]
            while (child.kind == kind and self.counts.get(child, 0) == 1):
                total += child.param("s")
                child = child.args[0]
            src = self._operand(child, _IDENT[kind])
            dst = self._alloc(None)
            seg = RunSeg("chain", (src,), (dst,),
                         (("n", total), ("op", kind)))
            slots = (dst,)
        elif kind in ("reconstruct", "geodesic"):
            fill = _IDENT[node.param("op")]
            msrc = self._operand(node.args[0], fill)
            ksrc = self._operand(node.args[1], fill)
            dst = self._alloc(None)
            seg = RunSeg(kind, (msrc, ksrc), (dst,), node.params)
            slots = (dst,)
        elif kind == "qdt":
            src = self._operand(node.args[0], "hi")
            d_slot, r_slot = self._alloc(None), self._alloc(None)
            seg = RunSeg("qdt", (src,), (d_slot, r_slot), ())
            slots = (d_slot, r_slot)
        elif kind == "gdt":
            # Both operands pad with the float lattice bottom (−inf):
            # the driver's ``gdt_stage`` reads it back as the pad marker
            # and derives the sanitized resident planes from it.
            isrc = self._operand(node.args[0], "lo")
            ssrc = self._operand(node.args[1], "lo")
            dst = self._alloc(None)
            seg = RunSeg("gdt", (isrc, ssrc), (dst,), node.params)
            slots = (dst,)
        else:  # pragma: no cover - Expr.__post_init__ guards kinds
            raise LoweringError(f"unhandled kernel kind {kind!r}")
        self.segments.append(seg)
        self.kernel_slots[node] = slots
        return slots

    def _point(self, node: Expr) -> int:
        """Lower a strictly-pointwise expression over kernel outputs as
        one ``point`` run segment (memoized).

        The segment's single param is a *relative* expression whose
        leaves ``__p0 … __pn`` bind to ``srcs`` in order; the executable
        evaluates it elementwise on the padded slots.  The dst's pad
        region is dirty (``None`` state), so the ordinary refill
        machinery masks it before any kernel consumer reads it.
        """
        slot = self.point_slots.get(node)
        if slot is not None:
            return slot
        srcs: list[int] = []

        def rel(n: Expr) -> Expr:
            if n.kind in KERNEL_KINDS:
                src = self._kernel(n)[0]
            elif n.kind == "pick" and n.args[0].kind in KERNEL_KINDS:
                src = self._kernel(n.args[0])[n.param("i")]
            elif _is_pre(n):
                src = self._operand(n, "lo")
            else:
                if n.kind not in _POINT_KINDS:
                    raise LoweringError(
                        f"{n.kind} depends on a kernel output but is not "
                        "an elementwise map — it cannot run between "
                        "kernels (compute it as a separate compiled "
                        "expression)"
                    )
                return Expr(n.kind, tuple(rel(a) for a in n.args), n.params)
            if src not in srcs:
                srcs.append(src)
            return E.input(f"__p{srcs.index(src)}")

        expr = rel(node)
        dst = self._alloc(None)
        self.segments.append(
            RunSeg("point", tuple(srcs), (dst,), (("expr", expr),))
        )
        self.point_slots[node] = dst
        return dst

    def _collect_outputs(self, node: Expr, needed: list, seen: set):
        """Kernel outputs the finalize evaluation of ``node`` reads."""
        if node in seen:
            return
        seen.add(node)
        if node.kind in KERNEL_KINDS:
            slots = self._kernel(node)
            for i in range(node.n_outputs):
                if (node, i) not in needed:
                    needed.append((node, i))
            return
        if node.kind == "pick" and node.args[0].kind in KERNEL_KINDS:
            child, i = node.args[0], node.param("i")
            self._kernel(child)
            if (child, i) not in needed:
                needed.append((child, i))
            return
        for a in node.args:
            self._collect_outputs(a, needed, seen)

    def lower(self) -> Program:
        self._check_no_kernel_under_pointwise_operand(self.root)
        needed: list = []
        self._collect_outputs(self.root, needed, set())
        kernel_outputs = tuple(
            (node, i, self.kernel_slots[node][i]) for node, i in needed
        )
        return Program(
            expr=self.root,
            input_names=_input_names(self.root),
            prepare=tuple(self.prepare),
            run_fills=tuple(self.fills),
            run_input_slots=tuple(self.input_slots),
            segments=tuple(self.segments),
            run_outputs=tuple(slot for _, _, slot in kernel_outputs),
            kernel_outputs=kernel_outputs,
            n_outputs=self.root.n_outputs,
        )

    def _check_no_kernel_under_pointwise_operand(self, root: Expr):
        """Kernel operands must resolve to run slots: prepare values,
        (possibly picked) kernel outputs, or strictly-elementwise maps
        of those (lowered as ``point`` segments).  A *non*-elementwise
        pointwise node between kernels — a per-image reduction or a
        crop-contract node like ``qdt_regularize`` — has nowhere to run
        without leaving the padded program, so it raises here, before
        any slot is allocated."""
        seen = set()

        def check_point(n):
            # mirrors _point's recursion, validating without allocating
            if (n.kind in KERNEL_KINDS or _is_pre(n)
                    or (n.kind == "pick"
                        and n.args[0].kind in KERNEL_KINDS)):
                return
            if n.kind not in _POINT_KINDS:
                raise LoweringError(
                    f"{n.kind} depends on a kernel output but is not an "
                    "elementwise map — such pointwise stages between "
                    "kernels are not lowerable (compute it as a "
                    "separate compiled expression)"
                )
            for a in n.args:
                check_point(a)

        def walk(node):
            if node in seen:
                return
            seen.add(node)
            if node.kind in KERNEL_KINDS:
                for a in node.args:
                    check_point(a)
            for a in node.args:
                walk(a)

        walk(root)


@functools.lru_cache(maxsize=512)
def lower(expr: Expr) -> Program:
    """Lower ``expr`` into a :class:`Program` (memoized on the graph)."""
    return _Lowerer(expr).lower()


# ---------------------------------------------------------------------------
# pointwise evaluation (shared by prepare and finalize)
# ---------------------------------------------------------------------------


def eval_pointwise(node: Expr, inputs: dict, kernel_vals: dict, memo: dict):
    """Evaluate the pointwise region of the graph with jnp.

    ``inputs`` maps leaf names to arrays; ``kernel_vals`` maps
    ``(kernel Expr, out_idx)`` to already-computed (cropped) arrays —
    empty for the prepare phase, whose exprs have no kernel deps.
    """
    if node in memo:
        return memo[node]
    kind = node.kind
    if kind in KERNEL_KINDS:
        val = kernel_vals[(node, 0)]
    elif kind == "pick":
        child = node.args[0]
        if child.kind in KERNEL_KINDS:
            val = kernel_vals[(child, node.param("i"))]
        else:  # pragma: no cover - pointwise nodes are single-output
            raise LoweringError(f"pick of single-output {child.kind}")
    elif kind == "input":
        val = inputs[node.param("name")]
    else:
        args = [eval_pointwise(a, inputs, kernel_vals, memo)
                for a in node.args]
        if kind == "sat_sub":
            val = OPS.sat_sub(args[0], node.param("h"))
        elif kind == "sat_add":
            val = OPS.sat_add(args[0], node.param("h"))
        elif kind == "sub":
            val = args[0] - args[1]
        elif kind == "ge":
            val = (args[0] >= node.param("t")).astype(args[0].dtype)
        elif kind == "hfill_marker":
            val = OPS.hfill_marker(args[0])
        elif kind == "raobj_marker":
            val = OPS.raobj_marker(args[0])
        elif kind == "qdt_regularize":
            val = OPS.qdt_regularize(args[0])
        else:  # pragma: no cover - Expr.__post_init__ guards kinds
            raise LoweringError(f"unhandled pointwise kind {kind!r}")
    memo[node] = val
    return val
