"""The paper's comparison methods (§4.3): naive per-filter iteration
(SMIL-like), the pixel-pump queue algorithm, van Herk/Gil-Werman, and a
hierarchical-queue reconstruction oracle."""
