"""Naive chain execution: one whole-image pass per elementary filter,
each dispatched as its own jitted call with a host sync in between.

This reproduces how iterative libraries (SMIL/OpenCV, paper §1) compute
geodesic operators: every filter of the chain re-streams the full image
through main memory.  It is the *unfused* baseline against which the
paper's (and our) locality win is measured.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import morphology as M

_erode3 = jax.jit(M.erode3)
_dilate3 = jax.jit(M.dilate3)
_geo_erode1 = jax.jit(M.geodesic_erode1)
_geo_dilate1 = jax.jit(M.geodesic_dilate1)


def chain(f: jnp.ndarray, n: int, op: str = "erode") -> jnp.ndarray:
    """n elementary filters, one dispatch + device sync each."""
    step = _erode3 if op == "erode" else _dilate3
    for _ in range(n):
        f = step(f)
        f.block_until_ready()
    return f


def reconstruct(f: jnp.ndarray, m: jnp.ndarray,
                op: str = "erode") -> jnp.ndarray:
    """Reconstruction with per-iteration host-side convergence check."""
    step = _geo_erode1 if op == "erode" else _geo_dilate1
    while True:
        nxt = step(f, m)
        if not bool(jnp.any(nxt != f)):
            return nxt
        f = nxt
