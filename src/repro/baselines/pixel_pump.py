"""Pixel pump: the queue-based single-pass streaming erosion/dilation of
Dokládal & Dokladalova (2011) [10] — the paper's principal streaming
competitor, reimplemented from the published pseudo-code.

A monotone deque per 1-D scan keeps (value, position) pairs with strictly
increasing values (erosion); each pixel is pushed/popped at most once ⇒
O(1) amortized comparisons per pixel, independent of window size, with
(w+1)-deep queues — the properties the paper cites (Table 3).

This is deliberately *scalar* Python/numpy: the paper notes the pixel
pump's throughput "remained consistent, due to the scalar processing"
(§4.3) — its algorithmic profile (ops/pixel, memory) is what the
benchmarks compare; wall-clock comparisons against it are reported
separately from the same-substrate jnp baselines (EXPERIMENTS.md).
"""
from __future__ import annotations

from collections import deque

import numpy as np


def _pump_1d(row: np.ndarray, w: int, op: str) -> np.ndarray:
    """Sliding min/max of window ``w`` anchored so output is centered,
    with border-clipped semantics (windows truncated at the edges)."""
    n = row.shape[0]
    s = w // 2
    out = np.empty_like(row)
    better = (lambda a, b: a <= b) if op == "erode" else (lambda a, b: a >= b)
    q: deque[tuple[int, np.generic]] = deque()  # (position, value), monotone
    for i in range(n + s):
        if i < n:
            v = row[i]
            while q and better(v, q[-1][1]):
                q.pop()
            q.append((i, v))
        if i >= s:
            # output position i - s; window = [i-2s, i] clipped
            while q and q[0][0] < i - 2 * s:
                q.popleft()
            out[i - s] = q[0][1]
    return out


def minmax_filter(f: np.ndarray, s: int, op: str = "erode") -> np.ndarray:
    """(2s+1)×(2s+1) erosion/dilation, separable pixel pump."""
    if s == 0:
        return f.copy()
    w = 2 * s + 1
    tmp = np.empty_like(f)
    for y in range(f.shape[0]):
        tmp[y] = _pump_1d(f[y], w, op)
    out = np.empty_like(f)
    for x in range(f.shape[1]):
        out[:, x] = _pump_1d(tmp[:, x], w, op)
    return out


def erode(f: np.ndarray, s: int) -> np.ndarray:
    return minmax_filter(f, s, "erode")


def dilate(f: np.ndarray, s: int) -> np.ndarray:
    return minmax_filter(f, s, "dilate")


def chain(f: np.ndarray, n: int, op: str = "erode") -> np.ndarray:
    """A chain of n elementary 3×3 filters, each a full pixel-pump pass —
    how a filter-size-insensitive method executes the paper's workload."""
    for _ in range(n):
        f = minmax_filter(f, 1, op)
    return f
