"""Hierarchical-queue grayscale reconstruction (Vincent 1993 [28]) —
SMIL's single-threaded reconstruction algorithm, used by the paper as
the near-parameter-insensitive baseline (§4.5, Table 5 footnote).

Hybrid algorithm: raster + anti-raster sweep, then FIFO-queue
propagation.  Serves as an independent correctness oracle for
``kernels.ops.reconstruct`` (it shares no code with the jnp/Pallas
paths) and as the baseline timing for the operator benchmarks.
"""
from __future__ import annotations

from collections import deque

import numpy as np

_N_MINUS = ((-1, -1), (-1, 0), (-1, 1), (0, -1))   # raster predecessors
_N_PLUS = ((1, 1), (1, 0), (1, -1), (0, 1))        # anti-raster predecessors
_N_ALL = _N_MINUS + _N_PLUS


def dilate_reconstruct(marker: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """δ_rec: grayscale reconstruction by dilation, marker ≤ mask."""
    f = marker.copy()
    h, w = f.shape

    # raster scan
    for y in range(h):
        for x in range(w):
            v = f[y, x]
            for dy, dx in _N_MINUS:
                ny, nx = y + dy, x + dx
                if 0 <= ny < h and 0 <= nx < w and f[ny, nx] > v:
                    v = f[ny, nx]
            f[y, x] = min(v, mask[y, x])

    # anti-raster scan + queue seeding
    fifo: deque[tuple[int, int]] = deque()
    for y in range(h - 1, -1, -1):
        for x in range(w - 1, -1, -1):
            v = f[y, x]
            for dy, dx in _N_PLUS:
                ny, nx = y + dy, x + dx
                if 0 <= ny < h and 0 <= nx < w and f[ny, nx] > v:
                    v = f[ny, nx]
            f[y, x] = min(v, mask[y, x])
            for dy, dx in _N_PLUS:
                ny, nx = y + dy, x + dx
                if (
                    0 <= ny < h
                    and 0 <= nx < w
                    and f[ny, nx] < f[y, x]
                    and f[ny, nx] < mask[ny, nx]
                ):
                    fifo.append((y, x))
                    break

    # propagation
    while fifo:
        y, x = fifo.popleft()
        for dy, dx in _N_ALL:
            ny, nx = y + dy, x + dx
            if 0 <= ny < h and 0 <= nx < w:
                if f[ny, nx] < f[y, x] and mask[ny, nx] != f[ny, nx]:
                    f[ny, nx] = min(f[y, x], mask[ny, nx])
                    fifo.append((ny, nx))
    return f


def erode_reconstruct(marker: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """ε_rec via duality: ε_rec(f, m) = -δ_rec(-f, -m) on the inverted
    lattice (complement within the dtype range for unsigned ints)."""
    if np.issubdtype(marker.dtype, np.unsignedinteger):
        top = np.iinfo(marker.dtype).max
        return top - dilate_reconstruct(top - marker, top - mask)
    return -dilate_reconstruct(-marker, -mask)
