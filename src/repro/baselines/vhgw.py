"""van Herk / Gil-Werman O(1)-per-pixel separable min/max filter.

The paper's "insensitive to window size" competitor family (§1, [23],
[8], [9]).  Used for the crossover experiment: the paper shows chained
3×3 filters beat O(1)/px methods up to window 183×183 (char) / 27×27
(double); we reproduce the crossover with this implementation.

Vectorized jnp: prefix/suffix min within w-aligned blocks, then
``out[i] = min(S[i], P[i+w-1])`` — one cummin + one reversed cummin +
one elementwise min per axis, independent of w.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.morphology import lattice_bottom, lattice_top


def _minmax_1d(x: jnp.ndarray, s: int, op: str, axis: int) -> jnp.ndarray:
    if s == 0:
        return x
    w = 2 * s + 1
    n = x.shape[axis]
    ident = lattice_top(x.dtype) if op == "erode" else lattice_bottom(x.dtype)
    reduce_fn = jnp.minimum if op == "erode" else jnp.maximum
    cum_op = jax.lax.cummin if op == "erode" else jax.lax.cummax
    cum = lambda a: cum_op(a, axis=a.ndim - 1)  # noqa: E731

    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    # pad so every window [p, p+w-1] of the s-left-shifted array is in range
    padded_len = n + 2 * s
    aligned = math.ceil(padded_len / w) * w
    y = jnp.full(lead + (aligned,), ident, x.dtype)
    y = jax.lax.dynamic_update_slice(y, x, (0,) * len(lead) + (s,))

    blocks = y.reshape(lead + (aligned // w, w))
    prefix = cum(blocks).reshape(lead + (aligned,))
    suffix = jnp.flip(cum(jnp.flip(blocks, -1)), -1).reshape(lead + (aligned,))

    idx = jnp.arange(n)
    out = reduce_fn(suffix[..., idx], prefix[..., idx + w - 1])
    return jnp.moveaxis(out, -1, axis)


@functools.partial(jax.jit, static_argnames=("s", "op"))
def minmax_filter(f: jnp.ndarray, s: int, op: str = "erode") -> jnp.ndarray:
    """(2s+1)×(2s+1) erosion/dilation in O(1) comparisons per pixel."""
    return _minmax_1d(_minmax_1d(f, s, op, -1), s, op, -2)


def erode(f: jnp.ndarray, s: int) -> jnp.ndarray:
    return minmax_filter(f, s, "erode")


def dilate(f: jnp.ndarray, s: int) -> jnp.ndarray:
    return minmax_filter(f, s, "dilate")
