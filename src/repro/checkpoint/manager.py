"""Fault-tolerant checkpointing.

Design (1000+-node posture, DESIGN.md §6):
  * **atomic**: writes go to ``step_XXXX.tmp/`` and are renamed into
    place only after the manifest is fsynced — a crash mid-write never
    corrupts the latest-good checkpoint.
  * **logical sharding**: arrays are saved whole with their *logical*
    PartitionSpec recorded in the manifest, not their device layout, so
    restore onto a different mesh shape (elastic scaling) is automatic
    re-sharding at device_put time.  (On a real multi-host cluster each
    host writes its owned shards; the manifest schema already carries
    the spec needed to reassemble.)
  * **async**: ``save_async`` snapshots to host RAM synchronously
    (cheap) and writes to disk on a background thread, so the train
    loop is blocked only for the device→host copy.
  * **retention**: keep the last N checkpoints; never delete the one a
    restore could need.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}{k}/")
                for k in template}
    if isinstance(template, tuple):
        return tuple(_unflatten_into(v, flat, f"{prefix}{i}/")
                     for i, v in enumerate(template))
    if isinstance(template, list):
        return [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None):
        """Synchronous atomic save."""
        host = jax.tree.map(np.asarray, state)   # device -> host
        self._write(step, host, extra or {})

    def save_async(self, step: int, state: Any, extra: dict | None = None):
        """Snapshot now, write on a background thread."""
        self.wait()
        host = jax.tree.map(np.asarray, state)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @staticmethod
    def _encode(v: np.ndarray) -> np.ndarray:
        """npz has no bfloat16: store bf16 as a uint16 view (the true
        dtype is recorded in the manifest and restored on load)."""
        v = np.asarray(v)
        if v.dtype == jnp.bfloat16:
            return v.view(np.uint16)
        return v

    def _write(self, step: int, host_state, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "."): self._encode(v)
                    for k, v in flat.items()})
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat.items()},
            "shapes": {k: list(np.asarray(v).shape) for k, v in flat.items()},
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``template``.  ``shardings`` (a
        matching pytree of NamedSharding, possibly for a *different* mesh
        than the one that saved) re-shards on load — elastic scaling."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k.replace(".", "/"): z[k] for k in z.files}
        for k, dt in manifest["dtypes"].items():
            if dt == "bfloat16" and k in flat:
                flat[k] = flat[k].view(jnp.bfloat16)
        host = _unflatten_into(template, flat)
        if shardings is not None:
            host = jax.tree.map(
                lambda a, s: jax.device_put(a, s), host, shardings)
        return host, manifest["extra"], step
