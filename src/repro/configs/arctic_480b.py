"""arctic-480b [moe]: 35L d=7168 56H (kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual FFN in parallel
[hf:Snowflake/snowflake-arctic-base].

bf16 params + bf16 optimizer moments (ZeRO-sharded over all mesh axes)
— required for the 480B×3-state footprint to fit 16 GB/chip at 256
chips (napkin math in EXPERIMENTS.md §Dry-run).
"""
from repro.configs.base import ModelConfig, MoEConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32_000,
        activation="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864,
                      dense_residual_ff=4864, router_chunk=256),
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32,
                      dense_residual_ff=32, router_chunk=16),
        param_dtype="float32", activation_dtype="float32", remat="none",
    )
