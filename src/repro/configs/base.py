"""Config schema for the assigned architectures.

A single ``ModelConfig`` drives the composable model in
``repro.models.model`` — every assigned architecture is a value of this
dataclass (one file per arch in this package).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0              # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    router_chunk: int = 512        # dispatch is scanned over seq chunks of
                                   # this size to bound dispatch-mask memory
    dense_residual_ff: int = 0     # arctic-style dense FFN in parallel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int | None = None            # default d_model // n_heads
    activation: str = "silu"               # silu | geglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: float | None = None

    # attention pattern: layers with (index % local_global_period) ==
    # local_global_period-1 are global; others use the sliding window.
    sliding_window: int | None = None
    local_global_period: int | None = None  # gemma3: 6 (5 local : 1 global)

    # encoder-decoder (seamless): sizes of the two stacks; n_layers is the
    # decoder depth when encoder_layers > 0.
    encoder_layers: int = 0

    # MoE
    moe: MoEConfig | None = None

    # hybrid / ssm
    block_pattern: tuple[str, ...] | None = None  # e.g. ("mlstm", "slstm")
    ssm_state: int = 0
    ssm_head_dim: int = 64
    shared_attn_period: int = 0   # zamba2: one *shared-weight* attn block
                                  # after every N ssm layers

    # modality frontend stub (assignment: frontends are stubs that accept
    # precomputed frame/patch embeddings)
    frontend: Literal[None, "audio", "vision"] = None

    # numerics
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attends(self) -> bool:
        """True if any layer is an attention layer."""
        if self.block_pattern is None:
            return True
        return "attn" in self.block_pattern or self.shared_attn_period > 0

    @property
    def pure_full_attention(self) -> bool:
        """True for archs where every token layer is full (non-windowed)
        attention — these skip the long_500k cell (DESIGN.md)."""
        return (
            self.block_pattern is None
            and self.sliding_window is None
            and self.ssm_state == 0
        )

    def layer_kind(self, i: int) -> str:
        """Static block kind for layer i: attn | attn_global | attn_local |
        mamba2 | slstm | mlstm."""
        if self.block_pattern is not None:
            return self.block_pattern[i % len(self.block_pattern)]
        if self.local_global_period:
            if i % self.local_global_period == self.local_global_period - 1:
                return "attn_global"
            return "attn_local"
        return "attn"

    # ------------------------------------------------------------------
    # parameter / flop accounting (roofline §7)
    # ------------------------------------------------------------------

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        qo = self.n_heads * hd * d * 2
        kv = self.n_kv_heads * hd * d * 2
        attn = qo + kv
        glu = self.activation in ("geglu", "silu")
        mlp = d * f * (3 if glu else 2)
        per_layer = 0
        n_attn = n_ffn = 0
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind.startswith("attn"):
                per_layer += attn + (mlp if f else 0)
            elif kind == "mamba2":
                d_in = 2 * d
                per_layer += d * (2 * d_in + 2 * self.ssm_state
                                  + d_in // self.ssm_head_dim) + d_in * d
            elif kind in ("slstm", "mlstm"):
                d_in = 2 * d
                per_layer += d * d_in * 4 + d_in * d  # qkv/gates + out
        if self.shared_attn_period:
            per_layer += 0  # counted once below
        total = per_layer
        if self.shared_attn_period:
            total += attn + mlp  # single shared block
        if self.moe is not None:
            m = self.moe
            expert = d * m.d_expert * 3
            per_moe = (m.n_experts * expert + m.n_shared * expert
                       + d * m.n_experts)
            if m.dense_residual_ff:
                per_moe += d * m.dense_residual_ff * 3
            total += self.n_layers * per_moe
            # attention params were counted with f=d_ff; for MoE archs d_ff
            # is the expert size, so drop the double-counted dense mlp
            total -= self.n_layers * mlp
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + mlp)
            dec_cross = self.n_layers * attn   # cross-attention blocks
            total += enc + dec_cross
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        expert = d * m.d_expert * 3
        inactive = (m.n_experts - m.top_k) * expert * self.n_layers
        return self.param_count() - inactive
