"""chameleon-34b [vlm]: 48L d=8192 64H (kv=8) d_ff=22016 vocab=65536 —
early-fusion, VQ image tokens, QK-norm [arXiv:2405.09818].

The VQ tokenizer is the modality frontend and is a STUB per the
assignment: ``input_specs`` provides precomputed patch/token embeddings
(B, S, d_model); text/image tokens share the 65536 vocab.
"""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65_536,
        activation="silu",
        qk_norm=True,
        tie_embeddings=False,
        frontend="vision",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        activation_dtype="float32", remat="none",
    )
