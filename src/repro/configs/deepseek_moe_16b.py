"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) expert d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared experts (fine-grained)
[arXiv:2401.06066; hf].

Deviation (documented): the HF checkpoint's first layer is a dense FFN;
we keep all 28 layers MoE for scan uniformity — active/total param
accounting uses the assigned config as written.
"""
from repro.configs.base import ModelConfig, MoEConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102_400,
        activation="silu",
        tie_embeddings=False,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      router_chunk=256),
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                      router_chunk=16),
        activation_dtype="float32", remat="none",
    )
