"""gemma3-27b [dense]: 62L d=5376 32H (kv=16) d_ff=21504 vocab=262144 —
5:1 local:global attention, 128k context [hf:google/gemma-3-*].

Sliding window 1024 on local layers; every 6th layer is global.
head_dim=128 (so H·hd ≠ d_model, as in the real checkpoint), GeGLU,
QK-norm.  RoPE theta: single 10k base (the real model uses 1M on global
layers — per-kind theta is a one-line extension, noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262_144,
        activation="geglu",
        qk_norm=True,
        sliding_window=1024,
        local_global_period=6,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, sliding_window=16,
        local_global_period=3, activation_dtype="float32", remat="none",
    )
