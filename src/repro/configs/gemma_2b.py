"""gemma-2b [dense]: 18L d=2048 8H (kv=1, MQA) d_ff=16384 vocab=256000 —
GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256_000,
        activation="geglu",
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=512,
        activation_dtype="float32", remat="none",
    )
