"""gemma-7b [dense]: 28L d=3072 16H (kv=16, MHA) d_ff=24576 vocab=256000
— GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256_000,
        activation="geglu",
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512,
        activation_dtype="float32", remat="none",
    )
