"""qwen2.5-32b [dense]: 64L d=5120 40H (kv=8, GQA) d_ff=27648
vocab=152064 — SwiGLU, QKV bias [hf:Qwen/Qwen2.5-*]."""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152_064,
        activation="silu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        activation_dtype="float32", remat="none",
    )
