"""Architecture registry: ``--arch <id>`` resolution for launchers,
dry-run, tests and benchmarks."""
from __future__ import annotations

import importlib

_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "gemma3-27b": "gemma3_27b",
    "gemma-7b": "gemma_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "gemma-2b": "gemma_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "arctic-480b": "arctic_480b",
    "zamba2-7b": "zamba2_7b",
    "chameleon-34b": "chameleon_34b",
    "xlstm-350m": "xlstm_350m",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).get_config()


def get_reduced(arch: str):
    return _module(arch).reduced()
