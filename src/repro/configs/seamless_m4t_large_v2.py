"""seamless-m4t-large-v2 [audio]: 24L (per stack) d=1024 16H (kv=16)
d_ff=8192 vocab=256206 — encoder-decoder, multimodal
[arXiv:2308.11596; hf].

The audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, d_model) to the encoder.  "24L"
describes each stack (the HF checkpoint has 24 encoder + 24 decoder
layers).  Real model uses ReLU FFNs + learned positions; we use gelu +
RoPE (framework-uniform, FLOP/byte-equivalent — DESIGN.md §2).
"""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=24,
        encoder_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=256_206,
        activation="gelu",
        frontend="audio",
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512,
        activation_dtype="float32", remat="none",
    )
