"""The assigned input-shape set.  Every LM arch is paired with all four;
decode/long shapes lower ``serve_step`` (one token against a seq_len
cache), not ``train_step``; long_500k applies only to sub-quadratic
archs (DESIGN.md §4)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg) -> list[str]:
    """Shape cells that apply to an arch (skips documented in DESIGN.md)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if not cfg.pure_full_attention:
        out.append("long_500k")
    return out
