"""xlstm-350m [ssm]: 24L d=1024 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own up/down projections (mLSTM
projection factor 2); there is no separate FFN sublayer.
"""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        activation="gelu",
        block_pattern=("mlstm", "slstm"),
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=512,
        activation_dtype="float32", remat="none",
    )
