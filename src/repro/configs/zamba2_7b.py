"""zamba2-7b [hybrid]: 81L d=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone + *shared-weight* attention block applied
after every 6 SSM layers [arXiv:2411.15242].

81 = 13 groups × 6 mamba2 layers (each followed by the shared attn+MLP
block) + 3 tail mamba2 layers.  The shared block's parameters exist
once; d_ff applies to its MLP (mamba2 layers carry no FFN).
"""
from repro.configs.base import ModelConfig
import dataclasses


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32_000,
        activation="silu",
        ssm_state=64,
        block_pattern=("mamba2",),
        shared_attn_period=6,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        get_config(), n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, ssm_state=16,
        ssm_head_dim=16, shared_attn_period=2,
        activation_dtype="float32", remat="none",
    )
