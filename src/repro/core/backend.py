"""The one place backend names are defined, validated and defaulted.

Historically ``core/operators.py`` re-validated ``("xla", "pallas")`` by
hand and defaulted to ``"xla"`` while ``kernels/ops.py`` kept its own
``Backend`` alias and defaulted to ``"pallas"``.  Both now import from
here, with one documented policy:

**Default-backend policy.**  ``default_backend()`` resolves to the
fastest *exact* backend for the platform: ``"pallas"`` when JAX is
running natively on TPU (the fused kernels compile with
``interpret=False``), ``"xla"`` everywhere else — on CPU the Pallas
kernels only run in interpret mode, which is a bit-exactness/validation
path, not a performance path.  Every public entry point that accepts a
backend treats ``None`` as "apply the policy"; passing a backend
explicitly always wins.  Both backends are bit-exact against the
``core.morphology`` oracles, so the choice may only ever change *how*
the result is computed, never the result.
"""
from __future__ import annotations

from typing import Literal

import jax

Backend = Literal["xla", "pallas"]

#: Every backend name a public entry point accepts.
BACKENDS: tuple[str, ...] = ("xla", "pallas")


def default_backend() -> str:
    """The policy default: native Pallas on TPU, XLA elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def canonicalize_backend(backend: str | None) -> str:
    """Validate ``backend``, resolving ``None`` to the policy default."""
    if backend is None:
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(
            f"backend must be one of {BACKENDS} (or None for the "
            f"platform default), got {backend!r}"
        )
    return backend


def warn_legacy_kwargs(entry: str, *names: str) -> None:
    """Deprecation shim for the pre-expression call surfaces.

    The legacy operator kwargs (``backend=``, ``max_iters=``,
    ``max_chunks=``) keep working — the wrappers forward them into
    compiled expressions — but new code should build an expression and
    bind the backend at ``repro.api.compile`` time.
    """
    import warnings

    warnings.warn(
        f"{entry}: the {'/'.join(names)} argument(s) are deprecated; "
        "build an expression and pass them to repro.api.compile("
        "expr, shape, dtype, backend, ...) instead",
        DeprecationWarning,
        stacklevel=3,
    )
