"""Fusion planner: the paper's pipeline schedule re-derived for TPU.

The paper keeps T elementary filters in flight on T threads, row-window
synchronized, so inter-filter traffic stays in cache.  On TPU the
equivalent is *temporal fusion*: one Pallas kernel applies K elementary
filters to a VMEM-resident row band before the band is written back to
HBM.  This module picks the fusion depth K and band height TH from the
dtype, image width and VMEM budget — the analogue of the paper's
run-time topology examination (§3.6).

Bandwidth model (per K-chunk, per band of TH rows, width W, dtype b):
    HBM traffic   = (TH + 2K)·W·b read + TH·W·b write      (once)
    vs. unfused   = K · 2·TH·W·b                            (K round trips)
    amplification ≈ 2K·TH / (2TH + 2K)  → K for TH >> K
Redundant compute fraction = 2K / (TH + 2K).

Convergence-driven chains (reconstruction, QDT — the paper's Alg. 4/5
requeue mechanism) additionally carry a *scheduling policy*: once the
geodesic wavefront localizes, only bands that changed in the previous
chunk — or whose vertical neighbours changed — need to be requeued.  The
policy fields below control that scheduler:

``requeue_halo``
    how many neighbouring bands to re-activate around a changed band.
    1 is exact for ``fuse_k <= band_h`` (influence propagates at most
    ``fuse_k`` rows per chunk, which cannot cross a full band).
``compact_threshold``
    when the active fraction drops below this, the driver gathers the
    active bands into a dense workspace and launches a smaller grid
    (the TPU analogue of the paper's work queue).  0 disables
    compaction.

For convergent plans the planner also *shrinks* the band height toward
``CONVERGENT_TARGET_BANDS`` bands per image: band-level requeueing is
only as fine-grained as the band, so a VMEM-maximal band (often the
whole image) would leave nothing to skip.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

#: VMEM budget we allow a kernel working set to claim (bytes).  TPU v5e has
#: 16 MiB/core more or less; leave half for double buffering + compiler slop.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

#: TPU lane count — last-dim tiles should be multiples of this.
LANES = 128
#: Sublane multiples per dtype (f32: 8, bf16: 16, int8: 32).
SUBLANES = {4: 8, 2: 16, 1: 32, 8: 8}

#: Bands per image the planner aims for on convergence-driven chains.
CONVERGENT_TARGET_BANDS = 16


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """A schedule for a chain of S elementary filters.

    The plan covers a stack of ``n_images`` same-shaped images laid out
    vertically (batched drivers stack ``(N, H_pad, W_pad)`` into
    ``(N·H_pad, W_pad)``); ``n_bands`` is *per image*.
    """

    band_h: int          # TH: rows of useful output per grid step
    fuse_k: int          # K: elementary filters fused per kernel launch
    width_pad: int       # W rounded up to a lane multiple
    height_pad: int      # H rounded up to a band multiple (per image)
    n_bands: int         # bands per image
    n_chunks: int        # ceil(S / K) kernel launches for a fixed chain
    n_images: int = 1    # images stacked vertically in the working array
    requeue_halo: int = 1        # bands re-activated around a changed band
    compact_threshold: float = 0.0   # active fraction below which to compact

    def __post_init__(self):
        # The one place the band/fuse contract is validated (the kernels
        # assert it too, but every driver goes through a ChainPlan).
        if self.band_h % self.fuse_k:
            raise ValueError(
                f"band_h={self.band_h} must be a multiple of fuse_k={self.fuse_k}"
            )
        if self.height_pad % self.band_h:
            raise ValueError(
                f"height_pad={self.height_pad} must be a multiple of "
                f"band_h={self.band_h}"
            )
        if self.requeue_halo < 1:
            raise ValueError("requeue_halo must be >= 1 (neighbour influence)")
        if not 0.0 <= self.compact_threshold <= 1.0:
            raise ValueError("compact_threshold must be in [0, 1]")

    @property
    def key(self) -> tuple:
        """Hashable compact identity for compiled-program caches
        (``repro.serve`` keys its jit entries on this together with the
        op/params/dtype/backend): exactly the fields that determine the
        compiled schedule.  ``ChainPlan`` itself is hashable (frozen
        dataclass) and usable as a ``jax.jit`` static argument; ``key``
        is the stable serialization-friendly form."""
        return (self.band_h, self.fuse_k, self.width_pad, self.height_pad,
                self.n_bands, self.n_chunks, self.n_images,
                self.requeue_halo, self.compact_threshold)

    @property
    def total_bands(self) -> int:
        """Grid size for the stacked (n_images · height_pad) working array."""
        return self.n_bands * self.n_images

    @property
    def compact_capacity(self) -> int:
        """Static workspace size (bands) for the compacted grid."""
        return max(1, math.ceil(self.compact_threshold * self.total_bands))

    @property
    def redundant_compute_fraction(self) -> float:
        return 2 * self.fuse_k / (self.band_h + 2 * self.fuse_k)

    @property
    def bandwidth_amplification(self) -> float:
        th, k = self.band_h, self.fuse_k
        return (2 * k * th) / (2 * th + 2 * k)


def plan_chain(
    height: int,
    width: int,
    dtype,
    chain_len: int | None = None,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    n_images_resident: int = 1,
    fuse_k: int | None = None,
    band_h: int | None = None,
    n_images: int = 1,
    convergent: bool = False,
    requeue_halo: int = 1,
    compact_threshold: float | None = None,
) -> ChainPlan:
    """Choose (TH, K) so the working set fits VMEM.

    ``n_images_resident`` counts extra same-shaped operands the kernel
    holds (e.g. the geodesic mask, QDT's r/d planes).  ``n_images`` is
    the batch size of the vertical image stack the plan will drive.

    ``convergent=True`` marks a convergence-driven chain (reconstruction
    / QDT): the planner caps the band height near
    ``CONVERGENT_TARGET_BANDS`` bands per image so the active-band
    requeue scheduler has skipping granularity, and enables compaction
    (``compact_threshold=0.5``) unless overridden.
    """
    b = jnp.dtype(dtype).itemsize
    w_pad = max(LANES, math.ceil(width / LANES) * LANES)
    sub = SUBLANES.get(b, 8)

    if fuse_k is None:
        fuse_k = 16 if b >= 4 else 32
    if chain_len is not None:
        fuse_k = min(fuse_k, max(1, chain_len))
    # round K to a sublane multiple so halo blocks tile cleanly
    fuse_k = max(sub, math.ceil(fuse_k / sub) * sub)

    if band_h is None:
        # working set ≈ (1 + n_resident)·(TH + 2K)·W·b  + TH·W·b scratch
        per_row = (2 + n_images_resident) * w_pad * b
        band_h = max(fuse_k, (vmem_budget - 2 * fuse_k * per_row) // per_row)
        band_h = max(fuse_k, (band_h // fuse_k) * fuse_k)  # TH % K == 0
        band_h = min(band_h, 512)
        if convergent:
            # requeue granularity: aim for ~CONVERGENT_TARGET_BANDS bands
            target = math.ceil(height / CONVERGENT_TARGET_BANDS)
            target = max(fuse_k, math.ceil(target / fuse_k) * fuse_k)
            band_h = min(band_h, target)

    if compact_threshold is None:
        compact_threshold = 0.5 if convergent else 0.0

    h_pad = math.ceil(height / band_h) * band_h
    n_bands = h_pad // band_h
    n_chunks = math.ceil((chain_len or fuse_k) / fuse_k)
    return ChainPlan(
        band_h, fuse_k, w_pad, h_pad, n_bands, n_chunks,
        n_images=n_images,
        requeue_halo=requeue_halo,
        compact_threshold=compact_threshold,
    )
