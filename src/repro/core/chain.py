"""Fusion planner: the paper's pipeline schedule re-derived for TPU.

The paper keeps T elementary filters in flight on T threads, row-window
synchronized, so inter-filter traffic stays in cache.  On TPU the
equivalent is *temporal fusion*: one Pallas kernel applies K elementary
filters to a VMEM-resident row band before the band is written back to
HBM.  This module picks the fusion depth K and band height TH from the
dtype, image width and VMEM budget — the analogue of the paper's
run-time topology examination (§3.6).

Bandwidth model (per K-chunk, per band of TH rows, width W, dtype b):
    HBM traffic   = (TH + 2K)·W·b read + TH·W·b write      (once)
    vs. unfused   = K · 2·TH·W·b                            (K round trips)
    amplification ≈ 2K·TH / (2TH + 2K)  → K for TH >> K
Redundant compute fraction = 2K / (TH + 2K).

Convergence-driven chains (reconstruction, QDT — the paper's Alg. 4/5
requeue mechanism) additionally carry a *scheduling policy*: once the
geodesic wavefront localizes, only bands that changed in the previous
chunk — or whose vertical neighbours changed — need to be requeued.  The
policy fields below control that scheduler:

``requeue_halo``
    how many neighbouring tiles to re-activate around a changed tile
    (per axis).  1 is exact for ``fuse_k <= min(band_h, tile_w)``
    (influence propagates at most ``fuse_k`` pixels in Chebyshev
    distance per chunk, which cannot cross a full tile).
``tile_w``
    column-tile width.  0 (the default) keeps full-width row bands —
    the paper's Alg. 4 granularity.  A positive ``tile_w`` splits each
    band into ``width_pad / tile_w`` column tiles, making the activity
    grid 2-D (``total_bands × n_tiles``) so a narrow *vertical*
    wavefront no longer re-processes full-width bands.
``compact_threshold``
    when the active fraction drops below this, the driver gathers the
    active tiles into a dense workspace and launches a smaller grid
    (the TPU analogue of the paper's work queue).  0 disables
    compaction.

For convergent plans the planner also *shrinks* the band height toward
``CONVERGENT_TARGET_BANDS`` bands per image and splits the width into
column tiles when it is at least two lane-groups wide: tile-level
requeueing is only as fine-grained as the tile, so a VMEM-maximal band
(often the whole image) would leave nothing to skip.

See ``docs/ARCHITECTURE.md`` for the full ChainPlan contract and the
scheduler lifecycle built on it.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

#: VMEM budget we allow a kernel working set to claim (bytes).  TPU v5e has
#: 16 MiB/core more or less; leave half for double buffering + compiler slop.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

#: TPU lane count — last-dim tiles should be multiples of this.
LANES = 128
#: Sublane multiples per dtype (f32: 8, bf16: 16, int8: 32).
SUBLANES = {4: 8, 2: 16, 1: 32, 8: 8}

#: Bands per image the planner aims for on convergence-driven chains.
CONVERGENT_TARGET_BANDS = 16

#: Column tiles per band row the planner caps itself at (very wide
#: images coarsen their tiles instead of growing the activity grid).
CONVERGENT_TARGET_TILES = 16

#: Scheduling policies for convergence-driven chains.  ``wavefront`` is
#: the active-tile requeue scheduler (Teodoro-style propagation — pays
#: only for tiles the wavefront touches); ``raster`` sweeps the whole
#: image with directional forward/backward passes (FastGeodis-style —
#: wins when the wavefront is dense and activity tracking is overhead).
SCHEDULES = ("wavefront", "raster")


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """A schedule for a chain of S elementary filters.

    The plan covers a stack of ``n_images`` same-shaped images laid out
    vertically (batched drivers stack ``(N, H_pad, W_pad)`` into
    ``(N·H_pad, W_pad)``); ``n_bands`` is *per image*.
    """

    band_h: int          # TH: rows of useful output per grid step
    fuse_k: int          # K: elementary filters fused per kernel launch
    width_pad: int       # W rounded up to a lane multiple
    height_pad: int      # H rounded up to a band multiple (per image)
    n_bands: int         # bands per image
    n_chunks: int        # ceil(S / K) kernel launches for a fixed chain
    n_images: int = 1    # images stacked vertically in the working array
    requeue_halo: int = 1        # tiles re-activated around a changed tile
    compact_threshold: float = 0.0   # active fraction below which to compact
    tile_w: int = 0      # column-tile width; 0 = full-width row bands
    schedule: str = "wavefront"  # "wavefront" (requeue) | "raster" (sweeps)

    def __post_init__(self):
        # The one place the band/fuse/tile contract is validated (the
        # kernels assert it too, but every driver goes through a
        # ChainPlan).
        if self.band_h % self.fuse_k:
            raise ValueError(
                f"band_h={self.band_h} must be a multiple of "
                f"fuse_k={self.fuse_k}"
            )
        if self.height_pad % self.band_h:
            raise ValueError(
                f"height_pad={self.height_pad} must be a multiple of "
                f"band_h={self.band_h}"
            )
        if self.requeue_halo < 1:
            raise ValueError("requeue_halo must be >= 1 (neighbour influence)")
        if not 0.0 <= self.compact_threshold <= 1.0:
            raise ValueError("compact_threshold must be in [0, 1]")
        if self.tile_w < 0:
            raise ValueError(f"tile_w={self.tile_w} must be >= 0")
        if self.tile_w:
            # Same contract as the row axis: the halo the kernels carry
            # is fuse_k wide, so a tile must be at least one fuse_k and
            # tile cleanly in both directions.
            if self.tile_w % self.fuse_k:
                raise ValueError(
                    f"tile_w={self.tile_w} must be a multiple of "
                    f"fuse_k={self.fuse_k} (or 0 for row-only bands)"
                )
            if self.width_pad % self.tile_w:
                raise ValueError(
                    f"width_pad={self.width_pad} must be a multiple of "
                    f"tile_w={self.tile_w}"
                )
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule={self.schedule!r} must be one of {SCHEDULES}"
            )

    @property
    def key(self) -> tuple:
        """Hashable compact identity for compiled-program caches
        (``repro.serve`` keys its jit entries on this together with the
        op/params/dtype/backend): exactly the fields that determine the
        compiled schedule.  ``ChainPlan`` itself is hashable (frozen
        dataclass) and usable as a ``jax.jit`` static argument; ``key``
        is the stable serialization-friendly form."""
        return (self.band_h, self.fuse_k, self.width_pad, self.height_pad,
                self.n_bands, self.n_chunks, self.n_images,
                self.requeue_halo, self.compact_threshold, self.tile_w,
                self.schedule)

    @property
    def total_bands(self) -> int:
        """Vertical grid size for the stacked (n_images · height_pad) array."""
        return self.n_bands * self.n_images

    @property
    def n_tiles(self) -> int:
        """Column tiles per band row (1 when ``tile_w == 0``)."""
        return self.width_pad // self.tile_w if self.tile_w else 1

    @property
    def total_tiles(self) -> int:
        """Scheduling cells in the activity grid (``total_bands × n_tiles``).
        This is the unit the requeue scheduler counts work in; for
        row-only plans it equals ``total_bands``."""
        return self.total_bands * self.n_tiles

    @property
    def compact_capacity(self) -> int:
        """Static workspace size (tiles) for the compacted grid."""
        return max(1, math.ceil(self.compact_threshold * self.total_tiles))

    @property
    def redundant_compute_fraction(self) -> float:
        return 2 * self.fuse_k / (self.band_h + 2 * self.fuse_k)

    @property
    def bandwidth_amplification(self) -> float:
        th, k = self.band_h, self.fuse_k
        return (2 * k * th) / (2 * th + 2 * k)


def plan_chain(
    height: int,
    width: int,
    dtype,
    chain_len: int | None = None,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    n_images_resident: int = 1,
    fuse_k: int | None = None,
    band_h: int | None = None,
    n_images: int = 1,
    convergent: bool = False,
    requeue_halo: int = 1,
    compact_threshold: float | None = None,
    tile_w: int | None = None,
    schedule: str = "wavefront",
) -> ChainPlan:
    """Choose (TH, K) so the working set fits VMEM.

    ``n_images_resident`` counts extra same-shaped operands the kernel
    holds (e.g. the geodesic mask, QDT's r/d planes).  ``n_images`` is
    the batch size of the vertical image stack the plan will drive.

    ``convergent=True`` marks a convergence-driven chain (reconstruction
    / QDT): the planner caps the band height near
    ``CONVERGENT_TARGET_BANDS`` bands per image so the active-tile
    requeue scheduler has skipping granularity, enables compaction
    (``compact_threshold=0.5``) and splits the width into column tiles
    when it is wide enough — all unless overridden.

    ``tile_w`` requests a column-tile width.  ``None`` auto-tiles
    (convergent plans only), ``0`` forces full-width row bands.  A
    requested width is rounded up to a ``fuse_k`` multiple; if the
    result cannot tile the padded width, or ``fuse_k > tile_w`` (the
    1-tile requeue halo would no longer bound the per-chunk influence),
    the planner *falls back to row-only tiling* rather than produce an
    inexact schedule.
    """
    b = jnp.dtype(dtype).itemsize
    w_pad = max(LANES, math.ceil(width / LANES) * LANES)
    sub = SUBLANES.get(b, 8)

    if fuse_k is None:
        fuse_k = 16 if b >= 4 else 32
    if chain_len is not None:
        fuse_k = min(fuse_k, max(1, chain_len))
    # round K to a sublane multiple so halo blocks tile cleanly
    fuse_k = max(sub, math.ceil(fuse_k / sub) * sub)

    if band_h is None:
        # working set ≈ (1 + n_resident)·(TH + 2K)·W·b  + TH·W·b scratch
        per_row = (2 + n_images_resident) * w_pad * b
        band_h = max(fuse_k, (vmem_budget - 2 * fuse_k * per_row) // per_row)
        band_h = max(fuse_k, (band_h // fuse_k) * fuse_k)  # TH % K == 0
        band_h = min(band_h, 512)
        if convergent:
            # requeue granularity: aim for ~CONVERGENT_TARGET_BANDS bands
            target = math.ceil(height / CONVERGENT_TARGET_BANDS)
            target = max(fuse_k, math.ceil(target / fuse_k) * fuse_k)
            band_h = min(band_h, target)

    if compact_threshold is None:
        compact_threshold = 0.5 if convergent else 0.0

    if tile_w is None:
        tile_w = _auto_tile_w(w_pad, fuse_k) if convergent else 0
    elif tile_w > 0:
        # honour the request when it can be made exact, else fall back
        # to row-only: fuse_k > tile_w breaks the 1-tile halo bound, and
        # a non-dividing width would leave ragged cells.
        if tile_w < fuse_k:
            tile_w = 0
        else:
            tile_w = math.ceil(tile_w / fuse_k) * fuse_k
            if tile_w >= w_pad or w_pad % tile_w:
                tile_w = 0

    h_pad = math.ceil(height / band_h) * band_h
    n_bands = h_pad // band_h
    n_chunks = math.ceil((chain_len or fuse_k) / fuse_k)
    return ChainPlan(
        band_h, fuse_k, w_pad, h_pad, n_bands, n_chunks,
        n_images=n_images,
        requeue_halo=requeue_halo,
        compact_threshold=compact_threshold,
        tile_w=tile_w,
        schedule=schedule,
    )


def _auto_tile_w(w_pad: int, fuse_k: int) -> int:
    """Column-tile width for convergent plans: the smallest lane-aligned
    ``fuse_k``-multiple that divides ``w_pad`` while keeping at most
    ``CONVERGENT_TARGET_TILES`` tiles across the width (very wide
    images coarsen their tiles instead of growing the activity grid);
    when every divisor overshoots the target the coarsest one wins.
    0 (row-only) when no divisor yields at least two tiles."""
    base = math.lcm(LANES, fuse_k)
    divisors = [k * base for k in range(1, w_pad // (2 * base) + 1)
                if w_pad % (k * base) == 0]
    for tile_w in divisors:
        if w_pad // tile_w <= CONVERGENT_TARGET_TILES:
            return tile_w
    return divisors[-1] if divisors else 0
