"""Distributed geodesic morphology: the paper's pipeline, scaled out.

The image is sharded in contiguous row/column blocks over mesh axes.
Every K fused elementary steps, each device exchanges a K-row (K-col)
halo with its mesh neighbours via ``ppermute`` — a 1-hop ICI transfer,
the device-level analogue of the paper's cache-topology-aware thread
pinning (adjacent filters of the chain share the fastest link).

Amortization: K steps need K halo rows; exchanging them in ONE message
per chunk instead of one row per step keeps the byte volume identical
but divides the message count (and therefore the latency term of the
collective roofline) by K, and unlocks the fused local kernel (the HBM
bandwidth win).  Redundant compute on the halo is the price — the same
trade the single-device kernel makes (DESIGN.md §2).

Corner halos are handled by exchanging rows first, then exchanging the
*row-extended* strips along columns, so corner data arrives via the
column neighbour (two-phase halo exchange).

Convergence of distributed reconstruction is a ``psum`` of the per-device
changed flags — the collective version of the paper's ``converged`` flag
(Alg. 4).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.4.35 exposes shard_map at the top level
    shard_map = jax.shard_map
    SHMAP_KW = {}
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

    # the experimental shard_map has no replication rule for while_loop;
    # disable the (purely diagnostic) replication check.  SHMAP_KW is
    # the single home for this shim — splat it into every shard_map call.
    SHMAP_KW = {"check_rep": False}

from repro.core import morphology as M
from repro.core.chain import plan_chain
from repro.kernels.common import ident_for


# ---------------------------------------------------------------------------
# halo exchange
# ---------------------------------------------------------------------------


def _exchange_axis(local, k: int, axis_name, fill, axis: int):
    """Attach a k-deep halo along ``axis`` from mesh neighbours on
    ``axis_name`` (global edges are filled with the absorbing value)."""
    # psum of 1 == axis size; jax.lax.axis_size only exists in newer jax
    n = jax.lax.psum(1, axis_name)
    if n == 1:
        pad = [(0, 0)] * local.ndim
        pad[axis] = (k, k)
        return jnp.pad(local, pad, constant_values=fill)
    idx = jax.lax.axis_index(axis_name)

    sl_lo = [slice(None)] * local.ndim
    sl_lo[axis] = slice(0, k)
    sl_hi = [slice(None)] * local.ndim
    sl_hi[axis] = slice(local.shape[axis] - k, local.shape[axis])

    fwd = [(i, i + 1) for i in range(n - 1)]  # shard i's tail -> shard i+1
    bwd = [(i + 1, i) for i in range(n - 1)]  # shard i+1's head -> shard i
    from_prev = jax.lax.ppermute(local[tuple(sl_hi)], axis_name, fwd)
    from_next = jax.lax.ppermute(local[tuple(sl_lo)], axis_name, bwd)
    from_prev = jnp.where(idx == 0, fill, from_prev)
    from_next = jnp.where(idx == n - 1, fill, from_next)
    return jnp.concatenate([from_prev, local, from_next], axis=axis)


def exchange_halo(local, k: int, row_axes, col_axes, fill):
    """Two-phase 2-D halo exchange (rows, then row-extended columns)."""
    out = _exchange_axis(local, k, row_axes, fill, axis=0)
    if col_axes:
        out = _exchange_axis(out, k, col_axes, fill, axis=1)
    return out


def _crop(ext, k: int, has_cols: bool):
    if has_cols:
        return ext[k:-k, k:-k]
    return ext[k:-k, :]


# ---------------------------------------------------------------------------
# distributed fixed-length chains
# ---------------------------------------------------------------------------


def distributed_chain(
    mesh: Mesh,
    row_axes: str | Sequence[str],
    col_axes: str | Sequence[str] | None = None,
    *,
    n: int,
    op: str = "erode",
    backend: str = "xla",
    fuse_k: int | None = None,
):
    """Build a jitted sharded n-step elementary chain over ``mesh``.

    Returns a function image -> image; the image is sharded
    P(row_axes, col_axes) on entry and exit.
    """
    spec = P(row_axes, col_axes)
    row_axes_t = row_axes if isinstance(row_axes, tuple) else (row_axes,)
    col_axes_t = (
        () if col_axes is None
        else col_axes if isinstance(col_axes, tuple) else (col_axes,)
    )

    def local_fn(f_loc):
        from repro.kernels import ops

        k = fuse_k or plan_chain(
            f_loc.shape[0], f_loc.shape[1], f_loc.dtype, n
        ).fuse_k
        fill = ident_for(op, f_loc.dtype)
        full, rem = divmod(n, k)

        def chunk(x, _):
            ext = exchange_halo(x, k, row_axes_t, col_axes_t, fill)
            ext = ops.morph_chain(ext, k, op, backend)
            return _crop(ext, k, bool(col_axes_t)), None

        if full:
            f_loc, _ = jax.lax.scan(chunk, f_loc, None, length=full)
        if rem:
            ext = exchange_halo(f_loc, rem, row_axes_t, col_axes_t, fill)
            body = M.erode3 if op == "erode" else M.dilate3
            ext = jax.lax.fori_loop(0, rem, lambda _, y: body(y), ext)
            f_loc = _crop(ext, rem, bool(col_axes_t))
        return f_loc

    sharded = shard_map(local_fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                        **SHMAP_KW)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# distributed reconstruction (geodesic, to convergence)
# ---------------------------------------------------------------------------


def distributed_reconstruct(
    mesh: Mesh,
    row_axes: str | Sequence[str],
    col_axes: str | Sequence[str] | None = None,
    *,
    op: str = "erode",
    backend: str = "xla",
    fuse_k: int | None = None,
    max_chunks: int | None = None,
):
    """Build a jitted sharded ε_rec/δ_rec over ``mesh``.

    Returns (marker, mask) -> reconstructed, both sharded P(rows, cols).
    """
    spec = P(row_axes, col_axes)
    row_axes_t = row_axes if isinstance(row_axes, tuple) else (row_axes,)
    col_axes_t = (
        () if col_axes is None
        else col_axes if isinstance(col_axes, tuple) else (col_axes,)
    )
    all_axes = row_axes_t + col_axes_t

    def local_fn(f_loc, m_loc):
        from repro.kernels import ops

        k = fuse_k or plan_chain(
            f_loc.shape[0], f_loc.shape[1], f_loc.dtype, None,
            n_images_resident=2
        ).fuse_k
        fill = ident_for(op, f_loc.dtype)
        # the mask halo is constant: exchange it once, reuse every chunk
        m_ext = exchange_halo(m_loc, k, row_axes_t, col_axes_t, fill)
        limit = max_chunks
        if limit is None:
            # pixel-count bound, like kernels.ops.reconstruct: geodesic
            # paths under a serpentine mask can exceed the H+W diameter
            h = f_loc.shape[0] * jax.lax.psum(1, row_axes_t[0])
            w = f_loc.shape[1] * (
                jax.lax.psum(1, col_axes_t[0]) if col_axes_t else 1
            )
            limit = (h * w) // k + 2

        def cond(state):
            _, changed, it = state
            return jnp.logical_and(changed, it < limit)

        def body(state):
            x, _, it = state
            ext = exchange_halo(x, k, row_axes_t, col_axes_t, fill)
            ext = ops.geodesic_chain(ext, m_ext, k, op, backend)
            nxt = _crop(ext, k, bool(col_axes_t))
            local_changed = jnp.any(nxt != x).astype(jnp.int32)
            changed = jax.lax.psum(local_changed, all_axes) > 0
            return nxt, changed, it + 1

        out, _, _ = jax.lax.while_loop(
            cond, body, (f_loc, jnp.asarray(True), jnp.asarray(0, jnp.int32))
        )
        return out

    sharded = shard_map(
        local_fn, mesh=mesh, in_specs=(spec, spec), out_specs=spec, **SHMAP_KW
    )
    return jax.jit(sharded)
