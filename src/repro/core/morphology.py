"""Elementary morphological operations (pure jnp) — the reference layer.

Semantics follow the paper (Žlaus & Mongus 2019, §2): the structuring
element is clipped at the image border (``w_s(p) ⊆ P``), i.e. min/max is
taken over the *available* neighbours only.  This is equivalent to
padding with the dtype's identity element (+inf for erosion, -inf for
dilation) before the windowed reduction.

All functions operate on 2-D images ``(H, W)`` and are dtype-polymorphic
(uint8/uint16/float32/float64 — the paper's char/short/float/double).
They are written with ``jax.lax`` primitives only, so they jit, vmap,
grad (where meaningful) and shard cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dtype lattice identities
# ---------------------------------------------------------------------------


def lattice_top(dtype) -> jnp.ndarray:
    """Identity for min (the largest representable value)."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def lattice_bottom(dtype) -> jnp.ndarray:
    """Identity for max (the smallest representable value)."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


# ---------------------------------------------------------------------------
# 1-D decomposed passes (paper Eq. 21-23): w1 = w1x ∘ w1y
# ---------------------------------------------------------------------------


def _shift(f: jnp.ndarray, offset: int, axis: int, fill) -> jnp.ndarray:
    """Shift ``f`` by ``offset`` along ``axis`` filling vacated entries."""
    pad = [(0, 0)] * f.ndim
    if offset > 0:
        pad[axis] = (offset, 0)
        sl = [slice(None)] * f.ndim
        sl[axis] = slice(0, f.shape[axis])
    else:
        pad[axis] = (0, -offset)
        sl = [slice(None)] * f.ndim
        sl[axis] = slice(-offset, f.shape[axis] - offset)
    padded = jnp.pad(f, pad, constant_values=fill)
    return padded[tuple(sl)]


def erode1d(f: jnp.ndarray, axis: int) -> jnp.ndarray:
    """ε along one axis with the 3-element SE (clipped at borders)."""
    top = lattice_top(f.dtype)
    return jnp.minimum(
        f, jnp.minimum(_shift(f, 1, axis, top), _shift(f, -1, axis, top))
    )


def dilate1d(f: jnp.ndarray, axis: int) -> jnp.ndarray:
    """δ along one axis with the 3-element SE (clipped at borders)."""
    bot = lattice_bottom(f.dtype)
    return jnp.maximum(
        f, jnp.maximum(_shift(f, 1, axis, bot), _shift(f, -1, axis, bot))
    )


# ---------------------------------------------------------------------------
# elementary 3x3 filters (Eq. 1-2 with s=1, decomposed)
# ---------------------------------------------------------------------------


def erode3(f: jnp.ndarray) -> jnp.ndarray:
    """ε₁: 3×3 erosion = ε₁ˣ ∘ ε₁ʸ (4 comparisons/pixel, Eq. 23)."""
    return erode1d(erode1d(f, axis=-1), axis=-2)


def dilate3(f: jnp.ndarray) -> jnp.ndarray:
    """δ₁: 3×3 dilation = δ₁ˣ ∘ δ₁ʸ."""
    return dilate1d(dilate1d(f, axis=-1), axis=-2)


def erode3_direct(f: jnp.ndarray) -> jnp.ndarray:
    """Non-decomposed 3×3 erosion (8 comparisons/px) — used only in tests
    to verify the decomposition identity Eq. 23."""
    top = lattice_top(f.dtype)
    out = f
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            out = jnp.minimum(out, _shift(_shift(f, dy, -2, top), dx, -1, top))
    return out


def dilate3_direct(f: jnp.ndarray) -> jnp.ndarray:
    bot = lattice_bottom(f.dtype)
    out = f
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            out = jnp.maximum(out, _shift(_shift(f, dy, -2, bot), dx, -1, bot))
    return out


# ---------------------------------------------------------------------------
# size-s erosion/dilation as chains of ε₁/δ₁ (the paper's central object)
# ---------------------------------------------------------------------------


def erode(f: jnp.ndarray, s: int) -> jnp.ndarray:
    """ε_s(f) as a chain of s elementary erosions (paper Eq. 4 analogue).

    For the square SE, chaining s 3×3 erosions equals one (2s+1)² erosion.
    """
    if s == 0:
        return f
    return jax.lax.fori_loop(0, s, lambda _, x: erode3(x), f)


def dilate(f: jnp.ndarray, s: int) -> jnp.ndarray:
    if s == 0:
        return f
    return jax.lax.fori_loop(0, s, lambda _, x: dilate3(x), f)


# ---------------------------------------------------------------------------
# elementary geodesic filters (Eq. 3) and bounded-size geodesic (Eq. 4)
# ---------------------------------------------------------------------------


def geodesic_erode1(f: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """ε₁ᵐ(f) = max(ε₁(f), m).  Requires f ≥ m for the usual semantics."""
    return jnp.maximum(erode3(f), m)


def geodesic_dilate1(f: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """δ₁ᵐ(f) = min(δ₁(f), m).  Requires f ≤ m."""
    return jnp.minimum(dilate3(f), m)


def geodesic_erode(f: jnp.ndarray, m: jnp.ndarray, s: int) -> jnp.ndarray:
    """ε_sᵐ(f): s-fold composition of ε₁ᵐ (Eq. 4)."""
    return jax.lax.fori_loop(0, s, lambda _, x: geodesic_erode1(x, m), f)


def geodesic_dilate(f: jnp.ndarray, m: jnp.ndarray, s: int) -> jnp.ndarray:
    return jax.lax.fori_loop(0, s, lambda _, x: geodesic_dilate1(x, m), f)


# ---------------------------------------------------------------------------
# reconstruction (Eq. 5): iterate to convergence
# ---------------------------------------------------------------------------


def _reconstruct(f, m, step, max_iters):
    def cond(state):
        x, prev, it, changed = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        x, _, it, _ = state
        nxt = step(x, m)
        return nxt, x, it + 1, jnp.any(nxt != x)

    x0 = step(f, m)
    init = (x0, f, jnp.asarray(1, jnp.int32), jnp.any(x0 != f))
    out, _, iters, _ = jax.lax.while_loop(cond, body, init)
    return out, iters


def erode_reconstruct(
    f: jnp.ndarray, m: jnp.ndarray, max_iters: int | None = None
) -> jnp.ndarray:
    """ε_recᵐ(f): erosion by reconstruction (Eq. 5); marker f, mask m,
    f ≥ m."""
    if max_iters is None:
        max_iters = f.shape[-1] * f.shape[-2]
    out, _ = _reconstruct(f, m, geodesic_erode1, max_iters)
    return out


def dilate_reconstruct(
    f: jnp.ndarray, m: jnp.ndarray, max_iters: int | None = None
) -> jnp.ndarray:
    """δ_recᵐ(f): dilation by reconstruction. Marker f, mask m, f ≤ m."""
    if max_iters is None:
        max_iters = f.shape[-1] * f.shape[-2]
    out, _ = _reconstruct(f, m, geodesic_dilate1, max_iters)
    return out


def erode_reconstruct_with_iters(f, m, max_iters=None):
    """Like erode_reconstruct but also returns the chain length used
    (the paper reports average chain lengths in Table 5)."""
    if max_iters is None:
        max_iters = f.shape[-1] * f.shape[-2]
    return _reconstruct(f, m, geodesic_erode1, max_iters)


def dilate_reconstruct_with_iters(f, m, max_iters=None):
    if max_iters is None:
        max_iters = f.shape[-1] * f.shape[-2]
    return _reconstruct(f, m, geodesic_dilate1, max_iters)


# ---------------------------------------------------------------------------
# opening / closing (Eq. 16, 19)
# ---------------------------------------------------------------------------


def opening(f: jnp.ndarray, s: int) -> jnp.ndarray:
    """γ_s(f) = δ_s(ε_s(f))."""
    return dilate(erode(f, s), s)


def closing(f: jnp.ndarray, s: int) -> jnp.ndarray:
    """φ_s(f) = ε_s(δ_s(f))."""
    return erode(dilate(f, s), s)
