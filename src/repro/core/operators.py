"""Geodesic operators of the paper (§2, Eq. 6-20), defined as
expression graphs over ``repro.api``.

This module keeps two kinds of things:

* the **pointwise/jnp primitives** the expression evaluator itself uses
  (``sat_sub``/``sat_add``, the HFILL/RAOBJ marker derivations,
  ``qdt_raw``/``qdt_regularize``) — pure jnp, jit/vmap/shard-clean,
  and the oracles the kernels are compared against;
* the **operator sugar** (``hmax``, ``dome``, ``hfill``, ``raobj``,
  ``opening_by_reconstruction``, ``asf``, ``qdt``): each builds its
  graph via the builders in ``repro.api.expr`` (``hmax_expr`` & co.)
  and executes it through ``repro.api.compile``, so composite chains
  fuse into one padded program and the backend resolves by the one
  policy in ``core.backend``.

Legacy kwargs keep working through deprecation shims: ``backend=``
forwards into the compiled expression (with a ``DeprecationWarning``),
and ``max_iters=`` — which counts *elementary* steps, finer than the
fused driver's K-chunk granularity — always runs the exact truncated
jnp path, as before.  All operators accept batched (..., H, W) input;
the markers use per-image reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import morphology as M
from repro.core.backend import warn_legacy_kwargs

#: Parameter types the expression builders can embed as graph literals;
#: anything else (e.g. a traced array threshold) takes the jnp path.
_SCALAR = (int, float, bool, np.integer, np.floating)


def _api():
    from repro import api  # lazy: repro.api's lowering imports this module

    return api


def _run(expr_builder, f, backend, *builder_args):
    api = _api()
    expr = expr_builder(*builder_args)
    if f.ndim > 3:
        # honour the (..., H, W) contract: fold leading batch dims into
        # one (N, H, W) stack and unfold after (markers reduce over the
        # trailing two axes, so per-image semantics are unaffected)
        lead, hw = f.shape[:-2], f.shape[-2:]
        n = int(np.prod(lead))
        out = api.compile(expr, (n, *hw), f.dtype, backend)(
            f.reshape(n, *hw))
        return out.reshape(*lead, *out.shape[-2:])
    return api.compile(expr, f.shape, f.dtype, backend)(f)


def _legacy_reconstruct(marker, mask, op, max_iters):
    """Truncated reconstruction: always the exact jnp path (an explicit
    ``max_iters`` counts elementary steps — the fused driver can only
    truncate at K-chunk granularity)."""
    if op == "erode":
        return M.erode_reconstruct(marker, mask, max_iters)
    return M.dilate_reconstruct(marker, mask, max_iters)


def _rec_with_marker(marker, mask, op, backend):
    """Reconstruction on a precomputed marker array, through compile."""
    api = _api()
    expr = api.E.reconstruct(api.E.input("marker"), api.E.input("mask"),
                             op=op)
    if marker.ndim > 3:
        lead, hw = marker.shape[:-2], marker.shape[-2:]
        n = int(np.prod(lead))
        out = api.compile(expr, (n, *hw), marker.dtype, backend)(
            marker.reshape(n, *hw), mask.reshape(n, *hw))
        return out.reshape(marker.shape)
    exe = api.compile(expr, marker.shape, marker.dtype, backend)
    return exe(marker, mask)


def _warn_legacy(entry, max_iters, backend):
    legacy = [n for n, v in (("max_iters", max_iters),
                             ("backend", backend)) if v is not None]
    if legacy:
        warn_legacy_kwargs(entry, *legacy)


# ---------------------------------------------------------------------------
# saturating arithmetic (the paper evaluates on unsigned char images)
# ---------------------------------------------------------------------------


def sat_sub(f: jnp.ndarray, h) -> jnp.ndarray:
    """f - h clamped to the dtype's range (needed for unsigned images)."""
    dtype = f.dtype
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        h = jnp.asarray(h, dtype)
        return jnp.where(f > h, f - h, jnp.zeros((), dtype))
    return f - jnp.asarray(h, dtype)


def sat_add(f: jnp.ndarray, h) -> jnp.ndarray:
    dtype = f.dtype
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        wide = f.astype(jnp.int64) + jnp.asarray(h, jnp.int64)
        return jnp.clip(wide, info.min, info.max).astype(dtype)
    return f + jnp.asarray(h, dtype)


# ---------------------------------------------------------------------------
# H-maxima / dome extraction (Eq. 6-7)
# ---------------------------------------------------------------------------


def hmax(
    f: jnp.ndarray, h, max_iters: int | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """HMAX_h(f) = δ_rec^f(f - h): suppress maxima of contrast < h."""
    _warn_legacy("core.operators.hmax", max_iters, backend)
    if max_iters is not None:
        return _legacy_reconstruct(sat_sub(f, h), f, "dilate", max_iters)
    if not isinstance(h, _SCALAR):
        # h is an array/tracer: it cannot embed in the graph, but the
        # reconstruction itself still compiles on the requested backend
        return _rec_with_marker(sat_sub(f, h), f, "dilate", backend)
    return _run(_api().hmax_expr, f, backend, h)


def dome(
    f: jnp.ndarray, h, max_iters: int | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """DOME_h(f) = f - HMAX_h(f): extract the suppressed maxima."""
    _warn_legacy("core.operators.dome", max_iters, backend)
    if max_iters is not None:
        return f - _legacy_reconstruct(sat_sub(f, h), f, "dilate", max_iters)
    if not isinstance(h, _SCALAR):
        return f - _rec_with_marker(sat_sub(f, h), f, "dilate", backend)
    return _run(_api().dome_expr, f, backend, h)


# ---------------------------------------------------------------------------
# hole filling / border-object removal (Eq. 8-11)
# ---------------------------------------------------------------------------


def _border_mask(shape) -> jnp.ndarray:
    h, w = shape[-2], shape[-1]
    yy = jnp.arange(h)
    xx = jnp.arange(w)
    return (
        (yy[:, None] == 0)
        | (yy[:, None] == h - 1)
        | (xx[None, :] == 0)
        | (xx[None, :] == w - 1)
    )


def hfill_marker(f: jnp.ndarray) -> jnp.ndarray:
    """m_HFILL (Eq. 9): border pixels keep f, interior = per-image max."""
    hi = jnp.max(f, axis=(-2, -1), keepdims=True)
    return jnp.where(_border_mask(f.shape), f, hi)


def hfill(
    f: jnp.ndarray, max_iters: int | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """HFILL(f) = ε_rec^f(m_HFILL(f)) (Eq. 8)."""
    _warn_legacy("core.operators.hfill", max_iters, backend)
    if max_iters is not None:
        return _legacy_reconstruct(hfill_marker(f), f, "erode", max_iters)
    return _run(_api().hfill_expr, f, backend)


def raobj_marker(f: jnp.ndarray) -> jnp.ndarray:
    """m_RAOBJ (Eq. 11): border pixels keep f, interior = per-image min."""
    lo = jnp.min(f, axis=(-2, -1), keepdims=True)
    return jnp.where(_border_mask(f.shape), f, lo)


def raobj(
    f: jnp.ndarray, max_iters: int | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """RAOBJ(f) = f - δ_rec^f(m_RAOBJ(f)) (Eq. 10)."""
    _warn_legacy("core.operators.raobj", max_iters, backend)
    if max_iters is not None:
        return f - _legacy_reconstruct(raobj_marker(f), f, "dilate",
                                       max_iters)
    return _run(_api().raobj_expr, f, backend)


# ---------------------------------------------------------------------------
# opening by reconstruction (Eq. 12)
# ---------------------------------------------------------------------------


def opening_by_reconstruction(
    f: jnp.ndarray, s: int, max_iters: int | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """γ_rec^s(f) = δ_rec^f(ε_s(f)): remove components smaller than s.

    The erosion chain and the reconstruction compile into *one* padded
    program (see ``repro.api.lower``)."""
    _warn_legacy("core.operators.opening_by_reconstruction", max_iters,
                 backend)
    if max_iters is not None:
        return _legacy_reconstruct(M.erode(f, s), f, "dilate", max_iters)
    return _run(_api().opening_by_reconstruction_expr, f, backend, s)


# ---------------------------------------------------------------------------
# quasi-distance transform (Eq. 13-15, Alg. 5)
# ---------------------------------------------------------------------------


def qdt_raw(f: jnp.ndarray, max_s: int | None = None):
    """d(f), r(f): distance of the largest residual per pixel (Eq. 13).

    Returns (d, r) where d is int32 distance and r the residual in a
    signed/float accumulator dtype (residuals of unsigned images fit).
    """
    if max_s is None:
        max_s = max(f.shape[-1], f.shape[-2])
    from repro.kernels.common import qdt_acc_dtype
    acc = qdt_acc_dtype(f.dtype)

    def body(state):
        cur, d, r, j, changed = state
        nxt = M.erode3(cur)
        res = cur.astype(acc) - nxt.astype(acc)
        upd = res > r
        r = jnp.where(upd, res, r)
        d = jnp.where(upd, j, d)
        return nxt, d, r, j + 1, jnp.any(nxt != cur)

    def cond(state):
        *_, j, changed = state
        return jnp.logical_and(changed, j <= max_s)

    d0 = jnp.zeros(f.shape, jnp.int32)
    r0 = jnp.zeros(f.shape, acc)
    init = (f, d0, r0, jnp.asarray(1, jnp.int32), jnp.asarray(True))
    _, d, r, _, _ = jax.lax.while_loop(cond, body, init)
    return d, r


def qdt_regularize(d: jnp.ndarray,
                   max_iters: int | None = None) -> jnp.ndarray:
    """η-iteration (Eq. 14) until d is 1-Lipschitz (Eq. 15)."""
    if max_iters is None:
        max_iters = d.shape[-1] * d.shape[-2]

    def step(x, _):
        e = M.erode3(x)
        return jnp.where(x - e > 1, e + 1, x)

    def cond(state):
        x, it, changed = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        x, it, _ = state
        nxt = step(x, None)
        return nxt, it + 1, jnp.any(nxt != x)

    x0 = step(d, None)
    out, _, _ = jax.lax.while_loop(
        cond, body, (x0, jnp.asarray(1, jnp.int32), jnp.any(x0 != d))
    )
    return out


def qdt(f: jnp.ndarray, max_s: int | None = None,
        backend: str | None = None) -> jnp.ndarray:
    """L1-regularized quasi-distance transform d_L1(f)."""
    if backend is not None:
        warn_legacy_kwargs("core.operators.qdt", "backend")
    if max_s is not None:
        d, _ = qdt_raw(f, max_s)
        return qdt_regularize(d)
    return _run(_api().qdt_l1_expr, f, backend)


# ---------------------------------------------------------------------------
# granulometry / pattern spectrum (Eq. 16-18)
# ---------------------------------------------------------------------------


def granulometric_function(f: jnp.ndarray, smax: int) -> jnp.ndarray:
    """G_s(f) = Σ_p γ_s(f) for s = 0..smax (Eq. 17), computed incrementally.

    γ_s is computed by extending the erosion chain one step per scale and
    re-dilating — the chain structure the paper exploits (Eq. 16).
    """
    acc = jnp.float64 if f.dtype == jnp.float64 else jnp.float32

    # G_0 = sum f. For s>=1 erode incrementally, then dilate s times.
    sums = [jnp.sum(f.astype(acc))]
    eroded = f
    for s in range(1, smax + 1):
        eroded = M.erode3(eroded)
        opened = M.dilate(eroded, s)
        sums.append(jnp.sum(opened.astype(acc)))
    return jnp.stack(sums)


def pattern_spectrum(f: jnp.ndarray, smax: int) -> jnp.ndarray:
    """PS_s(f) = G_s(f) - G_{s+1}(f) for s = 0..smax-1 (Eq. 18)."""
    g = granulometric_function(f, smax)
    return g[:-1] - g[1:]


# ---------------------------------------------------------------------------
# alternating sequential filter (Eq. 20)
# ---------------------------------------------------------------------------


def asf(f: jnp.ndarray, s: int) -> jnp.ndarray:
    """ASF_s(f) = φ_s(γ_s(...φ_1(γ_1(f))...)) — chain length 2·s·(s+1).

    Built as one expression graph; the lowered program fuses the
    alternating chains into 2s+1 launches around a single pad/crop."""
    return _run(_api().asf_expr, f, None, s)


def asf_chain_length(s: int) -> int:
    """Number of elementary 3×3 filters in ASF_s (for Table 5 analogue)."""
    return sum(4 * k for k in range(1, s + 1))


# ---------------------------------------------------------------------------
# serving registry hooks
# ---------------------------------------------------------------------------

#: Registry hooks for ``repro.serve``: each public geodesic operator
#: declared as data (name + param schema + expression builder) next to
#: its implementation.  The serve registry lowers the expression and
#: derives the prepare (unpadded marker derivation) / run (batched,
#: compiled per bucket) / finalize (post-crop residuals, the QDT
#: η-regularization) stages mechanically — see
#: ``repro.serve.registry``.
SERVE_OPS = (
    dict(name="hmax",
         expr=lambda p: _api().hmax_expr(p["h"]),
         params={"h": dict(type="float", required=True)}),
    dict(name="dome",
         expr=lambda p: _api().dome_expr(p["h"]),
         params={"h": dict(type="float", required=True)}),
    dict(name="hfill",
         expr=lambda p: _api().hfill_expr(), params={}),
    dict(name="raobj",
         expr=lambda p: _api().raobj_expr(), params={}),
    dict(name="open_rec",
         expr=lambda p: _api().opening_by_reconstruction_expr(p["s"]),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="asf",
         expr=lambda p: _api().asf_expr(p["s"]),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="qdt_l1",
         expr=lambda p: _api().qdt_l1_expr(), params={}),
)
