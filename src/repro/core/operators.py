"""Geodesic operators of the paper (§2, Eq. 6-20), built on core.morphology.

Every operator here is pure jnp/lax — it jits, shards (via the wrappers
in core.distributed) and serves as the oracle for the Pallas-kernel
fast path in repro.kernels.

The reconstruction-based operators additionally accept
``backend="pallas"`` to route their inner reconstruct through the fused
kernel fast path (with active-band requeue scheduling); the default
``"xla"`` keeps them pure-jnp oracles.  All of them accept batched
(..., H, W) input — the markers use per-image reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import morphology as M


def _reconstruct(marker, mask, op, max_iters, backend):
    """Dispatch reconstruction to the jnp oracle or the Pallas fast path.

    An explicit ``max_iters`` counts *elementary* steps — the fused
    driver can only truncate at K-chunk granularity, so truncated
    reconstructions always run the exact jnp path regardless of
    ``backend``.
    """
    if backend not in ("xla", "pallas"):
        raise ValueError(f"backend must be 'xla' or 'pallas', got {backend!r}")
    if backend == "pallas" and max_iters is None:
        from repro.kernels import ops as K  # lazy: kernels import this module

        return K.reconstruct(marker, mask, op, "pallas")
    if op == "erode":
        return M.erode_reconstruct(marker, mask, max_iters)
    return M.dilate_reconstruct(marker, mask, max_iters)

# ---------------------------------------------------------------------------
# saturating arithmetic (the paper evaluates on unsigned char images)
# ---------------------------------------------------------------------------


def sat_sub(f: jnp.ndarray, h) -> jnp.ndarray:
    """f - h clamped to the dtype's range (needed for unsigned images)."""
    dtype = f.dtype
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        h = jnp.asarray(h, dtype)
        return jnp.where(f > h, f - h, jnp.zeros((), dtype))
    return f - jnp.asarray(h, dtype)


def sat_add(f: jnp.ndarray, h) -> jnp.ndarray:
    dtype = f.dtype
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        wide = f.astype(jnp.int64) + jnp.asarray(h, jnp.int64)
        return jnp.clip(wide, info.min, info.max).astype(dtype)
    return f + jnp.asarray(h, dtype)


# ---------------------------------------------------------------------------
# H-maxima / dome extraction (Eq. 6-7)
# ---------------------------------------------------------------------------


def hmax(
    f: jnp.ndarray, h, max_iters: int | None = None, backend: str = "xla"
) -> jnp.ndarray:
    """HMAX_h(f) = δ_rec^f(f - h): suppress maxima of contrast < h."""
    return _reconstruct(sat_sub(f, h), f, "dilate", max_iters, backend)


def dome(
    f: jnp.ndarray, h, max_iters: int | None = None, backend: str = "xla"
) -> jnp.ndarray:
    """DOME_h(f) = f - HMAX_h(f): extract the suppressed maxima."""
    return f - hmax(f, h, max_iters, backend)


# ---------------------------------------------------------------------------
# hole filling / border-object removal (Eq. 8-11)
# ---------------------------------------------------------------------------


def _border_mask(shape) -> jnp.ndarray:
    h, w = shape[-2], shape[-1]
    yy = jnp.arange(h)
    xx = jnp.arange(w)
    return (
        (yy[:, None] == 0)
        | (yy[:, None] == h - 1)
        | (xx[None, :] == 0)
        | (xx[None, :] == w - 1)
    )


def hfill_marker(f: jnp.ndarray) -> jnp.ndarray:
    """m_HFILL (Eq. 9): border pixels keep f, interior = per-image max."""
    hi = jnp.max(f, axis=(-2, -1), keepdims=True)
    return jnp.where(_border_mask(f.shape), f, hi)


def hfill(
    f: jnp.ndarray, max_iters: int | None = None, backend: str = "xla"
) -> jnp.ndarray:
    """HFILL(f) = ε_rec^f(m_HFILL(f)) (Eq. 8)."""
    return _reconstruct(hfill_marker(f), f, "erode", max_iters, backend)


def raobj_marker(f: jnp.ndarray) -> jnp.ndarray:
    """m_RAOBJ (Eq. 11): border pixels keep f, interior = per-image min."""
    lo = jnp.min(f, axis=(-2, -1), keepdims=True)
    return jnp.where(_border_mask(f.shape), f, lo)


def raobj(
    f: jnp.ndarray, max_iters: int | None = None, backend: str = "xla"
) -> jnp.ndarray:
    """RAOBJ(f) = f - δ_rec^f(m_RAOBJ(f)) (Eq. 10)."""
    return f - _reconstruct(raobj_marker(f), f, "dilate", max_iters, backend)


# ---------------------------------------------------------------------------
# opening by reconstruction (Eq. 12)
# ---------------------------------------------------------------------------


def opening_by_reconstruction(
    f: jnp.ndarray, s: int, max_iters: int | None = None, backend: str = "xla"
) -> jnp.ndarray:
    """γ_rec^s(f) = δ_rec^f(ε_s(f)): remove components smaller than s."""
    return _reconstruct(M.erode(f, s), f, "dilate", max_iters, backend)


# ---------------------------------------------------------------------------
# quasi-distance transform (Eq. 13-15, Alg. 5)
# ---------------------------------------------------------------------------


def qdt_raw(f: jnp.ndarray, max_s: int | None = None):
    """d(f), r(f): distance of the largest residual per pixel (Eq. 13).

    Returns (d, r) where d is int32 distance and r the residual in a
    signed/float accumulator dtype (residuals of unsigned images fit).
    """
    if max_s is None:
        max_s = max(f.shape[-1], f.shape[-2])
    acc = jnp.float32 if jnp.issubdtype(f.dtype, jnp.floating) else jnp.int32

    def body(state):
        cur, d, r, j, changed = state
        nxt = M.erode3(cur)
        res = cur.astype(acc) - nxt.astype(acc)
        upd = res > r
        r = jnp.where(upd, res, r)
        d = jnp.where(upd, j, d)
        return nxt, d, r, j + 1, jnp.any(nxt != cur)

    def cond(state):
        *_, j, changed = state
        return jnp.logical_and(changed, j <= max_s)

    d0 = jnp.zeros(f.shape, jnp.int32)
    r0 = jnp.zeros(f.shape, acc)
    init = (f, d0, r0, jnp.asarray(1, jnp.int32), jnp.asarray(True))
    _, d, r, _, _ = jax.lax.while_loop(cond, body, init)
    return d, r


def qdt_regularize(d: jnp.ndarray, max_iters: int | None = None) -> jnp.ndarray:
    """η-iteration (Eq. 14) until d is 1-Lipschitz (Eq. 15)."""
    if max_iters is None:
        max_iters = d.shape[-1] * d.shape[-2]

    def step(x, _):
        e = M.erode3(x)
        return jnp.where(x - e > 1, e + 1, x)

    def cond(state):
        x, it, changed = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        x, it, _ = state
        nxt = step(x, None)
        return nxt, it + 1, jnp.any(nxt != x)

    x0 = step(d, None)
    out, _, _ = jax.lax.while_loop(
        cond, body, (x0, jnp.asarray(1, jnp.int32), jnp.any(x0 != d))
    )
    return out


def qdt(f: jnp.ndarray, max_s: int | None = None) -> jnp.ndarray:
    """L1-regularized quasi-distance transform d_L1(f)."""
    d, _ = qdt_raw(f, max_s)
    return qdt_regularize(d)


# ---------------------------------------------------------------------------
# granulometry / pattern spectrum (Eq. 16-18)
# ---------------------------------------------------------------------------


def granulometric_function(f: jnp.ndarray, smax: int) -> jnp.ndarray:
    """G_s(f) = Σ_p γ_s(f) for s = 0..smax (Eq. 17), computed incrementally.

    γ_s is computed by extending the erosion chain one step per scale and
    re-dilating — the chain structure the paper exploits (Eq. 16).
    """
    acc = jnp.float64 if f.dtype == jnp.float64 else jnp.float32

    # G_0 = sum f. For s>=1 erode incrementally, then dilate s times.
    sums = [jnp.sum(f.astype(acc))]
    eroded = f
    for s in range(1, smax + 1):
        eroded = M.erode3(eroded)
        opened = M.dilate(eroded, s)
        sums.append(jnp.sum(opened.astype(acc)))
    return jnp.stack(sums)


def pattern_spectrum(f: jnp.ndarray, smax: int) -> jnp.ndarray:
    """PS_s(f) = G_s(f) - G_{s+1}(f) for s = 0..smax-1 (Eq. 18)."""
    g = granulometric_function(f, smax)
    return g[:-1] - g[1:]


# ---------------------------------------------------------------------------
# alternating sequential filter (Eq. 20)
# ---------------------------------------------------------------------------


def asf(f: jnp.ndarray, s: int) -> jnp.ndarray:
    """ASF_s(f) = φ_s(γ_s(...φ_1(γ_1(f))...)) — chain length 2·s·(s+1)."""
    out = f
    for k in range(1, s + 1):
        out = M.opening(out, k)
        out = M.closing(out, k)
    return out


def asf_chain_length(s: int) -> int:
    """Number of elementary 3×3 filters in ASF_s (for Table 5 analogue)."""
    return sum(4 * k for k in range(1, s + 1))


# ---------------------------------------------------------------------------
# serving registry hooks
# ---------------------------------------------------------------------------

#: Registry hooks for ``repro.serve``: each public geodesic operator
#: declared as data (name + param schema) next to its implementation.
#:
#: ``marker_reconstruct`` ops split into a per-request ``marker`` stage
#: (runs on the *unpadded* image, so per-image reductions like
#: ``hfill_marker``'s interior max never see bucket padding) and a
#: batched reconstruction stage that the serve cache compiles once per
#: bucket; ``residual=True`` subtracts the reconstruction from the
#: original after cropping (DOME / RAOBJ).  ``whole_image`` ops run as
#: one jnp program and are bucketed by exact shape (ASF alternates
#: openings and closings, and the regularized QDT's η-iteration is
#: conditional — neither admits an absorbing pad fill).
SERVE_OPS = (
    dict(name="hmax", kind="marker_reconstruct", direction="dilate",
         marker=lambda f, p: sat_sub(f, p["h"]),
         params={"h": dict(type="float", required=True)}),
    dict(name="dome", kind="marker_reconstruct", direction="dilate",
         marker=lambda f, p: sat_sub(f, p["h"]), residual=True,
         params={"h": dict(type="float", required=True)}),
    dict(name="hfill", kind="marker_reconstruct", direction="erode",
         marker=lambda f, p: hfill_marker(f), params={}),
    dict(name="raobj", kind="marker_reconstruct", direction="dilate",
         marker=lambda f, p: raobj_marker(f), residual=True, params={}),
    dict(name="open_rec", kind="marker_reconstruct", direction="dilate",
         marker=lambda f, p: M.erode(f, p["s"]),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="asf", kind="whole_image", fn=lambda f, p: asf(f, p["s"]),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="qdt_l1", kind="whole_image", fn=lambda f, p: qdt(f),
         params={}),
)
