"""Synthetic test images for the morphology benchmarks.

The paper uses USC-SIPI Male/Airport/Airplane (offline here); these
generators produce images with the same *morphological* statistics that
drive the operators' run time: smooth background + blobs (regional
maxima for HMAX/DOME), basins (HFILL), border-touching structures
(RAOBJ), and multi-scale granularity (granulometry/ASF).
"""
from __future__ import annotations

import numpy as np


def _to_dtype(img01: np.ndarray, dtype) -> np.ndarray:
    dtype = np.dtype(dtype)
    if np.issubdtype(dtype, np.integer):
        hi = np.iinfo(dtype).max
        return np.clip(img01 * hi, 0, hi).astype(dtype)
    return img01.astype(dtype)


def blobs(h: int, w: int, dtype=np.uint8, n: int = 60, seed: int = 0):
    """Smooth background + Gaussian bumps of mixed scales ("Male"-like)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    img = 0.3 + 0.2 * np.sin(2 * np.pi * xx / w) * np.cos(2 * np.pi * yy / h)
    for _ in range(n):
        cy, cx = rng.uniform(0, h), rng.uniform(0, w)
        sig = rng.uniform(1.5, min(h, w) / 12)
        amp = rng.uniform(0.1, 0.6)
        img += amp * np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2)
                              / (2 * sig**2)))
    img = (img - img.min()) / (img.max() - img.min() + 1e-12)
    return _to_dtype(img, dtype)


def basins(h: int, w: int, dtype=np.uint8, n: int = 40, seed: int = 1):
    """Inverted blobs: regional minima, for hole filling."""
    img = blobs(h, w, np.float64, n, seed)
    img = img.max() - img
    img = (img - img.min()) / (img.max() - img.min() + 1e-12)
    return _to_dtype(img, dtype)


def border_objects(h: int, w: int, dtype=np.uint8, seed: int = 2):
    """Structures touching the border, for RAOBJ ("Airplane"-like)."""
    rng = np.random.default_rng(seed)
    img = blobs(h, w, np.float64, 30, seed)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    for side in range(4):
        c = rng.uniform(0.2, 0.8)
        sig = rng.uniform(h / 16, h / 6)
        if side == 0:
            img += 0.7 * np.exp(-((yy - 0) ** 2 + (xx - c * w) ** 2)
                                / (2 * sig**2))
        elif side == 1:
            img += 0.7 * np.exp(-((yy - h) ** 2 + (xx - c * w) ** 2)
                                / (2 * sig**2))
        elif side == 2:
            img += 0.7 * np.exp(-((yy - c * h) ** 2 + xx**2) / (2 * sig**2))
        else:
            img += 0.7 * np.exp(-((yy - c * h) ** 2 + (xx - w) ** 2)
                                / (2 * sig**2))
    img = (img - img.min()) / (img.max() - img.min() + 1e-12)
    return _to_dtype(img, dtype)
