"""Deterministic synthetic data pipelines.

Batches are a pure function of (seed, step) — this is the substrate for
the fault-tolerance story: a restarted or re-placed host regenerates
exactly its own shard for any step (no replay log needed), and elastic
re-sharding is just re-slicing the same deterministic stream
(DESIGN.md §6).

The token stream is a structured Markov-ish source (not uniform noise)
so language-model training loss has signal to descend — integration
tests assert loss decreases.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        """Global batch for ``step``, or the ``shard``-th of n_shards."""
        assert self.global_batch % n_shards == 0
        per = self.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard])
        )
        b = per
        # structured stream: piecewise-linear token walks => predictable
        start = rng.integers(0, self.vocab_size, (b, 1))
        stride = rng.integers(1, 8, (b, 1))
        idx = np.arange(self.seq_len + 1)[None, :]
        toks = (start + stride * idx) % self.vocab_size
        noise = rng.random((b, self.seq_len + 1)) < 0.05
        toks = np.where(noise,
                        rng.integers(0, self.vocab_size, toks.shape), toks)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass(frozen=True)
class EmbedPipeline:
    """Frontend-stub pipeline: precomputed frame/patch embeddings
    (audio/vision archs per the assignment)."""

    d_model: int
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        per = self.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard, 7])
        )
        emb = rng.standard_normal(
            (per, self.seq_len, self.d_model), dtype=np.float32)
        labels = rng.integers(0, self.vocab_size,
                              (per, self.seq_len)).astype(np.int32)
        return {"embeds": emb, "labels": labels}
