"""``repro.gdt`` — the generalised geodesic distance subsystem.

Grey-weighted geodesic distance (DTOCS-style additive cost
``w(p, q) = 1 + λ·|I(p) − I(q)|`` over the 8-neighbourhood) from soft
seeds ``D0 = ν·(1 − clip(S, 0, 1))``, plus the segmentation composites
built on it:

``gdt`` / ``gdt_expr``
    the transform itself — eager array entry point and expression
    builder (``E.gdt`` sugar).  ``λ = 0`` reduces it to the Chebyshev
    distance to the seed set, the bridge to the L1 QDT on binary
    images.
``seg_scribble_expr``
    two-seed scribble segmentation: foreground where the distance to
    the background scribbles is at least the distance to the
    foreground scribbles (two gdt segments sharing one image, compared
    in the finalize phase).
``seg_hmin_expr``
    h-minima-seeded propagation: the seed plane is derived *between
    kernels* (reconstruction-by-erosion → pointwise ``point``
    segments → gdt), exercising the lowered pointwise-bridge path.

``gdt_reference`` is the pure-NumPy Jacobi oracle every schedule
(wavefront requeue, raster sweeps, XLA fixpoint) is bit-exact against;
see ``repro.gdt.reference`` for the fold-cost argument that makes
bit-equality a theorem rather than a tolerance.

``SERVE_OPS`` exports the three ops to ``repro.serve.registry`` — the
single-kernel ``gdt`` op is pad-safe and refillable, so incremental
marker updates against a pinned image ride the continuous-batching
engine.
"""
from __future__ import annotations

from repro.gdt.reference import gdt_reference

__all__ = [
    "gdt", "gdt_expr", "gdt_reference", "seg_hmin_expr",
    "seg_scribble_expr", "SERVE_OPS",
]


def gdt(image, seeds, lamb: float = 1.0, nu: float = 1e6, **kw):
    """Eager generalised geodesic distance (see ``kernels.ops.gdt``)."""
    from repro.kernels.ops import gdt as _gdt

    return _gdt(image, seeds, lamb=lamb, nu=nu, **kw)


def _E():
    from repro import api

    return api.E


def gdt_expr(image, seeds, lamb: float = 1.0, nu: float = 1e6):
    """Expression builder: ``E.gdt`` with the package's defaults."""
    return _E().gdt(image, seeds, lamb=lamb, nu=nu)


def seg_scribble_expr(lamb: float = 1.0, nu: float = 1e6):
    """Scribble segmentation over inputs ``image`` and ``scribbles``.

    ``scribbles`` encodes both seed sets in one plane: 0 = unmarked,
    1 = foreground, 2 = background.  The result is the foreground
    indicator: 1.0 where the geodesic distance to the background
    scribbles is at least the distance to the foreground scribbles.

    Lowers to two gdt kernel segments over one shared image (the
    per-class distance maps) with the comparison in the finalize
    phase — the serve path co-batches both distances in one bucket
    program.
    """
    E = _E()
    f = E.input("image")
    s = E.input("scribbles")
    fg = E.sub(E.ge(s, 1.0), E.ge(s, 2.0))   # exactly the 1-labelled cells
    bg = E.ge(s, 2.0)
    d_fg = E.gdt(f, fg, lamb=lamb, nu=nu)
    d_bg = E.gdt(f, bg, lamb=lamb, nu=nu)
    return E.ge(E.sub(d_bg, d_fg), 0.0)


def seg_hmin_expr(h: float, lamb: float = 1.0, nu: float = 1e6):
    """h-minima-seeded geodesic propagation over input ``image``.

    Seeds are the h-minima indicator of the image — cells whose
    reconstruction-by-erosion of ``image + h`` over ``image`` still
    sits ``h`` above the image — fed straight into gdt.  The seed
    derivation sits *between* two kernel segments, so it lowers to
    ``point`` segments bridging the reconstruction to the gdt.
    """
    E = _E()
    if h <= 0:
        raise ValueError(f"h={h} must be > 0")
    f = E.input("image")
    hmin = E.reconstruct(E.sat_add(f, h), f, op="erode")
    seeds = E.ge(E.sub(hmin, f), float(h))
    return E.gdt(f, seeds, lamb=lamb, nu=nu)


#: Registry hooks for ``repro.serve`` (third hook source, next to
#: ``kernels.ops.SERVE_OPS`` and ``core.operators.SERVE_OPS``).
SERVE_OPS = (
    dict(name="gdt",
         expr=lambda p: gdt_expr(_E().input("image"), _E().input("seeds"),
                                 lamb=p["lamb"], nu=p["nu"]),
         params={"lamb": dict(type="float", default=1.0, min=0.0),
                 "nu": dict(type="float", default=1e6, min=1e-6)}),
    dict(name="seg_scribble",
         expr=lambda p: seg_scribble_expr(lamb=p["lamb"], nu=p["nu"]),
         params={"lamb": dict(type="float", default=1.0, min=0.0),
                 "nu": dict(type="float", default=1e6, min=1e-6)}),
    dict(name="seg_hmin",
         expr=lambda p: seg_hmin_expr(p["h"], lamb=p["lamb"], nu=p["nu"]),
         params={"h": dict(type="float", required=True, min=1e-6),
                 "lamb": dict(type="float", default=1.0, min=0.0),
                 "nu": dict(type="float", default=1e6, min=1e-6)}),
)
