"""Pure-NumPy reference for the generalised geodesic distance transform.

Semantics (shared contract with ``kernels.ops.gdt`` — the acceptance
oracle of the subsystem): over the 8-connected neighbourhood with the
additive DTOCS-style cost

    w(p, q) = 1 + lamb * |I(p) - I(q)|

the distance plane is the least fixpoint of the relaxation

    D'(p) = min(D(p), min_q D(q) + w(p, q))

from the soft-seed initialisation ``D0 = nu * (1 - clip(S, 0, 1))``.

Bit-exactness across schedules is not an accident: every value the
relaxation ever assigns is the *left-fold* float sum of one seed value
plus the edge weights along some path, float ``min`` is exact, and
float ``+`` is monotone in each argument — so any schedule that runs to
fixpoint (Jacobi here, the wavefront requeue scheduler, the raster
sweeps) lands on the same bits: the minimum fold-cost over all paths.
That is why the tests can require bit-equality rather than tolerances.

``lamb = 0`` makes every edge weight exactly 1, so the fixpoint is the
Chebyshev (L∞) distance to the seed set, capped at ``nu`` — the bridge
to the existing L1 QDT on binary images (see ``tests/test_gdt.py``).
"""
from __future__ import annotations

import numpy as np

__all__ = ["gdt_reference"]

#: Neighbour offsets of the 8-connected (Chebyshev) neighbourhood.
_OFFSETS = tuple(
    (dy, dx)
    for dy in (-1, 0, 1)
    for dx in (-1, 0, 1)
    if (dy, dx) != (0, 0)
)


def _shift(x: np.ndarray, dy: int, dx: int, fill) -> np.ndarray:
    """x translated by (dy, dx) with out-of-image cells set to ``fill``."""
    out = np.full_like(x, fill)
    h, w = x.shape
    ys = slice(max(dy, 0), h + min(dy, 0))
    xs = slice(max(dx, 0), w + min(dx, 0))
    yd = slice(max(-dy, 0), h + min(-dy, 0))
    xd = slice(max(-dx, 0), w + min(-dx, 0))
    out[yd, xd] = x[ys, xs]
    return out


def gdt_reference(image, seeds, lamb: float = 1.0,
                  nu: float = 1e6) -> np.ndarray:
    """Jacobi-iterated fixpoint of the generalised geodesic relaxation.

    ``image``: (H, W) float array (the grey-weight field).  ``seeds``:
    (H, W) float array, clipped to [0, 1] (1 = seed, 0 = unseeded; soft
    values interpolate the initial plateau).  Returns the distance
    plane in ``image``'s dtype.
    """
    img = np.asarray(image)
    if img.dtype.kind != "f":
        raise TypeError(
            f"gdt_reference: image must be floating, got {img.dtype}"
        )
    dtype = img.dtype
    s = np.clip(np.asarray(seeds).astype(dtype), 0.0, 1.0)
    if img.shape != s.shape or img.ndim != 2:
        raise ValueError(
            f"gdt_reference: image {img.shape} and seeds {s.shape} must "
            "be matching 2-D arrays"
        )
    lamb = float(lamb)
    d = (nu * (1.0 - s)).astype(dtype)

    inf = dtype.type(np.inf)
    # Pre-shift the constant planes once; the image pads with 0 so the
    # weight term stays finite at the border (the +inf distance pad is
    # what actually kills border candidates).
    d_fills = [inf] * len(_OFFSETS)
    if lamb == 0.0:
        # static branch: the weight is the constant 1 (and 0 * |ΔI|
        # never meets a padded operand)
        weights = [dtype.type(1.0)] * len(_OFFSETS)
    else:
        weights = [
            1.0 + lamb * np.abs(img - _shift(img, dy, dx, 0.0))
            for dy, dx in _OFFSETS
        ]

    while True:
        cand = d
        for (dy, dx), w, fill in zip(_OFFSETS, weights, d_fills):
            cand = np.minimum(cand, _shift(d, dy, dx, fill) + w)
        cand = cand.astype(dtype)
        if np.array_equal(cand, d):
            return d
        d = cand
