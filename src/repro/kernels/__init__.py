"""Fused Pallas kernels for the paper's operators, plus their drivers.

Layer contract (see ``docs/ARCHITECTURE.md`` for the full map): this
package owns everything that executes as a Pallas grid — the fused
K-step kernels (``erode_chain``, ``geodesic_chain``, ``qdt_chain``),
their shared in-kernel helpers (``common``), the jit'd public wrappers
and the active-tile requeue scheduler that drives the convergent ones
(``ops``), and the oracle re-exports used by the kernel tests
(``ref``).  Everything here must stay bit-exact against the pure-jnp
definitions in ``repro.core`` — the scheduler may only change *when*
work happens, never the result.
"""
