"""Shared in-kernel helpers for the fused morphology Pallas kernels.

Everything here executes inside a Pallas kernel body on VMEM-resident
values.  The 1-D passes mirror the paper's decomposed SIMD kernels
(Fig. 2): three displaced views min/max-ed together — on TPU the
"displaced registers" are lane/sublane shifts of a vreg tile.
"""
from __future__ import annotations

import jax.numpy as jnp


def image_edges(i, bands_per_image: int):
    """(at_top, at_bot) for grid step ``i`` of a vertically stacked batch.

    The drivers lay N images out as one (N·H_pad, W) array; band
    ``i`` is the ``i % bands_per_image``-th band of its image, and halo
    pinning must happen at *image* edges (not stack edges) so values
    never propagate between images.
    """
    j = i % bands_per_image
    return j == 0, j == bands_per_image - 1


def ident_for(op: str, dtype):
    """Lattice identity: +max for erosion (min-op), -max for dilation."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        hi, lo = jnp.array(jnp.inf, dtype), jnp.array(-jnp.inf, dtype)
    else:
        info = jnp.iinfo(dtype)
        hi, lo = jnp.array(info.max, dtype), jnp.array(info.min, dtype)
    return hi if op == "erode" else lo


def shift_minmax_1d(x: jnp.ndarray, axis: int, op: str) -> jnp.ndarray:
    """min/max(x, x<<1, x>>1) along ``axis`` with identity fill.

    This is the paper's Algorithm-1 inner step: registers A/B/C are the
    three displaced views; on TPU the displacement is a concat-shift on
    the sublane (axis 0) or lane (axis 1) dimension of the VMEM tile.
    """
    fill_shape = list(x.shape)
    fill_shape[axis] = 1
    fill = jnp.full(fill_shape, ident_for(op, x.dtype), x.dtype)

    idx_fwd = [slice(None)] * x.ndim
    idx_fwd[axis] = slice(1, None)
    idx_bwd = [slice(None)] * x.ndim
    idx_bwd[axis] = slice(0, -1)
    left = jnp.concatenate([x[tuple(idx_fwd)], fill], axis=axis)
    right = jnp.concatenate([fill, x[tuple(idx_bwd)]], axis=axis)

    f = jnp.minimum if op == "erode" else jnp.maximum
    return f(x, f(left, right))


def elementary_3x3(x: jnp.ndarray, op: str) -> jnp.ndarray:
    """ε₁ / δ₁ on a VMEM tile: horizontal then vertical decomposed pass."""
    return shift_minmax_1d(shift_minmax_1d(x, 1, op), 0, op)
