"""Shared in-kernel helpers for the fused morphology Pallas kernels.

Everything here executes inside a Pallas kernel body on VMEM-resident
values.  The 1-D passes mirror the paper's decomposed SIMD kernels
(Fig. 2): three displaced views min/max-ed together — on TPU the
"displaced registers" are lane/sublane shifts of a vreg tile.  The
edge/identity-pinning helpers implement the bit-exactness contract
documented in ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl


def image_edges(i, bands_per_image: int):
    """(at_top, at_bot) for grid step ``i`` of a vertically stacked batch.

    The drivers lay N images out as one (N·H_pad, W) array; band
    ``i`` is the ``i % bands_per_image``-th band of its image, and halo
    pinning must happen at *image* edges (not stack edges) so values
    never propagate between images.
    """
    j = i % bands_per_image
    return j == 0, j == bands_per_image - 1


def tile_edges(j, n_tiles: int):
    """(at_left, at_right) for column-tile ``j`` of a ``n_tiles``-wide
    activity grid.  Images are only ever stacked *vertically*, so the
    horizontal image edges coincide with the array edges — the first and
    last tile pin their column halos to the identity exactly like the
    row axis pins at image edges."""
    return j == 0, j == n_tiles - 1


def row_specs(band_h: int, fuse_k: int, h: int, w: int):
    """The three BlockSpecs feeding one full-width row band of a 1-D
    grid its K-row top halo, centre band and K-row bottom halo, in
    (top, mid, bot) order.  Halo index maps clamp at the array border;
    the kernels pin clamped out-of-image reads to the lattice identity
    (``image_edges``).  Shared by every row-band kernel
    (``erode_chain``, ``geodesic_chain``, ``qdt_chain``) and evaluated
    symbolically by ``repro.analysis.indexmaps`` — the bounds the
    verifier proves are the bounds the kernels run with.
    """
    r = band_h // fuse_k   # fuse_k-row halo blocks per band
    last_k_block = h // fuse_k - 1
    return [
        # K-row halo above the band (clamped at the stack top)
        pl.BlockSpec((fuse_k, w), lambda i: (jnp.maximum(i * r - 1, 0), 0)),
        # the band itself
        pl.BlockSpec((band_h, w), lambda i: (i, 0)),
        # K-row halo below the band (clamped at the stack bottom)
        pl.BlockSpec(
            (fuse_k, w),
            lambda i: (jnp.minimum((i + 1) * r, last_k_block), 0),
        ),
    ]


def tile_specs(band_h: int, tile_w: int, fuse_k: int, h: int, w: int):
    """The nine BlockSpecs feeding one (band_h, tile_w) cell of a 2-D
    grid its centre block and eight clamped neighbour halos, in
    ``assemble_tile`` order (tl, top, tr, left, mid, right, bl, bot,
    br).  Clamped edge reads are pinned in-kernel.

    NOTE (on-TPU follow-up): the corner/side halo blocks are only
    ``fuse_k`` lanes wide — fine in interpret mode, but narrower than
    the 128-lane tiling Mosaic wants; interpret=False validation may
    need them widened or fetched differently.
    """
    r = band_h // fuse_k   # fuse_k-row blocks per band
    c = tile_w // fuse_k   # fuse_k-col blocks per tile
    last_r = h // fuse_k - 1
    last_c = w // fuse_k - 1

    def up(i):
        return jnp.maximum(i * r - 1, 0)

    def dn(i):
        return jnp.minimum((i + 1) * r, last_r)

    def lf(j):
        return jnp.maximum(j * c - 1, 0)

    def rt(j):
        return jnp.minimum((j + 1) * c, last_c)

    kk, kw, bk = (fuse_k, fuse_k), (fuse_k, tile_w), (band_h, fuse_k)
    return [
        pl.BlockSpec(kk, lambda i, j: (up(i), lf(j))),
        pl.BlockSpec(kw, lambda i, j: (up(i), j)),
        pl.BlockSpec(kk, lambda i, j: (up(i), rt(j))),
        pl.BlockSpec(bk, lambda i, j: (i, lf(j))),
        pl.BlockSpec((band_h, tile_w), lambda i, j: (i, j)),
        pl.BlockSpec(bk, lambda i, j: (i, rt(j))),
        pl.BlockSpec(kk, lambda i, j: (dn(i), lf(j))),
        pl.BlockSpec(kw, lambda i, j: (dn(i), j)),
        pl.BlockSpec(kk, lambda i, j: (dn(i), rt(j))),
    ]


def assemble_tile(parts, edges, ident):
    """Assemble one (band_h + 2K, tile_w + 2K) working stack from the
    nine blocks of a 2-D tiled grid step, pinning out-of-image halos.

    ``parts`` are the (tl, top, tr, left, mid, right, bl, bot, br)
    kernel refs; ``edges`` the (at_top, at_bot, at_left, at_right)
    scalars for this grid step.  Edge halos read *clamped* blocks (the
    BlockSpec index maps clip at the array border), so every block whose
    true source lies outside the image is replaced with ``ident`` here —
    corners pin when either of their two axes is at an edge.  The result
    is the 2-D analogue of the row kernels' top/mid/bot concatenation:
    after K elementary steps the centre (band_h, tile_w) window is
    exact.
    """
    tl, top, tr, lf, mid, rt, bl, bot, br = parts
    at_top, at_bot, at_lf, at_rt = edges
    row_t = jnp.concatenate([
        jnp.where(jnp.logical_or(at_top, at_lf), ident, tl[...]),
        jnp.where(at_top, ident, top[...]),
        jnp.where(jnp.logical_or(at_top, at_rt), ident, tr[...]),
    ], axis=1)
    row_m = jnp.concatenate([
        jnp.where(at_lf, ident, lf[...]),
        mid[...],
        jnp.where(at_rt, ident, rt[...]),
    ], axis=1)
    row_b = jnp.concatenate([
        jnp.where(jnp.logical_or(at_bot, at_lf), ident, bl[...]),
        jnp.where(at_bot, ident, bot[...]),
        jnp.where(jnp.logical_or(at_bot, at_rt), ident, br[...]),
    ], axis=1)
    return jnp.concatenate([row_t, row_m, row_b], axis=0)


def qdt_acc_dtype(dtype):
    """Residual-accumulator dtype of the quasi-distance transform: the
    paper's convention is float32 for floating images and int32
    otherwise.  This is the single source of truth — the Pallas QDT
    kernels, the requeue driver and the jnp oracle (``operators.qdt_raw``)
    all call it, which is what keeps the two engines' accumulation
    bit-identical (and what ``repro.analysis.dtypes`` audits for
    overflow headroom per supported dtype).
    """
    return (jnp.float32 if jnp.issubdtype(jnp.dtype(dtype), jnp.floating)
            else jnp.int32)


def ident_for(op: str, dtype):
    """Lattice identity: +max for erosion (min-op), -max for dilation."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        hi, lo = jnp.array(jnp.inf, dtype), jnp.array(-jnp.inf, dtype)
    else:
        info = jnp.iinfo(dtype)
        hi, lo = jnp.array(info.max, dtype), jnp.array(info.min, dtype)
    return hi if op == "erode" else lo


def shift_minmax_1d(x: jnp.ndarray, axis: int, op: str) -> jnp.ndarray:
    """min/max(x, x<<1, x>>1) along ``axis`` with identity fill.

    This is the paper's Algorithm-1 inner step: registers A/B/C are the
    three displaced views; on TPU the displacement is a concat-shift on
    the sublane (axis 0) or lane (axis 1) dimension of the VMEM tile.
    """
    fill_shape = list(x.shape)
    fill_shape[axis] = 1
    fill = jnp.full(fill_shape, ident_for(op, x.dtype), x.dtype)

    idx_fwd = [slice(None)] * x.ndim
    idx_fwd[axis] = slice(1, None)
    idx_bwd = [slice(None)] * x.ndim
    idx_bwd[axis] = slice(0, -1)
    left = jnp.concatenate([x[tuple(idx_fwd)], fill], axis=axis)
    right = jnp.concatenate([fill, x[tuple(idx_bwd)]], axis=axis)

    f = jnp.minimum if op == "erode" else jnp.maximum
    return f(x, f(left, right))


def elementary_3x3(x: jnp.ndarray, op: str) -> jnp.ndarray:
    """ε₁ / δ₁ on a VMEM tile: horizontal then vertical decomposed pass."""
    return shift_minmax_1d(shift_minmax_1d(x, 1, op), 0, op)
