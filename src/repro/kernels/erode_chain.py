"""Fused K-step 3×3 erosion/dilation chain — the paper's core, as a
Pallas TPU kernel.

Layout (one grid step = one row band of TH useful rows):

        ┌──────────────┐   top halo   (K rows, block of the same array)
        │  K rows      │
        ├──────────────┤
        │  TH rows     │   band i     (useful output)
        ├──────────────┤
        │  K rows      │   bottom halo
        └──────────────┘

The stacked (TH+2K, W) tile lives in VMEM for all K elementary filter
applications; validity shrinks one row per application from each stack
edge, so after K steps the centre TH rows are exact.  This replaces the
paper's per-row atomic synchronization between pipelined threads with
redundant halo compute — the TPU-idiomatic trade (the bit-exactness
argument lives in ``docs/ARCHITECTURE.md``).

Fixed-length chains have no convergence flag, so this kernel stays on
the 1-D row-band grid; the 2-D tiled grids exist only on the
convergence-driven kernels the requeue scheduler drives
(``geodesic_chain``, ``qdt_chain``).

Border semantics: the wrapper pads the image to (H_pad, W_pad) with the
lattice identity; for a convex (rectangular) domain, iterated erosion
with identity padding restricted to the original domain equals the
paper's border-clipped erosion (projection argument — any 8-connected
path through the padding can be clamped coordinate-wise back into the
rectangle without growing its length).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (elementary_3x3, ident_for, image_edges,
                                  row_specs)


def _chain_kernel(x_top, x_mid, x_bot, out, *, op: str, fuse_k: int,
                  band_h: int, bands_per_image: int):
    ident = ident_for(op, x_mid.dtype)

    at_top, at_bot = image_edges(pl.program_id(0), bands_per_image)
    top = jnp.where(at_top, ident, x_top[...])
    bot = jnp.where(at_bot, ident, x_bot[...])
    stack = jnp.concatenate([top, x_mid[...], bot], axis=0)

    for _ in range(fuse_k):
        stack = elementary_3x3(stack, op)

    out[...] = stack[fuse_k : fuse_k + band_h, :]


def chain_step(
    x: jnp.ndarray,
    *,
    op: str,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
    bands_per_image: int | None = None,
) -> jnp.ndarray:
    """Apply K fused elementary filters to a pre-padded image (stack).

    ``x``: (H_pad, W_pad) with H_pad % band_h == 0, band_h % fuse_k == 0,
    padding filled with the lattice identity for ``op``.  For a vertical
    stack of N images pass ``bands_per_image`` so the halo is pinned at
    each image's edges rather than only the stack's.
    """
    h, w = x.shape
    assert h % band_h == 0 and band_h % fuse_k == 0, (h, band_h, fuse_k)
    n_bands = h // band_h
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0

    kern = functools.partial(_chain_kernel, op=op, fuse_k=fuse_k,
                             band_h=band_h, bands_per_image=bands_per_image)

    return pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=row_specs(band_h, fuse_k, h, w),
        out_specs=pl.BlockSpec((band_h, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=interpret,
    )(x, x, x)
