"""Fused generalised-geodesic-distance chunk (the ``gdt`` kernel op).

Each of the K fused steps relaxes the distance plane over the
8-connected neighbourhood with the grey-weighted additive cost

    w(p, q) = 1 + lamb * |I(p) - I(q)|

    D'(p)   = min(D(p), min_q D(q) + w(p, q))

— the FastGeodis/DTOCS generalisation of the paper's elementary
geodesic step (see ``repro.gdt.reference`` for the shared fixpoint
contract and the bit-exactness argument).

Three resident planes ride each scheduling cell:

``d``   the evolving distance plane (the only written plane);
``i``   the grey-weight image (constant; supplies the edge costs);
``s``   the seed plane doubling as the pad marker (constant): the
        driver stages ``s = -1`` on every padded cell, and the kernel
        re-clamps ``d = +inf`` wherever ``s < 0`` after *every*
        elementary step — padding can never propagate finite distances
        into the real region, which is what makes a lone gdt segment
        pad-safe under the usual absorbing-fill argument.

All three planes carry the K-pixel halo (neighbour distances *and*
neighbour grey values feed the relaxation), pinned at image edges to
their absorbing identities: ``d -> +inf``, ``i -> 0``, ``s -> -1``.
``lamb`` is a *static* kernel parameter: ``lamb == 0`` compiles the
constant-weight branch (pure Chebyshev propagation) with no multiply —
and, crucially, no ``0 * inf`` NaN hazard against pinned halos.

The same three grid shapes exist as for reconstruction and the QDT:
``gdt_chain_step`` (full-width row bands), ``gdt_tile_step`` (2-D
band × column-tile grid) and ``gdt_compact_step`` (dense workspace of
driver-gathered patches).  They plug into the same
``_drive_scheduler`` lifecycle (``kernels/ops.py``); the raster-scan
alternative schedule lives in the driver, not here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (assemble_tile, image_edges, row_specs,
                                  tile_edges, tile_specs)

#: Absorbing halo/pad identities per plane.
D_IDENT = jnp.inf    # distance: +inf never wins a min
I_IDENT = 0.0        # image: any finite value (weight stays finite)
S_IDENT = -1.0       # seeds: the pad marker the kernel clamps on


def _shift2(x, dy, dx, fill):
    """x translated by (dy, dx) with vacated cells set to ``fill``."""
    h, w = x.shape
    if dy > 0:
        x = jnp.concatenate(
            [jnp.full((dy, w), fill, x.dtype), x[:-dy]], axis=0)
    elif dy < 0:
        x = jnp.concatenate(
            [x[-dy:], jnp.full((-dy, w), fill, x.dtype)], axis=0)
    if dx > 0:
        x = jnp.concatenate(
            [jnp.full((h, dx), fill, x.dtype), x[:, :-dx]], axis=1)
    elif dx < 0:
        x = jnp.concatenate(
            [x[:, -dx:], jnp.full((h, -dx), fill, x.dtype)], axis=1)
    return x


#: The 8-connected neighbourhood.
_OFFSETS = tuple(
    (dy, dx)
    for dy in (-1, 0, 1)
    for dx in (-1, 0, 1)
    if (dy, dx) != (0, 0)
)


def elementary_gdt(d, i, s, lamb: float):
    """One grey-weighted relaxation on a halo-extended stack.

    Shift fills are absorbing (``d`` pulls +inf candidates, ``i`` a
    finite 0), so the outer ring degrades by one valid pixel per step —
    the same halo-shrinkage contract as ``elementary_3x3``.  The final
    ``where`` re-pins every pad cell (``s < 0``) to +inf.
    """
    best = d
    for dy, dx in _OFFSETS:
        dq = _shift2(d, dy, dx, D_IDENT)
        if lamb == 0.0:
            cand = dq + 1.0
        else:
            iq = _shift2(i, dy, dx, I_IDENT)
            # The outer abs is a no-op on the non-negative product but
            # blocks XLA's fmul+fadd→fma contraction, keeping the
            # jitted weight bit-identical to the two-rounding NumPy
            # reference (mul rounds, then add rounds).
            cand = dq + (1.0 + jnp.abs(lamb * jnp.abs(i - iq)))
        best = jnp.minimum(best, cand)
    return jnp.where(s < 0, jnp.asarray(D_IDENT, d.dtype), best)


def _gdt_update(d, i, s, window, *, fuse_k: int, lamb: float):
    """The K-step relaxation loop shared by every gdt grid shape."""
    (lo, hi), (cl, cr) = window
    for _ in range(fuse_k):
        d = elementary_gdt(d, i, s, lamb)
    return d[lo:hi, cl:cr]


def _gdt_kernel(
    active, d_top, d_mid, d_bot, i_top, i_mid, i_bot, s_top, s_mid, s_bot,
    d_out, changed,
    *, fuse_k: int, band_h: int, lamb: float, bands_per_image: int,
):
    # program_id is not available inside pl.when branches in interpret
    # mode — read it at kernel top level.
    at_top, at_bot = image_edges(pl.program_id(0), bands_per_image)

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        d_out[...] = d_mid[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        def stack3(top, mid, bot, ident):
            t = jnp.where(at_top, jnp.asarray(ident, mid.dtype), top[...])
            b = jnp.where(at_bot, jnp.asarray(ident, mid.dtype), bot[...])
            return jnp.concatenate([t, mid[...], b], axis=0)

        d = stack3(d_top, d_mid, d_bot, D_IDENT)
        i = stack3(i_top, i_mid, i_bot, I_IDENT)
        s = stack3(s_top, s_mid, s_bot, S_IDENT)
        w = d_mid.shape[1]
        centre = _gdt_update(
            d, i, s, ((fuse_k, fuse_k + band_h), (0, w)),
            fuse_k=fuse_k, lamb=lamb,
        )
        d_out[...] = centre
        changed[...] = (
            jnp.any(centre != d_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def gdt_chain_step(
    d: jnp.ndarray,
    i: jnp.ndarray,
    s: jnp.ndarray,
    *,
    lamb: float,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """One K-step gdt chunk on pre-padded planes (full-width row bands).

    ``d``/``i``/``s`` are same-shaped float planes (see the module
    docstring for their roles); ``active`` optionally skips converged
    bands.  Returns (d', changed) — changed is (n_bands, 1) int32.
    """
    h, w = d.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    assert i.shape == s.shape == d.shape
    n_bands = h // band_h
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, 1), jnp.int32)

    top_spec, mid_spec, bot_spec = row_specs(band_h, fuse_k, h, w)
    flag_spec = pl.BlockSpec((1, 1), lambda b: (b, 0))

    kern = functools.partial(
        _gdt_kernel, fuse_k=fuse_k, band_h=band_h, lamb=float(lamb),
        bands_per_image=bands_per_image,
    )
    return pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=[flag_spec,
                  top_spec, mid_spec, bot_spec,
                  top_spec, mid_spec, bot_spec,
                  top_spec, mid_spec, bot_spec],
        out_specs=[mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), d.dtype),
            jax.ShapeDtypeStruct((n_bands, 1), jnp.int32),
        ],
        interpret=interpret,
    )(active, d, d, d, i, i, i, s, s, s)


def _gdt_tile_kernel(
    active, *refs,
    fuse_k: int, band_h: int, tile_w: int, lamb: float,
    bands_per_image: int, n_tiles: int,
):
    """2-D grid body: ``refs`` are 9 d blocks, 9 i blocks, 9 s blocks,
    then the (d_out, changed) outputs."""
    d_parts, i_parts, s_parts = refs[:9], refs[9:18], refs[18:27]
    d_out, changed = refs[27:]
    d_mid = d_parts[4]
    at_top, at_bot = image_edges(pl.program_id(0), bands_per_image)
    at_lf, at_rt = tile_edges(pl.program_id(1), n_tiles)
    edges = (at_top, at_bot, at_lf, at_rt)

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        d_out[...] = d_mid[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        d = assemble_tile(d_parts, edges, jnp.asarray(D_IDENT, d_mid.dtype))
        i = assemble_tile(i_parts, edges, jnp.asarray(I_IDENT, d_mid.dtype))
        s = assemble_tile(s_parts, edges, jnp.asarray(S_IDENT, d_mid.dtype))
        centre = _gdt_update(
            d, i, s,
            ((fuse_k, fuse_k + band_h), (fuse_k, fuse_k + tile_w)),
            fuse_k=fuse_k, lamb=lamb,
        )
        d_out[...] = centre
        changed[...] = (
            jnp.any(centre != d_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def gdt_tile_step(
    d: jnp.ndarray,
    i: jnp.ndarray,
    s: jnp.ndarray,
    *,
    lamb: float,
    fuse_k: int,
    band_h: int,
    tile_w: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """One K-step gdt chunk on the 2-D (band × column-tile) grid.

    Same contract as :func:`gdt_chain_step` with the width split into
    ``W // tile_w`` column tiles; ``active``/``changed`` are
    (n_bands, n_tiles) int32 grids.
    """
    h, w = d.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    assert w % tile_w == 0 and tile_w % fuse_k == 0
    assert i.shape == s.shape == d.shape
    n_bands = h // band_h
    n_tiles = w // tile_w
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, n_tiles), jnp.int32)

    flag_spec = pl.BlockSpec((1, 1), lambda b, t: (b, t))
    mid_spec = pl.BlockSpec((band_h, tile_w), lambda b, t: (b, t))
    plane = tile_specs(band_h, tile_w, fuse_k, h, w)
    kern = functools.partial(
        _gdt_tile_kernel, fuse_k=fuse_k, band_h=band_h, tile_w=tile_w,
        lamb=float(lamb), bands_per_image=bands_per_image, n_tiles=n_tiles,
    )
    return pl.pallas_call(
        kern,
        grid=(n_bands, n_tiles),
        in_specs=[flag_spec] + plane + plane + plane,
        out_specs=[mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), d.dtype),
            jax.ShapeDtypeStruct((n_bands, n_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(active, *([d] * 9), *([i] * 9), *([s] * 9))


def _gdt_compact_kernel(
    valid, d_patch, i_patch, s_patch, d_out, changed,
    *, fuse_k: int, band_h: int, tile_w: int, lamb: float,
):
    lo, hi = fuse_k, fuse_k + band_h
    cl, cr = fuse_k, fuse_k + tile_w

    @pl.when(valid[0, 0] == 0)
    def _passthrough():
        d_out[...] = d_patch[lo:hi, cl:cr]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(valid[0, 0] > 0)
    def _compute():
        centre0 = d_patch[lo:hi, cl:cr]
        centre = _gdt_update(
            d_patch[...], i_patch[...], s_patch[...],
            ((lo, hi), (cl, cr)), fuse_k=fuse_k, lamb=lamb,
        )
        d_out[...] = centre
        changed[...] = (
            jnp.any(centre != centre0).astype(jnp.int32).reshape(1, 1)
        )


def gdt_compact_step(
    d_patch: jnp.ndarray,
    i_patch: jnp.ndarray,
    s_patch: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    lamb: float,
    fuse_k: int,
    band_h: int,
    tile_w: int,
    interpret: bool = True,
):
    """Compacted-grid gdt chunk on driver-gathered active cells.

    All three planes arrive as (C·(band_h+2K), tile_w+2K) patches with
    halos pre-pinned by the gather (``d -> +inf``, ``i -> 0``,
    ``s -> -1``); ``valid`` is (C, 1) int32.  Returns (d', changed)
    with d' centre-only (C·band_h, tile_w); row-only plans use
    ``tile_w = width_pad``.
    """
    ph = band_h + 2 * fuse_k
    pw = tile_w + 2 * fuse_k
    assert d_patch.shape[1] == pw and d_patch.shape[0] % ph == 0
    assert i_patch.shape == s_patch.shape == d_patch.shape
    cap = d_patch.shape[0] // ph

    patch_spec = pl.BlockSpec((ph, pw), lambda c: (c, 0))
    mid_spec = pl.BlockSpec((band_h, tile_w), lambda c: (c, 0))
    flag_spec = pl.BlockSpec((1, 1), lambda c: (c, 0))

    kern = functools.partial(
        _gdt_compact_kernel, fuse_k=fuse_k, band_h=band_h, tile_w=tile_w,
        lamb=float(lamb),
    )
    return pl.pallas_call(
        kern,
        grid=(cap,),
        in_specs=[flag_spec, patch_spec, patch_spec, patch_spec],
        out_specs=[mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((cap * band_h, tile_w), d_patch.dtype),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
        ],
        interpret=interpret,
    )(valid, d_patch, i_patch, s_patch)
