"""Fused K-step elementary geodesic erosion/dilation with convergence
flag — Algorithm 4 of the paper as a Pallas kernel.

Each of the K fused steps applies ε₁ then clamps with the mask
(max(·, m) for erosion, min(·, m) for dilation) — the geodesic clamp is
pointwise, so halo recompute stays exact as long as the mask halo is
available too.

Padding contract (enforced by kernels.ops): the *mask* padding pins the
pad region to the lattice identity of the marker (mask = +max for
geodesic erosion ⇒ padded marker rows stay +max forever), so no value
can propagate through the padding and the border-clipped semantics of
the paper are preserved exactly — including for geodesic paths, where
the convexity argument alone would not suffice (a path through padding
would dodge intermediate mask clamps).

Convergence: the per-tile flag is 1 iff any centre pixel changed during
the chunk.  Because the geodesic sequence is pointwise monotone, "no
centre pixel anywhere changed across K steps" ⇔ global fixpoint of ε₁ᵐ
— this is the kernel-level version of the paper's ``converged`` flag +
requeue mechanism.

Requeue scheduling (this file's side of it — the driver's side lives in
``kernels.ops`` and is documented in ``docs/ARCHITECTURE.md``): each
scheduling cell carries an ``active`` scalar.  When 0, the kernel
early-outs under ``pl.when`` and writes the input through unchanged
with a zero flag — the skipped cell costs one VMEM copy instead of K
elementary filters.  Three grid shapes share the one kernel body:

* ``geodesic_chain_step`` — 1-D grid of full-width row bands (the
  paper's Alg. 4 granularity); cells are bands.
* ``geodesic_tile_step`` — 2-D grid of (row band × column tile) cells;
  each grid step assembles a (band_h + 2K, tile_w + 2K) stack from the
  nine neighbouring blocks so a narrow *vertical* wavefront can skip
  the quiet column strips too.  Exact for
  ``fuse_k <= min(band_h, tile_w)``.
* ``geodesic_compact_step`` — 1-D grid over driver-gathered patches of
  the active cells (compaction; halos pre-pinned by the gather).

Batching: the driver stacks N images vertically into one
(N·H_pad, W) array; ``bands_per_image`` makes the halo pinning happen
at *image* edges so nothing leaks between stacked images.  Horizontal
image edges coincide with the array edges (images never stack
sideways), so column-halo pinning is per-tile-row (``tile_edges``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (assemble_tile, elementary_3x3, ident_for,
                                  image_edges, row_specs, tile_edges,
                                  tile_specs)


def _geodesic_kernel(
    active, f_top, f_mid, f_bot, m_top, m_mid, m_bot, out, changed,
    *, op: str, fuse_k: int, band_h: int, bands_per_image: int,
):
    # program_id must be read outside the pl.when bodies (the branches
    # are compiled as plain cond branches in interpret mode, where the
    # primitive has no lowering).
    at_top, at_bot = image_edges(pl.program_id(0), bands_per_image)

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        # converged band: pass the input through, report no change.
        out[...] = f_mid[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        ident = ident_for(op, f_mid.dtype)
        # Pin the out-of-image halo: marker ← identity, mask ←
        # identity, so the pad region is absorbing and transmits
        # nothing (also between stacked batch images).
        ftop = jnp.where(at_top, ident, f_top[...])
        fbot = jnp.where(at_bot, ident, f_bot[...])
        mtop = jnp.where(at_top, ident, m_top[...])
        mbot = jnp.where(at_bot, ident, m_bot[...])

        stack = jnp.concatenate([ftop, f_mid[...], fbot], axis=0)
        mask = jnp.concatenate([mtop, m_mid[...], mbot], axis=0)

        clamp = jnp.maximum if op == "erode" else jnp.minimum
        for _ in range(fuse_k):
            stack = clamp(elementary_3x3(stack, op), mask)

        centre = stack[fuse_k : fuse_k + band_h, :]
        out[...] = centre
        changed[...] = (
            jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def geodesic_chain_step(
    f: jnp.ndarray,
    m: jnp.ndarray,
    *,
    op: str,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """K fused geodesic steps on a pre-padded marker/mask (stack).

    ``f``/``m`` are (H, W) with H a multiple of ``band_h`` — possibly a
    vertical stack of ``H // (bands_per_image · band_h)`` images.
    ``active`` is an optional (n_bands, 1) int32 activity vector; bands
    with 0 are skipped (input copied through, flag 0).

    Returns (new_marker, changed) with changed an (n_bands, 1) int32.
    """
    h, w = f.shape
    assert f.shape == m.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    n_bands = h // band_h
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, 1), jnp.int32)

    act_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    plane = row_specs(band_h, fuse_k, h, w)

    kern = functools.partial(
        _geodesic_kernel, op=op, fuse_k=fuse_k, band_h=band_h,
        bands_per_image=bands_per_image,
    )
    out, changed = pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=[act_spec] + plane + plane,
        out_specs=[
            pl.BlockSpec((band_h, w), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((n_bands, 1), jnp.int32),
        ],
        interpret=interpret,
    )(active, f, f, f, m, m, m)
    return out, changed


def _geodesic_tile_kernel(
    active, *refs,
    op: str, fuse_k: int, band_h: int, tile_w: int,
    bands_per_image: int, n_tiles: int,
):
    """2-D grid body: ``refs`` are 9 marker blocks, 9 mask blocks, then
    the (out, changed) outputs."""
    f_parts, m_parts = refs[:9], refs[9:18]
    out, changed = refs[18], refs[19]
    f_mid = f_parts[4]
    at_top, at_bot = image_edges(pl.program_id(0), bands_per_image)
    at_lf, at_rt = tile_edges(pl.program_id(1), n_tiles)
    edges = (at_top, at_bot, at_lf, at_rt)

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        out[...] = f_mid[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        ident = ident_for(op, f_mid.dtype)
        stack = assemble_tile(f_parts, edges, ident)
        mask = assemble_tile(m_parts, edges, ident)

        clamp = jnp.maximum if op == "erode" else jnp.minimum
        for _ in range(fuse_k):
            stack = clamp(elementary_3x3(stack, op), mask)

        centre = stack[fuse_k : fuse_k + band_h, fuse_k : fuse_k + tile_w]
        out[...] = centre
        changed[...] = (
            jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def geodesic_tile_step(
    f: jnp.ndarray,
    m: jnp.ndarray,
    *,
    op: str,
    fuse_k: int,
    band_h: int,
    tile_w: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """K fused geodesic steps on the 2-D (band × column-tile) grid.

    Same contract as :func:`geodesic_chain_step` with the width split
    into ``W // tile_w`` column tiles: ``active``/``changed`` are
    (n_bands, n_tiles) int32 grids and inactive *tiles* (not just
    bands) early-out.  Requires ``tile_w % fuse_k == 0`` and
    ``W % tile_w == 0`` (``ChainPlan`` validates the same).
    """
    h, w = f.shape
    assert f.shape == m.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    assert w % tile_w == 0 and tile_w % fuse_k == 0
    n_bands = h // band_h
    n_tiles = w // tile_w
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, n_tiles), jnp.int32)

    act_spec = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    plane = tile_specs(band_h, tile_w, fuse_k, h, w)
    kern = functools.partial(
        _geodesic_tile_kernel, op=op, fuse_k=fuse_k, band_h=band_h,
        tile_w=tile_w, bands_per_image=bands_per_image, n_tiles=n_tiles,
    )
    out, changed = pl.pallas_call(
        kern,
        grid=(n_bands, n_tiles),
        in_specs=[act_spec] + plane + plane,
        out_specs=[pl.BlockSpec((band_h, tile_w), lambda i, j: (i, j)),
                   act_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((n_bands, n_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(active, *([f] * 9), *([m] * 9))
    return out, changed


def _geodesic_compact_kernel(
    valid, f_patch, m_patch, out, changed,
    *, op: str, fuse_k: int, band_h: int, tile_w: int,
):
    lo, hi = fuse_k, fuse_k + band_h
    cl, cr = fuse_k, fuse_k + tile_w

    @pl.when(valid[0, 0] == 0)
    def _passthrough():
        out[...] = f_patch[lo:hi, cl:cr]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(valid[0, 0] > 0)
    def _compute():
        stack = f_patch[...]
        mask = m_patch[...]
        centre0 = stack[lo:hi, cl:cr]
        clamp = jnp.maximum if op == "erode" else jnp.minimum
        for _ in range(fuse_k):
            stack = clamp(elementary_3x3(stack, op), mask)
        centre = stack[lo:hi, cl:cr]
        out[...] = centre
        changed[...] = (
            jnp.any(centre != centre0).astype(jnp.int32).reshape(1, 1)
        )


def geodesic_compact_step(
    f_patch: jnp.ndarray,
    m_patch: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    op: str,
    fuse_k: int,
    band_h: int,
    tile_w: int,
    interpret: bool = True,
):
    """Compacted-grid variant: the driver has already gathered each
    active cell into a (band_h + 2K, tile_w + 2K) *patch* — centre plus
    halos on all four sides, image-edge pinning applied by the gather
    (the kernel cannot know slot → image geometry).  Block ``i`` reads
    slot ``i``; ``valid`` masks workspace slots past the true active
    count (their output is dropped at scatter time anyway).

    Shapes: f_patch/m_patch (C·(band_h+2K), tile_w+2K); valid (C, 1)
    int32.  Returns (new_mid (C·band_h, tile_w), changed (C, 1)).
    Row-only plans use this too, with ``tile_w = width_pad``.
    """
    ph = band_h + 2 * fuse_k
    pw = tile_w + 2 * fuse_k
    assert f_patch.shape == m_patch.shape and f_patch.shape[1] == pw
    assert f_patch.shape[0] % ph == 0
    cap = f_patch.shape[0] // ph

    patch_spec = pl.BlockSpec((ph, pw), lambda i: (i, 0))
    mid_spec = pl.BlockSpec((band_h, tile_w), lambda i: (i, 0))
    flag_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))

    kern = functools.partial(
        _geodesic_compact_kernel, op=op, fuse_k=fuse_k, band_h=band_h,
        tile_w=tile_w,
    )
    out, changed = pl.pallas_call(
        kern,
        grid=(cap,),
        in_specs=[flag_spec, patch_spec, patch_spec],
        out_specs=[mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((cap * band_h, tile_w), f_patch.dtype),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
        ],
        interpret=interpret,
    )(valid, f_patch, m_patch)
    return out, changed
