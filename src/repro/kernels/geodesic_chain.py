"""Fused K-step elementary geodesic erosion/dilation with convergence
flag — Algorithm 4 of the paper as a Pallas kernel.

Each of the K fused steps applies ε₁ then clamps with the mask
(max(·, m) for erosion, min(·, m) for dilation) — the geodesic clamp is
pointwise, so halo recompute stays exact as long as the mask halo is
available too.

Padding contract (enforced by kernels.ops): the *mask* padding pins the
pad region to the lattice identity of the marker (mask = +max for
geodesic erosion ⇒ padded marker rows stay +max forever), so no value
can propagate through the padding and the border-clipped semantics of
the paper are preserved exactly — including for geodesic paths, where
the convexity argument alone would not suffice (a path through padding
would dodge intermediate mask clamps).

Convergence: the per-band flag is 1 iff any centre pixel changed during
the chunk.  Because the geodesic sequence is pointwise monotone, "no
centre pixel anywhere changed across K steps" ⇔ global fixpoint of ε₁ᵐ
(DESIGN.md §3) — this is the kernel-level version of the paper's
``converged`` flag + requeue mechanism.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import elementary_3x3, ident_for


def _geodesic_kernel(
    f_top, f_mid, f_bot, m_top, m_mid, m_bot, out, changed,
    *, op: str, fuse_k: int, band_h: int,
):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    # Pin the out-of-image halo: marker ← identity, mask ← identity, so the
    # pad region is absorbing and transmits nothing.
    ident = ident_for(op, f_mid.dtype)

    ftop = jnp.where(i > 0, f_top[...], ident)
    fbot = jnp.where(i < n - 1, f_bot[...], ident)
    mtop = jnp.where(i > 0, m_top[...], ident)
    mbot = jnp.where(i < n - 1, m_bot[...], ident)

    stack = jnp.concatenate([ftop, f_mid[...], fbot], axis=0)
    mask = jnp.concatenate([mtop, m_mid[...], mbot], axis=0)

    clamp = jnp.maximum if op == "erode" else jnp.minimum
    for _ in range(fuse_k):
        stack = clamp(elementary_3x3(stack, op), mask)

    centre = stack[fuse_k : fuse_k + band_h, :]
    out[...] = centre
    changed[...] = jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)


def geodesic_chain_step(
    f: jnp.ndarray,
    m: jnp.ndarray,
    *,
    op: str,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
):
    """K fused geodesic steps on pre-padded marker/mask.

    Returns (new_marker, changed) with changed an (n_bands, 1) int32.
    """
    h, w = f.shape
    assert f.shape == m.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    n_bands = h // band_h
    r = band_h // fuse_k
    last_k_block = h // fuse_k - 1

    top_spec = pl.BlockSpec((fuse_k, w), lambda i: (jnp.maximum(i * r - 1, 0), 0))
    mid_spec = pl.BlockSpec((band_h, w), lambda i: (i, 0))
    bot_spec = pl.BlockSpec(
        (fuse_k, w), lambda i: (jnp.minimum((i + 1) * r, last_k_block), 0)
    )

    kern = functools.partial(_geodesic_kernel, op=op, fuse_k=fuse_k, band_h=band_h)
    out, changed = pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=[top_spec, mid_spec, bot_spec, top_spec, mid_spec, bot_spec],
        out_specs=[
            pl.BlockSpec((band_h, w), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((n_bands, 1), jnp.int32),
        ],
        interpret=interpret,
    )(f, f, f, m, m, m)
    return out, changed
