"""Fused K-step elementary geodesic erosion/dilation with convergence
flag — Algorithm 4 of the paper as a Pallas kernel.

Each of the K fused steps applies ε₁ then clamps with the mask
(max(·, m) for erosion, min(·, m) for dilation) — the geodesic clamp is
pointwise, so halo recompute stays exact as long as the mask halo is
available too.

Padding contract (enforced by kernels.ops): the *mask* padding pins the
pad region to the lattice identity of the marker (mask = +max for
geodesic erosion ⇒ padded marker rows stay +max forever), so no value
can propagate through the padding and the border-clipped semantics of
the paper are preserved exactly — including for geodesic paths, where
the convexity argument alone would not suffice (a path through padding
would dodge intermediate mask clamps).

Convergence: the per-band flag is 1 iff any centre pixel changed during
the chunk.  Because the geodesic sequence is pointwise monotone, "no
centre pixel anywhere changed across K steps" ⇔ global fixpoint of ε₁ᵐ
(DESIGN.md §3) — this is the kernel-level version of the paper's
``converged`` flag + requeue mechanism.

Requeue scheduling (this file's side of it): each band carries an
``active`` scalar.  When 0, the kernel early-outs under ``pl.when`` and
writes the input band through unchanged with a zero flag — the skipped
band costs one VMEM copy instead of K elementary filters.  The driver
(kernels.ops) maintains the activity vector: a band is requeued iff it
or a vertical neighbour changed in the previous chunk, which is exact
because influence propagates at most ``fuse_k <= band_h`` rows per
chunk.

Batching: the driver stacks N images vertically into one
(N·H_pad, W) array; ``bands_per_image`` makes the halo pinning happen
at *image* edges so nothing leaks between stacked images.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import elementary_3x3, ident_for, image_edges


def _geodesic_kernel(
    active, f_top, f_mid, f_bot, m_top, m_mid, m_bot, out, changed,
    *, op: str, fuse_k: int, band_h: int, bands_per_image: int,
    pin_halos: bool,
):
    # program_id must be read outside the pl.when bodies (the branches
    # are compiled as plain cond branches in interpret mode, where the
    # primitive has no lowering).
    edges = image_edges(pl.program_id(0), bands_per_image) if pin_halos else None

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        # converged band: pass the input through, report no change.
        out[...] = f_mid[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        ident = ident_for(op, f_mid.dtype)
        ftop, fbot = f_top[...], f_bot[...]
        mtop, mbot = m_top[...], m_bot[...]
        if pin_halos:
            # Pin the out-of-image halo: marker ← identity, mask ←
            # identity, so the pad region is absorbing and transmits
            # nothing (also between stacked batch images).
            at_top, at_bot = edges
            ftop = jnp.where(at_top, ident, ftop)
            fbot = jnp.where(at_bot, ident, fbot)
            mtop = jnp.where(at_top, ident, mtop)
            mbot = jnp.where(at_bot, ident, mbot)

        stack = jnp.concatenate([ftop, f_mid[...], fbot], axis=0)
        mask = jnp.concatenate([mtop, m_mid[...], mbot], axis=0)

        clamp = jnp.maximum if op == "erode" else jnp.minimum
        for _ in range(fuse_k):
            stack = clamp(elementary_3x3(stack, op), mask)

        centre = stack[fuse_k : fuse_k + band_h, :]
        out[...] = centre
        changed[...] = (
            jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def geodesic_chain_step(
    f: jnp.ndarray,
    m: jnp.ndarray,
    *,
    op: str,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """K fused geodesic steps on a pre-padded marker/mask (stack).

    ``f``/``m`` are (H, W) with H a multiple of ``band_h`` — possibly a
    vertical stack of ``H // (bands_per_image · band_h)`` images.
    ``active`` is an optional (n_bands, 1) int32 activity vector; bands
    with 0 are skipped (input copied through, flag 0).

    Returns (new_marker, changed) with changed an (n_bands, 1) int32.
    """
    h, w = f.shape
    assert f.shape == m.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    n_bands = h // band_h
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, 1), jnp.int32)
    r = band_h // fuse_k
    last_k_block = h // fuse_k - 1

    act_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    top_spec = pl.BlockSpec((fuse_k, w), lambda i: (jnp.maximum(i * r - 1, 0), 0))
    mid_spec = pl.BlockSpec((band_h, w), lambda i: (i, 0))
    bot_spec = pl.BlockSpec(
        (fuse_k, w), lambda i: (jnp.minimum((i + 1) * r, last_k_block), 0)
    )

    kern = functools.partial(
        _geodesic_kernel, op=op, fuse_k=fuse_k, band_h=band_h,
        bands_per_image=bands_per_image, pin_halos=True,
    )
    out, changed = pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=[act_spec, top_spec, mid_spec, bot_spec,
                  top_spec, mid_spec, bot_spec],
        out_specs=[
            pl.BlockSpec((band_h, w), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((n_bands, 1), jnp.int32),
        ],
        interpret=interpret,
    )(active, f, f, f, m, m, m)
    return out, changed


def geodesic_compact_step(
    f_top: jnp.ndarray,
    f_mid: jnp.ndarray,
    f_bot: jnp.ndarray,
    m_top: jnp.ndarray,
    m_mid: jnp.ndarray,
    m_bot: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    op: str,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
):
    """Compacted-grid variant: the driver has already gathered the
    active bands (and their halos, with image-edge pinning applied) into
    dense workspaces, so block ``i`` simply reads slot ``i`` of each
    operand.  ``valid`` masks workspace slots past the true active count
    (their output is dropped at scatter time anyway).

    Shapes: f_mid/m_mid (C·band_h, W); f_top/f_bot/m_top/m_bot
    (C·fuse_k, W); valid (C, 1) int32.  Returns (new_mid, changed).
    """
    cap_bh, w = f_mid.shape
    assert cap_bh % band_h == 0
    cap = cap_bh // band_h
    assert f_top.shape == (cap * fuse_k, w)

    halo_spec = pl.BlockSpec((fuse_k, w), lambda i: (i, 0))
    mid_spec = pl.BlockSpec((band_h, w), lambda i: (i, 0))
    flag_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))

    kern = functools.partial(
        _geodesic_kernel, op=op, fuse_k=fuse_k, band_h=band_h,
        bands_per_image=cap, pin_halos=False,
    )
    out, changed = pl.pallas_call(
        kern,
        grid=(cap,),
        in_specs=[flag_spec, halo_spec, mid_spec, halo_spec,
                  halo_spec, mid_spec, halo_spec],
        out_specs=[mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((cap_bh, w), f_mid.dtype),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
        ],
        interpret=interpret,
    )(valid, f_top, f_mid, f_bot, m_top, m_mid, m_bot)
    return out, changed
