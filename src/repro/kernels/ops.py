"""jit'd public wrappers over the fused Pallas kernels.

These are the framework's fast path for the paper's operators.  Each
wrapper:

  1. plans the fusion schedule (``core.chain.plan_chain``),
  2. pads the image to the plan's (H_pad, W_pad) with the correct
     absorbing values (lattice identity / mask pinning — see the kernel
     docstrings for why this preserves border-clipped semantics),
  3. drives the kernel with ``lax.scan`` (fixed chains) or
     ``lax.while_loop`` (reconstruction — the paper's convergence
     detection, Alg. 4),
  4. crops back.

``backend``:
  * ``"pallas"``  — the fused kernels (interpret=True on CPU; on TPU the
    same code path compiles natively with interpret=False).
  * ``"xla"``     — same chunked schedule but pure-jnp bodies; what the
    framework runs when Pallas is unavailable.  Still one compiled
    program per chain (unlike the per-filter "naive" baseline).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import morphology as M
from repro.core.chain import ChainPlan, plan_chain
from repro.kernels.common import ident_for
from repro.kernels.erode_chain import chain_step
from repro.kernels.geodesic_chain import geodesic_chain_step
from repro.kernels.qdt_chain import qdt_chain_step

Backend = Literal["pallas", "xla"]

_INTERPRET = jax.default_backend() != "tpu"


def _pad(f: jnp.ndarray, plan: ChainPlan, fill) -> jnp.ndarray:
    h, w = f.shape
    return jnp.pad(
        f,
        ((0, plan.height_pad - h), (0, plan.width_pad - w)),
        constant_values=fill,
    )


def _crop(f: jnp.ndarray, shape) -> jnp.ndarray:
    return f[: shape[0], : shape[1]]


# ---------------------------------------------------------------------------
# fixed-length chains: ε_s / δ_s (paper Fig. 7 workload)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "op", "backend", "plan"))
def morph_chain(
    f: jnp.ndarray,
    n: int,
    op: str = "erode",
    backend: Backend = "pallas",
    plan: ChainPlan | None = None,
) -> jnp.ndarray:
    """Apply n elementary 3×3 erosions/dilations with K-step fusion."""
    if plan is None:
        plan = plan_chain(f.shape[0], f.shape[1], f.dtype, n)
    k = plan.fuse_k

    if backend == "xla":
        body = M.erode3 if op == "erode" else M.dilate3
        return jax.lax.fori_loop(0, n, lambda _, x: body(x), f)

    x = _pad(f, plan, ident_for(op, f.dtype))
    full, rem = divmod(n, k)

    def chunk(x, _):
        return chain_step(x, op=op, fuse_k=k, band_h=plan.band_h,
                          interpret=_INTERPRET), None

    if full:
        x, _ = jax.lax.scan(chunk, x, None, length=full)
    if rem:
        # tail chunk: fuse_k must divide band_h; run a rem-step chunk with
        # the smallest compatible fuse and finish with jnp steps if needed.
        body = M.erode3 if op == "erode" else M.dilate3
        x = jax.lax.fori_loop(0, rem, lambda _, y: body(y), x)
    return _crop(x, f.shape)


def erode(f: jnp.ndarray, s: int, backend: Backend = "pallas") -> jnp.ndarray:
    """ε_s via a chain of s elementary erosions (Eq. 4 decomposition)."""
    return morph_chain(f, s, "erode", backend)


def dilate(f: jnp.ndarray, s: int, backend: Backend = "pallas") -> jnp.ndarray:
    return morph_chain(f, s, "dilate", backend)


def opening(f: jnp.ndarray, s: int, backend: Backend = "pallas") -> jnp.ndarray:
    return dilate(erode(f, s, backend), s, backend)


def closing(f: jnp.ndarray, s: int, backend: Backend = "pallas") -> jnp.ndarray:
    return erode(dilate(f, s, backend), s, backend)


# ---------------------------------------------------------------------------
# geodesic chains + reconstruction (Alg. 4)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "op", "backend"))
def geodesic_chain(
    f: jnp.ndarray,
    m: jnp.ndarray,
    n: int,
    op: str = "erode",
    backend: Backend = "pallas",
) -> jnp.ndarray:
    """n elementary geodesic steps (fixed length, Eq. 4)."""
    if backend == "xla":
        step = M.geodesic_erode1 if op == "erode" else M.geodesic_dilate1
        return jax.lax.fori_loop(0, n, lambda _, x: step(x, m), f)

    plan = plan_chain(f.shape[0], f.shape[1], f.dtype, n, n_images_resident=2)
    k = plan.fuse_k
    ident = ident_for(op, f.dtype)
    # mask pinning: pad mask with the identity so pad rows are absorbing
    fp = _pad(f, plan, ident)
    mp = _pad(m, plan, ident)

    full, rem = divmod(n, k)

    def chunk(x, _):
        y, _ = geodesic_chain_step(
            x, mp, op=op, fuse_k=k, band_h=plan.band_h, interpret=_INTERPRET
        )
        return y, None

    if full:
        fp, _ = jax.lax.scan(chunk, fp, None, length=full)
    if rem:
        step = M.geodesic_erode1 if op == "erode" else M.geodesic_dilate1
        fp = jax.lax.fori_loop(0, rem, lambda _, x: step(x, mp), fp)
    return _crop(fp, f.shape)


@functools.partial(jax.jit, static_argnames=("op", "backend", "max_chunks"))
def reconstruct(
    f: jnp.ndarray,
    m: jnp.ndarray,
    op: str = "erode",
    backend: Backend = "pallas",
    max_chunks: int | None = None,
) -> jnp.ndarray:
    """ε_rec / δ_rec with kernel-fused convergence detection (Alg. 4)."""
    if backend == "xla":
        if op == "erode":
            return M.erode_reconstruct(f, m)
        return M.dilate_reconstruct(f, m)

    plan = plan_chain(f.shape[0], f.shape[1], f.dtype, None, n_images_resident=2)
    k = plan.fuse_k
    if max_chunks is None:
        # geodesic influence propagates ≥1 px/step ⇒ diameter bound
        max_chunks = (f.shape[0] + f.shape[1]) // k + 2
    ident = ident_for(op, f.dtype)
    fp = _pad(f, plan, ident)
    mp = _pad(m, plan, ident)

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_chunks)

    def body(state):
        x, _, it = state
        y, flags = geodesic_chain_step(
            x, mp, op=op, fuse_k=k, band_h=plan.band_h, interpret=_INTERPRET
        )
        return y, jnp.any(flags > 0), it + 1

    out, _, _ = jax.lax.while_loop(
        cond, body, (fp, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    return _crop(out, f.shape)


# ---------------------------------------------------------------------------
# quasi-distance transform (Alg. 5)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend", "max_chunks"))
def qdt_planes(
    f: jnp.ndarray,
    backend: Backend = "pallas",
    max_chunks: int | None = None,
):
    """d(f), r(f) of Eq. 13 with the fused masked-store kernel."""
    from repro.core.operators import qdt_raw

    if backend == "xla":
        return qdt_raw(f)

    plan = plan_chain(f.shape[0], f.shape[1], f.dtype, None, n_images_resident=3)
    k = plan.fuse_k
    if max_chunks is None:
        max_chunks = max(f.shape) // k + 2
    acc = jnp.float32 if jnp.issubdtype(f.dtype, jnp.floating) else jnp.int32

    fp = _pad(f, plan, ident_for("erode", f.dtype))
    rp = jnp.zeros(fp.shape, acc)
    dp = jnp.zeros(fp.shape, jnp.int32)

    def cond(state):
        *_, changed, it = state
        return jnp.logical_and(changed, it < max_chunks)

    def body(state):
        x, r, d, _, it = state
        base = (it * k).astype(jnp.int32).reshape(1, 1)
        x, r, d, flags = qdt_chain_step(
            x, r, d, base, fuse_k=k, band_h=plan.band_h, interpret=_INTERPRET
        )
        return x, r, d, jnp.any(flags > 0), it + 1

    _, r, d, _, _ = jax.lax.while_loop(
        cond,
        body,
        (fp, rp, dp, jnp.asarray(True), jnp.asarray(0, jnp.int32)),
    )
    return _crop(d, f.shape), _crop(r, f.shape)
