"""Scheduler engine + public wrappers over the fused Pallas kernels.

This module owns the *engine*: padding/stacking layout helpers, the
fixed-chain drivers (``morph_chain``, ``geodesic_chain``), and the
active-cell requeue scheduler (``_drive_scheduler`` and the
``_scheduled_reconstruct`` / ``_scheduled_qdt`` step bundles) that
``repro.api``'s compiled executables drive.  The public operator sugar
(``erode``/``dilate``/``opening``/``closing``/``reconstruct``/
``qdt_planes``) is now thin: each call builds an expression and routes
through ``repro.api.compile``, which

  1. plans one fusion schedule for the whole program
     (``core.chain.plan_chain``),
  2. pads every input once with the correct absorbing values (lattice
     identity / mask pinning — see the kernel docstrings for why this
     preserves border-clipped semantics),
  3. drives the kernels with ``lax.scan`` (fixed chains) or the requeue
     scheduler (reconstruction — the paper's convergence detection,
     Alg. 4),
  4. crops back once.

``backend``:
  * ``"pallas"``  — the fused kernels (interpret=True on CPU; on TPU the
    same code path compiles natively with interpret=False).
  * ``"xla"``     — the pure-jnp oracle bodies; what the framework runs
    when Pallas is unavailable.  Still one compiled program per chain
    (unlike the per-filter "naive" baseline).
  * ``None``      — the platform policy default
    (``core.backend.default_backend``).  Passing ``backend=`` to the
    operator sugar is deprecated (it still works, with a
    ``DeprecationWarning``); bind the backend at ``repro.api.compile``
    time instead.  ``morph_chain``/``geodesic_chain``/
    ``reconstruct_with_stats`` are engine entry points where
    ``backend``/``plan``/``max_chunks`` remain first-class arguments.

Batching: every public op accepts either a single (H, W) image or an
(N, H, W) stack.  The stack is laid out vertically as one
(N·H_pad, W_pad) working array so a single kernel grid covers all
images; halo pinning at image edges (``bands_per_image``) keeps the
images independent.

Active-tile requeue scheduling (the paper's Alg. 4 requeue mechanism,
extended to 2-D): the convergence-driven drivers (``reconstruct``,
``qdt_planes``) keep the per-cell ``changed`` flags as a live activity
grid instead of collapsing them into one global bit.  A *cell* is one
row band (``plan.tile_w == 0``) or one band × column tile
(``plan.tile_w > 0`` — ``total_bands × n_tiles`` grid); the 2-D grid is
what lets a narrow vertical wavefront skip the quiet column strips a
full-width band scheduler would re-process every chunk.  A cell is
requeued for the next K-chunk iff it *or a Chebyshev neighbour*
changed — influence propagates at most ``fuse_k`` pixels per chunk in
any direction, so a one-cell halo (``plan.requeue_halo``) is exact for
``fuse_k <= min(band_h, tile_w)`` (``plan_chain`` falls back to
row-only tiling otherwise).  Inactive cells are skipped by the kernel
(``pl.when`` early-out); once the active fraction drops below
``plan.compact_threshold`` the driver additionally *compacts*: it
gathers the active cells as (band_h+2K, tile_w+2K) patches (halos
pre-pinned at image edges) into a dense workspace of
``plan.compact_capacity`` cells and launches the smaller grid,
scattering centre windows back.  Per-image convergence in batched mode
falls out for free: a finished image's cells all go inactive and stop
contributing work while the remaining images iterate.

The full lifecycle (activity vector → halo dilation → compaction →
scatter) and the ChainPlan contract it hangs off are documented in
``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import morphology as M
from repro.core.backend import (Backend, canonicalize_backend,
                                warn_legacy_kwargs)
from repro.core.chain import ChainPlan, plan_chain
from repro.kernels.common import ident_for, qdt_acc_dtype
from repro.kernels.erode_chain import chain_step
from repro.kernels.gdt_chain import (D_IDENT, I_IDENT, S_IDENT,
                                     gdt_chain_step, gdt_compact_step,
                                     gdt_tile_step)
from repro.kernels.geodesic_chain import (geodesic_chain_step,
                                          geodesic_compact_step,
                                          geodesic_tile_step)
from repro.kernels.qdt_chain import (qdt_chain_step, qdt_compact_step,
                                     qdt_tile_step)

_INTERPRET = jax.default_backend() != "tpu"


def _api():
    from repro import api  # lazy: repro.api builds on this module

    return api


class ReconstructStats(NamedTuple):
    """Per-run scheduling statistics (the paper's Table 5 chain lengths,
    extended with the requeue scheduler's cell-level accounting).

    The unit is one *scheduling cell*: a full-width row band for
    row-only plans, a band × column tile for 2-D tiled plans
    (``plan.tile_w > 0``) — i.e. one kernel grid step.  The legacy
    field names say "band" because row-only cells are bands; for tiled
    plans ``total_bands`` reports ``plan.total_tiles`` so the
    ``active_band_sum / (total_bands · chunks)`` active-fraction recipe
    keeps working unchanged.

    ``converged`` is the scheduler watchdog's verdict: True iff every
    image's active set emptied before the chunk budget (``max_chunks``)
    ran out.  A False value means the result is a *partial* fixpoint —
    the degraded-mode contract (``docs/ROBUSTNESS.md``) says how the
    serving layer surfaces it (``Ticket.degraded``)."""

    chunks: jnp.ndarray           # int32: K-chunk iterations executed
    active_band_sum: jnp.ndarray  # int32: Σ scheduled cells over all chunks
    total_bands: jnp.ndarray      # int32: cells in the padded stack
    active_per_chunk: jnp.ndarray  # int32[max_chunks], 0 past ``chunks``
    converged: jnp.ndarray = True  # bool: active set emptied within budget


# ---------------------------------------------------------------------------
# layout helpers: batch promotion, padding, vertical stacking
# ---------------------------------------------------------------------------


def _as_stack(f: jnp.ndarray):
    """Promote (H, W) to (1, H, W); pass (N, H, W) through."""
    if f.ndim == 2:
        return f[None], True
    if f.ndim == 3:
        return f, False
    raise ValueError(f"expected (H, W) or (N, H, W), got shape {f.shape}")


def _pad(f3: jnp.ndarray, plan: ChainPlan, fill) -> jnp.ndarray:
    n, h, w = f3.shape
    return jnp.pad(
        f3,
        ((0, 0), (0, plan.height_pad - h), (0, plan.width_pad - w)),
        constant_values=fill,
    )


def _crop(f3: jnp.ndarray, shape, was_2d: bool) -> jnp.ndarray:
    out = f3[:, : shape[-2], : shape[-1]]
    return out[0] if was_2d else out


def _crop3(x2: jnp.ndarray, n: int, h: int, w: int) -> jnp.ndarray:
    """(N·H_pad, W_pad) stacked working array → unpadded (N, H, W).

    The re-band primitive of the multi-plan executable: a value leaving
    one plan group's band layout is cropped back to image form here,
    then ``_pad``-ed into the next group's layout with the pad identity
    its lowering expects."""
    return _unstacked(x2, n)[:, :h, :w]


def _reband(x2: jnp.ndarray, n: int, h: int, w: int, plan: ChainPlan,
            fill) -> jnp.ndarray:
    """Move a stacked working array into ``plan``'s band layout: crop
    the real image region and re-pad it with ``fill`` (one fused
    crop → pad round-trip across a plan-group boundary)."""
    return _stacked(_pad(_crop3(x2, n, h, w), plan, fill))


def _stacked(x3: jnp.ndarray) -> jnp.ndarray:
    """(N, H_pad, W_pad) → (N·H_pad, W_pad); free (row-major)."""
    return x3.reshape(x3.shape[0] * x3.shape[1], x3.shape[2])


def _unstacked(x2: jnp.ndarray, n: int) -> jnp.ndarray:
    return x2.reshape(n, x2.shape[0] // n, x2.shape[1])


def _plan_for(f3: jnp.ndarray, plan: ChainPlan | None) -> None:
    """Validate an explicitly supplied plan against the input stack."""
    if plan is None:
        return
    n, h, w = f3.shape
    if plan.n_images != n:
        raise ValueError(f"plan.n_images={plan.n_images} != batch size {n}")
    if plan.height_pad < h or plan.width_pad < w:
        raise ValueError(
            f"plan pads ({plan.height_pad}, {plan.width_pad}) smaller than "
            f"image ({h}, {w})"
        )


# ---------------------------------------------------------------------------
# active-cell bookkeeping (cell = row band × column tile; n_tiles may be 1)
# ---------------------------------------------------------------------------


def _cell_tile_w(plan: ChainPlan) -> int:
    """Pixel width of one scheduling cell (full width for row-only)."""
    return plan.tile_w or plan.width_pad


def _dilate_active(flags: jnp.ndarray, plan: ChainPlan) -> jnp.ndarray:
    """Requeue set from changed flags: a cell is active next chunk iff it
    or a Chebyshev neighbour (vertical within the same image, horizontal
    within the row, diagonals included) changed.  Diagonals matter for
    2-D tiles because influence propagates ``fuse_k`` pixels per chunk
    in *Chebyshev* distance; the separable row-then-column max over an
    already-row-dilated grid is exactly that 3×3 dilation."""
    a = flags.reshape(plan.n_images, plan.n_bands, plan.n_tiles)
    for _ in range(plan.requeue_halo):
        up = jnp.pad(a[:, 1:], ((0, 0), (0, 1), (0, 0)))
        dn = jnp.pad(a[:, :-1], ((0, 0), (1, 0), (0, 0)))
        a = jnp.maximum(a, jnp.maximum(up, dn))
        if plan.n_tiles > 1:
            lf = jnp.pad(a[:, :, 1:], ((0, 0), (0, 0), (0, 1)))
            rt = jnp.pad(a[:, :, :-1], ((0, 0), (0, 0), (1, 0)))
            a = jnp.maximum(a, jnp.maximum(lf, rt))
    return a.reshape(plan.total_bands, plan.n_tiles)


def _gather_patches(x2: jnp.ndarray, idx: jnp.ndarray, plan: ChainPlan, ident):
    """Gather (band_h+2K, tile_w+2K) halo patches for flat cell indices
    ``idx`` from a stacked (TOTAL_H, W) array → (C·(band_h+2K),
    tile_w+2K).  Rows outside the cell's *image* and columns outside the
    array are pinned to ``ident`` here, since the compact kernel cannot
    know slot → image geometry.  Sentinel slots (idx == total_tiles)
    come back all-``ident`` (their output is dropped at scatter)."""
    bh, k, tw = plan.band_h, plan.fuse_k, _cell_tile_w(plan)
    h, w = x2.shape
    bi = idx // plan.n_tiles         # global band index
    tj = idx % plan.n_tiles          # column tile index
    rows = bi[:, None] * bh - k + jnp.arange(bh + 2 * k)[None, :]
    cols = tj[:, None] * tw - k + jnp.arange(tw + 2 * k)[None, :]
    img0 = (bi // plan.n_bands) * plan.height_pad
    row_ok = (rows >= img0[:, None]) & (rows < img0[:, None] + plan.height_pad)
    col_ok = (cols >= 0) & (cols < w)
    g = jnp.take(x2, jnp.clip(rows, 0, h - 1), axis=0)
    g = jnp.take_along_axis(
        g, jnp.broadcast_to(jnp.clip(cols, 0, w - 1)[:, None, :],
                            (idx.shape[0], bh + 2 * k, tw + 2 * k)),
        axis=2,
    )
    g = jnp.where(row_ok[:, :, None] & col_ok[:, None, :], g, ident)
    return g.reshape(-1, tw + 2 * k)


def _cell_view(x2: jnp.ndarray, plan: ChainPlan) -> jnp.ndarray:
    """(TOTAL_H, W) → (total_tiles, band_h, tile_w) cell-major view."""
    bh, tw, nt = plan.band_h, _cell_tile_w(plan), plan.n_tiles
    return (x2.reshape(plan.total_bands, bh, nt, tw)
            .transpose(0, 2, 1, 3).reshape(-1, bh, tw))


def _gather_mid(x2: jnp.ndarray, idx: jnp.ndarray,
                plan: ChainPlan) -> jnp.ndarray:
    """Gather the centre windows of cells ``idx`` → (C·band_h, tile_w)."""
    cells = jnp.take(_cell_view(x2, plan), idx, axis=0, mode="clip")
    return cells.reshape(-1, _cell_tile_w(plan))


def _scatter_mid(
    x2: jnp.ndarray, idx: jnp.ndarray, new_mid: jnp.ndarray, plan: ChainPlan
) -> jnp.ndarray:
    """Scatter compact-workspace centre windows back; sentinel slots
    (idx == total_tiles, out of bounds) are dropped."""
    bh, tw, nt = plan.band_h, _cell_tile_w(plan), plan.n_tiles
    upd = new_mid.reshape(-1, bh, tw)
    cells = _cell_view(x2, plan).at[idx].set(upd, mode="drop")
    return (cells.reshape(plan.total_bands, nt, bh, tw)
            .transpose(0, 2, 1, 3).reshape(x2.shape))


def _scatter_flags(ch: jnp.ndarray, idx: jnp.ndarray, plan: ChainPlan):
    """Workspace-slot changed flags → full (total_bands, n_tiles) grid."""
    flat = jnp.zeros((plan.total_tiles,), jnp.int32)
    flat = flat.at[idx].set(ch.ravel(), mode="drop")
    return flat.reshape(plan.total_bands, plan.n_tiles)


def _active_indices(active: jnp.ndarray, plan: ChainPlan):
    """Dense slot → flat cell index map for the compact workspace."""
    total = plan.total_tiles
    idx = jnp.nonzero(
        active.ravel() > 0, size=plan.compact_capacity, fill_value=total
    )[0].astype(jnp.int32)
    valid = (idx < total).astype(jnp.int32)[:, None]
    return idx, valid


# ---------------------------------------------------------------------------
# fixed-length chains: ε_s / δ_s (paper Fig. 7 workload)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "op", "backend", "plan"))
def morph_chain(
    f: jnp.ndarray,
    n: int,
    op: str = "erode",
    backend: Backend | None = None,
    plan: ChainPlan | None = None,
) -> jnp.ndarray:
    """Apply n elementary 3×3 erosions/dilations with K-step fusion.

    Accepts (H, W) or a batched (N, H, W) stack.  Engine entry point:
    ``backend`` (None = platform default) stays first-class here.
    """
    backend = canonicalize_backend(backend)
    if backend == "xla":
        body = M.erode3 if op == "erode" else M.dilate3
        return jax.lax.fori_loop(0, n, lambda _, x: body(x), f)

    f3, was_2d = _as_stack(f)
    _plan_for(f3, plan)
    if plan is None:
        plan = plan_chain(
            f3.shape[1], f3.shape[2], f.dtype, n, n_images=f3.shape[0]
        )
    k = plan.fuse_k

    x3 = _pad(f3, plan, ident_for(op, f.dtype))
    full, rem = divmod(n, k)

    def chunk(x, _):
        return chain_step(x, op=op, fuse_k=k, band_h=plan.band_h,
                          interpret=_INTERPRET,
                          bands_per_image=plan.n_bands), None

    if full:
        x2, _ = jax.lax.scan(chunk, _stacked(x3), None, length=full)
        x3 = _unstacked(x2, f3.shape[0])
    if rem:
        # tail chunk: fuse_k must divide band_h; run a rem-step chunk with
        # the smallest compatible fuse and finish with jnp steps if needed.
        # (on the 3-D stack — jnp bodies are axis-polymorphic and cannot
        # leak between images.)
        body = M.erode3 if op == "erode" else M.dilate3
        x3 = jax.lax.fori_loop(0, rem, lambda _, y: body(y), x3)
    return _crop(x3, f.shape, was_2d)


def _compile_unary(build, f, backend, name):
    api = _api()
    if backend is not None:
        warn_legacy_kwargs(name, "backend")
    exe = api.compile(build(api.E.input("f")), f.shape, f.dtype, backend)
    return exe(f)


def erode(f: jnp.ndarray, s: int,
          backend: Backend | None = None) -> jnp.ndarray:
    """ε_s via a chain of s elementary erosions (Eq. 4 decomposition)."""
    api = _api()
    return _compile_unary(lambda x: api.E.erode(s, x), f, backend,
                          "kernels.ops.erode")


def dilate(f: jnp.ndarray, s: int,
           backend: Backend | None = None) -> jnp.ndarray:
    api = _api()
    return _compile_unary(lambda x: api.E.dilate(s, x), f, backend,
                          "kernels.ops.dilate")


def opening(f: jnp.ndarray, s: int,
            backend: Backend | None = None) -> jnp.ndarray:
    """γ_s = δ_s ∘ ε_s — compiled as one two-segment padded program."""
    api = _api()
    return _compile_unary(lambda x: api.E.opening(s, x), f, backend,
                          "kernels.ops.opening")


def closing(f: jnp.ndarray, s: int,
            backend: Backend | None = None) -> jnp.ndarray:
    api = _api()
    return _compile_unary(lambda x: api.E.closing(s, x), f, backend,
                          "kernels.ops.closing")


# ---------------------------------------------------------------------------
# geodesic chains + reconstruction (Alg. 4)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "op", "backend", "plan"))
def geodesic_chain(
    f: jnp.ndarray,
    m: jnp.ndarray,
    n: int,
    op: str = "erode",
    backend: Backend | None = None,
    plan: ChainPlan | None = None,
) -> jnp.ndarray:
    """n elementary geodesic steps (fixed length, Eq. 4).

    Accepts (H, W) or a batched (N, H, W) marker/mask stack.  Engine
    entry point: ``backend`` (None = platform default) stays
    first-class here.
    """
    backend = canonicalize_backend(backend)
    if backend == "xla":
        step = M.geodesic_erode1 if op == "erode" else M.geodesic_dilate1
        return jax.lax.fori_loop(0, n, lambda _, x: step(x, m), f)

    f3, was_2d = _as_stack(f)
    m3, _ = _as_stack(m)
    if f3.shape != m3.shape:
        raise ValueError(f"marker shape {f.shape} != mask shape {m.shape}")
    _plan_for(f3, plan)
    if plan is None:
        plan = plan_chain(
            f3.shape[1], f3.shape[2], f.dtype, n,
            n_images_resident=2, n_images=f3.shape[0],
        )
    k = plan.fuse_k
    ident = ident_for(op, f.dtype)
    # mask pinning: pad mask with the identity so pad rows are absorbing
    fp = _stacked(_pad(f3, plan, ident))
    mp = _stacked(_pad(m3, plan, ident))

    full, rem = divmod(n, k)

    def chunk(x, _):
        y, _ = geodesic_chain_step(
            x, mp, op=op, fuse_k=k, band_h=plan.band_h,
            interpret=_INTERPRET, bands_per_image=plan.n_bands,
        )
        return y, None

    if full:
        fp, _ = jax.lax.scan(chunk, fp, None, length=full)
    if rem:
        step = M.geodesic_erode1 if op == "erode" else M.geodesic_dilate1
        n_img = f3.shape[0]
        fp3 = jax.lax.fori_loop(
            0, rem, lambda _, x: step(x, _unstacked(mp, n_img)),
            _unstacked(fp, n_img),
        )
        return _crop(fp3, f.shape, was_2d)
    return _crop(_unstacked(fp, f3.shape[0]), f.shape, was_2d)


def scheduler_state0(plan: ChainPlan):
    """Fresh resumable scheduler state for :func:`_drive_scheduler`:
    ``(active, img_chunks, exhausted)`` with every cell active and no
    chunks applied.  A state with ``active`` all-zero (see
    ``Executable.slot_session``) describes a stack of parked slots that
    cost no work until a slot's rows are re-activated."""
    return (
        jnp.ones((plan.total_bands, plan.n_tiles), jnp.int32),
        jnp.zeros((plan.n_images,), jnp.int32),
        jnp.zeros((plan.n_images,), jnp.bool_),
    )


def _drive_scheduler(
    plan: ChainPlan,
    data,
    *,
    full_step,
    compact_step=None,
    gather_const=None,
    max_chunks: int,
    with_stats: bool = False,
    resume=None,
    budget: int | None = None,
):
    """Shared active-cell requeue driver loop (the paper's Alg. 4 work
    queue).  One loop serves every convergence-driven chain —
    reconstruction, QDT, and whatever ``repro.serve`` routes through
    them — and owns the full-grid/compact-grid cond, the changed-flag →
    requeue-set dilation, per-image chunk counters, and the scheduling
    statistics.  The activity state is a (total_bands, n_tiles) int32
    grid (n_tiles == 1 for row-only plans).  The chain being driven is
    supplied as a state pytree plus step functions:

    ``full_step(data, active, base) -> (data, flags)``
        one K-chunk over the full stacked grid.  ``base`` is a
        (total_bands, 1) int32 giving the number of elementary filters
        already applied to each band's *image* — counters advance
        per-image, only while the image still has active cells, so
        ragged-converged stacks stay consistent (QDT indexes its
        d-plane with it; reconstruction ignores it).  ``flags`` comes
        back (total_bands, n_tiles).
    ``compact_step(data, idx, valid, const, base) -> (data, flags)``
        one K-chunk on the compacted grid of gathered cells ``idx``
        (flat indices into the activity grid; ``valid`` masks workspace
        slots past the true active count).
    ``gather_const(idx) -> pytree``
        gathers the *chunk-invariant* compact operands (e.g. the
        geodesic mask patches).  The driver caches the result and
        reuses it while the active cell set is unchanged between
        chunks, so a localized wavefront iterating inside the same
        cells does not re-gather the mask every chunk.

    Returns (data, chunks, active_cell_sum, active_per_chunk,
    img_converged, state).  ``img_converged`` is the convergence
    watchdog's per-image verdict — a (n_images,) bool vector, True
    where the image's cells all went inactive *within the chunk
    budget*.  The loop already refuses to spin (``it < max_chunks`` in
    the cond); the vector is what turns a budget exhaustion from a
    silent partial result into a typed, per-image signal that
    ``reconstruct_with_stats`` (``ReconstructStats.converged``) and the
    serving layer's degraded-mode demux surface.  The per-chunk trace
    is only carried through the loop when ``with_stats`` — it is a
    max_chunks-sized array updated by scatter every chunk, which the
    plain paths must not pay for (XLA cannot DCE loop-carried state).

    **Resumable rounds** (the continuous-batching seam): ``resume``
    accepts a previously returned ``state = (active, img_chunks,
    exhausted)`` so the loop can run a *bounded round* of at most
    ``max_chunks`` chunks and be re-entered later exactly where it
    stopped — per-image chunk counters (and therefore the QDT distance
    base offsets, ``img_chunks * fuse_k``) carry across rounds.
    Because every kernel pins its halo at image boundaries
    (``bands_per_image``) and inactive cells are skipped, an image's
    chunk sequence depends only on its own activity rows: re-arming
    one slot's rows from a parked state replays exactly the chunk
    sequence a solo run of that image would take, which is what makes
    mid-flight slot refill bit-exact.

    ``budget`` (used with ``resume``) bounds the *per-image* chunk
    count across rounds: an image that reaches ``budget`` applied
    chunks while still active has its cells force-cleared — precisely
    the truncation a solo run under ``max_chunks=budget`` performs —
    and is flagged in ``state.exhausted`` so the caller can deliver it
    as a degraded partial fixpoint rather than mistaking the cleared
    activity for convergence.
    """
    total = plan.total_tiles
    cap = plan.compact_capacity
    use_compact = (
        compact_step is not None
        and plan.compact_threshold > 0.0
        and cap < total
    )
    with_cache = use_compact and gather_const is not None

    if with_cache:
        # A never-matching key forces a gather on the first compact
        # chunk; the initial value only fixes the cache pytree's shapes.
        key0 = jnp.full((cap,), -1, jnp.int32)
        val0 = gather_const(jnp.full((cap,), total, jnp.int32))
    else:
        key0, val0 = jnp.zeros((0,), jnp.int32), ()

    def img_active(active):
        return jnp.any(active.reshape(plan.n_images, -1) > 0, axis=1)

    def cond(state):
        active, it = state[1], state[2]
        return jnp.logical_and(jnp.any(active > 0), it < max_chunks)

    def body(state):
        (data, active, it, img_chunks, asum, per_chunk, ckey, cval,
         exhausted) = state
        count = jnp.sum(active)
        base = jnp.repeat(img_chunks * plan.fuse_k, plan.n_bands)[:, None]

        def do_full(data, ckey, cval):
            out, flags = full_step(data, active, base)
            return out, flags, ckey, cval

        def do_compact(data, ckey, cval):
            idx, valid = _active_indices(active, plan)
            if with_cache:
                cval = jax.lax.cond(
                    jnp.all(idx == ckey), lambda: cval,
                    lambda: gather_const(idx),
                )
                ckey = idx
            out, flags = compact_step(data, idx, valid, cval, base)
            return out, flags, ckey, cval

        if use_compact:
            data, flags, ckey, cval = jax.lax.cond(
                count <= cap, do_compact, do_full, data, ckey, cval
            )
        else:
            data, flags, ckey, cval = do_full(data, ckey, cval)
        if with_stats:
            per_chunk = per_chunk.at[it].set(count)
        next_active = _dilate_active(flags, plan)
        next_chunks = img_chunks + img_active(active).astype(jnp.int32)
        if budget is not None:
            # per-image budget truncation: an image at its chunk budget
            # stops receiving chunks — bit-exact with a solo run under
            # max_chunks=budget — and is flagged exhausted iff it was
            # cut off while still active (vs converging right at it).
            over = next_chunks >= budget
            exhausted = jnp.logical_or(
                exhausted, jnp.logical_and(over, img_active(next_active)))
            next_active = jnp.where(
                jnp.repeat(over, plan.n_bands)[:, None], 0, next_active)
        return (
            data,
            next_active,
            it + 1,
            next_chunks,
            asum + count,
            per_chunk,
            ckey,
            cval,
            exhausted,
        )

    active0, img_chunks0, exhausted0 = (
        resume if resume is not None else scheduler_state0(plan))
    init = (
        data,
        active0,
        jnp.asarray(0, jnp.int32),
        img_chunks0,
        jnp.asarray(0, jnp.int32),
        jnp.zeros((max_chunks if with_stats else 0,), jnp.int32),
        key0,
        val0,
        exhausted0,
    )
    (data, active, it, img_chunks, asum, per_chunk, _, _,
     exhausted) = jax.lax.while_loop(cond, body, init)
    img_converged = jnp.logical_not(img_active(active))
    return (data, it, asum, per_chunk, img_converged,
            (active, img_chunks, exhausted))


def _scheduled_reconstruct(fp, mp, plan: ChainPlan, op: str, max_chunks: int,
                           with_stats: bool, resume=None,
                           budget: int | None = None):
    """Reconstruction's step functions for :func:`_drive_scheduler`.

    ``fp``/``mp`` are stacked (TOTAL_H, W_pad) arrays.  The mask is
    chunk-invariant, so its compact-workspace gather goes through the
    driver's ``gather_const`` cache.  Tiled plans run the 2-D grid
    kernel for full chunks; compaction is patch-based either way.
    ``resume``/``budget`` pass through to the driver (the
    continuous-batching slot-refill entry — see
    ``Executable.slot_session``).
    """
    ident = ident_for(op, fp.dtype)

    def full_step(x, active, base):
        if plan.n_tiles > 1:
            return geodesic_tile_step(
                x, mp, op=op, fuse_k=plan.fuse_k, band_h=plan.band_h,
                tile_w=plan.tile_w, interpret=_INTERPRET, active=active,
                bands_per_image=plan.n_bands,
            )
        return geodesic_chain_step(
            x, mp, op=op, fuse_k=plan.fuse_k, band_h=plan.band_h,
            interpret=_INTERPRET, active=active, bands_per_image=plan.n_bands,
        )

    def gather_const(idx):
        return _gather_patches(mp, idx, plan, ident)

    def compact_step(x, idx, valid, mask_patch, base):
        f_patch = _gather_patches(x, idx, plan, ident)
        new_mid, ch = geodesic_compact_step(
            f_patch, mask_patch, valid,
            op=op, fuse_k=plan.fuse_k, band_h=plan.band_h,
            tile_w=_cell_tile_w(plan), interpret=_INTERPRET,
        )
        x = _scatter_mid(x, idx, new_mid, plan)
        return x, _scatter_flags(ch, idx, plan)

    return _drive_scheduler(
        plan, fp, full_step=full_step, compact_step=compact_step,
        gather_const=gather_const, max_chunks=max_chunks,
        with_stats=with_stats, resume=resume, budget=budget,
    )


def _reconstruct_impl(f, m, op, backend, max_chunks, plan, with_stats=False):
    f3, was_2d = _as_stack(f)
    m3, _ = _as_stack(m)
    if f3.shape != m3.shape:
        raise ValueError(f"marker shape {f.shape} != mask shape {m.shape}")
    _plan_for(f3, plan)
    if plan is None:
        plan = plan_chain(
            f3.shape[1], f3.shape[2], f.dtype, None,
            n_images_resident=2, n_images=f3.shape[0], convergent=True,
        )
    k = plan.fuse_k
    if max_chunks is None:
        # Geodesic influence follows mask-constrained paths, whose length
        # is bounded by the pixel count (serpentine masks exceed the
        # H+W Chebyshev diameter).  The cap is a safety net only: the
        # loop exits as soon as the active set empties, so the
        # conservative bound costs nothing at runtime.
        max_chunks = (f3.shape[1] * f3.shape[2]) // k + 2
    ident = ident_for(op, f.dtype)
    fp = _stacked(_pad(f3, plan, ident))
    mp = _stacked(_pad(m3, plan, ident))

    out, chunks, asum, per_chunk, img_conv, _ = _scheduled_reconstruct(
        fp, mp, plan, op, max_chunks, with_stats
    )
    stats = ReconstructStats(
        chunks=chunks,
        active_band_sum=asum,
        total_bands=jnp.asarray(plan.total_tiles, jnp.int32),
        active_per_chunk=per_chunk,
        converged=jnp.all(img_conv),
    )
    return _crop(_unstacked(out, f3.shape[0]), f.shape, was_2d), stats


def reconstruct(
    f: jnp.ndarray,
    m: jnp.ndarray,
    op: str = "erode",
    backend: Backend | None = None,
    max_chunks: int | None = None,
    plan: ChainPlan | None = None,
) -> jnp.ndarray:
    """ε_rec / δ_rec with kernel-fused convergence detection (Alg. 4).

    Accepts (H, W) or (N, H, W); in batched mode each image converges
    independently (its bands go inactive and stop costing work).
    Routes through ``repro.api.compile``; ``backend=``/``max_chunks=``
    are deprecated here (bind them at compile time instead).
    """
    legacy = [n for n, v in (("backend", backend),
                             ("max_chunks", max_chunks)) if v is not None]
    if legacy:
        warn_legacy_kwargs("kernels.ops.reconstruct", *legacy)
    if f.shape != m.shape:
        raise ValueError(f"marker shape {f.shape} != mask shape {m.shape}")
    api = _api()
    expr = api.E.reconstruct(api.E.input("marker"), api.E.input("mask"),
                             op=op)
    exe = api.compile(expr, f.shape, f.dtype, backend, plan=plan,
                      max_chunks=max_chunks)
    return exe(f, m)


@functools.partial(
    jax.jit, static_argnames=("op", "backend", "max_chunks", "plan")
)
def reconstruct_with_stats(
    f: jnp.ndarray,
    m: jnp.ndarray,
    op: str = "erode",
    backend: Backend | None = None,
    max_chunks: int | None = None,
    plan: ChainPlan | None = None,
):
    """Like ``reconstruct`` but also returns :class:`ReconstructStats`
    (chunk count and band-level requeue accounting — the analogue of the
    paper's Table 5 chain lengths).  Engine/diagnostic entry point:
    ``backend``/``max_chunks``/``plan`` remain first-class here."""
    backend = canonicalize_backend(backend)
    if backend == "xla":
        iter_cap = (max_chunks if max_chunks is not None
                    else f.shape[-1] * f.shape[-2])
        out, iters = (
            M.erode_reconstruct_with_iters(f, m, iter_cap) if op == "erode"
            else M.dilate_reconstruct_with_iters(f, m, iter_cap)
        )
        one = jnp.asarray(1, jnp.int32)
        return out, ReconstructStats(
            chunks=iters, active_band_sum=iters, total_bands=one,
            active_per_chunk=jnp.zeros((0,), jnp.int32),
            # the oracle loop exits early iff a fixpoint was reached;
            # hitting the cap exactly leaves convergence unproven
            converged=iters < jnp.asarray(iter_cap, jnp.int32),
        )
    return _reconstruct_impl(f, m, op, backend, max_chunks, plan,
                             with_stats=True)


# ---------------------------------------------------------------------------
# quasi-distance transform (Alg. 5)
# ---------------------------------------------------------------------------


def _scheduled_qdt(fp, plan: ChainPlan, max_chunks: int, rp=None, dp=None,
                   resume=None, budget: int | None = None):
    """QDT's step functions for :func:`_drive_scheduler`.

    ``fp`` is the stacked (TOTAL_H, W_pad) image, padded with the
    erosion identity.  Returns the final (eroded, residual, distance)
    stacked planes plus the watchdog's per-image convergence vector and
    the resumable scheduler state; the residual accumulator dtype
    follows the paper's convention (float32 for float images, int32
    otherwise).  ``rp``/``dp`` accept mid-flight residual/distance
    planes (with ``resume``/``budget``) for bounded continuous-batching
    rounds — the per-image chunk counters in the resumed state keep the
    distance base offsets consistent across rounds.
    """
    k = plan.fuse_k
    acc = qdt_acc_dtype(fp.dtype)
    ident = ident_for("erode", fp.dtype)
    if rp is None:
        rp = jnp.zeros(fp.shape, acc)
    if dp is None:
        dp = jnp.zeros(fp.shape, jnp.int32)

    def full_step(data, active, base):
        x, r, d = data
        if plan.n_tiles > 1:
            x, r, d, ch = qdt_tile_step(
                x, r, d, jnp.broadcast_to(base, (plan.total_bands,
                                                 plan.n_tiles)),
                fuse_k=k, band_h=plan.band_h, tile_w=plan.tile_w,
                interpret=_INTERPRET, active=active,
                bands_per_image=plan.n_bands,
            )
        else:
            x, r, d, ch = qdt_chain_step(
                x, r, d, base, fuse_k=k, band_h=plan.band_h,
                interpret=_INTERPRET, active=active,
                bands_per_image=plan.n_bands,
            )
        return (x, r, d), ch

    def compact_step(data, idx, valid, const, base):
        x, r, d = data
        f_patch = _gather_patches(x, idx, plan, ident)
        rm = _gather_mid(r, idx, plan)
        dm = _gather_mid(d, idx, plan)
        # per-slot distance offset: each gathered cell carries its own
        # image's erosion count (sentinel slots clip — dropped anyway).
        base_slots = jnp.take(base.ravel(), idx // plan.n_tiles,
                              mode="clip")[:, None]
        f2, r2, d2, ch = qdt_compact_step(
            f_patch, rm, dm, valid, base_slots,
            fuse_k=k, band_h=plan.band_h, tile_w=_cell_tile_w(plan),
            interpret=_INTERPRET,
        )
        x = _scatter_mid(x, idx, f2, plan)
        r = _scatter_mid(r, idx, r2, plan)
        d = _scatter_mid(d, idx, d2, plan)
        return (x, r, d), _scatter_flags(ch, idx, plan)

    (x, r, d), _, _, _, img_conv, state = _drive_scheduler(
        plan, (fp, rp, dp), full_step=full_step, compact_step=compact_step,
        max_chunks=max_chunks, resume=resume, budget=budget,
    )
    return x, r, d, img_conv, state


def qdt_planes(
    f: jnp.ndarray,
    backend: Backend | None = None,
    max_chunks: int | None = None,
    plan: ChainPlan | None = None,
):
    """d(f), r(f) of Eq. 13 with the fused masked-store kernel.

    Accepts (H, W) or (N, H, W); runs the same active-band requeue
    scheduler as ``reconstruct``.  Routes through
    ``repro.api.compile``; ``backend=``/``max_chunks=`` are deprecated
    here (bind them at compile time instead).
    """
    legacy = [n for n, v in (("backend", backend),
                             ("max_chunks", max_chunks)) if v is not None]
    if legacy:
        warn_legacy_kwargs("kernels.ops.qdt_planes", *legacy)
    api = _api()
    exe = api.compile(api.E.qdt(api.E.input("f")), f.shape, f.dtype,
                      backend, plan=plan, max_chunks=max_chunks)
    return exe(f)


# ---------------------------------------------------------------------------
# generalised geodesic distance transform (grey-weighted, FastGeodis-style)
# ---------------------------------------------------------------------------


def gdt_stage(ip: jnp.ndarray, sp: jnp.ndarray, nu: float):
    """Derive the kernel's three resident planes from the *padded*
    image/seed operands (both arrive with the float lattice bottom,
    −inf, as their absorbing pad fill).

    Returns ``(d0, i, s)``: the initial distance plane ``d0 = nu·(1−S)``
    (+inf on pads), the sanitized image (0 on pads, so the weight term
    never computes ``|−inf − (−inf)| = NaN``) and the seed/pad-marker
    plane (clipped to [0, 1] in the real region, −1 on pads — the value
    the kernels re-clamp ``d = +inf`` on after every elementary step).
    This is the single sanitization point: the kernels and the raster
    sweeps assume the planes are already in this form.
    """
    in_pad = jnp.isneginf(sp)
    sc = jnp.clip(sp, 0.0, 1.0)  # clip(−inf) → 0.0 without NaN
    d0 = jnp.where(in_pad, jnp.asarray(D_IDENT, ip.dtype),
                   (nu * (1.0 - sc)).astype(ip.dtype))
    i = jnp.where(in_pad, jnp.asarray(I_IDENT, ip.dtype), ip)
    s = jnp.where(in_pad, jnp.asarray(S_IDENT, ip.dtype), sc)
    return d0, i, s


def _scheduled_gdt(dp, ip, sp, plan: ChainPlan, lamb: float, max_chunks: int,
                   resume=None, budget: int | None = None):
    """gdt's step functions for :func:`_drive_scheduler` (the wavefront
    schedule).

    ``dp``/``ip``/``sp`` are stacked (TOTAL_H, W_pad) planes from
    :func:`gdt_stage`.  Only the distance plane evolves; the image and
    seed planes are chunk-invariant, so their compact-workspace patches
    go through the driver's ``gather_const`` cache as one pytree.
    Returns (d, img_converged, state) — the same resumable contract as
    ``_scheduled_qdt``, which is what lets ``Executable.slot_session``
    refill gdt slots mid-flight.
    """
    k = plan.fuse_k

    def full_step(d, active, base):
        if plan.n_tiles > 1:
            return gdt_tile_step(
                d, ip, sp, lamb=lamb, fuse_k=k, band_h=plan.band_h,
                tile_w=plan.tile_w, interpret=_INTERPRET, active=active,
                bands_per_image=plan.n_bands,
            )
        return gdt_chain_step(
            d, ip, sp, lamb=lamb, fuse_k=k, band_h=plan.band_h,
            interpret=_INTERPRET, active=active,
            bands_per_image=plan.n_bands,
        )

    def gather_const(idx):
        return (_gather_patches(ip, idx, plan, I_IDENT),
                _gather_patches(sp, idx, plan, S_IDENT))

    def compact_step(d, idx, valid, const, base):
        i_patch, s_patch = const
        d_patch = _gather_patches(d, idx, plan, D_IDENT)
        new_mid, ch = gdt_compact_step(
            d_patch, i_patch, s_patch, valid,
            lamb=lamb, fuse_k=k, band_h=plan.band_h,
            tile_w=_cell_tile_w(plan), interpret=_INTERPRET,
        )
        d = _scatter_mid(d, idx, new_mid, plan)
        return d, _scatter_flags(ch, idx, plan)

    d, _, _, _, img_conv, state = _drive_scheduler(
        plan, dp, full_step=full_step, compact_step=compact_step,
        gather_const=gather_const, max_chunks=max_chunks,
        resume=resume, budget=budget,
    )
    return d, img_conv, state


def _shift_row(x: jnp.ndarray, dx: int, fill):
    """(N, W) row batch translated along W with ``fill`` at the border."""
    if dx == 1:
        return jnp.concatenate(
            [jnp.full_like(x[:, :1], fill), x[:, :-1]], axis=1)
    if dx == -1:
        return jnp.concatenate(
            [x[:, 1:], jnp.full_like(x[:, :1], fill)], axis=1)
    return x


def _gdt_sweep(d3, i3, s3, lamb: float, reverse: bool):
    """One directional raster pass: a ``lax.scan`` over rows (axis 1)
    carrying the *updated* previous row, relaxing each row against its
    three upper (``reverse=False``) or lower (``reverse=True``)
    neighbours.  The left/right passes run this on the W↔H transposed
    planes; across the four directions the candidate sets cover the
    full 8-neighbourhood, so iterating rounds to a fixpoint lands on
    the same bits as the wavefront scheduler (see
    ``repro.gdt.reference``)."""
    inf = jnp.asarray(D_IDENT, d3.dtype)
    xs = (jnp.moveaxis(d3, 1, 0), jnp.moveaxis(i3, 1, 0),
          jnp.moveaxis(s3, 1, 0))

    def step(carry, row):
        prev_d, prev_i = carry
        d_row, i_row, s_row = row
        best = d_row
        for dx in (-1, 0, 1):
            dq = _shift_row(prev_d, dx, inf)
            if lamb == 0.0:
                cand = dq + 1.0
            else:
                iq = _shift_row(prev_i, dx, jnp.asarray(I_IDENT, d3.dtype))
                # outer abs blocks fmul+fadd→fma contraction (see
                # kernels.gdt_chain.elementary_gdt)
                cand = dq + (1.0 + jnp.abs(lamb * jnp.abs(i_row - iq)))
            best = jnp.minimum(best, cand)
        new_d = jnp.where(s_row < 0, inf, best)
        return (new_d, i_row), new_d

    init = (jnp.full_like(d3[:, 0], inf), jnp.zeros_like(d3[:, 0]))
    _, out = jax.lax.scan(step, init, xs, reverse=reverse)
    return jnp.moveaxis(out, 0, 1)


def _raster_gdt(dp, ip, sp, plan: ChainPlan, lamb: float, max_rounds: int):
    """The raster-scan schedule: FastGeodis-style down/up/left/right
    sweeps iterated to fixpoint (``plan.schedule == "raster"``).

    Runs on the *unstacked* (N, H_pad, W_pad) view — the scans walk
    rows/columns of each image separately, so batched images can never
    leak into each other (no band-halo pinning needed).  Returns
    ``(d, rounds, img_converged)`` with ``d`` re-stacked; an image
    unchanged by the last full round is at its fixpoint (the sweeps are
    deterministic per image), so the convergence vector is exact even
    when the round budget truncates the others.
    """
    n = plan.n_images
    d3, i3, s3 = (_unstacked(x, n) for x in (dp, ip, sp))
    i3t, s3t = i3.swapaxes(1, 2), s3.swapaxes(1, 2)

    def one_round(d3):
        d3 = _gdt_sweep(d3, i3, s3, lamb, reverse=False)
        d3 = _gdt_sweep(d3, i3, s3, lamb, reverse=True)
        d3t = _gdt_sweep(d3.swapaxes(1, 2), i3t, s3t, lamb, reverse=False)
        d3t = _gdt_sweep(d3t, i3t, s3t, lamb, reverse=True)
        return d3t.swapaxes(1, 2)

    def cond(state):
        _, it, changed = state
        return jnp.logical_and(jnp.any(changed), it < max_rounds)

    def body(state):
        d, it, _ = state
        new = one_round(d)
        changed = jnp.any(new != d, axis=(1, 2))
        return new, it + 1, changed

    d3, rounds, changed = jax.lax.while_loop(
        cond, body,
        (d3, jnp.asarray(0, jnp.int32), jnp.ones((n,), jnp.bool_)),
    )
    return _stacked(d3), rounds, jnp.logical_not(changed)


def gdt_fixpoint_xla(img3: jnp.ndarray, seeds3: jnp.ndarray, lamb: float,
                     nu: float, max_iters: int) -> jnp.ndarray:
    """Pure-jnp Jacobi oracle on unpadded (..., H, W) stacks — the "xla"
    backend body, bit-exact with ``repro.gdt.reference`` by the shared
    fold-cost argument.  Axis-polymorphic over leading batch dims (2-D
    executables keep 2-D arrays end-to-end)."""
    dtype = img3.dtype
    inf = jnp.asarray(jnp.inf, dtype)
    sc = jnp.clip(seeds3.astype(dtype), 0.0, 1.0)
    d = (nu * (1.0 - sc)).astype(dtype)

    def shift(x, dy, dx, fill):
        pad = ([(0, 0)] * (x.ndim - 2)
               + [(max(dy, 0), max(-dy, 0)), (max(dx, 0), max(-dx, 0))])
        y = jnp.pad(x, pad, constant_values=fill)
        h, w = x.shape[-2], x.shape[-1]
        return y[..., max(-dy, 0): max(-dy, 0) + h,
                 max(-dx, 0): max(-dx, 0) + w]

    offsets = [(dy, dx) for dy in (-1, 0, 1) for dx in (-1, 0, 1)
               if (dy, dx) != (0, 0)]
    if lamb == 0.0:
        weights = [jnp.asarray(1.0, dtype)] * len(offsets)
    else:
        # outer abs blocks fmul+fadd→fma contraction (see
        # kernels.gdt_chain.elementary_gdt)
        weights = [
            1.0 + jnp.abs(lamb * jnp.abs(img3 - shift(img3, dy, dx, 0.0)))
            for dy, dx in offsets
        ]

    def cond(state):
        d, prev, it = state
        return jnp.logical_and(jnp.any(d != prev), it < max_iters)

    def body(state):
        d, _, it = state
        cand = d
        for (dy, dx), w in zip(offsets, weights):
            cand = jnp.minimum(cand, shift(d, dy, dx, inf) + w)
        return cand, d, it + 1

    d, _, _ = jax.lax.while_loop(
        cond, body, (d, jnp.full_like(d, -inf), jnp.asarray(0, jnp.int32)))
    return d


def gdt(
    image: jnp.ndarray,
    seeds: jnp.ndarray,
    lamb: float = 1.0,
    nu: float = 1e6,
    backend: Backend | None = None,
    max_chunks: int | None = None,
    plan: ChainPlan | None = None,
) -> jnp.ndarray:
    """Generalised geodesic distance transform (see ``E.gdt``).

    Accepts (H, W) or (N, H, W) image/seed stacks; float dtypes only.
    Routes through ``repro.api.compile``; pass a ``plan`` with
    ``schedule="raster"`` to select the sweep schedule.
    ``backend=``/``max_chunks=`` are deprecated here (bind them at
    compile time instead).
    """
    legacy = [n for n, v in (("backend", backend),
                             ("max_chunks", max_chunks)) if v is not None]
    if legacy:
        warn_legacy_kwargs("kernels.ops.gdt", *legacy)
    # resolve dtypes the way execution will: without x64, jnp downcasts
    # a NumPy float64 to float32 — compile at the post-cast dtype
    image = jnp.asarray(image)
    seeds = jnp.asarray(seeds)
    if jnp.dtype(image.dtype).kind != "f":
        raise TypeError(
            f"gdt: image must be a float dtype, got {image.dtype} (the "
            "distance plane is a float lattice)"
        )
    if image.shape != seeds.shape:
        raise ValueError(
            f"image shape {image.shape} != seeds shape {seeds.shape}")
    api = _api()
    expr = api.E.gdt(api.E.input("image"), api.E.input("seeds"),
                     lamb=lamb, nu=nu)
    exe = api.compile(expr, image.shape, image.dtype, backend, plan=plan,
                      max_chunks=max_chunks)
    return exe(image, seeds)


# ---------------------------------------------------------------------------
# serving registry hooks
# ---------------------------------------------------------------------------

#: Registry hooks for ``repro.serve``: every public kernel op declared
#: as data next to its implementation — a string name, a param schema
#: and an *expression builder*.  ``repro.serve.registry`` lowers the
#: expression (``repro.api.lower``) and derives the pipeline stages,
#: pad fills and bucket identity mechanically from the lowered program;
#: nothing op-specific lives in the registry anymore.
SERVE_OPS = (
    dict(name="erode",
         expr=lambda p: _api().E.erode(p["s"], _api().E.input("f")),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="dilate",
         expr=lambda p: _api().E.dilate(p["s"], _api().E.input("f")),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="opening",
         expr=lambda p: _api().E.opening(p["s"], _api().E.input("f")),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="closing",
         expr=lambda p: _api().E.closing(p["s"], _api().E.input("f")),
         params={"s": dict(type="int", required=True, min=1)}),
    dict(name="reconstruct",
         expr=lambda p: _api().E.reconstruct(_api().E.input("marker"),
                                             _api().E.input("mask"),
                                             op=p["op"]),
         params={"op": dict(type="str", default="dilate",
                            choices=("erode", "dilate"))}),
    dict(name="geodesic",
         expr=lambda p: _api().E.geodesic(_api().E.input("marker"),
                                          _api().E.input("mask"),
                                          p["n"], p["op"]),
         params={"n": dict(type="int", required=True, min=1),
                 "op": dict(type="str", default="erode",
                            choices=("erode", "dilate"))}),
    dict(name="qdt",
         expr=lambda p: _api().E.qdt(_api().E.input("f")),
         params={}),
)
