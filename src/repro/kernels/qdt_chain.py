"""Fused quasi-distance-transform chunk — Algorithm 5 of the paper.

Each of the K fused steps computes ε₁, the residual B = f − ε₁(f), and
performs the *masked store* update of the residual plane r(f) and the
distance plane d(f) (update only where the new residual exceeds the
stored one).  The paper uses AVX2 masked stores for this; on TPU the
masked store is a vectorized ``jnp.where`` on the VMEM tile.

r/d only need the centre window (their update is pointwise), so they
are blocked without halo — only the eroding image carries the K-pixel
halo.

Like the geodesic kernel, each scheduling cell carries an ``active``
scalar: once a cell's erosion has reached the lattice bottom everywhere
(no pixel changed, nor in its neighbours), the driver stops requeueing
it and the kernel passes f/r/d through unchanged under ``pl.when``.
The same three grid shapes exist as in ``geodesic_chain``:
``qdt_chain_step`` (full-width row bands), ``qdt_tile_step`` (2-D
band × column-tile grid) and ``qdt_compact_step`` (dense workspace of
driver-gathered patches).  The scheduler lifecycle these plug into is
documented in ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (assemble_tile, elementary_3x3, ident_for,
                                  image_edges, qdt_acc_dtype, row_specs,
                                  tile_edges, tile_specs)


def _qdt_update(stack, r, d, j0, window, *, fuse_k: int, acc_dtype):
    """The K-step masked-store loop shared by every QDT grid shape.

    ``window`` slices the centre (band_h, tile_w) region out of the
    halo-extended ``stack``; r/d are centre-only.  Returns the final
    centre, r, d."""
    (lo, hi), (cl, cr) = window
    for k in range(fuse_k):
        nxt = elementary_3x3(stack, "erode")
        res = (stack[lo:hi, cl:cr].astype(acc_dtype)
               - nxt[lo:hi, cl:cr].astype(acc_dtype))
        upd = res > r
        r = jnp.where(upd, res, r)
        d = jnp.where(upd, j0 + (k + 1), d)
        stack = nxt
    return stack[lo:hi, cl:cr], r, d


def _qdt_kernel(
    base, active, f_top, f_mid, f_bot, r_in, d_in, f_out, r_out, d_out,
    changed,
    *, fuse_k: int, band_h: int, acc_dtype, bands_per_image: int,
):
    # ``base`` is blocked per band: each band reads the elementary-erosion
    # count already applied to *its image*, so ragged-converged stacks
    # keep per-image distance indices (a finished image's counter stops
    # advancing with the rest of the batch).
    # program_id is not available inside pl.when branches in interpret
    # mode — read it at kernel top level.
    at_top, at_bot = image_edges(pl.program_id(0), bands_per_image)

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        # converged band: pass all planes through, report no change.
        f_out[...] = f_mid[...]
        r_out[...] = r_in[...]
        d_out[...] = d_in[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        ident = ident_for("erode", f_mid.dtype)
        top = jnp.where(at_top, ident, f_top[...])
        bot = jnp.where(at_bot, ident, f_bot[...])
        stack = jnp.concatenate([top, f_mid[...], bot], axis=0)

        w = f_mid.shape[1]
        centre, r, d = _qdt_update(
            stack, r_in[...], d_in[...], base[0, 0],
            ((fuse_k, fuse_k + band_h), (0, w)),
            fuse_k=fuse_k, acc_dtype=acc_dtype,
        )
        f_out[...] = centre
        r_out[...] = r
        d_out[...] = d
        changed[...] = (
            jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def qdt_chain_step(
    f: jnp.ndarray,
    r: jnp.ndarray,
    d: jnp.ndarray,
    base: jnp.ndarray,
    *,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """One K-step QDT chunk on pre-padded planes.

    ``base`` is an (n_bands, 1) int32 with the number of elementary
    erosions already applied to each band's image — per *band* so the
    batched driver can give every stacked image its own distance offset
    (a (1, 1) array is broadcast for the unbatched callers).
    ``active`` optionally skips converged bands (see module docstring).
    Returns (f', r', d', changed) — changed is (n_bands, 1) int32.
    """
    h, w = f.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    n_bands = h // band_h
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, 1), jnp.int32)
    if base.shape == (1, 1):
        base = jnp.broadcast_to(base, (n_bands, 1))
    assert base.shape == (n_bands, 1)
    acc_dtype = qdt_acc_dtype(f.dtype)
    assert r.dtype == acc_dtype and d.dtype == jnp.int32

    top_spec, mid_spec, bot_spec = row_specs(band_h, fuse_k, h, w)
    flag_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))

    kern = functools.partial(
        _qdt_kernel, fuse_k=fuse_k, band_h=band_h, acc_dtype=acc_dtype,
        bands_per_image=bands_per_image,
    )
    return pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=[flag_spec, flag_spec, top_spec, mid_spec, bot_spec,
                  mid_spec, mid_spec],
        out_specs=[mid_spec, mid_spec, mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((h, w), acc_dtype),
            jax.ShapeDtypeStruct((h, w), jnp.int32),
            jax.ShapeDtypeStruct((n_bands, 1), jnp.int32),
        ],
        interpret=interpret,
    )(base, active, f, f, f, r, d)


def _qdt_tile_kernel(
    base, active, *refs,
    fuse_k: int, band_h: int, tile_w: int, acc_dtype,
    bands_per_image: int, n_tiles: int,
):
    """2-D grid body: ``refs`` are 9 f blocks, r_in, d_in, then the
    (f_out, r_out, d_out, changed) outputs."""
    f_parts = refs[:9]
    r_in, d_in = refs[9], refs[10]
    f_out, r_out, d_out, changed = refs[11:]
    f_mid = f_parts[4]
    at_top, at_bot = image_edges(pl.program_id(0), bands_per_image)
    at_lf, at_rt = tile_edges(pl.program_id(1), n_tiles)

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        f_out[...] = f_mid[...]
        r_out[...] = r_in[...]
        d_out[...] = d_in[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        ident = ident_for("erode", f_mid.dtype)
        stack = assemble_tile(f_parts, (at_top, at_bot, at_lf, at_rt), ident)
        centre, r, d = _qdt_update(
            stack, r_in[...], d_in[...], base[0, 0],
            ((fuse_k, fuse_k + band_h), (fuse_k, fuse_k + tile_w)),
            fuse_k=fuse_k, acc_dtype=acc_dtype,
        )
        f_out[...] = centre
        r_out[...] = r
        d_out[...] = d
        changed[...] = (
            jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def qdt_tile_step(
    f: jnp.ndarray,
    r: jnp.ndarray,
    d: jnp.ndarray,
    base: jnp.ndarray,
    *,
    fuse_k: int,
    band_h: int,
    tile_w: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """One K-step QDT chunk on the 2-D (band × column-tile) grid.

    Same contract as :func:`qdt_chain_step` with the width split into
    ``W // tile_w`` column tiles: ``base``/``active``/``changed`` are
    (n_bands, n_tiles) int32 grids (``base`` stays per-*image*; the
    driver broadcasts it across each band's tiles).
    """
    h, w = f.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    assert w % tile_w == 0 and tile_w % fuse_k == 0
    n_bands = h // band_h
    n_tiles = w // tile_w
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, n_tiles), jnp.int32)
    if base.shape == (1, 1):
        base = jnp.broadcast_to(base, (n_bands, n_tiles))
    assert base.shape == (n_bands, n_tiles)
    acc_dtype = qdt_acc_dtype(f.dtype)
    assert r.dtype == acc_dtype and d.dtype == jnp.int32

    flag_spec = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    mid_spec = pl.BlockSpec((band_h, tile_w), lambda i, j: (i, j))
    plane = tile_specs(band_h, tile_w, fuse_k, h, w)
    kern = functools.partial(
        _qdt_tile_kernel, fuse_k=fuse_k, band_h=band_h, tile_w=tile_w,
        acc_dtype=acc_dtype, bands_per_image=bands_per_image,
        n_tiles=n_tiles,
    )
    return pl.pallas_call(
        kern,
        grid=(n_bands, n_tiles),
        in_specs=[flag_spec, flag_spec] + plane + [mid_spec, mid_spec],
        out_specs=[mid_spec, mid_spec, mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((h, w), acc_dtype),
            jax.ShapeDtypeStruct((h, w), jnp.int32),
            jax.ShapeDtypeStruct((n_bands, n_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(base, active, *([f] * 9), r, d)


def _qdt_compact_kernel(
    base, valid, f_patch, r_in, d_in, f_out, r_out, d_out, changed,
    *, fuse_k: int, band_h: int, tile_w: int, acc_dtype,
):
    lo, hi = fuse_k, fuse_k + band_h
    cl, cr = fuse_k, fuse_k + tile_w

    @pl.when(valid[0, 0] == 0)
    def _passthrough():
        f_out[...] = f_patch[lo:hi, cl:cr]
        r_out[...] = r_in[...]
        d_out[...] = d_in[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(valid[0, 0] > 0)
    def _compute():
        stack = f_patch[...]
        centre0 = stack[lo:hi, cl:cr]
        centre, r, d = _qdt_update(
            stack, r_in[...], d_in[...], base[0, 0],
            ((lo, hi), (cl, cr)), fuse_k=fuse_k, acc_dtype=acc_dtype,
        )
        f_out[...] = centre
        r_out[...] = r
        d_out[...] = d
        changed[...] = (
            jnp.any(centre != centre0).astype(jnp.int32).reshape(1, 1)
        )


def qdt_compact_step(
    f_patch: jnp.ndarray,
    r_mid: jnp.ndarray,
    d_mid: jnp.ndarray,
    valid: jnp.ndarray,
    base: jnp.ndarray,
    *,
    fuse_k: int,
    band_h: int,
    tile_w: int,
    interpret: bool = True,
):
    """Compacted-grid QDT chunk on driver-gathered active cells.

    Shapes mirror ``geodesic_compact_step``: f_patch
    (C·(band_h+2K), tile_w+2K) with halos pre-pinned by the gather,
    r_mid/d_mid (C·band_h, tile_w) centre-only, valid/base (C, 1) int32
    — the driver gathers each active cell's per-image erosion count
    into its workspace slot (a (1, 1) array is broadcast).  Returns
    (f', r', d', changed); row-only plans use ``tile_w = width_pad``.
    """
    ph = band_h + 2 * fuse_k
    assert f_patch.shape[1] == tile_w + 2 * fuse_k
    assert f_patch.shape[0] % ph == 0
    cap = f_patch.shape[0] // ph
    acc_dtype = qdt_acc_dtype(f_patch.dtype)
    assert r_mid.dtype == acc_dtype and d_mid.dtype == jnp.int32
    assert r_mid.shape == d_mid.shape == (cap * band_h, tile_w)
    if base.shape == (1, 1):
        base = jnp.broadcast_to(base, (cap, 1))
    assert base.shape == (cap, 1)

    patch_spec = pl.BlockSpec((ph, tile_w + 2 * fuse_k), lambda i: (i, 0))
    mid_spec = pl.BlockSpec((band_h, tile_w), lambda i: (i, 0))
    flag_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))

    kern = functools.partial(
        _qdt_compact_kernel, fuse_k=fuse_k, band_h=band_h, tile_w=tile_w,
        acc_dtype=acc_dtype,
    )
    return pl.pallas_call(
        kern,
        grid=(cap,),
        in_specs=[flag_spec, flag_spec, patch_spec, mid_spec, mid_spec],
        out_specs=[mid_spec, mid_spec, mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((cap * band_h, tile_w), f_patch.dtype),
            jax.ShapeDtypeStruct((cap * band_h, tile_w), acc_dtype),
            jax.ShapeDtypeStruct((cap * band_h, tile_w), jnp.int32),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
        ],
        interpret=interpret,
    )(base, valid, f_patch, r_mid, d_mid)
