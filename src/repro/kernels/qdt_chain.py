"""Fused quasi-distance-transform chunk — Algorithm 5 of the paper.

Each of the K fused steps computes ε₁, the residual B = f − ε₁(f), and
performs the *masked store* update of the residual plane r(f) and the
distance plane d(f) (update only where the new residual exceeds the
stored one).  The paper uses AVX2 masked stores for this; on TPU the
masked store is a vectorized ``jnp.where`` on the VMEM tile.

r/d only need the centre rows (their update is pointwise), so they are
blocked without halo — only the eroding image carries the K-row halo.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import elementary_3x3, ident_for


def _qdt_kernel(
    base, f_top, f_mid, f_bot, r_in, d_in, f_out, r_out, d_out, changed,
    *, fuse_k: int, band_h: int, acc_dtype,
):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    ident = ident_for("erode", f_mid.dtype)

    top = jnp.where(i > 0, f_top[...], ident)
    bot = jnp.where(i < n - 1, f_bot[...], ident)
    stack = jnp.concatenate([top, f_mid[...], bot], axis=0)

    r = r_in[...]
    d = d_in[...]
    j0 = base[0, 0]

    lo, hi = fuse_k, fuse_k + band_h
    for k in range(fuse_k):
        nxt = elementary_3x3(stack, "erode")
        res = stack[lo:hi, :].astype(acc_dtype) - nxt[lo:hi, :].astype(acc_dtype)
        upd = res > r
        r = jnp.where(upd, res, r)
        d = jnp.where(upd, j0 + (k + 1), d)
        stack = nxt

    centre = stack[lo:hi, :]
    f_out[...] = centre
    r_out[...] = r
    d_out[...] = d
    changed[...] = jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)


def qdt_chain_step(
    f: jnp.ndarray,
    r: jnp.ndarray,
    d: jnp.ndarray,
    base: jnp.ndarray,
    *,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
):
    """One K-step QDT chunk on pre-padded planes.

    ``base`` is a (1,1) int32 with the number of erosions already applied.
    Returns (f', r', d', changed) — changed is (n_bands, 1) int32.
    """
    h, w = f.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    n_bands = h // band_h
    rr = band_h // fuse_k
    last_k_block = h // fuse_k - 1
    acc_dtype = jnp.float32 if jnp.issubdtype(f.dtype, jnp.floating) else jnp.int32
    assert r.dtype == acc_dtype and d.dtype == jnp.int32

    top_spec = pl.BlockSpec((fuse_k, w), lambda i: (jnp.maximum(i * rr - 1, 0), 0))
    mid_spec = pl.BlockSpec((band_h, w), lambda i: (i, 0))
    bot_spec = pl.BlockSpec(
        (fuse_k, w), lambda i: (jnp.minimum((i + 1) * rr, last_k_block), 0)
    )
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    flag_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))

    kern = functools.partial(
        _qdt_kernel, fuse_k=fuse_k, band_h=band_h, acc_dtype=acc_dtype
    )
    return pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=[scalar_spec, top_spec, mid_spec, bot_spec, mid_spec, mid_spec],
        out_specs=[mid_spec, mid_spec, mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((h, w), acc_dtype),
            jax.ShapeDtypeStruct((h, w), jnp.int32),
            jax.ShapeDtypeStruct((n_bands, 1), jnp.int32),
        ],
        interpret=interpret,
    )(base, f, f, f, r, d)
