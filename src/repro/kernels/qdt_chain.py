"""Fused quasi-distance-transform chunk — Algorithm 5 of the paper.

Each of the K fused steps computes ε₁, the residual B = f − ε₁(f), and
performs the *masked store* update of the residual plane r(f) and the
distance plane d(f) (update only where the new residual exceeds the
stored one).  The paper uses AVX2 masked stores for this; on TPU the
masked store is a vectorized ``jnp.where`` on the VMEM tile.

r/d only need the centre rows (their update is pointwise), so they are
blocked without halo — only the eroding image carries the K-row halo.

Like the geodesic kernel, each band carries an ``active`` scalar: once a
band's erosion has reached the lattice bottom everywhere (no pixel
changed, nor in its neighbours), the driver stops requeueing it and the
kernel passes f/r/d through unchanged under ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import elementary_3x3, ident_for, image_edges


def _qdt_kernel(
    base, active, f_top, f_mid, f_bot, r_in, d_in, f_out, r_out, d_out, changed,
    *, fuse_k: int, band_h: int, acc_dtype, bands_per_image: int,
    pin_halos: bool,
):
    # ``base`` is blocked per band: each band reads the elementary-erosion
    # count already applied to *its image*, so ragged-converged stacks
    # keep per-image distance indices (a finished image's counter stops
    # advancing with the rest of the batch).
    # program_id is not available inside pl.when branches in interpret
    # mode — read it at kernel top level.
    edges = image_edges(pl.program_id(0), bands_per_image) if pin_halos else None

    @pl.when(active[0, 0] == 0)
    def _passthrough():
        # converged band: pass all planes through, report no change.
        f_out[...] = f_mid[...]
        r_out[...] = r_in[...]
        d_out[...] = d_in[...]
        changed[...] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(active[0, 0] > 0)
    def _compute():
        ident = ident_for("erode", f_mid.dtype)
        top, bot = f_top[...], f_bot[...]
        if pin_halos:
            at_top, at_bot = edges
            top = jnp.where(at_top, ident, top)
            bot = jnp.where(at_bot, ident, bot)
        stack = jnp.concatenate([top, f_mid[...], bot], axis=0)

        r = r_in[...]
        d = d_in[...]
        j0 = base[0, 0]

        lo, hi = fuse_k, fuse_k + band_h
        for k in range(fuse_k):
            nxt = elementary_3x3(stack, "erode")
            res = stack[lo:hi, :].astype(acc_dtype) - nxt[lo:hi, :].astype(acc_dtype)
            upd = res > r
            r = jnp.where(upd, res, r)
            d = jnp.where(upd, j0 + (k + 1), d)
            stack = nxt

        centre = stack[lo:hi, :]
        f_out[...] = centre
        r_out[...] = r
        d_out[...] = d
        changed[...] = (
            jnp.any(centre != f_mid[...]).astype(jnp.int32).reshape(1, 1)
        )


def qdt_chain_step(
    f: jnp.ndarray,
    r: jnp.ndarray,
    d: jnp.ndarray,
    base: jnp.ndarray,
    *,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
    active: jnp.ndarray | None = None,
    bands_per_image: int | None = None,
):
    """One K-step QDT chunk on pre-padded planes.

    ``base`` is an (n_bands, 1) int32 with the number of elementary
    erosions already applied to each band's image — per *band* so the
    batched driver can give every stacked image its own distance offset
    (a (1, 1) array is broadcast for the unbatched callers).
    ``active`` optionally skips converged bands (see module docstring).
    Returns (f', r', d', changed) — changed is (n_bands, 1) int32.
    """
    h, w = f.shape
    assert h % band_h == 0 and band_h % fuse_k == 0
    n_bands = h // band_h
    if bands_per_image is None:
        bands_per_image = n_bands
    assert n_bands % bands_per_image == 0
    if active is None:
        active = jnp.ones((n_bands, 1), jnp.int32)
    if base.shape == (1, 1):
        base = jnp.broadcast_to(base, (n_bands, 1))
    assert base.shape == (n_bands, 1)
    rr = band_h // fuse_k
    last_k_block = h // fuse_k - 1
    acc_dtype = jnp.float32 if jnp.issubdtype(f.dtype, jnp.floating) else jnp.int32
    assert r.dtype == acc_dtype and d.dtype == jnp.int32

    top_spec = pl.BlockSpec((fuse_k, w), lambda i: (jnp.maximum(i * rr - 1, 0), 0))
    mid_spec = pl.BlockSpec((band_h, w), lambda i: (i, 0))
    bot_spec = pl.BlockSpec(
        (fuse_k, w), lambda i: (jnp.minimum((i + 1) * rr, last_k_block), 0)
    )
    flag_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))

    kern = functools.partial(
        _qdt_kernel, fuse_k=fuse_k, band_h=band_h, acc_dtype=acc_dtype,
        bands_per_image=bands_per_image, pin_halos=True,
    )
    return pl.pallas_call(
        kern,
        grid=(n_bands,),
        in_specs=[flag_spec, flag_spec, top_spec, mid_spec, bot_spec,
                  mid_spec, mid_spec],
        out_specs=[mid_spec, mid_spec, mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((h, w), acc_dtype),
            jax.ShapeDtypeStruct((h, w), jnp.int32),
            jax.ShapeDtypeStruct((n_bands, 1), jnp.int32),
        ],
        interpret=interpret,
    )(base, active, f, f, f, r, d)


def qdt_compact_step(
    f_top: jnp.ndarray,
    f_mid: jnp.ndarray,
    f_bot: jnp.ndarray,
    r_mid: jnp.ndarray,
    d_mid: jnp.ndarray,
    valid: jnp.ndarray,
    base: jnp.ndarray,
    *,
    fuse_k: int,
    band_h: int,
    interpret: bool = True,
):
    """Compacted-grid QDT chunk on driver-gathered active bands.

    Shapes mirror ``geodesic_compact_step``: f_mid/r_mid/d_mid
    (C·band_h, W), f_top/f_bot (C·fuse_k, W), valid (C, 1) int32,
    base (C, 1) int32 — the driver gathers each active band's per-image
    erosion count into the workspace slot (a (1, 1) array is broadcast).
    Returns (f', r', d', changed).
    """
    cap_bh, w = f_mid.shape
    assert cap_bh % band_h == 0
    cap = cap_bh // band_h
    acc_dtype = jnp.float32 if jnp.issubdtype(f_mid.dtype, jnp.floating) else jnp.int32
    assert r_mid.dtype == acc_dtype and d_mid.dtype == jnp.int32
    if base.shape == (1, 1):
        base = jnp.broadcast_to(base, (cap, 1))
    assert base.shape == (cap, 1)

    halo_spec = pl.BlockSpec((fuse_k, w), lambda i: (i, 0))
    mid_spec = pl.BlockSpec((band_h, w), lambda i: (i, 0))
    flag_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))

    kern = functools.partial(
        _qdt_kernel, fuse_k=fuse_k, band_h=band_h, acc_dtype=acc_dtype,
        bands_per_image=cap, pin_halos=False,
    )
    return pl.pallas_call(
        kern,
        grid=(cap,),
        in_specs=[flag_spec, flag_spec, halo_spec, mid_spec, halo_spec,
                  mid_spec, mid_spec],
        out_specs=[mid_spec, mid_spec, mid_spec, flag_spec],
        out_shape=[
            jax.ShapeDtypeStruct((cap_bh, w), f_mid.dtype),
            jax.ShapeDtypeStruct((cap_bh, w), acc_dtype),
            jax.ShapeDtypeStruct((cap_bh, w), jnp.int32),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
        ],
        interpret=interpret,
    )(base, valid, f_top, f_mid, f_bot, r_mid, d_mid)
