"""Pure-jnp oracles for every Pallas kernel in this package.

The reference implementations live in ``repro.core.morphology`` /
``repro.core.operators`` (they ARE the paper's definitions, Eq. 1-20);
this module re-exports them under kernel-aligned names so each kernel
test reads ``kernel_out == ref.<name>(...)`` — bit-exact, per the
convention in ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.morphology import (  # noqa: F401
    dilate,
    dilate3,
    erode,
    erode3,
    geodesic_dilate,
    geodesic_erode,
    dilate_reconstruct,
    erode_reconstruct,
)
from repro.core.operators import qdt_raw  # noqa: F401


def chain(f: jnp.ndarray, n: int, op: str) -> jnp.ndarray:
    """n elementary 3×3 filters — oracle for erode_chain.chain_step."""
    return erode(f, n) if op == "erode" else dilate(f, n)


def geodesic_chain(f: jnp.ndarray, m: jnp.ndarray, n: int,
                   op: str) -> jnp.ndarray:
    """n elementary geodesic filters — oracle for geodesic_chain_step."""
    if op == "erode":
        return geodesic_erode(f, m, n)
    return geodesic_dilate(f, m, n)


def qdt_chunk(f: jnp.ndarray, r: jnp.ndarray, d: jnp.ndarray, base: int,
              n: int):
    """n QDT erosion steps with residual/distance update — oracle for
    qdt_chain_step."""
    acc = r.dtype
    cur = f
    for k in range(n):
        nxt = erode3(cur)
        res = cur.astype(acc) - nxt.astype(acc)
        upd = res > r
        r = jnp.where(upd, res, r)
        d = jnp.where(upd, base + k + 1, d)
        cur = nxt
    return cur, r, d
