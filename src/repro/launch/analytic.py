"""Analytic FLOP / HBM-traffic model per (arch × shape) — the roofline's
compute and memory terms, cross-checked against the HLO-parsed numbers.

MODEL_FLOPS convention (assignment §Roofline): 6·N·D for dense training
(N params, D tokens), 6·N_active·D for MoE; attention adds
12·L·H·hd·S²·(causal ½)·D_batch terms.  Forward-only steps use 2·N·D.
The HBM model counts the bytes a chip must move per step given the
sharding policy: TP-sharded weights are read once per pass (fwd, bwd,
remat-fwd), gradients/optimizer sharded by FSDP, KV cache read per
decode step, activations written/read once per layer boundary
(everything interior is assumed fused).
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec

# TPU v5e-like constants (assignment)
PEAK_FLOPS = 197e12          # bf16 MXU / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
ICI_LATENCY = 1e-6           # per message (ring hop), order-of-magnitude
#: elementwise min/max throughput (VPU, not MXU): 8×128 lanes × ~1 op
#: per cycle × ~0.94 GHz ≈ 1 Top/s per 32-bit lane-op; ×4 for int8
#: packing.  Used for the morphology cells — crediting the MXU peak to
#: elementwise ops would overstate headroom ~50×.
VPU_OPS = {1: 4e12, 2: 2e12, 4: 1e12, 8: 0.5e12}


@dataclasses.dataclass
class Terms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float | None = None

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)


def _attn_flops_per_layer(cfg: ModelConfig, s: int, kind: str,
                          causal: bool = True) -> float:
    """QK^T + PV flops per token-batch row (batch excluded)."""
    h, hd = cfg.n_heads, cfg.head_dim
    if kind == "attn_local" and cfg.sliding_window:
        ctx = min(cfg.sliding_window, s)
    else:
        ctx = s / 2 if causal else s
    return 2.0 * 2.0 * s * ctx * h * hd


def _layer_linear_flops(cfg: ModelConfig, kind: str) -> float:
    """Per-token matmul flops (fwd) for one layer of ``kind``."""
    d, f = cfg.d_model, cfg.d_ff
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    glu = cfg.activation in ("silu", "geglu")
    if kind.startswith("attn"):
        fl = 2 * d * (h * hd * 2 + kv * hd * 2)          # qkvo
        if cfg.moe is not None:
            m = cfg.moe
            fl += 2 * d * m.n_experts                     # router
            fl += (m.top_k + m.n_shared) * 2 * d * m.d_expert * 3
            if m.dense_residual_ff:
                fl += 2 * d * m.dense_residual_ff * 3
        elif f:
            fl += 2 * d * f * (3 if glu else 2)
        return fl
    if kind == "mamba2":
        d_in = 2 * d
        nh = d_in // cfg.ssm_head_dim
        fl = 2 * d * (2 * d_in + 2 * cfg.ssm_state + nh) + 2 * d_in * d
        # ssd: chunked quadratic (chunk=128) + state products
        chunk = 128
        fl += 2 * chunk * cfg.ssm_state * 2              # scores per token
        fl += 2 * chunk * d_in                            # intra y
        fl += 4 * cfg.ssm_state * d_in                    # state in/out
        return fl
    if kind == "mlstm":
        d_in = 2 * d
        fl = 2 * d * (3 * d_in + d_in) + 2 * d_in * d
        chunk = 128
        p = d_in // cfg.n_heads
        fl += 2 * chunk * d_in * 2                        # scores + out
        fl += 4 * p * d_in                                # state update/query
        return fl
    if kind == "slstm":
        fl = 2 * d * 4 * d + 2 * d * d
        fl += 2 * 4 * d * (d // cfg.n_heads)              # recurrent (blocked)
        return fl
    raise ValueError(kind)


def step_flops(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Global model flops for one step (all chips together)."""
    s = shape.seq_len
    b = shape.global_batch
    train = shape.step == "train"
    tokens = b * (1 if shape.step == "decode" else s)

    per_tok = 0.0
    attn = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        per_tok += _layer_linear_flops(cfg, kind)
        if kind.startswith("attn"):
            if shape.step == "decode":
                ctx = (min(cfg.sliding_window, s)
                       if kind == "attn_local" and cfg.sliding_window else s)
                attn += 2.0 * 2.0 * ctx * cfg.n_heads * cfg.head_dim * b
            else:
                attn += _attn_flops_per_layer(cfg, s, kind) * b
    if cfg.shared_attn_period:
        napp = cfg.n_layers // cfg.shared_attn_period
        per_tok += napp * _layer_linear_flops(cfg, "attn")
        if shape.step == "decode":
            attn += napp * 2.0 * 2.0 * s * cfg.n_heads * cfg.head_dim * b
        else:
            attn += napp * _attn_flops_per_layer(cfg, s, "attn") * b
    if cfg.is_enc_dec:
        enc_s = min(s, 4096)
        enc_tok = b * enc_s
        enc_per_tok = _layer_linear_flops(
            dataclasses.replace(cfg, moe=None), "attn")
        per_tok_enc = enc_per_tok * cfg.encoder_layers
        attn += cfg.encoder_layers * _attn_flops_per_layer(
            cfg, enc_s, "attn", causal=False) * b
        # cross attention in every decoder layer
        per_tok += cfg.n_layers * 2 * cfg.d_model * (
            cfg.n_heads * cfg.head_dim + 2 * cfg.n_kv_heads * cfg.head_dim)
        if shape.step == "decode":
            attn += cfg.n_layers * 2.0 * 2.0 * enc_s * cfg.n_heads \
                * cfg.head_dim * b
        else:
            # cross attention: S decoder queries × enc_s keys per layer
            attn += cfg.n_layers * 2.0 * 2.0 * s * enc_s * cfg.n_heads \
                * cfg.head_dim * b
    else:
        per_tok_enc = 0.0
        enc_tok = 0

    # embedding + head
    head = 2 * cfg.d_model * cfg.vocab_size
    fwd = per_tok * tokens + per_tok_enc * enc_tok + attn + head * tokens
    mult = 3.0 if train else 1.0          # bwd = 2x fwd
    total = fwd * mult
    n_active = cfg.active_param_count()
    model_flops = (6 if train else 2) * n_active * tokens
    return {"flops": total, "model_flops": model_flops, "fwd_flops": fwd}


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, mesh_shape: dict,
                   accum: int = 1) -> float:
    """Per-chip HBM traffic (bytes) per step under the sharding policy."""
    model_par = mesh_shape.get("model", 1)
    data_par = math.prod(v for k, v in mesh_shape.items() if k != "model")
    chips = model_par * data_par
    pbytes = {"float32": 4, "bfloat16": 2}.get(cfg.param_dtype, 4)
    abytes = {"float32": 4, "bfloat16": 2}.get(cfg.activation_dtype, 2)

    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    # weights a chip reads per pass: TP-sharded (1/model_par) of the
    # *active* params (routed experts it does not own are other chips' work)
    w_read = n_active * pbytes / model_par

    s = shape.seq_len
    b = shape.global_batch
    if shape.step == "train":
        # fwd + bwd + remat-recompute reads of weights; grads + adam rw
        traffic = 3 * w_read * accum
        opt = n_total / chips * pbytes  # param shard rw (ZeRO)
        traffic += 6 * opt              # grad w + m rw + v rw + p rw
        act = b * s * cfg.d_model * abytes / data_par / model_par
        traffic += act * cfg.n_layers * 4      # layer-boundary acts, fwd+bwd
        return traffic
    if shape.step == "prefill":
        act = b * s * cfg.d_model * abytes / data_par / model_par
        return w_read + act * cfg.n_layers * 2
    # decode: weights + full KV/state read per step
    kv_bytes = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind.startswith("attn"):
            kv_bytes += 2 * b * s * cfg.n_kv_heads * cfg.head_dim * abytes
        elif kind == "mamba2":
            d_in = 2 * cfg.d_model
            kv_bytes += b * (d_in // cfg.ssm_head_dim) * cfg.ssm_head_dim \
                * cfg.ssm_state * 4
        elif kind == "mlstm":
            p = 2 * cfg.d_model // cfg.n_heads
            kv_bytes += b * cfg.n_heads * p * p * 4
        elif kind == "slstm":
            kv_bytes += 4 * b * cfg.d_model * 4
    if cfg.shared_attn_period:
        kv_bytes += (cfg.n_layers // cfg.shared_attn_period) * 2 * b * s \
            * cfg.n_kv_heads * cfg.head_dim * abytes
    # the cache is sharded over every mesh axis (batch/seq -> data axes,
    # heads -> model)
    return w_read + kv_bytes / chips


def roofline_terms(cfg: ModelConfig, shape: ShapeSpec, mesh_shape: dict,
                   hlo: dict | None = None, chips: int | None = None) -> Terms:
    chips = chips or math.prod(mesh_shape.values())
    fl = step_flops(cfg, shape)
    compute_s = fl["flops"] / (chips * PEAK_FLOPS)
    memory_s = step_hbm_bytes(cfg, shape, mesh_shape) / HBM_BW
    if hlo is not None:
        coll = hlo.get("collective_bytes_total", 0.0)
        # per-device bytes over ~2 links usable per transfer direction
        collective_s = coll / (2 * ICI_BW)
        hlo_flops = hlo.get("dot_flops")
    else:
        collective_s, hlo_flops = 0.0, None
    return Terms(compute_s, memory_s, collective_s, fl["model_flops"],
                 hlo_flops)
