import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell
with 512 placeholder CPU devices, record memory / cost / collective
analysis — proves the distribution config is coherent without hardware.

MUST be run as its own process (the XLA_FLAGS line above runs before any
other import so jax initializes with 512 devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun/

Also covers the paper's own workload (``--arch geodesic2d``): the
distributed reconstruction of core.distributed sharded over the full
mesh.
"""  # noqa: E402

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, cells_for
from repro.launch import analytic, hlo_parse, sharding as SH
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import decode as DEC
from repro.models import model as MDL
from repro.models import partitioning as PT
from repro.optim import adamw
from repro.train import steps as STEPS

ENC_LEN_CAP = 4096  # bounded encoder memory for enc-dec (DESIGN.md §4)


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    b, s = shape.global_batch, shape.seq_len
    adt = jnp.dtype(cfg.activation_dtype)
    if shape.step == "train":
        batch = {"labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.frontend == "vision":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), adt)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.is_enc_dec:
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, min(s, ENC_LEN_CAP), cfg.d_model), adt)
        return batch
    if shape.step == "prefill":
        batch = {}
        if cfg.frontend == "vision":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), adt)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.is_enc_dec:
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, min(s, ENC_LEN_CAP), cfg.d_model), adt)
        return batch
    # decode
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _params_shape(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: MDL.init_params(cfg, jax.random.PRNGKey(0)))


def _q_chunk(shape: ShapeSpec) -> int:
    return min(1024, shape.seq_len)


def choose_accum(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 budget: float = 10e9) -> int:
    """Microbatch count for train cells: smallest power of two whose
    estimated per-chip activation footprint fits the budget.

    Napkin model: saved residual-stream x per layer + flash-attention
    residuals (q,k,v,out) ≈ 4 tensors × tokens/chip × d_model × 2 B."""
    if shape.step != "train":
        return 1
    data_par = 1
    for a, s in mesh.shape.items():
        if a != "model":
            data_par *= s
    tokens_per_chip = shape.global_batch * shape.seq_len / data_par
    depth = cfg.n_layers + cfg.encoder_layers
    est = tokens_per_chip * cfg.d_model * depth * 2 * 4
    accum = 1
    max_accum = max(1, shape.global_batch // data_par)
    while est / accum > budget and accum < max_accum:
        accum *= 2
    return accum


def effective_mesh(cfg: ModelConfig, mesh):
    """Logical mesh re-factorization (§Perf qwen H1): when the head
    counts don't divide the model axis, attention would replicate across
    it (16× wasted FLOPs at 32k prefill).  The same physical chips are
    re-viewed with TP = the largest power of two dividing both head
    counts, folding the rest into the data axis.  Physical topology and
    chip count are unchanged."""
    msize = mesh.shape["model"]
    if not cfg.attends or cfg.block_pattern is not None:
        return mesh
    tp = msize
    while tp > 1 and (cfg.n_heads % tp or cfg.n_kv_heads % tp):
        tp //= 2
    if tp == msize or tp < 2:
        return mesh
    from jax.sharding import Mesh

    names = mesh.axis_names
    sizes = dict(mesh.shape)
    factor = msize // tp
    new_sizes = [sizes[n] for n in names]
    new_sizes[names.index("data")] *= factor
    new_sizes[names.index("model")] = tp
    devs = mesh.devices.reshape(new_sizes)
    return Mesh(devs, names)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (jitted fn, example args as sharded ShapeDtypeStructs)."""
    params_shape = _params_shape(cfg)
    if shape.step == "decode":
        # serving: bf16 weights, TP-only sharding (no per-token FSDP
        # gathers), replicated across the batch axes
        params_shape = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype),
            params_shape)
    attn_tp = (shape.step != "decode"
               or cfg.n_kv_heads % mesh.shape["model"] == 0)
    pspecs = SH.param_specs(cfg, params_shape, mesh, attn_tp=attn_tp)
    pshard = SH.to_named(pspecs, mesh)
    batch = input_specs(cfg, shape)
    bshard = SH.to_named(SH.batch_specs(batch, mesh), mesh)

    if shape.step == "train":
        opt_cfg = adamw.AdamWConfig(
            state_dtype="bfloat16" if cfg.param_dtype == "bfloat16" else None)
        opt_shape = jax.eval_shape(
            lambda p: adamw.init_state(opt_cfg, p), params_shape)
        ospecs = SH.opt_state_specs(cfg, pspecs)
        oshard = SH.to_named(ospecs, mesh)
        accum = choose_accum(cfg, shape, mesh)
        fn = STEPS.build_train_step(cfg, opt_cfg, q_chunk=_q_chunk(shape),
                                    accum=accum, grad_shardings=pshard)
        jfn = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                      out_shardings=(pshard, oshard, None),
                      donate_argnums=(0, 1))
        return jfn, (params_shape, opt_shape, batch)

    if shape.step == "prefill":
        fn = STEPS.build_prefill_step(cfg, q_chunk=_q_chunk(shape))
        jfn = jax.jit(fn, in_shardings=(pshard, bshard))
        return jfn, (params_shape, batch)

    # decode
    enc_len = min(shape.seq_len, ENC_LEN_CAP) if cfg.is_enc_dec else 0
    cache_shape = jax.eval_shape(
        lambda: DEC.init_cache(cfg, shape.global_batch, shape.seq_len,
                               enc_len))
    cspecs = SH.cache_specs(cfg, cache_shape, mesh)
    cshard = SH.to_named(cspecs, mesh)
    tokens = input_specs(cfg, shape)["tokens"]
    tshard = NamedSharding(mesh, P())
    fn = STEPS.build_serve_step(cfg)
    jfn = jax.jit(fn, in_shardings=(pshard, cshard, tshard),
                  out_shardings=(None, cshard), donate_argnums=(1,))
    return jfn, (params_shape, cache_shape, tokens)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             dynamic_trip: float | None = None,
             refactor_mesh: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    if arch == "geodesic2d":
        return run_geodesic_cell(shape_name, mesh, multi_pod)
    cfg = get_config(arch)
    if refactor_mesh:
        mesh = effective_mesh(cfg, mesh)
    shape = SHAPES[shape_name]
    t0 = time.time()
    policy = PT.Policy(mesh, batch_axes(mesh))
    with PT.apply_policy(policy):
        jfn, args = build_cell(cfg, shape, mesh)
        lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if dynamic_trip is None:
        nq = max(1, shape.seq_len // _q_chunk(shape))
        dynamic_trip = (nq + 1) / 2
    hlo = hlo_parse.analyze(compiled.as_text(), dynamic_trip=dynamic_trip)
    chips = int(np.prod(list(mesh.shape.values())))
    terms = analytic.roofline_terms(cfg, shape, dict(mesh.shape), hlo,
                                    chips=chips)

    per_dev_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "logical_mesh": "x".join(str(v) for v in mesh.shape.values()),
        "chips": chips,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "bytes_per_device": int(per_dev_bytes),
        "arg_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "fits_16g": bool(per_dev_bytes < 16e9),
        "xla_flops_per_device_raw": float(ca.get("flops", 0.0)),
        "hlo_dot_flops_per_device": hlo["dot_flops"],
        "collective_bytes_per_device": hlo["collective_bytes_total"],
        "collectives": hlo["collective_bytes"],
        "collective_counts": hlo["collective_counts"],
        "top_collectives": hlo.get("top_collectives", []),
        "model_flops": terms.model_flops,
        "analytic_flops": analytic.step_flops(cfg, shape)["flops"],
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "dominant": terms.dominant,
    }


# ---------------------------------------------------------------------------
# the paper's own workload on the production mesh
# ---------------------------------------------------------------------------

GEO_SHAPES = {
    "img_16k": (16384, 16384, "uint8"),    # H, W, dtype
    "img_64k_rows": (65536, 8192, "uint8"),
}

GEO_TOTAL_STEPS = 4096  # elementary filters applied (reconstruction scale)

#: tuned fusion depth (§Perf geodesic2d): the fused chain is VPU-compute
#: bound for K ≥ 8, so halo redundancy (∝K) sets the roofline fraction —
#: K=8 measures 97% vs 80% at the paper-instinct maximum K=64.
GEO_FUSE_K = 8


def geodesic_terms(h, w, dt, k, chips, mesh_shape):
    """Analytic three-term roofline for the K-fused distributed chain.

    compute: 5 VPU ops/px/step on the local shard + halo redundancy
             (2K/H_loc + 2K/W_loc extra rows/cols recomputed per chunk);
    memory:  one read+write of the shard per K-chunk (the fusion win);
    collective: 2K halo rows+cols per chunk (volume ∝ steps, but the
             message COUNT is steps/K — latency amortization).
    """
    b = np.dtype(dt).itemsize
    rows_par = int(np.prod([v for a, v in mesh_shape.items()
                            if a != "model"]))
    cols_par = mesh_shape.get("model", 1)
    h_loc, w_loc = h / rows_par, w / cols_par
    chunks = GEO_TOTAL_STEPS / k
    redundancy = 1.0 + 2 * k / h_loc + 2 * k / w_loc
    ops = 5.0 * h_loc * w_loc * GEO_TOTAL_STEPS * redundancy
    compute_s = ops / analytic.VPU_OPS[b]
    memory_s = chunks * 2 * h_loc * w_loc * b / analytic.HBM_BW
    halo_bytes = chunks * 2 * k * (h_loc + w_loc) * b
    collective_s = (halo_bytes / analytic.ICI_BW
                    + chunks * 4 * analytic.ICI_LATENCY)
    useful = 5.0 * h * w * GEO_TOTAL_STEPS / chips / analytic.VPU_OPS[b]
    return compute_s, memory_s, collective_s, useful


def run_geodesic_cell(shape_name: str, mesh, multi_pod: bool,
                      fuse_k: int = GEO_FUSE_K) -> dict:
    from repro.core import distributed as D

    h, w, dt = GEO_SHAPES[shape_name]
    rows = tuple(a for a in mesh.axis_names if a != "model")
    fn = D.distributed_reconstruct(
        mesh, rows, "model", op="erode", backend="xla", fuse_k=fuse_k,
        max_chunks=GEO_TOTAL_STEPS // fuse_k)
    f = jax.ShapeDtypeStruct((h, w), jnp.dtype(dt))
    t0 = time.time()
    lowered = fn.lower(f, f)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    hlo = hlo_parse.analyze(compiled.as_text(),
                            dynamic_trip=GEO_TOTAL_STEPS / fuse_k)
    chips = int(np.prod(list(mesh.shape.values())))
    per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    compute_s, memory_s, collective_s, useful = geodesic_terms(
        h, w, dt, fuse_k, chips, dict(mesh.shape))
    bound = max(compute_s, memory_s, collective_s)
    dom = {"compute": compute_s, "memory": memory_s,
           "collective": collective_s}
    return {
        "arch": "geodesic2d", "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "fuse_k": fuse_k,
        "ok": True, "compile_s": round(time.time() - t0, 1),
        "bytes_per_device": int(per_dev), "fits_16g": bool(per_dev < 16e9),
        "hlo_dot_flops_per_device": hlo["dot_flops"],
        "collective_bytes_per_device": hlo["collective_bytes_total"],
        "collectives": hlo["collective_bytes"],
        "model_flops": 5.0 * h * w * GEO_TOTAL_STEPS,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "roofline_frac": useful / bound,
        "dominant": max(dom, key=dom.get),
    }


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("geodesic2d",))
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = []
    if args.all:
        cells = []
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shp in cells_for(cfg):
                for mp in (False, True):
                    cells.append((arch, shp, mp))
        for shp in GEO_SHAPES:
            for mp in (False, True):
                cells.append(("geodesic2d", shp, mp))
    else:
        cells = [(args.arch, args.shape, args.multi_pod)]

    for arch, shp, mp in cells:
        tag = f"{arch} × {shp} × {'2x16x16' if mp else '16x16'}"
        try:
            r = run_cell(arch, shp, mp)
            print(f"[OK] {tag}: {r['bytes_per_device']/1e9:.2f} GB/dev, "
                  f"dominant={r.get('dominant')}")
        except Exception as e:  # noqa: BLE001
            r = {"arch": arch, "shape": shp,
                 "mesh": "2x16x16" if mp else "16x16", "ok": False,
                 "error": f"{type(e).__name__}: {e}",
                 "traceback": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {tag}: {r['error']}")
        results.append(r)

    if args.out:
        if args.out.endswith(".json"):
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
        else:
            os.makedirs(args.out, exist_ok=True)
            for r in results:
                name = f"{r['arch']}_{r['shape']}_{r['mesh']}.json"
                with open(os.path.join(args.out, name), "w") as f:
                    json.dump(r, f, indent=1)
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{ok}/{len(results)} cells OK")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
