"""Post-SPMD HLO text analysis for the roofline.

``compiled.as_text()`` is the per-device program after partitioning:
shapes are per-shard, collectives are explicit.  XLA's
``cost_analysis()`` counts while bodies ONCE (verified empirically), so
we parse the text ourselves:

  * computations + per-computation symbol table (op name -> shape),
  * a call graph (while body/condition, fusion calls, to_apply,
    conditional branches) with execution multipliers — while trip counts
    are recovered from the largest integer constant in the loop's
    condition computation (lax.scan emits static bounds); dynamic-bound
    loops (e.g. flash attention's causal kv fori) fall back to a
    caller-supplied multiplier,
  * dot FLOPs = 2 · |result| · |contracted dims| · multiplier,
  * collective bytes = payload bytes · ring factor · multiplier
    (all-reduce 2·(n-1)/n ≈ 2, others ≈ 1).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s"
                    r"([a-z][a-z0-9\-]*)\(")
_CALL_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)|branches=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict          # op name -> type_str


def parse_module(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    head_re = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
    for line in text.splitlines():
        if cur is None:
            m = head_re.match(line)
            if m:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, kind = m.groups()
            cur.symbols[name] = type_str
            cur.ops.append(Op(name, kind, type_str, line.strip()))
    if cur is not None:
        comps[cur.name] = cur
    return {"computations": comps, "entry": entry}


def _while_trip(cond: Computation) -> int | None:
    """Static trip count: scan conditions compare the counter against a
    constant; take the largest integer constant found."""
    best = None
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                v = int(m.group(1))
                if v > 0 and (best is None or v > best):
                    best = v
    return best


def execution_multipliers(mod: dict, dynamic_trip: float = 1.0) -> dict:
    """computation name -> times executed per step."""
    comps = mod["computations"]
    mult: dict[str, float] = defaultdict(float)
    entry = mod["entry"]
    if entry is None:
        return {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] += m
        comp = comps[name]
        for op in comp.ops:
            trips = 1.0
            if op.kind == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                cdm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                body = bm.group(1) if bm else None
                cond = cdm.group(1) if cdm else None
                t = None
                if cond and cond in comps:
                    t = _while_trip(comps[cond])
                trips = float(t) if t else dynamic_trip
                if body:
                    visit(body, m * trips)
                if cond:
                    visit(cond, m * (trips + 1))
                continue
            for g in _CALL_RE.finditer(op.line):
                if g.group(1):
                    visit(g.group(1), m)
                elif g.group(2):
                    for b in _OPERAND_RE.findall(g.group(2)):
                        visit(b, m)

    visit(entry, 1.0)
    return dict(mult)


def _dot_flops(op: Op, symbols: dict) -> float:
    out_dims = _shape_dims(op.type_str)
    if out_dims is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    # first operand name after "dot("
    call = op.line.split(" dot(", 1)[-1] if " dot(" in op.line else ""
    ops_names = _OPERAND_RE.findall(call.split(")", 1)[0])
    contract = 1
    if ops_names:
        lhs_type = symbols.get(ops_names[0])
        if lhs_type:
            ld = _shape_dims(lhs_type) or []
            for c in cdims:
                if c < len(ld):
                    contract *= ld[c]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * contract


def analyze(text: str, dynamic_trip: float = 1.0) -> dict:
    """Per-device totals: dot flops, collective bytes by kind, op counts."""
    mod = parse_module(text)
    mult = execution_multipliers(mod, dynamic_trip)
    flops = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_count: dict[str, float] = defaultdict(float)
    top: list[tuple[float, str]] = []
    for cname, comp in mod["computations"].items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, comp.symbols)
            elif op.kind in COLLECTIVES:
                payload = _shape_bytes(op.type_str)
                factor = 2.0 if op.kind == "all-reduce" else 1.0
                coll_bytes[op.kind] += m * payload * factor
                coll_count[op.kind] += m
                top.append((m * payload * factor,
                            f"{op.kind} x{m:.0f} {op.type_str[:60]}"))
    top.sort(reverse=True)
    return {
        "dot_flops": flops,
        "collective_bytes": dict(coll_bytes),
        "collective_bytes_total": float(sum(coll_bytes.values())),
        "collective_counts": dict(coll_count),
        "top_collectives": [f"{b/1e9:.2f}GB {d}" for b, d in top[:10]],
    }
