"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — "pod"
crosses the DCN; it joins "data" for batch/FSDP sharding so only
gradient reductions and FSDP gathers traverse the slow links.

Functions, not module constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

try:  # AxisType only exists in newer jax; Auto is the old default anyway
    from jax.sharding import AxisType

    def _axis_kwargs(axes):
        return {"axis_types": (AxisType.Auto,) * len(axes)}
except ImportError:  # pragma: no cover - depends on installed jax
    def _axis_kwargs(axes):
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(axes))


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes used for batch/FSDP sharding (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests and examples."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes, **_axis_kwargs(axes))
