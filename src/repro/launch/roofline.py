"""Roofline report: reads dry-run JSON artifacts, emits the per-cell
three-term table (§Roofline of EXPERIMENTS.md) and ranks hillclimb
candidates.

    PYTHONPATH=src python -m repro.launch.roofline results/dryrun \
        [--markdown] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.analytic import PEAK_FLOPS


def load(directory: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def enrich(r: dict) -> dict:
    if not r.get("ok"):
        return r
    chips = r["chips"]
    if r["arch"] == "geodesic2d":
        # elementwise workload: terms are VPU-based, computed by the
        # dry run itself (dot-flop parsing would see ~0)
        r["step_s_bound"] = max(r["compute_s"], r["memory_s"],
                                r["collective_s"])
        return r
    # prefer HLO-measured flops (includes remat recompute) for the
    # compute term; analytic model_flops gives the usefulness ratio
    hlo_f = r.get("hlo_dot_flops_per_device")
    if hlo_f:
        r["compute_s_hlo"] = hlo_f / PEAK_FLOPS
    total_s = max(r.get("compute_s_hlo", r["compute_s"]),
                  r["memory_s"], r["collective_s"])
    r["step_s_bound"] = total_s
    useful = r.get("model_flops", 0.0) / (chips * PEAK_FLOPS)
    r["roofline_frac"] = useful / total_s if total_s else 0.0
    if hlo_f and r.get("model_flops"):
        r["useful_ratio"] = r["model_flops"] / (hlo_f * chips)
    dom = {"compute": r.get("compute_s_hlo", r["compute_s"]),
           "memory": r["memory_s"], "collective": r["collective_s"]}
    r["dominant"] = max(dom, key=dom.get)
    return r


def table(rows: list[dict], mesh: str | None = None) -> str:
    out = ["| arch | shape | mesh | GB/dev | fits | compute_s | memory_s "
           "| collective_s | dominant | MODEL/HLO | roofline |",
           "|---|---|---|---|---|---|---|---|---|---|---|"[:-4]]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAILED: {r.get('error','?')[:40]} |")
            continue
        if mesh and r["mesh"] != mesh:
            continue
        out.append(
            "| {arch} | {shape} | {mesh} | {gb:.1f} | {fits} | {c:.3f} | "
            "{m:.3f} | {k:.3f} | {dom} | {ur} | {rf:.1%} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                gb=r["bytes_per_device"] / 1e9,
                fits="Y" if r.get("fits_16g") else "N",
                c=r.get("compute_s_hlo", r.get("compute_s", 0.0)),
                m=r["memory_s"], k=r["collective_s"],
                dom=r["dominant"],
                ur=(f"{r['useful_ratio']:.2f}"
                    if r.get("useful_ratio") else "-"),
                rf=r.get("roofline_frac", 0.0),
            ))
    return "\n".join(out)


def hillclimb_candidates(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("ok") and r["arch"] != "geodesic2d"
          and r["mesh"] == "16x16"]
    worst = min(ok, key=lambda r: r.get("roofline_frac", 1.0))
    coll = max(ok, key=lambda r: r.get("collective_s", 0.0))
    return {"worst_roofline": f"{worst['arch']}×{worst['shape']}",
            "most_collective_bound": f"{coll['arch']}×{coll['shape']}"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("directory")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = [enrich(r) for r in load(args.directory)]
    print(table(rows, args.mesh))
    print()
    print("hillclimb candidates:", hillclimb_candidates(rows))


if __name__ == "__main__":
    main()
