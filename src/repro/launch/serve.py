"""Serving launcher: batched prefill + decode for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.models import decode as DEC
from repro.models import model as MDL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = args.batch, args.prompt_len
    smax = s + args.gen

    kw = {}
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    tk = tokens
    if cfg.frontend == "vision":
        kw["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model), dtype=np.float32))
        tk = None
    if cfg.is_enc_dec:
        kw["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model), dtype=np.float32))

    prefill = jax.jit(lambda p: DEC.prefill(p, cfg, tk, smax=smax,
                                            q_chunk=min(128, s), **kw))
    step = jax.jit(lambda p, c, t: DEC.decode_step(p, cfg, c, t))

    t0 = time.time()
    logits, cache = prefill(params)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    print(f"arch={cfg.name} batch={b} prompt={s} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode/args.gen*1e3:.2f} ms/token "
          f"({b*args.gen/t_decode:.1f} tok/s)")
    print("sample token ids:", np.stack(out, 1)[0][:10])


if __name__ == "__main__":
    main()
