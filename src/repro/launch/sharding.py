"""Sharding policy: param/optimizer/batch/cache PartitionSpecs.

Policy (MaxText-style FSDP+TP, DESIGN.md §6):
  * "model" axis = tensor parallel: attention heads, FFN hidden, MoE
    experts, vocab.
  * batch axes ("pod","data") = FSDP: every weight is additionally
    sharded on its largest remaining dim; optimizer moments inherit the
    param spec => ZeRO-3.
  * activations: batch over ("pod","data"); for batch-1 decode cells the
    KV-cache sequence dim takes the batch axes instead (sequence
    parallelism over the cache).

Every rule is divisibility-guarded: if a dim doesn't divide by the axis
size the axis is dropped (e.g. seamless's vocab 256206 is indivisible by
16 — its embedding shards on d_model instead).  Rules are name-based on
the param-tree path; unknown leaves fall back to greedy largest-dim
assignment.
"""
from __future__ import annotations

import math
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None or axes == ():
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def _assign(shape, mesh, prefs):
    """prefs: [(dim, [axis-candidates in priority order]), ...] —
    divisibility-guarded greedy assignment."""
    spec: list[Any] = [None] * len(shape)
    used: set[str] = set()
    for dim, candidates in prefs:
        if dim >= len(shape):
            continue
        for axes in candidates:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            if not flat or any(a in used for a in flat):
                continue
            if shape[dim] % _axis_size(mesh, axes) == 0:
                spec[dim] = axes
                used.update(flat)
                break
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# rules: regex on path -> function(shape_without_stack_dim) -> prefs
def _param_prefs(name: str, nd: int, fsdp, model, heads_ok=True, kv_ok=True):
    """Returns (dim, candidates) prefs for the *unstacked* shape.

    heads_ok/kv_ok: whether the (q / kv) head count divides the model
    axis — if not, the projection must NOT be sharded on its head dim
    (sharding head_dim instead would force per-tile all-gathers of the
    attention accumulators; MQA replicates KV instead)."""
    both = tuple((fsdp if isinstance(fsdp, tuple) else (fsdp,))) + (model,)
    if re.search(r"embed/table$", name):
        # (V, D): vocab->model, d->fsdp; indivisible vocab falls through
        # to sharding D over everything
        return [(0, [model]), (1, [fsdp, both])]
    if re.search(r"lm_head/w$", name):
        return [(1, [model]), (0, [fsdp])]
    if re.search(r"(attn|cross)/wq$", name):
        return [(1, [model]), (0, [fsdp])] if heads_ok else [(0, [fsdp])]
    if re.search(r"(attn|cross)/w[kv]$", name):
        return [(1, [model]), (0, [fsdp])] if kv_ok else [(0, [fsdp])]
    if re.search(r"(attn|cross)/wo$", name):
        return [(0, [model]), (1, [fsdp])] if heads_ok else [(1, [fsdp])]
    if re.search(r"(attn|cross)/bq$", name):
        return [(0, [model])] if heads_ok else []
    if re.search(r"(attn|cross)/b[kv]$", name):
        return [(0, [model])] if kv_ok else []
    if re.search(r"moe/router$", name):
        return [(0, [fsdp])]
    # Expert weights: experts -> model (EP) and the expert hidden dim ->
    # batch axes (TP-style).  NOT FSDP on d_model: FSDP would all-gather
    # the full expert set 3×accum times per step (fwd/bwd/remat) — for a
    # 480B MoE that is TBs of gathers; sharding F keeps weights resident
    # and moves only (E,C,D) partial sums (§Perf arctic H2).
    if re.search(r"moe/(gate|up)$", name):          # (E, D, F)
        return [(0, [model]), (2, [fsdp])]
    if re.search(r"moe/down$", name):               # (E, F, D)
        return [(0, [model]), (1, [fsdp])]
    if re.search(r"(mlp|shared|dense)/(gate|up)$", name):
        return [(1, [model]), (0, [fsdp])]
    if re.search(r"(mlp|shared|dense)/down$", name):
        return [(0, [model]), (1, [fsdp])]
    if re.search(r"mamba/in_proj$", name):
        return [(1, [model]), (0, [fsdp])]
    if re.search(r"mamba/out_proj$", name):
        return [(0, [model]), (1, [fsdp])]
    if re.search(r"mamba/conv_[wb]$", name):
        return [(nd - 1, [model])]
    if re.search(r"mamba/(A_log|D|dt_bias)$", name):
        return [(0, [model])]
    if re.search(r"(mlstm/qkv|mlstm/ogate|slstm/wx)$", name):
        return [(1, [model]), (0, [fsdp])]
    if re.search(r"(mlstm|slstm)/out$", name):
        return [(0, [model]), (1, [fsdp])]
    if re.search(r"slstm/r$", name):                # (H, P, 4P)
        return [(2, [model]), (1, [fsdp])]
    if re.search(r"mlstm/gates$", name):
        return [(0, [fsdp])]
    if re.search(r"(norm|scale|bias)", name):
        return []
    # fallback: greedy largest dims
    return None


def param_specs(cfg: ModelConfig, params_shape, mesh: Mesh,
                fsdp_enabled: bool = True, attn_tp: bool = True):
    """Pytree of PartitionSpec matching a params (or ShapeDtypeStruct)
    tree.

    fsdp_enabled=False (decode/serving): weights are sharded on the
    model axis only and *replicated* across the batch axes — a decode
    step touches every weight, so FSDP would re-gather the full model
    per generated token.
    """
    fsdp = batch_axes(mesh) if fsdp_enabled else ()
    fsdp = fsdp[0] if len(fsdp) == 1 else fsdp
    model = "model"
    msize = mesh.shape["model"]
    heads_ok = cfg.n_heads % msize == 0 and attn_tp
    kv_ok = cfg.n_kv_heads % msize == 0 and attn_tp

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        # scanned stacks carry a leading group dim: never shard it
        stacked = bool(re.search(r"/blocks/", name))
        base_shape = shape[1:] if stacked else shape
        prefs = _param_prefs(name, len(base_shape), fsdp, model,
                             heads_ok, kv_ok)
        if prefs is None:
            order = sorted(range(len(base_shape)),
                           key=lambda i: -base_shape[i])
            prefs = []
            if order:
                prefs.append((order[0], [model]))
            if len(order) > 1:
                prefs.append((order[1], [fsdp]))
        if stacked:
            prefs = [(d + 1, c) for d, c in prefs]
        return _assign(shape, mesh, prefs)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def opt_state_specs(cfg: ModelConfig, pspecs):
    return {
        "m": pspecs,
        "v": pspecs,
        "step": P(),
    }


def batch_specs(batch_shape, mesh: Mesh):
    axes = batch_axes(mesh)

    def spec_for(leaf):
        # suffix fallback: small global batches shard over the inner
        # batch axes (e.g. batch 32 on ("pod","data")=2×32 -> "data")
        cand = axes
        while cand and (not leaf.shape
                        or leaf.shape[0] % _axis_size(mesh, cand)):
            cand = cand[1:]
        if not cand:
            return P()
        return P(cand if len(cand) > 1 else cand[0])

    return jax.tree.map(spec_for, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh):
    """KV caches: batch->fsdp axes when divisible, else sequence->fsdp
    (sequence-parallel cache for batch-1 long-context decode); kv-heads /
    ssm-heads -> model."""
    fsdp = batch_axes(mesh)
    fsdp = fsdp[0] if len(fsdp) == 1 else fsdp
    fsdp_n = _axis_size(mesh, fsdp)

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        stacked = name.startswith("blocks/")
        off = 1 if stacked else 0
        base = shape[off:]
        prefs: list = []
        last = name.rsplit("/", 1)[-1]
        if last in ("k", "v", "ck", "cv"):       # (B, S, KV, hd)
            # batch -> fsdp axes (sequence for batch-1 long-context);
            # kv-heads -> model when divisible, else sequence -> model
            # (paired with attn_tp=False weights so attention einsums
            # never regather the cache)
            if base[0] % fsdp_n == 0:
                prefs = [(0, [fsdp]), (2, ["model"]), (1, ["model"])]
            else:
                prefs = [(1, [fsdp]), (2, ["model"]), (1, ["model"])]
        elif last == "state":                     # mamba (B, H, P, N)
            prefs = [(0, [fsdp]), (1, ["model"])]
        elif last == "conv":                      # (B, K-1, conv_dim)
            prefs = [(0, [fsdp]), (2, ["model"])]
        elif last in ("c", "n", "h", "m"):        # xlstm states
            prefs = [(0, [fsdp])]
            if len(base) >= 3:
                prefs.append((2, ["model"]))
        elif last == "enc_out":                   # (B, S, D)
            prefs = [(0, [fsdp]), (2, ["model"])]
        elif last == "pos":
            prefs = []
        if stacked:
            prefs = [(d + 1, c) for d, c in prefs]
        return _assign(shape, mesh, prefs)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
