"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 200 --checkpoint-dir /tmp/ckpt [--restore] [--fail-at 50]

On real hardware the same entry point runs the full config over the
production mesh (launch.mesh); on this CPU container use --reduced.
``--fail-at N`` injects a node failure at step N (fault-tolerance demo:
rerun with --restore to resume from the latest atomic checkpoint).
"""
from __future__ import annotations

import argparse

from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.train.loop import FailureInjector, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainerConfig(
        steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        q_chunk=min(128, args.seq_len),
    )
    trainer = Trainer(cfg, tcfg)
    injector = FailureInjector(args.fail_at) if args.fail_at else None
    state, history = trainer.run(injector=injector, restore=args.restore)
    print(f"final loss: {history[-1]:.4f} (from {history[0]:.4f})")


if __name__ == "__main__":
    main()
