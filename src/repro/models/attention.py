"""Attention: GQA/MQA/MHA with RoPE, flash-style chunked softmax (memory
O(S·chunk), never materializing the (S,S) logits), sliding-window band
attention, cross-attention, and single-token decode against a KV cache.

The flash path is pure ``lax`` (scan over query blocks, fori over KV
blocks with a *dynamic* upper bound so no FLOPs are spent above the
causal diagonal) — it compiles for any mesh without a custom kernel,
which is what the 32k-prefill dry-run cells require.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import normal_init, rmsnorm
from repro.models.partitioning import constrain

NEG_INF = -1e30


class AttnDims(NamedTuple):
    n_heads: int
    n_kv_heads: int
    head_dim: int


def attn_init(key, d: int, dims: AttnDims, dtype, qkv_bias=False,
              qk_norm=False):
    h, kv, hd = dims
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal_init(ks[0], (d, h * hd), dtype),
        "wk": normal_init(ks[1], (d, kv * hd), dtype),
        "wv": normal_init(ks[2], (d, kv * hd), dtype),
        "wo": normal_init(ks[3], (h * hd, d), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.zeros((hd,), dtype)}
    return p


def qkv(params, x, dims: AttnDims, positions, rope_theta, qk_norm=False,
        rope_fn=None):
    """x: (B,S,D) -> q (B,S,H,hd), k,v (B,S,KV,hd) with RoPE applied."""
    from repro.models.layers import rope as _rope

    h, kv_h, hd = dims
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = constrain(q.reshape(b, s, h, hd),
                  ("batch", None, "model", None), free=True)
    k = constrain(k.reshape(b, s, kv_h, hd),
                  ("batch", None, "model", None), free=True)
    v = constrain(v.reshape(b, s, kv_h, hd),
                  ("batch", None, "model", None), free=True)
    if qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if rope_theta:
        apply = rope_fn or _rope
        q = apply(q, positions, rope_theta)
        k = apply(k, positions, rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention (chunked online softmax)
# ---------------------------------------------------------------------------


def _block_attend(q, k, v, qpos, kpos, scale, causal, window, kv_len):
    """One (q-block, kv-block) tile.  q: (B,qc,KV,G,hd); k,v: (B,kc,KV,hd).
    Returns (scores_max, exp_scores@v, sumexp) pieces for online softmax."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    mask = (kpos < kv_len)[None, :]
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,KV,G,qc)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)                                   # (B,KV,G,qc)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v)
    return m, l, pv


def _tile_mask(qpos, kpos, causal, window, kv_len):
    mask = (kpos < kv_len)[None, :]
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    return mask


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd).  O(S·chunk) memory in BOTH
    passes: a custom VJP recomputes tiles in the backward from the saved
    per-row logsumexp statistics (the flash-attention algorithm), so the
    tile scan saves no per-step residuals.

    - full causal: static lower-triangle tile list (no FLOPs above the
      diagonal).
    - sliding window (window ≤ kv_chunk): static two-block band.
    - non-causal (cross-attention): all kv blocks.
    """
    b, sq0, h, hd = q.shape
    sk0, kv_h = k.shape[1], k.shape[2]
    g = h // kv_h
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, sq0)
    kv_chunk = min(kv_chunk, sk0)
    # pad to chunk multiples; padded keys are masked via kv_len, padded
    # query rows are sliced off the output
    sq = math.ceil(sq0 / q_chunk) * q_chunk
    sk = math.ceil(sk0 / kv_chunk) * kv_chunk
    if sq != sq0:
        q = jnp.pad(q, ((0, 0), (0, sq - sq0), (0, 0), (0, 0)))
    if sk != sk0:
        k = jnp.pad(k, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
    nq, nk = sq // q_chunk, sk // kv_chunk
    if window is not None:
        assert window <= kv_chunk and q_chunk == kv_chunk, (
            "band path needs window <= kv_chunk == q_chunk"
        )

    # Static tile list: exactly the (q-block, kv-block) pairs that carry
    # any unmasked entry — the lower triangle for causal, a two-block band
    # for sliding windows, the full grid for cross attention.  One scan
    # over the list => no FLOPs above the diagonal, static trip count
    # (exact HLO-side accounting).
    pairs = []
    for qi in range(nq):
        if not causal:
            pairs += [(qi, ki, 1) for ki in range(nk)]
        elif window is not None:
            pairs.append((qi, qi - 1, 1) if qi > 0 else (qi, 0, 0))
            pairs.append((qi, qi, 1))
        else:
            pairs += [(qi, ki, 1) for ki in range(qi + 1)]
    tiles = jnp.asarray(pairs, jnp.int32)
    cfgt = _FlashCfg(causal, window, q_chunk, kv_chunk, sk0)
    return _flash_call(cfgt, q, k, v, tiles)[:, :sq0]





class _FlashCfg(NamedTuple):
    causal: bool
    window: int | None
    q_chunk: int
    kv_chunk: int
    sk0: int            # unpadded kv length (padding mask)


_CARRY_DIMS = (None, "batch", "model", None, None)


def _flash_fwd_impl(cfgt: _FlashCfg, q, k, v, tiles):
    b, sq, h, hd = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    nq = sq // cfgt.q_chunk
    scale = 1.0 / math.sqrt(hd)
    orig_dtype = q.dtype
    qb = q.reshape(b, nq, cfgt.q_chunk, kv_h, g, hd)

    # the +neutral makes the carry inits data-dependent so they inherit
    # the device-varying type under shard_map (a pure jnp.zeros carry is
    # unvarying and scan rejects the carry-type mismatch)
    neutral = (q.reshape(-1)[0] * 0).astype(jnp.float32)
    m0 = constrain(jnp.full((nq, b, kv_h, g, cfgt.q_chunk), NEG_INF,
                            jnp.float32) + neutral, _CARRY_DIMS, free=True)
    l0 = constrain(jnp.zeros((nq, b, kv_h, g, cfgt.q_chunk), jnp.float32)
                   + neutral, _CARRY_DIMS, free=True)
    acc0 = constrain(jnp.zeros((nq, b, kv_h, g, cfgt.q_chunk, hd),
                               jnp.float32) + neutral,
                     _CARRY_DIMS + (None,), free=True)

    def tile_step(carry, tile):
        m, l, acc = carry
        qi, ki, valid = tile[0], tile[1], tile[2]
        qt = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
        kt = jax.lax.dynamic_slice_in_dim(k, ki * cfgt.kv_chunk,
                                          cfgt.kv_chunk, 1)
        vt = jax.lax.dynamic_slice_in_dim(v, ki * cfgt.kv_chunk,
                                          cfgt.kv_chunk, 1)
        qpos = qi * cfgt.q_chunk + jnp.arange(cfgt.q_chunk)
        kpos = ki * cfgt.kv_chunk + jnp.arange(cfgt.kv_chunk)
        bm, bl, bpv = _block_attend(qt, kt, vt, qpos, kpos, scale,
                                    cfgt.causal, cfgt.window, cfgt.sk0)
        bm = jnp.where(valid > 0, bm, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(mi, bm)
        alpha = jnp.exp(mi - m_new)
        beta = jnp.exp(bm - m_new)
        li = li * alpha + bl * beta
        ai = ai * alpha[..., None] + bpv.astype(jnp.float32) * beta[..., None]
        m = constrain(jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0),
                      _CARRY_DIMS, free=True)
        l = constrain(jax.lax.dynamic_update_index_in_dim(l, li, qi, 0),
                      _CARRY_DIMS, free=True)
        acc = constrain(jax.lax.dynamic_update_index_in_dim(acc, ai, qi, 0),
                        _CARRY_DIMS + (None,), free=True)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(tile_step, (m0, l0, acc0), tiles)
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    # (nq,B,KV,G,qc,hd) -> (B, Sq, H, hd)
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5)).reshape(b, sq, h, hd)
    # logsumexp per row; guard fully-masked rows (l == 0)
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    return out.astype(orig_dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_call(cfgt: _FlashCfg, q, k, v, tiles):
    out, _ = _flash_fwd_impl(cfgt, q, k, v, tiles)
    return out


def _flash_call_fwd(cfgt, q, k, v, tiles):
    out, lse = _flash_fwd_impl(cfgt, q, k, v, tiles)
    return out, (q, k, v, out, lse, tiles)


def _flash_call_bwd(cfgt, res, dout):
    """Flash backward: recompute every tile from (q, k, v, lse)."""
    q, k, v, out, lse, tiles = res
    b, sq, h, hd = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    nq = sq // cfgt.q_chunk
    scale = 1.0 / math.sqrt(hd)
    do = dout.astype(jnp.float32)
    qb = q.reshape(b, nq, cfgt.q_chunk, kv_h, g, hd)
    dob = do.reshape(b, nq, cfgt.q_chunk, kv_h, g, hd)
    ob = out.astype(jnp.float32).reshape(b, nq, cfgt.q_chunk, kv_h, g, hd)
    delta = jnp.einsum("bnqkgd,bnqkgd->bnkgq", dob, ob)

    neutral = (do.reshape(-1)[0] * 0).astype(jnp.float32)
    dq0 = jnp.zeros((b, nq, cfgt.q_chunk, kv_h, g, hd), jnp.float32) + neutral
    dk0 = jnp.zeros(k.shape, jnp.float32) + neutral
    dv0 = jnp.zeros(v.shape, jnp.float32) + neutral

    def tile_step(carry, tile):
        dq, dk, dv = carry
        qi, ki, valid = tile[0], tile[1], tile[2]
        qt = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
        dot = jax.lax.dynamic_index_in_dim(dob, qi, 1, keepdims=False)
        dlt = jax.lax.dynamic_index_in_dim(delta, qi, 1, keepdims=False)
        lse_i = jax.lax.dynamic_index_in_dim(lse, qi, 0, keepdims=False)
        kt = jax.lax.dynamic_slice_in_dim(k, ki * cfgt.kv_chunk,
                                          cfgt.kv_chunk, 1)
        vt = jax.lax.dynamic_slice_in_dim(v, ki * cfgt.kv_chunk,
                                          cfgt.kv_chunk, 1)
        qpos = qi * cfgt.q_chunk + jnp.arange(cfgt.q_chunk)
        kpos = ki * cfgt.kv_chunk + jnp.arange(cfgt.kv_chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qt, kt).astype(jnp.float32) * scale
        mask = _tile_mask(qpos, kpos, cfgt.causal, cfgt.window, cfgt.sk0)
        p = jnp.where(mask, jnp.exp(s - lse_i[..., None]), 0.0)
        p = jnp.where(valid > 0, p, 0.0)
        # dv += p^T dout ; dp = dout v^T ; ds = p (dp - delta)
        dv_t = jnp.einsum("bkgqs,bqkgd->bskd", p, dot)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dot, vt)
        ds = p * (dp - dlt[..., None]) * scale
        dq_t = jnp.einsum("bkgqs,bskd->bqkgd", ds, kt)
        dk_t = jnp.einsum("bkgqs,bqkgd->bskd", ds, qt)
        dqi = jax.lax.dynamic_index_in_dim(dq, qi, 1, keepdims=False)
        dq = jax.lax.dynamic_update_index_in_dim(dq, dqi + dq_t, qi, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(
                dk, ki * cfgt.kv_chunk, cfgt.kv_chunk, 1) + dk_t,
            ki * cfgt.kv_chunk, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(
                dv, ki * cfgt.kv_chunk, cfgt.kv_chunk, 1) + dv_t,
            ki * cfgt.kv_chunk, 1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(tile_step, (dq0, dk0, dv0), tiles)
    dtiles = np.zeros(tiles.shape, dtype=jax.dtypes.float0)
    return (dq.reshape(b, sq, h, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype), dtiles)


_flash_call.defvjp(_flash_call_fwd, _flash_call_bwd)


# ---------------------------------------------------------------------------
# decode (single token against a cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,          # (B, 1, H, hd)
    k_cache: jnp.ndarray,    # (B, Smax, KV, hd)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,        # scalar int32: index of the current token
    window: int | None = None,
) -> jnp.ndarray:
    b, smax, kv_h, hd = k_cache.shape
    h = q.shape[2]
    g = h // kv_h
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kv_h, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    idx = jnp.arange(smax)
    mask = idx <= pos
    if window is not None:
        mask &= idx > pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd)
