"""Serving path: cache init, prefill (cache capture), single-token decode.

The cache pytree mirrors the scan grouping of models.model exactly
(stacked (n_groups, ...) leaves for scanned super-blocks, a list for the
unrolled tail), so decode scans the same structure prefill produced.

Per layer kind the cache entry is:
  attn   : k/v (B, Smax, KV, hd) [+ ck/cv cross-attn memory for enc-dec]
  mamba2 : ssm state (B, H, P, N) f32 + conv tail (B, K-1, conv_dim)
  mlstm  : matrix memory (C, n, m)
  slstm  : scalar memory (c, n, h, m)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.layers import embed, mlp, rmsnorm, softcap, unembed
from repro.models.model import _dims, layer_plan
from repro.models import partitioning as PT


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


RING_THRESHOLD = 8  # use a ring buffer when smax > threshold × window


def _ring_len(cfg: ModelConfig, kind: str, smax: int) -> int:
    """Sliding-window layers never attend further than `window` back —
    a ring buffer of exactly `window` slots replaces the full-sequence
    cache (write at pos % window; slot recency is guaranteed by the ring
    size, so no extra masking is needed).  For gemma3's 5:1 local:global
    stack at 500k context this shrinks the cache ~27× (§Perf G2)."""
    if (kind == "attn_local" and cfg.sliding_window
            and smax > RING_THRESHOLD * cfg.sliding_window):
        return cfg.sliding_window
    return smax


def _entry_shape(cfg: ModelConfig, kind: str, b: int, smax: int,
                 enc_len: int, cross: bool):
    adt = jnp.dtype(cfg.activation_dtype)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if kind.startswith("attn"):
        slen = _ring_len(cfg, kind, smax)
        e = {
            "k": jnp.zeros((b, slen, kv, hd), adt),
            "v": jnp.zeros((b, slen, kv, hd), adt),
        }
        if cross:
            e["ck"] = jnp.zeros((b, enc_len, kv, hd), adt)
            e["cv"] = jnp.zeros((b, enc_len, kv, hd), adt)
        return e
    if kind == "mamba2":
        d_in, h = SSM.ssm_dims(cfg.d_model, cfg.ssm_head_dim)
        conv_dim = d_in + 2 * cfg.ssm_state
        return {
            "state": jnp.zeros((b, h, cfg.ssm_head_dim, cfg.ssm_state),
                               jnp.float32),
            "conv": jnp.zeros((b, SSM.CONV_K - 1, conv_dim), adt),
        }
    if kind == "mlstm":
        p = 2 * cfg.d_model // cfg.n_heads
        return {
            "c": jnp.zeros((b, cfg.n_heads, p, p), jnp.float32),
            "n": jnp.zeros((b, cfg.n_heads, p), jnp.float32),
            "m": jnp.full((b, cfg.n_heads), -1e30, jnp.float32),
        }
    if kind == "slstm":
        d = cfg.d_model
        return {
            "c": jnp.zeros((b, d), jnp.float32),
            "n": jnp.zeros((b, d), jnp.float32),
            "h": jnp.zeros((b, d), jnp.float32),
            "m": jnp.full((b, d), -1e30, jnp.float32),
        }
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, smax: int,
               enc_len: int = 0) -> dict:
    period, n_groups, tail_kinds = layer_plan(cfg)
    cross = cfg.is_enc_dec

    def group_entry():
        ent = tuple(
            _entry_shape(cfg, cfg.layer_kind(j), batch, smax, enc_len, cross)
            for j in range(period)
        )
        if cfg.shared_attn_period:
            ent = ent + (_entry_shape(cfg, "attn", batch, smax, enc_len,
                                      cross),)
        return ent

    blocks = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape),
        group_entry(),
    )
    tail = [
        _entry_shape(cfg, k, batch, smax, enc_len, cross) for k in tail_kinds
    ]
    cache: dict[str, Any] = {"blocks": blocks, "tail": tail,
                             "pos": jnp.zeros((), jnp.int32)}
    if cross:
        cache["enc_out"] = jnp.zeros(
            (batch, enc_len, cfg.d_model), jnp.dtype(cfg.activation_dtype))
    return cache


# ---------------------------------------------------------------------------
# per-layer decode
# ---------------------------------------------------------------------------


def _attn_decode(p, cfg: ModelConfig, x, kind, entry, pos):
    b = x.shape[0]
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = A.qkv(p["attn"], h, _dims(cfg), positions, cfg.rope_theta,
                    cfg.qk_norm)

    def _kv_dims(shape):
        pol = PT.get_policy()
        if pol is None:
            return (None, None, None, None)
        bdim = "batch" if shape[0] % pol.axis_size("batch") == 0 else None
        sdim = None if bdim else "batch"        # seq-parallel cache (B=1)
        if shape[2] % pol.axis_size("model") == 0:
            return (bdim, sdim, "model", None)
        return (bdim, sdim or "model", None, None)

    window = cfg.sliding_window if kind == "attn_local" else None
    ring = (kind == "attn_local"
            and entry["k"].shape[1] == cfg.sliding_window)
    wpos = pos % cfg.sliding_window if ring else pos
    kc = PT.constrain(
        jax.lax.dynamic_update_slice_in_dim(entry["k"], k, wpos, axis=1),
        _kv_dims(entry["k"].shape))
    vc = PT.constrain(
        jax.lax.dynamic_update_slice_in_dim(entry["v"], v, wpos, axis=1),
        _kv_dims(entry["v"].shape))
    entry = dict(entry, k=kc, v=vc)
    if ring:
        # ring recency is structural; only pre-warmup slots need masking,
        # which `slot_index <= pos` provides (always true once pos >= W)
        out = A.decode_attention(q, kc, vc, pos, None)
    else:
        out = A.decode_attention(q, kc, vc, pos, window)
    x = x + out.reshape(b, 1, -1) @ p["attn"]["wo"]

    if "cross" in p:
        h = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        q, _, _ = A.qkv(p["cross"], h, _dims(cfg), positions, 0.0)
        out = A.decode_attention(q, entry["ck"], entry["cv"],
                                 entry["ck"].shape[1] - 1)
        x = x + out.reshape(b, 1, -1) @ p["cross"]["wo"]

    if "moe" in p:
        hh = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = MOE.moe_apply(p["moe"], hh, cfg.moe, cfg.activation)
        x = x + y
    elif "mlp" in p:
        hh = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], hh, cfg.activation)
    return x, entry


def _layer_decode(p, cfg: ModelConfig, x, kind, entry, pos):
    if kind.startswith("attn"):
        return _attn_decode(p, cfg, x, kind, entry, pos)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "mamba2":
        y, state, conv = SSM.mamba2_decode(
            p["mamba"], h, entry["state"], entry["conv"],
            n_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
        return x + y, {"state": state, "conv": conv}
    if kind == "mlstm":
        y, (c, n, m) = XL.mlstm_decode(p["mlstm"], h, (entry["c"], entry["n"],
                                                       entry["m"]),
                                       n_heads=cfg.n_heads)
        return x + y, {"c": c, "n": n, "m": m}
    if kind == "slstm":
        y, (c, n, hh, m) = XL.slstm_decode(
            p["slstm"], h, (entry["c"], entry["n"], entry["h"], entry["m"]),
            n_heads=cfg.n_heads)
        return x + y, {"c": c, "n": n, "h": hh, "m": m}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ModelConfig, cache, tokens=None, *, embeds=None):
    """One token for every sequence in the batch.

    tokens: (B, 1) int32 (or embeds (B, 1, D)).  Returns (logits, cache).
    """
    from repro.models.model import cast_params

    adt = jnp.dtype(cfg.activation_dtype)
    params = cast_params(params, adt)
    pos = cache["pos"]
    if embeds is None:
        x = embed(params["embed"], tokens, cfg.d_model).astype(adt)
    else:
        x = embeds.astype(adt)

    period, n_groups, tail_kinds = layer_plan(cfg)
    stack = params["decoder"]

    # the cache rides in the scan CARRY with per-group dynamic updates,
    # not as scan ys — ys stacking would allocate a second full cache
    # buffer (while-loop carries alias in place, donated caches update
    # truly in-place)
    def scan_body(carry, inp):
        x, blocks_cache = carry
        block_params, g = inp
        block_cache = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, g, 0, keepdims=False),
            blocks_cache)
        new_cache = []
        for j in range(period):
            kind = cfg.layer_kind(j)
            x, ent = _layer_decode(block_params[j], cfg, x, kind,
                                   block_cache[j], pos)
            new_cache.append(ent)
        if cfg.shared_attn_period:
            x, ent = _attn_decode(params["shared_attn"], cfg, x, "attn",
                                  block_cache[period], pos)
            new_cache.append(ent)
        blocks_cache = jax.tree.map(
            lambda c, e: jax.lax.dynamic_update_index_in_dim(c, e, g, 0),
            blocks_cache, tuple(new_cache))
        return (x, blocks_cache), None

    (x, new_blocks), _ = jax.lax.scan(
        scan_body, (x, cache["blocks"]),
        (stack["blocks"], jnp.arange(n_groups)))
    new_tail = []
    for j, kind in enumerate(tail_kinds):
        x, ent = _layer_decode(stack["tail"][j], cfg, x, kind,
                               cache["tail"][j], pos)
        new_tail.append(ent)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = x @ params["lm_head"]["w"]
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)

    out_cache = dict(cache, blocks=new_blocks, tail=new_tail, pos=pos + 1)
    return logits, out_cache


# ---------------------------------------------------------------------------
# prefill: forward pass that captures the cache
# ---------------------------------------------------------------------------


def _layer_prefill(p, cfg: ModelConfig, x, kind, positions, enc_out, smax,
                   q_chunk):
    b, s, _ = x.shape
    if kind.startswith("attn"):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = A.qkv(p["attn"], h, _dims(cfg), positions, cfg.rope_theta,
                        cfg.qk_norm)
        window = cfg.sliding_window if kind == "attn_local" else None
        out = A.flash_attention(q, k, v, causal=True, window=window,
                                q_chunk=q_chunk, kv_chunk=q_chunk)
        x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
        slen = _ring_len(cfg, kind, smax)
        if slen < smax:
            # ring capture: scatter the last `window` keys into their
            # pos%window slots so decode continues seamlessly
            w = cfg.sliding_window
            keep = min(w, s)
            perm = jnp.arange(s - keep, s) % w
            kc = jnp.zeros((b, w) + k.shape[2:], k.dtype)
            entry = {"k": kc.at[:, perm].set(k[:, -keep:]),
                     "v": kc.at[:, perm].set(v[:, -keep:])}
        else:
            pad = [(0, 0), (0, smax - s), (0, 0), (0, 0)]
            entry = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}

        if "cross" in p and enc_out is not None:
            h = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
            q, _, _ = A.qkv(p["cross"], h, _dims(cfg), positions, 0.0)
            eb, es = enc_out.shape[:2]
            ck = (enc_out @ p["cross"]["wk"]).reshape(
                eb, es, cfg.n_kv_heads, cfg.head_dim)
            cv = (enc_out @ p["cross"]["wv"]).reshape(ck.shape)
            out = A.flash_attention(q, ck, cv, causal=False, q_chunk=q_chunk,
                                    kv_chunk=q_chunk)
            x = x + out.reshape(b, s, -1) @ p["cross"]["wo"]
            entry["ck"], entry["cv"] = ck, cv

        if "moe" in p:
            hh = rmsnorm(p["norm2"], x, cfg.norm_eps)
            y, _ = MOE.moe_apply(p["moe"], hh, cfg.moe, cfg.activation)
            x = x + y
        elif "mlp" in p:
            hh = rmsnorm(p["norm2"], x, cfg.norm_eps)
            x = x + mlp(p["mlp"], hh, cfg.activation)
        return x, entry

    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "mamba2":
        y, state, conv = SSM.mamba2_apply(p["mamba"], h, n_state=cfg.ssm_state,
                                          head_dim=cfg.ssm_head_dim)
        return x + y, {"state": state,
                       "conv": conv.astype(jnp.dtype(cfg.activation_dtype))}
    if kind == "mlstm":
        y, (c, n, m) = XL.mlstm_apply(p["mlstm"], h, n_heads=cfg.n_heads)
        return x + y, {"c": c, "n": n, "m": m}
    if kind == "slstm":
        y, (c, n, hh, m) = XL.slstm_apply(p["slstm"], h, n_heads=cfg.n_heads)
        return x + y, {"c": c, "n": n, "h": hh, "m": m}
    raise ValueError(kind)


def prefill(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            enc_tokens=None, enc_embeds=None, smax=None, q_chunk: int = 1024):
    """Forward pass over the prompt; returns (last-token logits, cache)."""
    from repro.models.model import _run_stack, cast_params

    adt = jnp.dtype(cfg.activation_dtype)
    params = cast_params(params, adt)
    if embeds is None:
        x = embed(params["embed"], tokens, cfg.d_model).astype(adt)
    else:
        x = embeds.astype(adt)
    b, s, _ = x.shape
    smax = smax or s
    positions = jnp.arange(s)[None, :]

    enc_out = None
    enc_len = 0
    if cfg.is_enc_dec:
        if enc_embeds is None:
            e = embed(params["embed"], enc_tokens, cfg.d_model).astype(adt)
        else:
            e = enc_embeds.astype(adt)
        enc_cfg = dataclasses.replace(
            cfg, moe=None, block_pattern=None, local_global_period=None,
            shared_attn_period=0)
        enc_out, _ = _run_stack(params["encoder"], enc_cfg, e,
                                depth=cfg.encoder_layers, causal=False,
                                q_chunk=q_chunk)
        enc_out = rmsnorm(params["enc_final_norm"], enc_out, cfg.norm_eps)
        enc_len = enc_out.shape[1]

    period, n_groups, tail_kinds = layer_plan(cfg)
    stack = params["decoder"]

    def scan_body(x, block_params):
        entries = []
        for j in range(period):
            kind = cfg.layer_kind(j)
            x, ent = _layer_prefill(block_params[j], cfg, x, kind, positions,
                                    enc_out, smax, q_chunk)
            entries.append(ent)
        if cfg.shared_attn_period:
            x, ent = _layer_prefill(params["shared_attn"], cfg, x, "attn",
                                    positions, enc_out, smax, q_chunk)
            entries.append(ent)
        return x, tuple(entries)

    x, blocks = jax.lax.scan(scan_body, x, stack["blocks"])
    tail = []
    for j, kind in enumerate(tail_kinds):
        x, ent = _layer_prefill(stack["tail"][j], cfg, x, kind, positions,
                                enc_out, smax, q_chunk)
        tail.append(ent)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = x[:, -1:, :]
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], last)
    else:
        logits = last @ params["lm_head"]["w"]
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)

    cache: dict[str, Any] = {"blocks": blocks, "tail": tail,
                             "pos": jnp.asarray(s, jnp.int32)}
    if cfg.is_enc_dec:
        cache["enc_out"] = enc_out
    return logits, cache
