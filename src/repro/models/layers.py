"""Shared building blocks: norms, MLPs, rotary embeddings, embedding
tables.  Raw-JAX (no flax): params are nested dicts of arrays, layers
are pure functions, initializers mirror standard LM practice
(truncated-normal fan-in scaling).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def normal_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    out = jax.random.truncated_normal(key, -2.0, 2.0, shape) * std
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# MLP (dense FFN): silu (SwiGLU), geglu, gelu
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, activation: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": normal_init(k2, (f, d), dtype)}
    if activation in ("silu", "geglu"):
        p["gate"] = normal_init(k1, (d, f), dtype)
        p["up"] = normal_init(k3, (d, f), dtype)
    else:
        p["up"] = normal_init(k1, (d, f), dtype)
    return p


def mlp(params: dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    from repro.models.partitioning import constrain

    if activation == "silu":
        h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    elif activation == "geglu":
        h = (jax.nn.gelu(x @ params["gate"], approximate=True)
             * (x @ params["up"]))
    else:
        h = jax.nn.gelu(x @ params["up"], approximate=True)
    if h.ndim == 3:
        h = constrain(h, ("batch", None, "model"))
    return h @ params["down"]


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype) -> dict:
    # std 1/sqrt(d): the sqrt(d) forward scaling then yields a unit-variance
    # residual stream AND unit-variance tied-unembed logits.
    return {"table": normal_init(key, (vocab, d), dtype, scale=d**-0.5)}


def embed(params: dict, tokens: jnp.ndarray, d: int) -> jnp.ndarray:
    out = jnp.take(params["table"], tokens, axis=0)
    return out * jnp.asarray(math.sqrt(d), out.dtype)  # gemma-style scaling


def unembed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["table"].T


def softcap(logits: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
