"""Config-driven composable LM covering all ten assigned architectures.

Layer stacking: the layer-kind sequence (cfg.layer_kind) is periodic for
every assigned arch; layers are grouped into super-blocks of one period
and scanned with ``lax.scan`` over the group axis — compile time is
O(period), independent of depth (62-layer gemma3 compiles as fast as a
2-layer toy).  Remainder layers (depth % period) run unrolled after the
scan.  zamba2's *shared-weight* attention block is a closure over a
single (non-scanned) param subtree applied once per super-block.

Caches mirror the same grouping so decode scans the exact structure the
prefill produced.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.layers import (
    embed,
    embed_init,
    mlp,
    mlp_init,
    normal_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
    unembed,
)
from repro.models.partitioning import constrain


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------


def layer_plan(cfg: ModelConfig, depth: int | None = None):
    """(period, n_groups, remainder_kinds) for the scan structure."""
    depth = depth if depth is not None else cfg.n_layers
    kinds = [cfg.layer_kind(i) for i in range(depth)]
    if cfg.shared_attn_period:
        period = cfg.shared_attn_period
    else:
        period = 1
        for p in range(1, len(set(kinds)) * 4 + 1):
            if all(kinds[i] == kinds[i % p] for i in range(depth)):
                period = p
                break
    n_groups = depth // period
    return period, n_groups, kinds[n_groups * period :]


def _dims(cfg: ModelConfig) -> A.AttnDims:
    return A.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)


def cast_params(params, dtype):
    """Compute-dtype view of the (f32 master) parameters — the mixed
    precision boundary.  Gradients flow back through the cast, so the
    optimizer still updates masters in f32."""
    dt = jnp.dtype(dtype)

    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype != dt:
            return x.astype(dt)
        return x

    return jax.tree.map(c, params)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, kind: str, dtype, cross: bool = False):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": rmsnorm_init(d, dtype)}
    if kind.startswith("attn"):
        p["attn"] = A.attn_init(ks[0], d, _dims(cfg), dtype,
                                cfg.qkv_bias, cfg.qk_norm)
        if cross:
            p["norm_cross"] = rmsnorm_init(d, dtype)
            p["cross"] = A.attn_init(ks[1], d, _dims(cfg), dtype)
        if cfg.moe is not None:
            p["norm2"] = rmsnorm_init(d, dtype)
            p["moe"] = MOE.moe_init(ks[2], d, cfg.moe, cfg.activation, dtype)
        elif cfg.d_ff:
            p["norm2"] = rmsnorm_init(d, dtype)
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, cfg.activation, dtype)
    elif kind == "mamba2":
        p["mamba"] = SSM.mamba2_init(ks[0], d, cfg.ssm_state,
                                     cfg.ssm_head_dim, dtype)
    elif kind == "mlstm":
        p["mlstm"] = XL.mlstm_init(ks[0], d, cfg.n_heads, dtype)
    elif kind == "slstm":
        p["slstm"] = XL.slstm_init(ks[0], d, cfg.n_heads, dtype)
    else:
        raise ValueError(kind)
    return p


def _stack_init(key, cfg: ModelConfig, depth: int, cross: bool):
    """Scanned super-block params + unrolled tail params."""
    period, n_groups, tail_kinds = layer_plan(cfg, depth)
    keys = jax.random.split(key, depth + 1)

    def group_params(g):
        return tuple(
            _layer_init(keys[g * period + j], cfg,
                        cfg.layer_kind(g * period + j),
                        jnp.dtype(cfg.param_dtype), cross)
            for j in range(period)
        )

    groups = [group_params(g) for g in range(n_groups)]
    # stack along a new leading axis
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *groups) if n_groups > 1 \
        else jax.tree.map(lambda x: x[None], groups[0])
    tail = [
        _layer_init(keys[n_groups * period + j], cfg,
                    cfg.layer_kind(n_groups * period + j),
                    jnp.dtype(cfg.param_dtype), cross)
        for j in range(len(tail_kinds))
    ]
    return {"blocks": blocks, "tail": tail}


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "decoder": _stack_init(ks[1], cfg, cfg.n_layers, cross=cfg.is_enc_dec),
    }
    if cfg.shared_attn_period:
        params["shared_attn"] = _layer_init(ks[2], cfg, "attn", dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": normal_init(ks[3], (cfg.d_model, cfg.vocab_size), dtype)
        }
    if cfg.is_enc_dec:
        enc_cfg = dataclasses.replace(
            cfg, moe=None, block_pattern=None, local_global_period=None,
            shared_attn_period=0,
        )
        params["encoder"] = _stack_init(ks[4], enc_cfg, cfg.encoder_layers,
                                        cross=False)
        params["enc_final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _attn_sublayer(p, cfg: ModelConfig, x, kind, positions, causal, enc_out,
                   q_chunk):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    q, k, v = A.qkv(p["attn"], h, _dims(cfg), positions, cfg.rope_theta,
                    cfg.qk_norm)
    window = cfg.sliding_window if kind == "attn_local" else None
    out = A.flash_attention(q, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, kv_chunk=q_chunk)
    b, s = x.shape[:2]
    out = constrain(out.reshape(b, s, -1), ("batch", None, "model"))
    x = constrain(x + out @ p["attn"]["wo"], ("batch", None, None))

    if "cross" in p and enc_out is not None:
        h = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        q, _, _ = A.qkv(p["cross"], h, _dims(cfg), positions, 0.0)
        ek = (enc_out @ p["cross"]["wk"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        ev = (enc_out @ p["cross"]["wv"]).reshape(ek.shape)
        out = A.flash_attention(q, ek, ev, causal=False, q_chunk=q_chunk,
                                kv_chunk=q_chunk)
        x = x + out.reshape(b, s, -1) @ p["cross"]["wo"]

    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = MOE.moe_apply(p["moe"], h, cfg.moe, cfg.activation)
        x = x + y
    elif "mlp" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.activation)
    return x, aux


def _layer_fwd(p, cfg: ModelConfig, x, kind, positions, causal, enc_out,
               q_chunk):
    """One layer, training/prefill mode.  Returns (x, aux)."""
    if kind.startswith("attn"):
        x, aux = _attn_sublayer(p, cfg, x, kind, positions, causal, enc_out,
                                q_chunk)
        return x, aux
    if kind == "mamba2":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, _, _ = SSM.mamba2_apply(p["mamba"], h, n_state=cfg.ssm_state,
                                   head_dim=cfg.ssm_head_dim)
        return x + y, jnp.zeros((), jnp.float32)
    if kind == "mlstm":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, _ = XL.mlstm_apply(p["mlstm"], h, n_heads=cfg.n_heads)
        return x + y, jnp.zeros((), jnp.float32)
    if kind == "slstm":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, _ = XL.slstm_apply(p["slstm"], h, n_heads=cfg.n_heads)
        return x + y, jnp.zeros((), jnp.float32)
    raise ValueError(kind)


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _run_stack(stack, cfg: ModelConfig, x, *, depth, causal, enc_out=None,
               shared_attn=None, q_chunk=1024):
    period, n_groups, tail_kinds = layer_plan(cfg, depth)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    def super_block(x, block_params):
        x = constrain(x, ("batch", None, None))
        aux = jnp.zeros((), jnp.float32)
        for j in range(period):
            kind = cfg.layer_kind(j)  # periodic: kind depends on j only
            xj, auxj = _layer_fwd(block_params[j], cfg, x, kind, positions,
                                  causal, enc_out, q_chunk)
            x, aux = xj, aux + auxj
        if shared_attn is not None:
            x, auxs = _attn_sublayer(shared_attn, cfg, x, "attn", positions,
                                     causal, enc_out, q_chunk)
            aux = aux + auxs
        return x, aux

    wrapped = _remat_wrap(super_block, cfg)

    def scan_body(x, block_params):
        return wrapped(x, block_params)

    x, auxs = jax.lax.scan(scan_body, x, stack["blocks"])
    aux = jnp.sum(auxs)
    for j, kind in enumerate(tail_kinds):
        x, auxj = _layer_fwd(stack["tail"][j], cfg, x, kind, positions,
                             causal, enc_out, q_chunk)
        aux = aux + auxj
    return x, aux


def forward(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray | None = None,
    *,
    embeds: jnp.ndarray | None = None,
    enc_tokens: jnp.ndarray | None = None,
    enc_embeds: jnp.ndarray | None = None,
    q_chunk: int = 1024,
):
    """Full forward pass -> (logits, aux).  ``embeds`` bypasses the token
    embedding (modality-frontend stub per the assignment)."""
    adt = jnp.dtype(cfg.activation_dtype)
    params = cast_params(params, adt)
    if embeds is None:
        x = embed(params["embed"], tokens, cfg.d_model).astype(adt)
    else:
        x = embeds.astype(adt)

    enc_out = None
    if cfg.is_enc_dec:
        if enc_embeds is None:
            e = embed(params["embed"], enc_tokens, cfg.d_model).astype(adt)
        else:
            e = enc_embeds.astype(adt)
        enc_cfg = dataclasses.replace(
            cfg, moe=None, block_pattern=None, local_global_period=None,
            shared_attn_period=0,
        )
        enc_out, _ = _run_stack(params["encoder"], enc_cfg, e,
                                depth=cfg.encoder_layers, causal=False,
                                q_chunk=q_chunk)
        enc_out = rmsnorm(params["enc_final_norm"], enc_out, cfg.norm_eps)

    x, aux = _run_stack(
        params["decoder"], cfg, x, depth=cfg.n_layers, causal=True,
        enc_out=enc_out, shared_attn=params.get("shared_attn"),
        q_chunk=q_chunk,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = x @ params["lm_head"]["w"]
    logits = constrain(logits, ("batch", None, "model"))
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap), aux


def forward_hidden(
    params,
    cfg: ModelConfig,
    tokens=None,
    *,
    embeds=None,
    enc_tokens=None,
    enc_embeds=None,
    q_chunk: int = 1024,
):
    """Forward pass up to (and including) the final norm -> (x, aux)."""
    adt = jnp.dtype(cfg.activation_dtype)
    params = cast_params(params, adt)
    if embeds is None:
        x = embed(params["embed"], tokens, cfg.d_model).astype(adt)
    else:
        x = embeds.astype(adt)

    enc_out = None
    if cfg.is_enc_dec:
        if enc_embeds is None:
            e = embed(params["embed"], enc_tokens, cfg.d_model).astype(adt)
        else:
            e = enc_embeds.astype(adt)
        enc_cfg = dataclasses.replace(
            cfg, moe=None, block_pattern=None, local_global_period=None,
            shared_attn_period=0,
        )
        enc_out, _ = _run_stack(params["encoder"], enc_cfg, e,
                                depth=cfg.encoder_layers, causal=False,
                                q_chunk=q_chunk)
        enc_out = rmsnorm(params["enc_final_norm"], enc_out, cfg.norm_eps)

    x, aux = _run_stack(
        params["decoder"], cfg, x, depth=cfg.n_layers, causal=True,
        enc_out=enc_out, shared_attn=params.get("shared_attn"),
        q_chunk=q_chunk,
    )
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def loss_fn(params, cfg: ModelConfig, batch, q_chunk: int = 1024,
            ce_chunk: int = 256):
    """Next-token cross-entropy (+ MoE aux), computed in sequence chunks
    so the (B, S, V) f32 logits never materialize (the unembed of a 256k
    vocab at 4k seq would otherwise dominate per-chip memory).  Each
    chunk is rematerialized in the backward pass."""
    x, aux = forward_hidden(
        params, cfg,
        batch.get("tokens"),
        embeds=batch.get("embeds"),
        enc_tokens=batch.get("enc_tokens"),
        enc_embeds=batch.get("enc_embeds"),
        q_chunk=q_chunk,
    )
    labels = batch["labels"]
    if cfg.tie_embeddings:
        table = cast_params(params["embed"]["table"], cfg.activation_dtype)
        unemb = lambda h: h @ table.T                      # noqa: E731
    else:
        w = cast_params(params["lm_head"]["w"], cfg.activation_dtype)
        unemb = lambda h: h @ w                            # noqa: E731

    @jax.checkpoint
    def chunk_nll(xc, lc):
        logits = constrain(unemb(xc), ("batch", None, "model"))
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    b, s, _ = x.shape
    cc = min(ce_chunk, s)
    if s % cc:
        cc = s  # fall back to one chunk for odd lengths
    nc = s // cc
    xs = x.reshape(b, nc, cc, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, cc).transpose(1, 0, 2)

    def scan_body(carry, inp):
        tot, cnt = carry
        xc, lc = inp
        t, c = chunk_nll(xc, lc)
        return (tot + t, cnt + c), None

    # data-dependent zero so the carry is device-varying under shard_map
    zero = (x.reshape(-1)[0] * 0).astype(jnp.float32)
    (tot, cnt), _ = jax.lax.scan(scan_body, (zero, zero), (xs, ls))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}
