"""Mixture-of-Experts FFN: token-choice top-k routing with capacity,
deepseek-style shared experts, arctic-style dense residual branch.

Dispatch uses the SPMD-friendly one-hot einsum formulation (tokens stay
data-sharded, experts model-sharded; XLA reduces the contraction over
the data axis).  The dispatch mask is O(B·Cs·E·C) — quadratic in the
chunk length Cs — so routing is scanned over sequence chunks of
``router_chunk`` tokens, which bounds both the mask memory and the
dispatch-einsum FLOP overhead (≈ Cs·K·cf·D FLOPs/token, ~4% of expert
FLOPs at Cs=256 for deepseek-moe).  Chunking makes the capacity limit
per-chunk rather than per-sequence; with capacity_factor ≥ 1.25 the
drop statistics are equivalent in expectation (documented deviation).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import mlp, mlp_init, normal_init
from repro.models.partitioning import constrain


def moe_init(key, d: int, cfg: MoEConfig, activation: str, dtype) -> dict:
    ks = jax.random.split(key, 6)
    e, f = cfg.n_experts, cfg.d_expert
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": normal_init(ks[0], (d, e), jnp.float32),
        "gate": (jax.random.truncated_normal(ks[1], -2, 2, (e, d, f)) * scale
                 ).astype(dtype),
        "up": (jax.random.truncated_normal(ks[2], -2, 2, (e, d, f)) * scale
               ).astype(dtype),
        "down": (jax.random.truncated_normal(ks[3], -2, 2, (e, f, d))
                 * (1.0 / math.sqrt(f))).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], d, cfg.n_shared * f, activation, dtype)
    if cfg.dense_residual_ff:
        p["dense"] = mlp_init(ks[5], d, cfg.dense_residual_ff,
                              activation, dtype)
    return p


def _capacity(tokens_per_expert: float, cf: float) -> int:
    c = math.ceil(tokens_per_expert * cf)
    return max(4, math.ceil(c / 4) * 4)


def _route_chunk(params, x, cfg: MoEConfig, activation):
    """x: (B, Cs, D) -> (B, Cs, D), aux metrics."""
    b, cs, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cs * k / e, cfg.capacity_factor)

    logits = (x.astype(jnp.float32) @ params["router"])          # (B,Cs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                   # (B,Cs,K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # (B,Cs,K,E)
    # position of each assignment within its expert (per batch row)
    flat = onehot.reshape(b, cs * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (B,Cs*K,E)
    pos = pos.reshape(b, cs, k, e)
    within = pos < cap

    # per-assignment (expert, slot) index; overflow gets a sentinel that
    # one_hot maps to all-zeros.  Accumulating over the K assignments keeps
    # the peak intermediate at (B,Cs,E·C) instead of (B,Cs,K,E,C).
    pos_k = jnp.sum(pos * onehot, axis=-1)                       # (B,Cs,K)
    valid = jnp.sum(within * onehot, axis=-1)                    # (B,Cs,K)
    comb_idx = jnp.where(valid > 0, gate_idx * cap + pos_k.astype(jnp.int32),
                         e * cap)
    dispatch = jnp.zeros((b, cs, e * cap), jnp.float32)
    combine = jnp.zeros((b, cs, e * cap), jnp.float32)
    for kk in range(k):
        oh = jax.nn.one_hot(comb_idx[..., kk], e * cap, dtype=jnp.float32)
        dispatch = dispatch + oh
        combine = combine + oh * gate_w[..., kk : kk + 1]
    dispatch = constrain(dispatch.reshape(b, cs, e, cap),
                         ("batch", None, "model", None))
    combine = constrain(combine.reshape(b, cs, e, cap),
                        ("batch", None, "model", None))

    xe = jnp.einsum("bsec,bsd->ecd", dispatch.astype(x.dtype), x)  # (E,C,D)
    xe = constrain(xe, ("model", None, None))
    if activation in ("silu", "geglu"):
        act = jax.nn.silu if activation == "silu" else (
            lambda t: jax.nn.gelu(t, approximate=True))
        h = act(jnp.einsum("ecd,edf->ecf", xe, params["gate"])) * jnp.einsum(
            "ecd,edf->ecf", xe, params["up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, params["up"]),
                        approximate=True)
    # expert hidden: F rides the batch axes (matches the weight TP
    # sharding; an unsharded-F constraint here would force the backward
    # to all-gather the down weights over F — §Perf arctic H2b)
    h = constrain(h, ("model", None, "batch"))
    ye = constrain(jnp.einsum("ecf,efd->ecd", h, params["down"]),
                   ("model", None, None))                          # (E,C,D)
    y = jnp.einsum("bsec,ecd->bsd", combine.astype(x.dtype), ye)   # (B,Cs,D)
    y = constrain(y, ("batch", None, None))

    # load-balance auxiliaries (Switch-style)
    me = jnp.mean(onehot.sum(2).reshape(-1, e), axis=0)
    pe = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(me * pe)
    return y, aux


def moe_apply(params, x: jnp.ndarray, cfg: MoEConfig, activation: str):
    """x: (B, S, D) -> (B, S, D); scans routing over seq chunks."""
    b, s0, d = x.shape
    cs = min(cfg.router_chunk, s0)
    # pad to a chunk multiple; pad tokens only dilute capacity in the
    # final chunk and their outputs are sliced off
    s = math.ceil(s0 / cs) * cs
    x = jnp.pad(x, ((0, 0), (0, s - s0), (0, 0))) if s != s0 else x
    n = s // cs

    if n == 1:
        y, aux = _route_chunk(params, x, cfg, activation)
    else:
        xs = x.reshape(b, n, cs, d).transpose(1, 0, 2, 3)

        def step(_, xc):
            yc, aux_c = _route_chunk(params, xc, cfg, activation)
            return None, (yc, aux_c)

        _, (ys, auxs) = jax.lax.scan(step, None, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = jnp.mean(auxs)

    if "shared" in params:
        y = y + mlp(params["shared"], x, activation)
    if "dense" in params:
        y = y + mlp(params["dense"], x, activation)
    return y[:, :s0], aux
