"""Activation-sharding constraints (logical axes -> mesh axes).

XLA's sharding propagation replicates large intermediates it cannot
infer (flash-attention carries, MoE dispatch masks) — on a 256-chip mesh
that turns GB-scale temporaries into per-device copies and inserts
whole-activation all-reduces.  The launcher installs a policy
(mesh + batch axes); model code marks intermediates with logical dims:

    x = constrain(x, ("batch", None, "model"))

Every constraint is divisibility-guarded: a logical axis whose dim size
doesn't divide the mesh-axis size is dropped (e.g. MQA's single KV head
is replicated rather than sharded).  Without an installed policy (unit
tests, single-device runs) `constrain` is a no-op.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class Policy:
    mesh: Any
    batch_axes: tuple    # mesh axes used for batch/fsdp
    model_axis: str = "model"

    def axis_size(self, logical: str) -> int:
        if logical == "batch":
            return math.prod(self.mesh.shape[a] for a in self.batch_axes)
        if logical == "model":
            return self.mesh.shape[self.model_axis]
        return 1

    def mesh_axes(self, logical: str):
        if logical == "batch":
            return (self.batch_axes if len(self.batch_axes) > 1
                    else self.batch_axes[0])
        if logical == "model":
            return self.model_axis
        return None


def set_policy(policy: Policy | None):
    _STATE.policy = policy


def get_policy() -> Policy | None:
    return getattr(_STATE, "policy", None)


class apply_policy:
    """Context manager used by launchers around trace/lower calls."""

    def __init__(self, policy: Policy | None):
        self.policy = policy

    def __enter__(self):
        self.prev = get_policy()
        set_policy(self.policy)
        return self.policy

    def __exit__(self, *exc):
        set_policy(self.prev)


def constrain(x, dims, free: bool = False):
    """dims: per-axis logical name ("batch" | "model" | None).

    free=True leaves unpinned dims UNCONSTRAINED (XLA may shard them as
    it likes) instead of forcing replication — used for tensors whose
    best extra sharding is architecture-dependent (e.g. flash-attention
    accumulators when the head count doesn't divide the model axis)."""
    pol = get_policy()
    if pol is None:
        return x
    if len(dims) != x.ndim:
        raise ValueError(f"dims {dims} vs shape {x.shape}")
    fill = P.UNCONSTRAINED if free else None
    used = set()
    spec = []
    for d, size in zip(dims, x.shape):
        if d is None or d in used:
            spec.append(fill)
            continue
        if d == "batch":
            # suffix fallback: a batch smaller than the full batch-axes
            # product still shards over the inner axes (e.g. global
            # batch 32 on ("pod","data")=64 -> shard over "data")
            axes = pol.batch_axes
            while axes and size % math.prod(
                    pol.mesh.shape[a] for a in axes):
                axes = axes[1:]
            if not axes:
                spec.append(fill)
                continue
            spec.append(axes if len(axes) > 1 else axes[0])
            used.add(d)
        elif size % pol.axis_size(d) == 0:
            spec.append(pol.mesh_axes(d))
            used.add(d)
        else:
            spec.append(fill)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, P(*spec)))


def constrain_tree(tree, dims_fn):
    """Constrain every array leaf; dims_fn(leaf) -> dims tuple."""
    return jax.tree.map(lambda x: constrain(x, dims_fn(x)), tree)
