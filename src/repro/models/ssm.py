"""Mamba2 (state-space dual) block — the SSM layer of zamba2-7b.

Chunked SSD algorithm (Dao & Gu 2024, minimal form): the sequence is
scanned in chunks of L tokens; within a chunk the quadratic (L×L)
decay-masked form runs dense (MXU-friendly), across chunks only the
(H, P, N) state is carried — the same VMEM-residency reasoning as the
paper's fused filter chains (state stays on-chip across a chunk;
DESIGN.md §4).

Simplifications vs. the reference CUDA implementation (documented):
single B/C group (G=1), no variance-reduction norm on dt, conv kernel
of 4.  These do not change the FLOP/byte profile the roofline reads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rmsnorm
from repro.models.partitioning import constrain

CONV_K = 4


def ssm_dims(d_model: int, head_dim: int):
    d_in = 2 * d_model
    n_heads = d_in // head_dim
    return d_in, n_heads


def mamba2_init(key, d: int, n_state: int, head_dim: int, dtype) -> dict:
    d_in, h = ssm_dims(d, head_dim)
    conv_dim = d_in + 2 * n_state
    ks = jax.random.split(key, 5)
    return {
        "in_proj": normal_init(
            ks[0], (d, 2 * d_in + 2 * n_state + h), dtype
        ),
        "conv_w": normal_init(ks[1], (CONV_K, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": {"scale": jnp.zeros((d_in,), dtype)},
        "out_proj": normal_init(ks[2], (d_in, d), dtype),
    }


def _split_proj(params, x, d: int, n_state: int, head_dim: int):
    d_in, h = ssm_dims(d, head_dim)
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n_state], axis=-1)
    return z, xbc, dt, d_in, h


def _causal_conv(xbc, params, prev=None):
    """Depthwise causal conv, kernel CONV_K.  prev: (B, K-1, C) history for
    decode; None means zero history (training/prefill from scratch)."""
    b, s, c = xbc.shape
    if prev is None:
        prev = jnp.zeros((b, CONV_K - 1, c), xbc.dtype)
    ext = jnp.concatenate([prev, xbc], axis=1)
    out = sum(
        ext[:, i : i + s, :] * params["conv_w"][i]
        for i in range(CONV_K)
    )
    out = jax.nn.silu(out + params["conv_b"])
    return out, ext[:, -(CONV_K - 1) :, :]


def mamba2_apply(
    params,
    x: jnp.ndarray,       # (B, S, D)
    *,
    n_state: int,
    head_dim: int,
    chunk: int = 128,
):
    """Training/prefill forward.  Returns (y, final_state, conv_tail)."""
    b, s, d = x.shape
    z, xbc, dt, d_in, h = _split_proj(params, x, d, n_state, head_dim)
    xbc, conv_tail = _causal_conv(xbc, params)
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n_state], axis=-1)

    p = head_dim
    xs = xs.reshape(b, s, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])                                     # (H,)

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xs = constrain(xs.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4),
                   (None, "batch", None, "model", None))
    dt_c = constrain(dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3),
                     (None, "batch", None, "model"))
    b_c = constrain(
        bmat.reshape(b, nc, chunk, n_state).transpose(1, 0, 2, 3),
        (None, "batch", None, None))
    c_c = constrain(cmat.reshape(b, nc, chunk, n_state).transpose(1, 0, 2, 3),
                    (None, "batch", None, None))

    def chunk_step(state, inp):
        xc, dtc, bc, cc = inp                     # (B,L,H,P), (B,L,H), (B,L,N)
        da = dtc * a                              # (B,L,H)
        cum = jnp.cumsum(da, axis=1)              # (B,L,H)
        total = cum[:, -1:, :]                    # (B,1,H)

        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum(
            "bln,bhpn,blh->blhp", cc, state, jnp.exp(cum)
        )

        # intra-chunk: decay-masked quadratic form.  Mask BEFORE the exp:
        # exp on masked (j > i) entries can overflow and grad(where)
        # yields inf·0 = NaN in the backward.
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,L,L,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        seg = jnp.where(causal[None, :, :, None], seg, -1e30)
        decay = jnp.exp(seg)
        scores = jnp.einsum("bln,bmn->blm", cc, bc)            # (B,L,L)
        w = scores[..., None] * decay                          # (B,L,L,H)
        y_intra = jnp.einsum("blmh,bmh,bmhp->blhp", w, dtc, xc)

        # state update
        rev = jnp.exp(total - cum)                             # (B,L,H)
        new_state = state * jnp.exp(total)[:, 0, :, None, None] + jnp.einsum(
            "bln,blh,blhp->bhpn", bc, dtc * rev, xc
        )
        y = y_intra + y_inter + params["D"][None, None, :, None] * xc
        return new_state, y

    state0 = constrain(jnp.zeros((b, h, p, n_state), jnp.float32),
                       ("batch", "model", None, None))
    final_state, ys = jax.lax.scan(
        chunk_step, state0, (xs, dt_c, b_c, c_c)
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"], final_state, conv_tail


def mamba2_decode(
    params,
    x: jnp.ndarray,        # (B, 1, D)
    state: jnp.ndarray,    # (B, H, P, N) float32
    conv_prev: jnp.ndarray,  # (B, K-1, conv_dim)
    *,
    n_state: int,
    head_dim: int,
):
    """Single-token step.  Returns (y, new_state, new_conv_prev)."""
    b, _, d = x.shape
    z, xbc, dt, d_in, h = _split_proj(params, x, d, n_state, head_dim)
    xbc, conv_prev = _causal_conv(xbc, params, conv_prev)
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n_state], axis=-1)

    p = head_dim
    xs = xs.reshape(b, h, p)
    bv = bmat[:, 0, :]                                         # (B,N)
    cv = cmat[:, 0, :]
    dt = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * a)                                       # (B,H)

    state = state * da[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", bv, dt, xs.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", cv, state) + params["D"][None, :, None] * xs
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"], state, conv_prev
