"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, chunkwise
parallel — linear-attention-like) and sLSTM (scalar memory, strictly
recurrent with head-blocked recurrent gate weights).

mLSTM runs chunkwise like the Mamba2 SSD path: within a chunk the
decay-masked quadratic form, across chunks a carried (C, n, m) state.
sLSTM is a lax.scan over time (its recurrence is not parallelizable —
that is the point of the block).

Simplifications (documented): forget gate via logsigmoid in both cells;
per-chunk stabilization for mLSTM (exact stabilized recurrence in the
decode path); mLSTM projection factor 2, sLSTM projection factor 1.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, d: int, n_heads: int, dtype) -> dict:
    d_in = 2 * d
    ks = jax.random.split(key, 4)
    return {
        "qkv": normal_init(ks[0], (d, 3 * d_in), dtype),
        "gates": normal_init(ks[1], (d, 2 * n_heads), dtype, scale=0.01),
        "ogate": normal_init(ks[2], (d, d_in), dtype),
        "norm": {"scale": jnp.zeros((d_in,), dtype)},
        "out": normal_init(ks[3], (d_in, d), dtype),
        "fbias": jnp.full((n_heads,), 3.0, jnp.float32),  # open forget gates
    }


def _mlstm_proj(params, x, n_heads):
    b, s, d = x.shape
    d_in = 2 * d
    p = d_in // n_heads
    q, k, v = jnp.split(x @ params["qkv"], 3, axis=-1)
    q = q.reshape(b, s, n_heads, p)
    k = k.reshape(b, s, n_heads, p) / math.sqrt(p)
    v = v.reshape(b, s, n_heads, p)
    gates = (x @ params["gates"]).astype(jnp.float32)
    li, lf = jnp.split(gates, 2, axis=-1)                  # (B,S,H) each
    lf = jax.nn.log_sigmoid(lf + params["fbias"])
    o = jax.nn.sigmoid(x @ params["ogate"])
    return q, k, v, li, lf, o, p


def mlstm_apply(params, x, *, n_heads: int, chunk: int = 128):
    """(B,S,D) -> (B,S,D); returns (y, (C, n, m) final state)."""
    b, s, d = x.shape
    q, k, v, li, lf, o, p = _mlstm_proj(params, x, n_heads)
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def to_chunks(t):
        return t.reshape((b, nc, chunk) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1))
        )

    qc, kc, vc, lic, lfc = map(to_chunks, (q, k, v, li, lf))

    def step(state, inp):
        c_st, n_st, m_st = state              # (B,H,P,P), (B,H,P), (B,H)
        qt, kt, vt, lit, lft = inp
        cum = jnp.cumsum(lft, axis=1)                        # (B,L,H)
        total = cum[:, -1, :]                                # (B,H)

        # log source strength of token j, measured at the chunk origin:
        #   a_j = li_j - cum_j  (weight of j at i is exp(cum_i + a_j))
        a = lit - cum                                        # (B,L,H)
        amax = jax.lax.cummax(a, axis=1)                     # max_{j<=i} a_j
        # stabilizer at i: m_i = cum_i + max(m_st, max_{j<=i} a_j)
        m_new = cum + jnp.maximum(m_st[:, None, :], amax)    # (B,L,H)

        # inter: decayed carry-in (stored state carries scale e^{-m_st})
        inter_w = jnp.exp(m_st[:, None, :] + cum - m_new)    # (B,L,H)
        num_inter = jnp.einsum("blhp,bhqp,blh->blhq", qt, c_st, inter_w)
        den_inter = jnp.einsum("blhp,bhp,blh->blh", qt, n_st, inter_w)

        # intra: w_ij = exp(cum_i - cum_j + li_j - m_i), j <= i.
        # mask before exp (masked-exp grads are inf·0 = NaN otherwise)
        logw = (cum - m_new)[:, :, None, :] + a[:, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        logw = jnp.where(causal[None, :, :, None], logw, -1e30)
        w = jnp.exp(logw)
        scores = jnp.einsum("blhp,bmhp->blmh", qt, kt)
        sw = scores * w
        num = num_inter + jnp.einsum("blmh,bmhp->blhp", sw, vt)
        # denominator: q·n = Σ_j w_ij (q·k_j) = Σ_j sw_ij
        den = den_inter + jnp.sum(sw, axis=2)

        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]

        # state update (stabilized at the chunk-end max)
        m_out = total + jnp.maximum(m_st, amax[:, -1, :])    # (B,H)
        carry_w = jnp.exp(m_st + total - m_out)              # (B,H)
        in_w = jnp.exp(total[:, None, :] + a - m_out[:, None, :])  # (B,L,H)
        c_new = c_st * carry_w[..., None, None] + jnp.einsum(
            "bmhp,bmhq,bmh->bhpq", vt, kt, in_w
        )
        n_new = n_st * carry_w[..., None] + jnp.einsum(
            "bmhp,bmh->bhp", kt, in_w
        )
        return (c_new, n_new, m_out), h

    p_dim = p
    state0 = (
        jnp.zeros((b, n_heads, p_dim, p_dim), jnp.float32),
        jnp.zeros((b, n_heads, p_dim), jnp.float32),
        jnp.full((b, n_heads), -1e30, jnp.float32),
    )
    state, hs = jax.lax.scan(step, state0, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, 2 * d)
    y = rmsnorm(params["norm"], h.astype(x.dtype) * o)
    return y @ params["out"], state


def mlstm_decode(params, x, state, *, n_heads: int):
    """Single token.  x: (B,1,D); state (C,n,m)."""
    b, _, d = x.shape
    q, k, v, li, lf, o, p = _mlstm_proj(params, x, n_heads)
    qt, kt, vt = q[:, 0], k[:, 0], v[:, 0]                # (B,H,P)
    lit, lft = li[:, 0], lf[:, 0]                         # (B,H)
    c_st, n_st, m_st = state

    m_new = jnp.maximum(lft + m_st, lit)
    fw = jnp.exp(lft + m_st - m_new)
    iw = jnp.exp(lit - m_new)
    c_new = c_st * fw[..., None, None] + jnp.einsum("bhp,bhq->bhpq", vt, kt) \
        * iw[..., None, None]
    n_new = n_st * fw[..., None] + kt * iw[..., None]
    num = jnp.einsum("bhp,bhqp->bhq", qt, c_new)
    den = jnp.einsum("bhp,bhp->bh", qt, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(b, 1, 2 * d)
    y = rmsnorm(params["norm"], h.astype(x.dtype) * o)
    return y @ params["out"], (c_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, d: int, n_heads: int, dtype) -> dict:
    p = d // n_heads
    ks = jax.random.split(key, 3)
    return {
        "wx": normal_init(ks[0], (d, 4 * d), dtype),
        "r": normal_init(ks[1], (n_heads, p, 4 * p), dtype),
        "fbias": jnp.full((d,), 3.0, jnp.float32),
        "norm": {"scale": jnp.zeros((d,), dtype)},
        "out": normal_init(ks[2], (d, d), dtype),
    }


def _slstm_cell(params, xg, state, n_heads, d):
    """xg: (B, 4d) pre-activations from x; state = (c, n, h, m)."""
    p = d // n_heads
    c, n, h, m = state
    hh = h.reshape(-1, n_heads, p)
    rg = jnp.einsum("bhp,hpq->bhq", hh, params["r"]).reshape(-1, 4 * d)
    zi, zf, zz, zo = jnp.split((xg + rg).astype(jnp.float32), 4, axis=-1)
    lf = jax.nn.log_sigmoid(zf + params["fbias"])
    m_new = jnp.maximum(lf + m, zi)
    i = jnp.exp(zi - m_new)
    f = jnp.exp(lf + m - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_apply(params, x, *, n_heads: int):
    """(B,S,D) -> (B,S,D); sequential scan over time."""
    b, s, d = x.shape
    xg = (x @ params["wx"]).astype(jnp.float32)          # (B,S,4d)
    state0 = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) + (
        jnp.full((b, d), -1e30, jnp.float32),
    )

    def step(state, xt):
        return _slstm_cell(params, xt, state, n_heads, d)

    state, hs = jax.lax.scan(step, state0, xg.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    y = rmsnorm(params["norm"], h)
    return y @ params["out"], state


def slstm_decode(params, x, state, *, n_heads: int):
    b, _, d = x.shape
    xg = (x[:, 0] @ params["wx"]).astype(jnp.float32)
    state, h = _slstm_cell(params, xg, state, n_heads, d)
    y = rmsnorm(params["norm"], h[:, None, :].astype(x.dtype))
    return y @ params["out"], state
