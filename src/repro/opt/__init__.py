"""Expression optimizer: exact algebraic rewrites over Expr graphs.

The compiler middle-end — runs between composition (``repro.api.expr``)
and lowering (``repro.api.lower``).  ``rewrite()`` canonicalizes a
graph with the exactness-provable rule catalog in ``repro.opt.rules``;
``repro.api.compile`` applies it by default (escape hatch
``rewrite=False``) and keys its cache on the canonical form, so source
graphs that are algebraically equal share one compiled program.
"""
from repro.opt.engine import (Applied, RewriteResult, clear_rewrite_cache,
                              rewrite, rewrite_traced)
from repro.opt.rules import (DEFAULT_RULES, Rule, active_rules,
                             register_rule, rule_names)

__all__ = [
    "Applied",
    "RewriteResult",
    "Rule",
    "DEFAULT_RULES",
    "active_rules",
    "register_rule",
    "rule_names",
    "rewrite",
    "rewrite_traced",
    "clear_rewrite_cache",
]
