"""Bounded deterministic fixed-point driver for the rewrite rules.

:func:`rewrite_traced` canonicalizes an :class:`~repro.api.expr.Expr`
graph by running the registered rules (``repro.opt.rules``) bottom-up
to a fixed point, and returns the rewritten root together with the
ordered trace of every rule application.  The trace is what the
soundness hook (``repro.analysis.rewrites``) replays numerically, and
what serve surfaces as the ``rewrites_applied`` counter.

Determinism and termination:

* rules are tried in registry order at every node, first match wins;
  within one pass the graph is rebuilt bottom-up with structural
  memoization, so identical sub-DAGs rewrite identically and stay
  shared;
* each pass may cascade (a node is re-matched after a rule fires on
  it, bounded by :data:`MAX_NODE_STEPS`), and whole passes repeat
  until the root stops changing, bounded by :data:`MAX_PASSES`;
* guards see consumer counts of the graph *at the start of the pass*
  (a conservative snapshot — a vetoed match is retried next pass with
  fresh counts, so the bound is on latency, not on what gets found).

The engine enforces the one global safety invariant rules cannot
express locally: a rewrite must preserve the graph's named-input
signature (the calling convention of the compiled program).  If a
rule ever changes it, the whole rewrite is discarded and the source
graph is returned untouched.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.api.expr import Expr
from repro.api.lower import _consumer_counts, _input_names
from repro.opt import rules as _rules

__all__ = ["Applied", "RewriteResult", "rewrite", "rewrite_traced",
           "clear_rewrite_cache"]

#: Whole-graph passes before the driver gives up (a diverging rule set
#: is a bug; every built-in rule strictly shrinks the graph or is
#: applied at most once per node, so 2-3 passes is typical).
MAX_PASSES = 32

#: Cascaded rule firings at a single node within one pass.
MAX_NODE_STEPS = 16


@dataclasses.dataclass(frozen=True)
class Applied:
    """One rule application: ``before`` → ``after`` (both sub-graphs
    of the rewrite in flight; replayable in isolation because every
    rule is locally exact)."""

    rule: str
    before: Expr
    after: Expr


@dataclasses.dataclass(frozen=True)
class RewriteResult:
    source: Expr
    expr: Expr
    trace: tuple  # of Applied, in application order

    @property
    def changed(self) -> bool:
        return self.expr != self.source

    @property
    def n_applied(self) -> int:
        return len(self.trace)


class RewriteContext:
    """Per-pass graph context handed to rule guards."""

    def __init__(self, root: Expr):
        self._counts = _consumer_counts(root)

    def consumers(self, node: Expr) -> int:
        """How many parents ``node`` had at the start of this pass."""
        return self._counts.get(node, 0)


def _apply_at(node: Expr, active, ctx: RewriteContext, trace: list) -> Expr:
    """Cascade rules at one node (children already rewritten)."""
    for _ in range(MAX_NODE_STEPS):
        for rule in active:
            bindings = rule.pattern(node)
            if bindings is None:
                continue
            if not rule.guard(bindings, ctx):
                continue
            replacement = rule.build(bindings)
            if replacement == node:
                continue
            trace.append(Applied(rule.name, node, replacement))
            node = replacement
            break
        else:
            return node
    return node


def _one_pass(root: Expr, active, trace: list) -> Expr:
    ctx = RewriteContext(root)
    memo: dict = {}

    def rec(node: Expr) -> Expr:
        hit = memo.get(node)
        if hit is not None:
            return hit
        new_args = tuple(rec(a) for a in node.args)
        if new_args != node.args:
            node2 = Expr(node.kind, new_args, node.params)
        else:
            node2 = node
        out = _apply_at(node2, active, ctx, trace)
        memo[node] = out
        return out

    return rec(root)


@functools.lru_cache(maxsize=1024)
def rewrite_traced(expr: Expr) -> RewriteResult:
    """Canonicalize ``expr``; returns the rewritten graph + trace.

    Pure and memoized — safe to call from the compile cache's key
    derivation and from serve's per-request path.
    """
    active = _rules.active_rules()
    trace: list = []
    node = expr
    for _ in range(MAX_PASSES):
        before = node
        node = _one_pass(node, active, trace)
        if node == before:
            break
    if node != expr and _input_names(node) != _input_names(expr):
        # a rule dropped or reordered a named input: the rewritten
        # program would have a different calling convention — discard
        return RewriteResult(expr, expr, ())
    return RewriteResult(expr, node, tuple(trace))


def rewrite(expr: Expr) -> Expr:
    """The canonical form of ``expr`` (same graph if nothing fired)."""
    return rewrite_traced(expr).expr


def clear_rewrite_cache() -> None:
    rewrite_traced.cache_clear()
