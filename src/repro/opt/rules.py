"""Algebraic rewrite rules over morphology expression graphs.

Every rule is a :class:`Rule` — a *pattern / guard / rewrite* triple
over :class:`~repro.api.expr.Expr` nodes — registered in
:data:`DEFAULT_RULES` in a deterministic order (the fixed-point driver
in ``repro.opt.engine`` applies them in registry order, first match
wins).  Rules must be **exactness-provable**: the rewritten graph is
bit-identical to the original on every input, dtype and backend, which
is what lets ``repro.api.compile`` apply them by default.  The catalog,
the lattice-algebra argument behind each rule and the recipe for adding
one live in ``docs/OPTIMIZER.md``; the numeric replay harness that
re-checks every applied rule on randomized inputs is
``repro.analysis.rewrites``.

The built-in catalog (morphology algebra over the 3×3 elementary
filters the paper's chains are built from):

``neutral-chain`` / ``neutral-sat``
    zero-length erode/dilate chains and ``sat_sub``/``sat_add`` with
    ``h == 0`` are identities — eliminated.
``chain-merge``
    ε_a ∘ ε_b = ε_{a+b} (δ dual): adjacent same-op chains merge and,
    because both association orders collapse to one node, re-associate
    to a canonical form — two source graphs that differ only in chain
    association lower to one shared program (this is what feeds the
    compile cache's shared-program hits and serve's cross-bucket
    sharing).  Guarded on the inner chain having no other consumer, so
    a shared intermediate is never recomputed.
``opening-absorb`` / ``closing-absorb``
    granulometry absorption γ_s γ_t = γ_t γ_s = γ_max(s,t) (φ dual):
    the s-fold 3×3 ball family is a granulometry (B_t = B_s ⊕ B_{t-s}
    for t ≥ s), so stacked openings collapse — γ/φ idempotence
    (s == t) is the degenerate case.
``double-reconstruct``
    Rec(Rec(m, f), f) = Rec(m, f): reconstruction is idempotent in its
    marker (its output is already a geodesic fixpoint under ``f``).
``geodesic-prefix``
    Rec(δ_f^n(m), f) = Rec(m, f): a fixed-length geodesic prefix of a
    reconstruction toward the *same* mask and op is absorbed by the
    limit — the whole geodesic segment is dead.
``rec-opening-idem``
    γ_rec^s γ_rec^s = γ_rec^s (φ_rec dual): opening by reconstruction
    is an algebraic opening, so applying it to its own output is dead
    work — an entire convergent segment is pruned.
``self-reconstruct`` / ``self-geodesic``
    Rec(f, f) = f and δ_f^n(f) = f: the mask is its own fixpoint —
    the convergent segment is dead and pruned entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.api.expr import E, Expr

__all__ = ["Rule", "DEFAULT_RULES", "register_rule", "rule_names"]


@dataclasses.dataclass(frozen=True)
class Rule:
    """One algebraic rewrite: pattern → (guard) → replacement.

    ``pattern(node)`` returns a bindings dict when the node matches
    (``None`` otherwise); ``guard(bindings, ctx)`` may veto a match
    using graph context (consumer counts of the *current* root — see
    :class:`repro.opt.engine.RewriteContext`); ``build(bindings)``
    constructs the replacement.  The replacement must be bit-exact and
    must preserve the graph's input-leaf set (the engine enforces the
    latter).
    """

    name: str
    pattern: Callable      # Expr -> dict | None
    guard: Callable        # (bindings, RewriteContext) -> bool
    build: Callable        # bindings -> Expr
    doc: str = ""


def _no_guard(bindings, ctx) -> bool:
    return True


def _chain(node: Expr, op: str | None = None):
    """Match an erode/dilate chain node; returns (op, s, child)."""
    if node.kind not in ("erode", "dilate"):
        return None
    if op is not None and node.kind != op:
        return None
    return node.kind, node.param("s"), node.args[0]


def _opening_like(node: Expr):
    """Match γ_s (dilate∘erode) or φ_s (erode∘dilate) with equal s.

    Returns ``(outer_op, s, operand)`` where ``outer_op`` is the kind
    of the *outer* chain ("dilate" for an opening, "erode" for a
    closing).
    """
    outer = _chain(node)
    if outer is None:
        return None
    o_op, o_s, inner_node = outer
    inner = _chain(inner_node, "erode" if o_op == "dilate" else "dilate")
    if inner is None or inner[1] != o_s:
        return None
    return o_op, o_s, inner[2]


# -- patterns ---------------------------------------------------------------


def _p_neutral_chain(node: Expr):
    m = _chain(node)
    if m is not None and m[1] == 0:
        return {"child": m[2]}
    return None


def _p_neutral_sat(node: Expr):
    if node.kind in ("sat_sub", "sat_add") and node.param("h") == 0:
        return {"child": node.args[0]}
    return None


def _p_chain_merge(node: Expr):
    outer = _chain(node)
    if outer is None:
        return None
    op, a, child = outer
    inner = _chain(child, op)
    if inner is None:
        return None
    return {"op": op, "a": a, "b": inner[1], "x": inner[2], "inner": child}


def _g_chain_merge(b, ctx) -> bool:
    # merging through a shared intermediate would recompute it for the
    # other consumers; the lowerer applies the same single-consumer rule
    return ctx.consumers(b["inner"]) <= 1


def _b_chain_merge(b) -> Expr:
    return Expr(b["op"], (b["x"],), (("s", b["a"] + b["b"]),))


def _p_absorb(kind: str):
    """Pattern factory for γ_s γ_t (kind='dilate') / φ_s φ_t ('erode')."""

    def pattern(node: Expr):
        outer = _opening_like(node)
        if outer is None or outer[0] != kind:
            return None
        _, s, y = outer
        inner = _opening_like(y)
        if inner is None or inner[0] != kind:
            return None
        _, t, x = inner
        return {"s": s, "t": t, "x": x, "inner": y}

    return pattern


def _g_absorb(b, ctx) -> bool:
    # s <= t collapses to the existing inner node (always safe); s > t
    # builds a fresh γ_s(x) / φ_s(x), so require the inner stage to
    # have no other consumer (it would otherwise still be computed).
    return b["s"] <= b["t"] or ctx.consumers(b["inner"]) <= 1


def _b_absorb(kind: str):
    def build(b) -> Expr:
        if b["s"] <= b["t"]:
            return b["inner"]
        make = E.opening if kind == "dilate" else E.closing
        return make(b["s"], b["x"])

    return build


def _p_double_reconstruct(node: Expr):
    if node.kind != "reconstruct":
        return None
    marker, mask = node.args
    if (marker.kind == "reconstruct" and marker.args[1] == mask
            and marker.param("op") == node.param("op")):
        return {"inner": marker}
    return None


def _p_geodesic_prefix(node: Expr):
    if node.kind != "reconstruct":
        return None
    marker, mask = node.args
    if (marker.kind == "geodesic" and marker.args[1] == mask
            and marker.param("op") == node.param("op")):
        return {"m": marker.args[0], "f": mask, "op": node.param("op")}
    return None


def _b_geodesic_prefix(b) -> Expr:
    return E.reconstruct(b["m"], b["f"], op=b["op"])


def _p_rec_opening_idem(node: Expr):
    """γ_rec^s γ_rec^s = γ_rec^s (and the φ_rec dual).

    Matches ``Rec_δ(ε_s(Rec_δ(ε_s(f), f)), f)`` — opening by
    reconstruction applied to its own output — and collapses to the
    inner reconstruction.  Exact because γ_rec^s is an algebraic
    opening (anti-extensive, increasing, idempotent); the erode→dilate
    /dilate→erode pairing below is what makes it one.
    """
    if node.kind != "reconstruct":
        return None
    op = node.param("op")
    chain_op = "erode" if op == "dilate" else "dilate"
    marker, mask = node.args
    m = _chain(marker, chain_op)
    if m is None:
        return None
    _, s, inner = m
    if inner.kind != "reconstruct" or inner.param("op") != op:
        return None
    if inner.args[1] != mask:
        return None
    im = _chain(inner.args[0], chain_op)
    if im is None or im[1] != s or im[2] != mask:
        return None
    return {"inner": inner}


def _p_self_reconstruct(node: Expr):
    if node.kind == "reconstruct" and node.args[0] == node.args[1]:
        return {"x": node.args[0]}
    return None


def _p_self_geodesic(node: Expr):
    if node.kind == "geodesic" and node.args[0] == node.args[1]:
        return {"x": node.args[0]}
    return None


#: The built-in exactness-provable catalog, in application order.
#: Shrinking rules run first so compositions (e.g. ``sat_sub(f, 0)``
#: feeding a reconstruction) cascade within one pass.
DEFAULT_RULES: tuple = (
    Rule("neutral-chain", _p_neutral_chain, _no_guard,
         lambda b: b["child"],
         "ε_0 = δ_0 = id: zero-length chains are identities"),
    Rule("neutral-sat", _p_neutral_sat, _no_guard,
         lambda b: b["child"],
         "sat_sub/sat_add with h=0 clamp nothing: x ∓ 0 = x"),
    Rule("self-reconstruct", _p_self_reconstruct, _no_guard,
         lambda b: b["x"],
         "Rec(f, f) = f: the mask is already a geodesic fixpoint"),
    Rule("self-geodesic", _p_self_geodesic, _no_guard,
         lambda b: b["x"],
         "δ_f^n(f) = f (ε dual): geodesic steps from the mask are dead"),
    Rule("double-reconstruct", _p_double_reconstruct, _no_guard,
         lambda b: b["inner"],
         "Rec(Rec(m, f), f) = Rec(m, f): reconstruction is idempotent"),
    Rule("geodesic-prefix", _p_geodesic_prefix, _no_guard,
         _b_geodesic_prefix,
         "Rec(δ_f^n(m), f) = Rec(m, f): a bounded geodesic prefix is "
         "absorbed by the reconstruction limit"),
    Rule("rec-opening-idem", _p_rec_opening_idem, _no_guard,
         lambda b: b["inner"],
         "γ_rec^s γ_rec^s = γ_rec^s (φ_rec dual): opening by "
         "reconstruction is an algebraic opening, hence idempotent"),
    Rule("chain-merge", _p_chain_merge, _g_chain_merge, _b_chain_merge,
         "ε_a ε_b = ε_{a+b} (δ dual): canonicalizes chain association"),
    Rule("opening-absorb", _p_absorb("dilate"), _g_absorb,
         _b_absorb("dilate"),
         "γ_s γ_t = γ_max(s,t): granulometry absorption (idempotence "
         "at s = t)"),
    Rule("closing-absorb", _p_absorb("erode"), _g_absorb,
         _b_absorb("erode"),
         "φ_s φ_t = φ_max(s,t): dual granulometry absorption"),
)

_EXTRA_RULES: list = []


def register_rule(rule: Rule) -> Rule:
    """Append a custom rule after the built-in catalog (extension
    point; see ``docs/OPTIMIZER.md`` for the exactness obligations).
    Clears the engine's memoized rewrites so the new rule applies to
    already-seen graphs."""
    if rule.name in rule_names():
        raise ValueError(f"rule {rule.name!r} already registered")
    _EXTRA_RULES.append(rule)
    from repro.opt import engine

    engine.clear_rewrite_cache()
    return rule


def active_rules() -> tuple:
    return DEFAULT_RULES + tuple(_EXTRA_RULES)


def rule_names() -> tuple:
    return tuple(r.name for r in active_rules())
