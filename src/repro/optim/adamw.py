"""AdamW with global-norm clipping and configurable moment dtype.

Pure pytree implementation (no optax dependency).  Optimizer states
inherit the parameter sharding (launch.sharding maps the same
PartitionSpec onto m/v), which with FSDP-sharded params gives
ZeRO-3-style fully sharded optimizer state.  ``state_dtype="bfloat16"``
halves the moment footprint (required for arctic-480b to fit —
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str | None = None      # None -> same as param
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(cfg: AdamWConfig, params) -> dict:
    def moment(p):
        dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(moment, params),
        "v": jax.tree.map(moment, params),
        "step": jnp.zeros((), jnp.int32),
    }


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (params, state, metrics)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        # (H3 in §Perf: a scan-over-slices variant was tried for the
        # huge stacked MoE leaves and REGRESSED memory by 15 GB/dev —
        # scan xs/ys defeat XLA's buffer aliasing; the monolithic
        # elementwise update lets XLA free each leaf's f32 transients
        # before the next leaf.)
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        u = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p32 = p.astype(jnp.float32) - lr * u
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
