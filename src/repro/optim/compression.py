"""int8 gradient compression with error feedback.

A distributed-optimization trick for collective-bound training: the
data-axis gradient reduction moves int8 + one f32 scale per tensor
instead of f32 — a ~3.9× cut of the reduce volume.  Error feedback
(residual carried in optimizer state) keeps the quantization unbiased
over time (Karimireddy et al. 2019).

Usage is explicit-DP: the train step computes per-shard gradients under
``shard_map``, quantizes, ``psum``s the int32-accumulated int8 payload,
then dequantizes — see train.steps.build_train_step(compress_grads=True).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g: jnp.ndarray, err: jnp.ndarray):
    """Returns (int8 payload, scale, new_error)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def psum_compressed(grads, errors, axis_name):
    """Quantize + reduce each gradient leaf over ``axis_name``.

    int8 payloads are accumulated in int32 (no overflow up to 2^24
    shards), scales are meaned; returns (mean grads f32, new errors).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, new_e = quantize(g, e)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale = jax.lax.pmean(scale, axis_name)
        return (acc.astype(jnp.float32) * scale / n).astype(jnp.float32), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
