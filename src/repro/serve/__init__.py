"""repro.serve — shape-bucketed micro-batching service for the geodesic
operators, with a compiled-plan cache and an async double-buffered
pipeline.

Mapping onto the paper's stream-processing pipeline (§3.6)
----------------------------------------------------------

The paper's CPU implementation reaches real-time throughput (>30 FPS on
1024×1024 frames through chains of up to 1500 elementary 3×3 filters)
by treating the operator chain as a *stream pipeline*: a run-time
topology examination picks the thread/window schedule, T elementary
filters stay in flight at once, row-window synchronized, and the
per-frame work is overlapped so the cores never idle between filters.
This package is the serving-side analogue of that pipeline for the
TPU/Pallas port, one stage per module:

``registry``
    The paper examines the machine topology at run time and schedules
    the chain accordingly; here every public operator of
    ``core.operators`` / ``kernels.ops`` is declared as data (string
    name + param schema + *expression builder* via their ``SERVE_OPS``
    hooks).  The registry lowers each expression through
    ``repro.api.lower`` and derives the prepare/run/finalize pipeline
    stages, pad fills and bucket identity mechanically from the lowered
    program; the per-bucket :class:`~repro.core.chain.ChainPlan` — the
    TPU analogue of that topology examination — is bound per compiled
    program by ``repro.api.compile``.
``bucketer``
    The paper feeds same-shaped row windows through a fixed pipeline;
    heterogeneous request traffic is coalesced into ``(N, H, W)``
    stacks per (run-signature, padded-shape, dtype) bucket — cross-op
    packing: different operators whose run phases compile identically
    (HMAX/DOME/RAOBJ) co-batch — with absorbing-identity padding (the
    kernels' own border contract) and a ``max_delay_ms`` deadline so
    stragglers never wait for co-batched traffic that may never arrive.
``cache``
    The paper amortizes schedule construction across the stream; the
    LRU compiled-program cache amortizes trace+compile across requests,
    keyed on ``Executable.key`` (lowered run signature + bucket shape/
    dtype/backend + plan key — the same identity the ``repro.api``
    compile cache uses), each entry carrying the ChainPlan it embeds.
``executor``
    The paper overlaps the filters of a chain across cores; the
    executor overlaps *host staging* of the next stack with *device
    compute* of the current one (JAX async dispatch, bounded in-flight
    depth = double buffering) and demuxes per-request results, cropping
    bucket padding and dropping sentinel slots.
``metrics``
    The paper reports FPS per operator chain; ``ServeMetrics`` reports
    per-bucket latency percentiles, batch occupancy, cache hit-rate and
    FPS / MPx-per-s in the same JSON schema as ``benchmarks/run.py
    --json``.

The convergence-driven operators routed through ``kernels.ops`` all run
on the shared active-tile requeue driver (``_drive_scheduler``; the
scheduler lifecycle and the ChainPlan contract it schedules against are
documented in ``docs/ARCHITECTURE.md``), so a converged image in a
served stack stops costing tile work while its batch-mates iterate —
the serving-level payoff of the paper's Alg. 4 requeue mechanism.

Fault-tolerant lifecycle (PR 7, full contract in ``docs/ROBUSTNESS.md``)
------------------------------------------------------------------------

``errors``
    the typed error taxonomy: admission rejections
    (:class:`RequestRejected` and subclasses, :class:`QueueFullError`)
    raised synchronously from ``submit``; execution outcomes
    (:class:`DeadlineExceededError`, :class:`ExecutorError`,
    :class:`PoisonedRequestError`) recorded on tickets — no
    unstructured exception escapes ``Service.poll()``.
``faults``
    the deterministic fault-injection harness (:class:`FaultInjector`,
    seeded via ``REPRO_FAULTS``) driving the chaos suite and the CI
    ``chaos`` job through the named sites
    dispatch/drain/poison/deadline/budget.

Event-driven engine (PR 9)
--------------------------

``loop``
    the deterministic timer core: :class:`EventLoop` (a pumped
    ``(when, seq)``-ordered callback heap on an injectable clock) and
    :class:`VirtualClock` — the seam that makes every flush, expiry,
    refill and backpressure decision replayable in tests
    (``tests/serve_sim.py``).
``continuous``
    continuous batching: :class:`SlotEngine` keeps a resident
    :class:`~repro.api.executable.SlotSession` per refillable bucket
    and refills slots the moment their image converges, while
    stragglers keep iterating (``Service(continuous=True)``).
``service.AsyncService``
    the asyncio front-end — service timers trampolined onto the
    running asyncio loop so deadline flushes fire with no caller, and
    tickets awaitable as futures.
"""
from repro.serve import errors, faults, registry
from repro.serve.bucketer import BucketKey, Ticket, bucket_hw, canonical_batch
from repro.serve.cache import CacheEntry, CompiledProgramCache
from repro.serve.continuous import SlotEngine
from repro.serve.errors import (DeadlineExceededError, ExecutorError,
                                InvalidRequestError, NonFiniteInputError,
                                PoisonedRequestError, QueueFullError,
                                RequestRejected, ServeError,
                                ServiceClosedError, UnsupportedDtypeError)
from repro.serve.executor import Executor
from repro.serve.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serve.loop import EventLoop, VirtualClock
from repro.serve.metrics import ServeMetrics
from repro.serve.service import AsyncService, Service, serve_stream

__all__ = [
    "AsyncService",
    "BucketKey",
    "CacheEntry",
    "CompiledProgramCache",
    "DeadlineExceededError",
    "EventLoop",
    "Executor",
    "ExecutorError",
    "FaultInjector",
    "FaultSpec",
    "InjectedFault",
    "InvalidRequestError",
    "NonFiniteInputError",
    "PoisonedRequestError",
    "QueueFullError",
    "RequestRejected",
    "ServeError",
    "ServeMetrics",
    "Service",
    "ServiceClosedError",
    "SlotEngine",
    "Ticket",
    "UnsupportedDtypeError",
    "VirtualClock",
    "bucket_hw",
    "canonical_batch",
    "errors",
    "faults",
    "registry",
    "serve_stream",
]
