"""Request queue + shape/dtype bucketer.

Incoming requests are coalesced per *bucket* so the executor can push
full ``(N, H, W)`` stacks through one compiled program:

* **bucket key** = (lowered run signature, padded (H, W), dtype) —
  cross-op packing: ops whose run phases compile identically co-batch
  regardless of op name (see :class:`BucketKey`).  For pad-safe ops the
  image shape is rounded up to ``pad_quantum`` multiples, so a 500×300
  and a 512×320 request share one compiled program; pad-unsafe ops get
  exact-shape buckets (still batched across same-shape requests).
* **batch canonicalization**: a flushed batch of n requests is padded
  with sentinel images to the next power of two ≤ ``max_batch``, so the
  handful of canonical batch shapes reuse compiled programs instead of
  recompiling per occupancy.  Sentinels are filled with the op's
  absorbing identity — under the active-tile requeue scheduler (see
  ``docs/ARCHITECTURE.md``) they converge in one chunk and stop costing
  work.
* **deadline flush**: every queue records its oldest enqueue time; the
  service launches a bucket when it reaches ``max_batch`` *or* its
  oldest request has waited ``max_delay_ms`` — a straggler request
  never waits longer than that for co-batched traffic that may never
  arrive.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import numpy as np

from repro.core import morphology as M
from repro.serve.errors import (NonFiniteInputError, UnsupportedDtypeError)


def pad_fill(dtype, which: str):
    """Absorbing fill value: "hi" = erosion identity, "lo" = dilation's
    (the lattice top/bottom already defined by ``core.morphology``)."""
    top = which == "hi"
    return np.asarray(M.lattice_top(dtype) if top else M.lattice_bottom(dtype))


def check_payload(op: str, images) -> None:
    """Admission gate between user payloads and the absorbing pad fills.

    The bucket staging pads every request with lattice identities —
    which for floating dtypes are **±Inf**.  A payload that itself
    contains NaN/±Inf is therefore indistinguishable from padding once
    staged: the kernels would absorb it silently and the demuxed result
    would be garbage while still *looking* bit-exact.  Instead of
    coercing, admission rejects such payloads with a typed error; dtypes
    outside the lattice (no min/max identity) are rejected likewise.
    """
    for im in images:
        kind = np.dtype(im.dtype).kind
        if kind not in "uif":
            raise UnsupportedDtypeError(
                f"op {op!r}: dtype {im.dtype} has no lattice identity "
                "(integer and floating dtypes only)"
            )
        if kind == "f" and not np.isfinite(im).all():
            raise NonFiniteInputError(
                f"op {op!r}: input contains NaN/Inf, which collides with "
                "the absorbing pad fills (float lattice identities are "
                "±Inf) — sanitize the payload before submitting"
            )


def bucket_hw(h: int, w: int, quantum: int) -> tuple[int, int]:
    """Round a shape up to the bucket grid."""
    q = max(1, quantum)
    return (math.ceil(h / q) * q, math.ceil(w / q) * q)


def canonical_batch(n: int, max_batch: int) -> int:
    """Next power of two >= n, capped at max_batch."""
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


class BucketKey(NamedTuple):
    """Bucket identity: the lowered *run signature* + padded shape +
    dtype.  Keying on the run signature instead of the op name is what
    lets different ops with identical compiled run phases (HMAX / DOME
    / RAOBJ — all one dilate-reconstruction) co-batch; params that only
    affect prepare/finalize (e.g. HMAX's ``h``) never split buckets."""

    sig: tuple             # run-phase signature (registry.RunInfo.sig)
    hw: tuple[int, int]    # bucket (H, W) after canonicalization
    dtype: str
    tag: str               # human label for the run phase (derived)

    def label(self) -> str:
        """Human/metrics-facing name for this bucket."""
        return f"{self.tag}/{self.hw[0]}x{self.hw[1]}/{self.dtype}"


@dataclasses.dataclass
class Ticket:
    """Per-request handle, fulfilled by the executor's demux.

    Typed outcome surface: exactly one of ``value``/``error`` is set
    once ``done``; ``error`` is always a
    :class:`~repro.serve.errors.ServeError` subclass (the lifecycle
    guarantees no unstructured exception reaches a ticket).
    ``degraded`` marks a *successful* result whose convergence watchdog
    tripped — the value is a partial fixpoint (see the degraded-mode
    contract in ``docs/ROBUSTNESS.md``).  ``deadline`` is the absolute
    monotonic time after which the request is shed instead of served.
    """

    request_id: int
    op: str
    t_enqueue: float
    done: bool = False
    value: Any = None
    error: Exception | None = None
    degraded: bool = False
    deadline: float | None = None
    t_done: float = 0.0
    _service: Any = dataclasses.field(default=None, repr=False)
    _bucket_key: Any = dataclasses.field(default=None, repr=False)
    _queued: bool = dataclasses.field(default=False, repr=False)
    _done_cbs: list = dataclasses.field(default_factory=list, repr=False)

    def _fulfill(self, now: float) -> None:
        """Mark the ticket done (exactly once) and fire completion
        callbacks — the single terminal transition every lifecycle path
        (demux, recovery, expiry, shed) goes through, which is what
        lets the async front-end resolve futures and the property suite
        assert exactly-one-terminal-outcome."""
        if self.done:
            return
        self.done = True
        self.t_done = now
        cbs, self._done_cbs = list(self._done_cbs), []
        for cb in cbs:
            cb(self)

    def add_done_callback(self, cb) -> None:
        """Call ``cb(ticket)`` when the ticket reaches its terminal
        outcome (immediately if already done).  Callbacks run on the
        thread that completes the ticket — the single service thread
        or the asyncio loop pumping it."""
        if self.done:
            cb(self)
        else:
            self._done_cbs.append(cb)

    def result(self):
        """The request's output; drives the service forward if needed."""
        if not self.done and self._service is not None:
            self._service._complete(self)
        if self.error is not None:
            raise self.error
        if not self.done:
            raise RuntimeError(
                f"request {self.request_id} ({self.op}) not completed — "
                "call Service.flush() or poll()"
            )
        return self.value

    @property
    def outcome(self) -> str:
        """Stable slug for the request's lifecycle outcome: ``pending``,
        ``ok``, ``degraded``, or the typed error's ``code``."""
        if not self.done:
            return "pending"
        if self.error is not None:
            return getattr(self.error, "code", "error")
        return "degraded" if self.degraded else "ok"


@dataclasses.dataclass
class PendingRequest:
    """A submitted request staged in a bucket queue.

    Requests in one bucket may come from *different ops* (cross-op
    packing), so everything per-op rides on the request: the staging
    info derived from its lowered program and its finalize callable.
    """

    ticket: Ticket
    images: tuple           # original user images (np, unpadded)
    inputs: tuple           # canonical inputs after prepare (unpadded)
    shape: tuple[int, int]  # original (H, W) for the demux crop
    info: Any = None        # registry.RunInfo (staging/bucket identity)
    finalize: Any = None    # (outputs, images) -> outputs, or None
    poisoned: bool = False  # fault harness: this request kills its batch
    timer: Any = None       # armed expiry TimerHandle, cancelled at launch


class BucketQueue:
    """FIFO queues per bucket key with deadline accounting."""

    def __init__(self, max_batch: int, max_delay_s: float):
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self._queues: dict[BucketKey, list[PendingRequest]] = {}

    def add(self, key: BucketKey, req: PendingRequest) -> bool:
        """Enqueue; True when the bucket just reached ``max_batch``."""
        q = self._queues.setdefault(key, [])
        q.append(req)
        return len(q) >= self.max_batch

    def pop(self, key: BucketKey,
            limit: int | None = None) -> list[PendingRequest]:
        """Dequeue up to ``limit`` (default ``max_batch``) oldest
        requests of a bucket."""
        cap = self.max_batch if limit is None else limit
        q = self._queues.get(key, [])
        batch, rest = q[:cap], q[cap:]
        if rest:
            self._queues[key] = rest
        else:
            self._queues.pop(key, None)
        return batch

    def size(self, key: BucketKey) -> int:
        return len(self._queues.get(key, ()))

    def oldest(self, key: BucketKey) -> PendingRequest | None:
        q = self._queues.get(key)
        return q[0] if q else None

    def discard(self, key: BucketKey, req: PendingRequest) -> bool:
        """Remove one specific queued request (deadline expiry firing
        from a timer while the request still sits in its bucket)."""
        q = self._queues.get(key)
        if not q:
            return False
        try:
            q.remove(req)
        except ValueError:
            return False
        if not q:
            self._queues.pop(key, None)
        return True

    def due(self, now: float) -> list[BucketKey]:
        """Buckets whose oldest request has exceeded the flush deadline."""
        return [
            key for key, q in self._queues.items()
            if q and now - q[0].ticket.t_enqueue >= self.max_delay_s
        ]

    def keys(self) -> list[BucketKey]:
        return [k for k, q in self._queues.items() if q]

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())
