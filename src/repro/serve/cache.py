"""Compiled-program cache: LRU over jitted bucket programs.

Each entry wraps the callable compiled for one bucket program together
with the :class:`~repro.core.chain.ChainPlan` it embeds
(``entry.plan.key`` exposes it for introspection/metrics).  Keys are
``Executable.key`` — lowered run signature + bucket shape/dtype/backend
+ plan key, the same identity the ``repro.api`` compile cache uses —
so the serve cache key and the compile key are one object; custom
(non-expression) OpSpecs key on (name, params) instead.  Eviction is
least-recently-used; ``warm`` prefill builds entries without counting
toward the hit/miss statistics so steady-state hit-rate stays
meaningful.

The ChainPlan fields that make up ``plan.key`` — i.e. exactly what a
compiled schedule is identified by — are documented in
``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import collections
from typing import Any, NamedTuple


class CacheEntry(NamedTuple):
    fn: Any              # the jitted batched program
    plan: Any            # ChainPlan the program embeds (None for pure-XLA ops)
    key: tuple
    #: stats-returning variant ``(inputs) -> (outputs, (N,) converged)``
    #: (``Executable.run_batch_stats``); None for custom OpSpecs, whose
    #: hand-written run exposes no convergence watchdog.
    stats_fn: Any = None
    #: the underlying ``api.Executable`` for expression ops — the
    #: continuous engine asks it for a ``slot_session`` (refillable
    #: resumable scheduler); None for custom OpSpecs.
    exe: Any = None

    def primary(self):
        """The callable the executor dispatches (and warmup executes):
        the stats variant when the program has one, else ``fn``."""
        return self.stats_fn if self.stats_fn is not None else self.fn


class CompiledProgramCache:
    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._entries: collections.OrderedDict[tuple, CacheEntry] = (
            collections.OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.warm_builds = 0

    def get(self, key: tuple, builder) -> CacheEntry:
        """Look up, counting a hit/miss; ``builder()`` fills on miss."""
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return entry
        self.misses += 1
        return self._insert(key, builder)

    def warm(self, key: tuple, builder) -> CacheEntry:
        """Prefill an entry (no hit/miss accounting)."""
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            return entry
        self.warm_builds += 1
        return self._insert(key, builder)

    def _insert(self, key: tuple, builder) -> CacheEntry:
        entry = builder()
        if not isinstance(entry, CacheEntry):
            entry = CacheEntry(fn=entry, plan=None, key=key)
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return entry

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "warm_builds": self.warm_builds,
            "hit_rate": self.hits / total if total else 0.0,
        }

    def entries(self) -> list[CacheEntry]:
        """Resident entries, LRU-first (introspection/tests)."""
        return list(self._entries.values())

    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)
