"""Continuous batching: refill converged slots mid-flight.

The batch executor retires a bucket only when *every* image in the
batch has converged — under the requeue scheduler a batch of mixed
images runs at the speed of its slowest member, and every early
finisher parks as dead capacity until the straggler lands.  The
:class:`SlotEngine` removes that coupling: it owns one resident
:class:`~repro.api.executable.SlotSession` per bucket (a persistent
padded device stack whose row blocks are independent images), advances
it in *rounds* of ``refill_quantum`` scheduler chunks, and the moment
the per-image converged vector marks a slot finished it harvests that
slot and admits the next queued request into it — while the other
slots keep iterating.

Correctness leans on two established invariants:

* **per-slot independence** — the plan pins band halos inside each
  image's row block, so one slot's values never leak into another's,
  and a slot admitted mid-flight starts from exactly the state a solo
  run would stage (same absorbing pads, all-active rows, zero chunk
  counter).  Harvested outputs are therefore bit-exact with solo
  execution (asserted by ``tests/test_serve_async.py``).
* **budget truncation** — each slot carries the same per-image chunk
  budget a solo run compiles with; a budget-cut slot is harvested as a
  degraded partial fixpoint identical to a solo run truncated at the
  same budget (``Ticket.degraded``), so the watchdog contract survives
  refill.

Fault sites thread through the same grammar as the batch path
(``serve/faults.py``): ``dispatch`` fires per admit wave, ``drain``
per round, and a ``poison``-marked occupant kills its *session* — the
engine evicts every occupant into the executor's recovery ladder
(retry → bisect quarantine), which isolates the poisoned request and
re-runs the healthy ones bit-exactly, then re-initializes the session
state.  Faults arriving mid-refill (after some harvests) therefore
never corrupt later occupants.  No exception escapes
:meth:`SlotEngine.step`.

Accounting: each round reports ``busy/total`` slots plus the
chunk-counter deltas (``busy_chunks``/``cap_chunks``) to
``ServeMetrics.record_round`` — the time-weighted occupancy and the
chunk-weighted ``work_occupancy`` the batch fill counter cannot
express — and every admit into a session that already has live
occupants bumps the ``refills`` counter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import faults as F
from repro.serve.bucketer import BucketKey, pad_fill


class SlotEngine:
    """Resident continuous-batching session for one bucket key."""

    def __init__(self, service, key: BucketKey, info, entry):
        self.service = service
        self.key = key
        self.info = info
        self.entry = entry
        self.session = entry.exe.slot_session(service.refill_quantum)
        self.state = None                       # lazy: built on first admit
        self.slots: list = [None] * self.session.n_slots
        self._t_admit = [0.0] * self.session.n_slots
        self._prev_chunks = np.zeros(self.session.n_slots, np.int64)
        self.rounds = 0

    # -- occupancy ---------------------------------------------------------

    @property
    def n_occupied(self) -> int:
        return sum(1 for r in self.slots if r is not None)

    @property
    def occupied(self) -> bool:
        return any(r is not None for r in self.slots)

    # -- admission ---------------------------------------------------------

    def pull(self) -> int:
        """Admit queued requests into free slots; returns how many.

        Pops only what fits (surplus stays queued with its expiry
        timers intact) and sheds expired requests *after* the pop —
        this runs post-compile, so a deadline that lapsed during
        trace/compile is caught here instead of being dispatched (the
        race the poll-only check had).
        """
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free:
            return 0
        svc = self.service
        batch = svc._queue.pop(self.key, limit=len(free))
        if not batch:
            return 0
        for req in batch:
            req.ticket._queued = False
            if req.timer is not None:
                req.timer.cancel()
                req.timer = None
        batch = svc._shed_expired(batch)
        if not batch:
            return 0
        return self._admit(batch, free)

    def _admit(self, batch, free) -> int:
        svc = self.service
        if self.state is None:
            self.state = self.session.init()
        try:
            svc.faults.check("dispatch", self.key.label())
        except Exception as exc:
            runner = functools.partial(svc._run_sync, self.key, self.info)
            svc.executor.recover(self.key, batch, runner, exc)
            return 0
        refill = self.occupied  # others still iterating → these are refills
        for req, slot in zip(batch, free):
            self.state = self.session.admit(
                self.state, slot, *self._staged(req))
            self.slots[slot] = req
            self._t_admit[slot] = svc.clock()
            self._prev_chunks[slot] = 0  # admit re-arms the slot counter
            if refill:
                svc.metrics.count("refills")
        return len(batch)

    def _staged(self, req):
        """Pad one request's canonical inputs to the bucket (H, W) with
        the program's absorbing fills — byte-identical to the slice of
        the batch path's ``_stage`` stack this request would occupy."""
        h, w = self.key.hw
        dtype = np.dtype(self.key.dtype)
        rh, rw = req.shape
        out = []
        for j in range(self.info.n_inputs):
            buf = np.full((h, w), pad_fill(dtype, self.info.fills[j]), dtype)
            buf[:rh, :rw] = np.asarray(req.inputs[j])
            out.append(jnp.asarray(buf))
        return out

    # -- rounds ------------------------------------------------------------

    def step(self) -> bool:
        """One scheduler round: advance every occupied slot by up to
        ``refill_quantum`` chunks, harvest finished slots, refill from
        the queue.  Returns True when any work happened; never raises
        (failures evict the session into the recovery ladder)."""
        occupied = [i for i, r in enumerate(self.slots) if r is not None]
        if not occupied:
            return False
        svc = self.service
        try:
            for i in occupied:
                if self.slots[i].poisoned:
                    raise F.InjectedFault(
                        "poison",
                        f"request {self.slots[i].ticket.request_id}")
            self.state, finished, exhausted = self.session.round(self.state)
            svc.faults.check("drain", self.key.label())
            jax.block_until_ready(self.state)
        except Exception as exc:
            self._fail_session(exc)
            return True
        self.rounds += 1
        # chunk-weighted utilization: counter deltas are exactly the
        # chunks each slot ran this round; the device was held for the
        # longest slot's chunks across every slot
        chunks = np.asarray(self.session.chunks_of(self.state),
                            dtype=np.int64)
        delta = chunks - self._prev_chunks
        self._prev_chunks = chunks
        svc.metrics.record_round(self.key.label(), n_busy=len(occupied),
                                 n_slots=self.session.n_slots,
                                 t=svc.clock(),
                                 busy_chunks=int(delta.sum()),
                                 cap_chunks=(int(delta.max())
                                             * self.session.n_slots))
        fin = np.asarray(finished)
        exh = np.asarray(exhausted)
        done = [i for i in occupied if fin[i]]
        if done:
            self._harvest(done, exh)
        self.pull()
        return True

    def _harvest(self, done, exh) -> None:
        """Deliver finished slots through the executor's demux (crop to
        request shape, finalize, fulfill) and free them."""
        svc = self.service
        outputs = self.session.extract(self.state)
        outs = tuple(np.asarray(o)[done] for o in outputs)
        conv = ~exh[done]  # exhausted slot → degraded partial fixpoint
        requests = [self.slots[i] for i in done]
        t0 = min(self._t_admit[i] for i in done)
        svc.executor._demux(self.key, requests, len(done), outs, conv,
                            t_dispatch=t0)
        for i in done:
            self.slots[i] = None  # parked: no active rows → zero cost

    def _fail_session(self, exc: Exception) -> None:
        """A round failed (injected or real): evict every occupant into
        the recovery ladder and reset the session state.  Retry re-runs
        the eviction as a solo batch; bisect isolates poisoned
        requests while healthy occupants complete bit-exactly."""
        svc = self.service
        evicted = [r for r in self.slots if r is not None]
        self.slots = [None] * self.session.n_slots
        self.state = self.session.init()
        self._prev_chunks[:] = 0
        runner = functools.partial(svc._run_sync, self.key, self.info)
        svc.executor.recover(self.key, evicted, runner, exc)
