"""Structured error model for the serving lifecycle.

Every way a request can fail is a *typed* outcome raised (admission) or
recorded on the ticket (execution), so callers can branch on error
class/``code`` instead of parsing tracebacks, and so the service can
guarantee its core robustness contract: **no unstructured exception
escapes ``Service.poll()``** — a failing batch resolves into per-request
typed errors while healthy co-batched requests still complete.

Taxonomy (see ``docs/ROBUSTNESS.md`` for the full contract):

admission-time (raised synchronously from ``Service.submit``)
    :class:`InvalidRequestError`
        malformed request: wrong arity, ragged shapes/dtypes, non-2-D
        images.  Subclasses :class:`ValueError` so pre-existing callers
        keep working.
    :class:`UnsupportedDtypeError`
        dtype outside the lattice the kernels define identities for
        (integer and floating dtypes only).
    :class:`NonFiniteInputError`
        a floating-point payload containing NaN/±Inf — these collide
        with the absorbing pad fills (±Inf *are* the float lattice
        identities), so downstream bit-exactness would silently break.
    :class:`QueueFullError`
        admission control: the service's bounded queue is full and the
        request is load-shed instead of growing the backlog.

execution-time (recorded on ``Ticket.error``, raised by ``result()``)
    :class:`DeadlineExceededError`
        the request's deadline expired while it was still queued; it is
        shed at launch instead of wasting device time.
    :class:`ExecutorError`
        a batch kept failing after the executor's retry budget; wraps
        the underlying cause (``cause`` attribute).
    :class:`PoisonedRequestError`
        quarantine outcome: bisect-retry isolated *this* request as the
        one that keeps killing its batch.  Healthy co-batched requests
        are re-run and complete normally.

Partial convergence (the scheduler watchdog hitting its chunk budget)
is deliberately **not** an error: the partial result is returned with
``Ticket.degraded = True`` (see the degraded-mode contract in
``docs/ROBUSTNESS.md``).
"""
from __future__ import annotations


class ServeError(Exception):
    """Base of every typed serving error; ``code`` is a stable,
    machine-readable slug (mirrored by the metrics counters)."""

    code = "serve_error"


class RequestRejected(ServeError, ValueError):
    """Admission-time rejection: the request never entered a bucket.

    Subclasses :class:`ValueError` because the pre-robustness service
    raised plain ``ValueError`` for malformed requests.
    """

    code = "rejected"


class InvalidRequestError(RequestRejected):
    """Malformed request (arity, rank, ragged shape/dtype)."""

    code = "invalid"


class UnsupportedDtypeError(RequestRejected):
    """Dtype has no lattice identity (not integer/floating)."""

    code = "unsupported_dtype"


class NonFiniteInputError(RequestRejected):
    """Float payload contains NaN/±Inf, which would be
    indistinguishable from the absorbing pad fills downstream."""

    code = "non_finite"


class QueueFullError(ServeError):
    """Load shedding: the bounded request queue is at capacity."""

    code = "shed"


class ServiceClosedError(ServeError):
    """The service was closed (``Service.close()`` /
    ``AsyncService.close()``); no new requests are admitted.  Requests
    already admitted at close time still drain to a terminal outcome."""

    code = "closed"


class DeadlineExceededError(ServeError):
    """The request's deadline expired before its bucket dispatched."""

    code = "deadline"


class ExecutorError(ServeError):
    """A batch failed and kept failing through the retry budget; the
    original exception is preserved on ``cause``."""

    code = "executor"

    def __init__(self, message: str, *, cause: BaseException | None = None):
        super().__init__(message)
        self.cause = cause
        if cause is not None:
            self.__cause__ = cause


class PoisonedRequestError(ExecutorError):
    """Bisect-retry isolated this request as the one poisoning its
    batch (every subset containing it failed; its siblings' subsets
    succeeded or were themselves isolated)."""

    code = "poisoned"
