"""Async double-buffered executor: overlap host staging with device
compute, demux per-request results.

JAX dispatch is asynchronous — calling a compiled program enqueues the
device work and returns device buffers immediately — so the pipeline
falls out of bounded in-flight tracking: the service stages (pads,
stacks, uploads) the *next* batch on the host while the device crunches
the current one, and the executor only blocks when ``depth`` batches
are already in flight (``depth=2`` is classic double buffering).

Draining a batch demuxes it: each real request slot is cropped back to
its original (H, W) (dropping the pad-to-bucket canonicalization), the
*request's own* finalize stage runs (requests in one bucket may come
from different ops under cross-op packing — e.g. DOME's ``f - hmax``
residual next to plain HMAX requests), the ticket is fulfilled, and
sentinel slots (batch padding up to the canonical size) are discarded.

Where this sits in the pipeline (registry → bucketer → cache →
executor) is mapped in ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import collections
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.serve.bucketer import BucketKey, PendingRequest
from repro.serve.metrics import ServeMetrics


class InflightBatch(NamedTuple):
    outputs: tuple           # device buffers, one per run output
    requests: list           # real PendingRequests (sentinel slots excluded)
    key: BucketKey
    n_slots: int
    t_dispatch: float


class Executor:
    def __init__(self, metrics: ServeMetrics, depth: int = 2,
                 clock=time.monotonic):
        if depth < 1:
            raise ValueError("pipeline depth must be >= 1")
        self.depth = depth
        self.metrics = metrics
        self.clock = clock
        self._inflight: collections.deque[InflightBatch] = collections.deque()

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def dispatch(self, entry, key: BucketKey,
                 requests: list[PendingRequest], n_slots: int,
                 stacked_inputs: tuple) -> None:
        """Launch one batch (async) and retire the oldest if the
        pipeline is full."""
        try:
            out = entry.fn(*stacked_inputs)
        except Exception as exc:
            # trace/compile failure: the requests are already out of the
            # queue, so resolve their tickets with the error instead of
            # stranding them, then surface it to the caller.
            self._fail_batch(requests, exc)
            raise
        outputs = out if isinstance(out, tuple) else (out,)
        self._inflight.append(InflightBatch(
            outputs=outputs, requests=requests, key=key,
            n_slots=n_slots, t_dispatch=self.clock(),
        ))
        while len(self._inflight) > self.depth:
            self.drain_one()

    def _fail_batch(self, requests, exc: Exception) -> None:
        now = self.clock()
        for req in requests:
            req.ticket.error = exc
            req.ticket.done = True
            req.ticket.t_done = now

    def drain_one(self) -> bool:
        """Block on the oldest in-flight batch and demux it."""
        if not self._inflight:
            return False
        batch = self._inflight.popleft()
        try:
            jax.block_until_ready(batch.outputs)
        except Exception as exc:  # async execution error surfaces here
            self._fail_batch(batch.requests, exc)
            now = self.clock()
            self.metrics.record_batch(
                batch.key.label(),
                n_real=len(batch.requests),
                n_slots=batch.n_slots,
                pixels=sum(h * w for h, w in
                           (r.shape for r in batch.requests)),
                t_dispatch=batch.t_dispatch,
                t_done=now,
                latencies_s=[now - r.ticket.t_enqueue
                             for r in batch.requests],
                n_errors=len(batch.requests),
            )
            return True
        now = self.clock()

        latencies = []
        pixels = 0
        n_errors = 0
        for slot, req in enumerate(batch.requests):
            h, w = req.shape
            cropped = tuple(o[slot, :h, :w] for o in batch.outputs)
            try:
                if req.finalize is not None:
                    cropped = tuple(req.finalize(
                        cropped, tuple(map(jnp.asarray, req.images))))
                # arity per request: co-batched ops share a run phase
                # but may fan their finalize into different output counts
                req.ticket.value = (
                    cropped[0] if req.info.n_outputs == 1 else cropped
                )
            except Exception as exc:  # surface per-request, keep serving
                req.ticket.error = exc
                n_errors += 1
            req.ticket.done = True
            req.ticket.t_done = now
            latencies.append(now - req.ticket.t_enqueue)
            pixels += h * w

        self.metrics.record_batch(
            batch.key.label(),
            n_real=len(batch.requests),
            n_slots=batch.n_slots,
            pixels=pixels,
            t_dispatch=batch.t_dispatch,
            t_done=now,
            latencies_s=latencies,
            n_errors=n_errors,
        )
        return True

    def drain_all(self) -> None:
        while self.drain_one():
            pass
