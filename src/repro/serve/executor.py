"""Async double-buffered executor: overlap host staging with device
compute, demux per-request results — and keep serving through failures.

JAX dispatch is asynchronous — calling a compiled program enqueues the
device work and returns device buffers immediately — so the pipeline
falls out of bounded in-flight tracking: the service stages (pads,
stacks, uploads) the *next* batch on the host while the device crunches
the current one, and the executor only blocks when ``depth`` batches
are already in flight (``depth=2`` is classic double buffering).

Draining a batch demuxes it: each real request slot is cropped back to
its original (H, W) (dropping the pad-to-bucket canonicalization), the
*request's own* finalize stage runs (requests in one bucket may come
from different ops under cross-op packing — e.g. DOME's ``f - hmax``
residual next to plain HMAX requests), the ticket is fulfilled, and
sentinel slots (batch padding up to the canonical size) are discarded.
Slots whose convergence watchdog tripped (the per-image vector from
``Executable.run_batch_stats``) are delivered with
``Ticket.degraded = True`` — partial convergence is a degraded result,
not an error.

Fault tolerance (the recovery ladder, ``docs/ROBUSTNESS.md``):

1. **retry with backoff** — a failed batch (trace, dispatch, or the
   asynchronous error surfacing at ``block_until_ready``) is re-run
   synchronously up to ``max_retries`` times via the service-provided
   ``runner`` closure; transient errors clear here and only cost a
   ``retried`` counter bump.
2. **bisect quarantine** — a batch that keeps failing is split in
   halves and each half re-run recursively, so a single poisoned
   request converges to a singleton that fails alone: *it* gets a typed
   :class:`~repro.serve.errors.PoisonedRequestError` while every
   healthy co-batched request completes bit-exactly (sub-batch
   execution is bit-exact by the bucketer's absorbing-pad/sentinel
   invariance).

No exception escapes the executor's public surface: every failure ends
as a typed error on the affected tickets.  Injected faults
(``serve/faults.py`` sites ``dispatch``/``drain``) enter exactly where
the real failures would.

Where this sits in the pipeline (registry → bucketer → cache →
executor) is mapped in ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import collections
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import faults as F
from repro.serve.bucketer import BucketKey, PendingRequest
from repro.serve.errors import ExecutorError, PoisonedRequestError
from repro.serve.metrics import ServeMetrics


class InflightBatch(NamedTuple):
    outputs: tuple           # device buffers, one per run output
    converged: Any           # (n_slots,) bool device buffer, or None
    requests: list           # real PendingRequests (sentinel slots excluded)
    key: BucketKey
    n_slots: int
    t_dispatch: float
    runner: Any              # sync re-execution closure (recovery ladder)
    util: Any = None         # (busy, cap) chunk-utilization scalars, or None


class Executor:
    def __init__(self, metrics: ServeMetrics, depth: int = 2,
                 clock=time.monotonic, faults: F.FaultInjector = F.NULL,
                 max_retries: int = 2, backoff_s: float = 0.0,
                 sleep=time.sleep):
        if depth < 1:
            raise ValueError("pipeline depth must be >= 1")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.depth = depth
        self.metrics = metrics
        self.clock = clock
        self.faults = faults
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.sleep = sleep
        self._inflight: collections.deque[InflightBatch] = collections.deque()

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    # -- dispatch ----------------------------------------------------------

    def dispatch(self, entry, key: BucketKey,
                 requests: list[PendingRequest], n_slots: int,
                 stacked_inputs: tuple, runner=None) -> None:
        """Launch one batch (async) and retire the oldest if the
        pipeline is full.  Never raises: a trace/compile failure at the
        call enters the recovery ladder instead."""
        try:
            outputs, conv, util = self._call_entry(entry, stacked_inputs)
        except Exception as exc:
            self.recover(key, requests, runner, exc)
            return
        self._inflight.append(InflightBatch(
            outputs=outputs, converged=conv, requests=requests, key=key,
            n_slots=n_slots, t_dispatch=self.clock(), runner=runner,
            util=util,
        ))
        while len(self._inflight) > self.depth:
            self.drain_one()

    @staticmethod
    def _call_entry(entry, stacked_inputs):
        """Run a cache entry's primary callable →
        ``(outputs, conv|None, util|None)`` where ``util`` is the
        ``(busy_chunks, cap_chunks)`` pair of ``run_batch_stats``."""
        if entry.stats_fn is not None:
            outputs, conv, busy, cap = entry.stats_fn(*stacked_inputs)
            return outputs, conv, (busy, cap)
        out = entry.fn(*stacked_inputs)
        return (out if isinstance(out, tuple) else (out,)), None, None

    # -- drain + demux -----------------------------------------------------

    def drain_one(self) -> bool:
        """Block on the oldest in-flight batch and demux it."""
        if not self._inflight:
            return False
        batch = self._inflight.popleft()
        try:
            self.faults.check("drain", batch.key.label())
            jax.block_until_ready((batch.outputs, batch.converged,
                                   batch.util))
        except Exception as exc:  # async execution error surfaces here
            self.recover(batch.key, batch.requests, batch.runner, exc)
            return True
        self._demux(batch.key, batch.requests, batch.n_slots,
                    batch.outputs, batch.converged, batch.t_dispatch,
                    util=batch.util)
        return True

    def drain_all(self) -> None:
        while self.drain_one():
            pass

    def _demux(self, key: BucketKey, requests, n_slots: int, outputs,
               converged, t_dispatch: float, util=None) -> None:
        """Crop, finalize and deliver per-request results (shared by the
        async drain path, the continuous engine's harvest, and the
        synchronous recovery re-runs)."""
        now = self.clock()
        conv = None if converged is None else np.asarray(converged)
        latencies = []
        pixels = 0
        n_errors = 0
        n_degraded = 0
        for slot, req in enumerate(requests):
            h, w = req.shape
            cropped = tuple(o[slot, :h, :w] for o in outputs)
            try:
                if req.finalize is not None:
                    cropped = tuple(req.finalize(
                        cropped, tuple(map(jnp.asarray, req.images))))
                # arity per request: co-batched ops share a run phase
                # but may fan their finalize into different output counts
                req.ticket.value = (
                    cropped[0] if req.info.n_outputs == 1 else cropped
                )
                if conv is not None and not conv[slot]:
                    req.ticket.degraded = True
                    n_degraded += 1
                    self.metrics.count("degraded")
            except Exception as exc:  # surface per-request, keep serving
                req.ticket.error = ExecutorError(
                    f"finalize failed for request {req.ticket.request_id} "
                    f"({req.ticket.op})", cause=exc)
                n_errors += 1
            req.ticket._fulfill(now)
            latencies.append(now - req.ticket.t_enqueue)
            pixels += h * w

        busy, cap = ((int(util[0]), int(util[1])) if util is not None
                     else (0, 0))
        self.metrics.record_batch(
            key.label(),
            n_real=len(requests),
            n_slots=n_slots,
            pixels=pixels,
            t_dispatch=t_dispatch,
            t_done=now,
            latencies_s=latencies,
            n_errors=n_errors,
            n_degraded=n_degraded,
            busy_chunks=busy,
            cap_chunks=cap,
        )
        return

    # -- recovery ladder: retry with backoff, then bisect quarantine -------

    def recover(self, key: BucketKey, requests, runner,
                exc: Exception) -> None:
        """A batch failed: retry whole, then bisect-quarantine.

        Every request ends with a typed outcome — value, degraded
        value, or :class:`PoisonedRequestError`/:class:`ExecutorError`
        — and nothing is raised to the caller.
        """
        self.metrics.count("batch_failures")
        if runner is None:
            # no re-execution path (direct executor use): typed failure
            self._fail_batch(requests, ExecutorError(
                f"batch {key.label()} failed with no runner to retry",
                cause=exc))
            return
        for attempt in range(self.max_retries):
            if self.backoff_s > 0.0:
                self.sleep(self.backoff_s * (2 ** attempt))
            self.metrics.count("retried")
            try:
                outputs, n_slots, conv = runner(requests)
            except Exception as exc2:
                exc = exc2
                continue
            self._demux(key, requests, n_slots, outputs, conv,
                        t_dispatch=self.clock())
            return
        self._quarantine(key, requests, runner, exc)

    def _quarantine(self, key: BucketKey, requests, runner,
                    cause: Exception) -> None:
        """Bisect-retry: isolate poisoned request(s) so healthy
        co-batched requests still complete bit-exactly."""
        if len(requests) == 1:
            req = requests[0]
            req.ticket.error = PoisonedRequestError(
                f"request {req.ticket.request_id} ({req.ticket.op}) "
                "poisoned its batch: every containing subset failed",
                cause=cause)
            req.ticket._fulfill(self.clock())
            self.metrics.count("poisoned")
            return
        mid = len(requests) // 2
        for part in (requests[:mid], requests[mid:]):
            try:
                outputs, n_slots, conv = runner(part)
            except Exception as exc:
                self._quarantine(key, part, runner, exc)
            else:
                self.metrics.count("quarantine_reruns")
                self._demux(key, part, n_slots, outputs, conv,
                            t_dispatch=self.clock())

    def _fail_batch(self, requests, exc: Exception) -> None:
        now = self.clock()
        for req in requests:
            req.ticket.error = exc
            req.ticket._fulfill(now)
