"""Deterministic fault-injection harness for the serving lifecycle.

The chaos suite (``tests/test_faults.py``) and the CI ``chaos`` job
need to provoke every failure path — executor exceptions, poisoned
co-batches, deadline pressure, exhausted convergence budgets — *and*
reproduce a failing run exactly.  So faults are injected at **named
sites** by a seeded :class:`FaultInjector` the service consults at
each site; the whole schedule is a pure function of the spec string
and seed.

Sites (checked where the real failure would originate):

``dispatch``
    raise :class:`InjectedFault` in ``Service._launch`` just before the
    batch is handed to the executor — models a trace/compile/launch
    failure.  Fires per *batch*.
``drain``
    raise in ``Executor.drain_one`` before ``jax.block_until_ready`` —
    models an asynchronous device-side execution failure.  Fires per
    *batch*.
``poison``
    mark a submitted request as poisoned (fires per *request*); any
    batch execution whose requests include a poisoned one raises
    :class:`InjectedFault` deterministically, which is exactly the
    semantics the executor's bisect-retry quarantine needs to isolate
    it.
``deadline``
    deadline pressure: override the request's deadline with ``value``
    milliseconds (fires per request), so it expires while queued.
``budget``
    non-convergence pressure: compile bucket programs with
    ``max_chunks=value`` so the scheduler watchdog trips and results
    come back degraded (``value`` is part of ``Executable.key``, so
    injected and clean programs never share a cache entry).

Spec grammar (also accepted from the ``REPRO_FAULTS`` environment
variable, e.g. in the CI chaos job)::

    REPRO_FAULTS="seed=1702;dispatch:p=0.2,n=2;poison:p=0.1;budget:value=1"

``;``-separated clauses; ``seed=<int>`` fixes the RNG, every other
clause is ``site[:key=value,...]`` with keys ``n`` (max fires, 0 =
unlimited), ``p`` (per-opportunity probability) and ``value``
(site-specific payload).  Services built without an explicit
``faults=`` injector pick up the environment via :func:`from_env`;
:data:`NULL` never fires.
"""
from __future__ import annotations

import collections
import dataclasses
import os

import numpy as np

from repro.serve.errors import ServeError

#: Every site the service consults, in lifecycle order.
SITES = ("dispatch", "drain", "poison", "deadline", "budget")


class InjectedFault(RuntimeError):
    """The injected failure itself.

    Deliberately *not* a :class:`~repro.serve.errors.ServeError`: it
    models an unstructured backend/kernel failure, and the whole point
    of the chaos suite is asserting the service converts it into typed
    per-request outcomes.
    """

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at site {site!r}"
                         + (f": {detail}" if detail else ""))
        self.site = site


class FaultSpecError(ServeError):
    """A malformed fault spec string (bad site/key/number)."""

    code = "fault_spec"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed site: fire up to ``n`` times (0 = unlimited), each
    opportunity with probability ``p``; ``value`` is the site payload
    (budget's ``max_chunks``, deadline's milliseconds)."""

    site: str
    n: int = 0
    p: float = 1.0
    value: float | None = None

    def __post_init__(self):
        if self.site not in SITES:
            raise FaultSpecError(
                f"unknown fault site {self.site!r}; sites: {', '.join(SITES)}"
            )
        if self.n < 0:
            raise FaultSpecError(f"site {self.site!r}: n must be >= 0")
        if not 0.0 <= self.p <= 1.0:
            raise FaultSpecError(f"site {self.site!r}: p must be in [0, 1]")


class FaultInjector:
    """Seeded, replayable fault schedule over the named sites.

    Decision order is the order sites are consulted at run time, so a
    given (spec, seed, request stream) always injects the same faults.
    ``fired`` counts injections per site (surfaced by
    ``Service.stats()['faults']``).
    """

    def __init__(self, specs=(), seed: int = 0):
        self.specs: dict[str, FaultSpec] = {}
        for spec in specs:
            if spec.site in self.specs:
                raise FaultSpecError(f"duplicate fault site {spec.site!r}")
            self.specs[spec.site] = spec
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self.fired: collections.Counter = collections.Counter()

    def armed(self, site: str) -> bool:
        return site in self.specs

    def should_fire(self, site: str) -> bool:
        """Consume one opportunity at ``site``; True iff it injects."""
        spec = self.specs.get(site)
        if spec is None:
            return False
        if spec.n and self.fired[site] >= spec.n:
            return False
        if spec.p < 1.0 and self._rng.random() >= spec.p:
            return False
        self.fired[site] += 1
        return True

    def check(self, site: str, detail: str = "") -> None:
        """Raise :class:`InjectedFault` iff ``site`` fires now."""
        if self.should_fire(site):
            raise InjectedFault(site, detail)

    def value(self, site: str, default=None):
        """The armed site's payload (no fire accounting) — used for
        *pressure* sites (budget) whose effect must be stable across
        every compile of the same bucket."""
        spec = self.specs.get(site)
        return default if spec is None or spec.value is None else spec.value

    def snapshot(self) -> dict:
        """JSON-serializable view: armed sites + per-site fire counts."""
        return {
            "seed": self.seed,
            "armed": sorted(self.specs),
            "fired": {k: int(v) for k, v in sorted(self.fired.items())},
        }

    def __repr__(self):
        return (f"FaultInjector(seed={self.seed}, "
                f"sites={sorted(self.specs)}, fired={dict(self.fired)})")


#: Injector with no armed sites — every check is a no-op.
NULL = FaultInjector()


def parse(text: str) -> FaultInjector:
    """Parse the ``REPRO_FAULTS`` grammar into an injector."""
    seed = 0
    specs = []
    for clause in filter(None, (c.strip() for c in text.split(";"))):
        if clause.startswith("seed="):
            try:
                seed = int(clause[5:])
            except ValueError:
                raise FaultSpecError(f"bad seed clause {clause!r}") from None
            continue
        site, _, rest = clause.partition(":")
        kwargs: dict = {}
        for kv in filter(None, (p.strip() for p in rest.split(","))):
            key, eq, raw = kv.partition("=")
            if not eq or key not in ("n", "p", "value"):
                raise FaultSpecError(
                    f"bad fault option {kv!r} in clause {clause!r} "
                    "(keys: n, p, value)"
                )
            try:
                kwargs[key] = int(raw) if key == "n" else float(raw)
            except ValueError:
                raise FaultSpecError(
                    f"bad number {raw!r} in clause {clause!r}"
                ) from None
        specs.append(FaultSpec(site=site.strip(), **kwargs))
    return FaultInjector(specs, seed=seed)


def from_env(environ=os.environ) -> FaultInjector:
    """Injector from ``REPRO_FAULTS``; :data:`NULL` when unset/empty."""
    text = environ.get("REPRO_FAULTS", "").strip()
    return parse(text) if text else NULL
