"""Deterministic timer loop + injectable clocks for the serving engine.

The event-driven :class:`~repro.serve.service.Service` never reads
``time.monotonic()`` directly: every timestamp comes from an injectable
*clock* (any zero-arg callable returning monotonic seconds) and every
deferred action — bucket deadline flushes, per-request expiry — is a
*timer* on an :class:`EventLoop`.  That seam is what makes the engine
testable: under a :class:`VirtualClock` plus manual ``run_due()``
pumping (the stepped-loop driver in ``tests/serve_sim.py``) every
flush, expiry, refill and backpressure decision replays identically,
while the asyncio front-end (``service.AsyncService``) arms the same
timers on a real ``asyncio`` loop so they fire without any caller.

The loop is intentionally *not* a thread or an asyncio loop itself —
it is a heap of ``(when, seq)``-ordered callbacks fired by whoever
pumps it (``Service.submit``/``poll``/``pump`` in cooperative use, an
asyncio ``call_at`` trampoline in async use).  Determinism contract:
timers due at the same instant fire in arming order (``seq``), and
``run_due`` uses one clock reading per pump so a callback arming a
same-instant timer cannot starve the pump.
"""
from __future__ import annotations

import heapq
import time
from typing import Any, Callable


class VirtualClock:
    """A manually advanced monotonic clock (seconds).

    The test half of the virtual-clock harness: inject one of these as
    ``Service(clock=...)`` and drive time explicitly with
    :meth:`advance`.  Calling the instance reads the current time, so
    it is a drop-in for ``time.monotonic``.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds (monotonic: dt >= 0)."""
        if dt < 0:
            raise ValueError("a monotonic clock cannot go backwards")
        self._now += float(dt)
        return self._now


class TimerHandle:
    """A cancellable timer armed on an :class:`EventLoop`."""

    __slots__ = ("when", "seq", "callback", "cancelled")

    def __init__(self, when: float, seq: int, callback: Callable[[], Any]):
        self.when = when
        self.seq = seq
        self.callback = callback
        self.cancelled = False

    def cancel(self) -> None:
        """Mark the timer dead; the loop drops it lazily."""
        self.cancelled = True
        self.callback = None

    def __repr__(self):
        state = "cancelled" if self.cancelled else f"t={self.when:.6f}"
        return f"TimerHandle({state}, seq={self.seq})"


class EventLoop:
    """Single-threaded deterministic timer heap.

    ``call_at``/``call_later`` arm callbacks; ``run_due()`` fires every
    timer whose deadline has passed on the injected clock, in strict
    ``(when, seq)`` order.  Nothing fires spontaneously — the loop is
    pumped by its owner — which is exactly what the deterministic test
    harness needs, and the asyncio adapter turns ``next_deadline()``
    into real wakeups.
    """

    def __init__(self, clock: Callable[[], float] | None = None):
        self.clock = clock if clock is not None else time.monotonic
        self._heap: list[tuple[float, int, TimerHandle]] = []
        self._seq = 0

    def now(self) -> float:
        return self.clock()

    def call_at(self, when: float, callback: Callable[[], Any]) -> TimerHandle:
        """Arm ``callback`` to fire once ``clock() >= when``."""
        handle = TimerHandle(float(when), self._seq, callback)
        self._seq += 1
        heapq.heappush(self._heap, (handle.when, handle.seq, handle))
        return handle

    def call_later(self, delay: float,
                   callback: Callable[[], Any]) -> TimerHandle:
        return self.call_at(self.clock() + delay, callback)

    def run_due(self) -> int:
        """Fire every timer due *now*; returns how many fired.

        The clock is read once, so callbacks arming new timers at or
        before the same instant fire on the *next* pump — a same-time
        re-arm cannot loop this call forever.
        """
        now = self.clock()
        fired = 0
        due: list[TimerHandle] = []
        while self._heap and self._heap[0][0] <= now:
            _, _, handle = heapq.heappop(self._heap)
            if not handle.cancelled:
                due.append(handle)
        for handle in due:  # (when, seq) order by heap extraction
            callback, handle.callback = handle.callback, None
            if callback is not None:
                callback()
                fired += 1
        return fired

    def next_deadline(self) -> float | None:
        """Earliest armed (uncancelled) timer, or None when idle."""
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def pending(self) -> int:
        """Number of live timers (introspection/tests)."""
        return sum(1 for _, _, h in self._heap if not h.cancelled)
