"""Serving metrics: per-bucket latency percentiles, batch occupancy,
throughput (FPS / MPx-per-s) and cache statistics.

Throughput is measured over the *wall span* of each bucket (first
dispatch → last drain), not the sum of per-batch intervals — with the
double-buffered executor those intervals overlap, and summing them
would understate FPS exactly when the pipelining works.  Latency
percentiles are computed over a bounded window of the most recent
``LATENCY_WINDOW`` requests per bucket, so a long-running service keeps
O(1) memory per bucket while ``requests`` counts the full history.

``bench_rows()`` / ``as_bench_json()`` emit the same row contract as
``benchmarks/run.py`` (``name,us_per_call,derived`` rows and the
``--json`` name → us_per_call mapping), so serving throughput lands in
the same machine-readable perf trajectory as the kernel benchmarks.
Every emitted field is documented in ``docs/BENCHMARKS.md``.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

#: Most recent per-bucket request latencies retained for percentiles.
LATENCY_WINDOW = 4096

#: Lifecycle counters always present in ``summary()["counters"]`` (and
#: as ``serve/counters/*`` rows), so the benchmarks JSON schema is
#: stable whether or not faults occurred.  Semantics (full contract in
#: ``docs/ROBUSTNESS.md``):
#:   rejected    admission-time typed rejections (invalid/non-finite/
#:               unsupported dtype/unknown op)
#:   shed        requests load-shed because the bounded queue was full
#:   expired     requests whose deadline passed while queued (shed at
#:               launch with DeadlineExceededError)
#:   retried     whole-batch retry attempts after an executor failure
#:   poisoned    requests isolated by bisect-retry quarantine
#:   degraded    requests whose convergence watchdog tripped (partial
#:               result returned, Ticket.degraded = True)
#:   batch_failures    batches whose first execution failed
#:   quarantine_reruns successful sub-batch re-executions during bisect
#:   rewrites_applied  optimizer rule applications behind admitted
#:                     requests (``repro.opt``; 0 for already-canonical
#:                     graphs)
#:   programs_shared   times a distinct source graph joined an
#:                     already-compiled program identity (rewrite
#:                     canonicalization or run-signature co-batching)
COUNTERS = ("rejected", "shed", "expired", "retried", "poisoned",
            "degraded", "batch_failures", "quarantine_reruns",
            "rewrites_applied", "programs_shared")


@dataclasses.dataclass
class _BucketStats:
    requests: int = 0
    batches: int = 0
    slots: int = 0
    pixels: int = 0
    errors: int = 0
    degraded: int = 0
    t_first: float | None = None   # earliest dispatch seen
    t_last: float = 0.0            # latest drain seen
    latencies_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW)
    )

    @property
    def occupancy(self) -> float:
        return self.requests / self.slots if self.slots else 0.0

    @property
    def span_s(self) -> float:
        if self.t_first is None:
            return 0.0
        return max(0.0, self.t_last - self.t_first)


class ServeMetrics:
    def __init__(self):
        self._buckets: dict[str, _BucketStats] = {}
        self.counters = collections.Counter()

    def count(self, name: str, n: int = 1) -> None:
        """Bump one lifecycle counter (see :data:`COUNTERS`)."""
        self.counters[name] += n

    def record_batch(
        self,
        label: str,
        *,
        n_real: int,
        n_slots: int,
        pixels: int,
        t_dispatch: float,
        t_done: float,
        latencies_s,
        n_errors: int = 0,
        n_degraded: int = 0,
    ) -> None:
        b = self._buckets.setdefault(label, _BucketStats())
        b.requests += n_real
        b.batches += 1
        b.slots += n_slots
        b.pixels += pixels
        b.errors += n_errors
        b.degraded += n_degraded
        b.t_first = t_dispatch if b.t_first is None else min(b.t_first,
                                                             t_dispatch)
        b.t_last = max(b.t_last, t_done)
        b.latencies_s.extend(float(t) for t in latencies_s)

    @staticmethod
    def _percentiles(lat_s) -> dict:
        if not lat_s:
            return {"p50_ms": 0.0, "p90_ms": 0.0, "p99_ms": 0.0,
                    "mean_ms": 0.0}
        a = np.asarray(lat_s) * 1e3
        return {
            "p50_ms": float(np.percentile(a, 50)),
            "p90_ms": float(np.percentile(a, 90)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()),
        }

    @staticmethod
    def _rates(requests: int, pixels: int, span_s: float) -> tuple:
        if span_s <= 0.0:
            return 0.0, 0.0
        return requests / span_s, pixels / span_s / 1e6

    def summary(self, cache_stats: dict | None = None) -> dict:
        """Full metrics tree (buckets + totals + cache)."""
        buckets = {}
        tot = _BucketStats()
        all_lat: list = []
        for label, b in sorted(self._buckets.items()):
            fps, mpx = self._rates(b.requests, b.pixels, b.span_s)
            buckets[label] = {
                "requests": b.requests,
                "batches": b.batches,
                "errors": b.errors,
                "degraded": b.degraded,
                "batch_occupancy": b.occupancy,
                "latency": self._percentiles(b.latencies_s),
                "fps": fps,
                "mpx_per_s": mpx,
            }
            tot.requests += b.requests
            tot.batches += b.batches
            tot.slots += b.slots
            tot.pixels += b.pixels
            tot.errors += b.errors
            tot.degraded += b.degraded
            if b.t_first is not None:
                tot.t_first = (b.t_first if tot.t_first is None
                               else min(tot.t_first, b.t_first))
                tot.t_last = max(tot.t_last, b.t_last)
            all_lat.extend(b.latencies_s)
        fps, mpx = self._rates(tot.requests, tot.pixels, tot.span_s)
        out = {
            "buckets": buckets,
            "totals": {
                "requests": tot.requests,
                "batches": tot.batches,
                "errors": tot.errors,
                "degraded": tot.degraded,
                "batch_occupancy": tot.occupancy,
                "latency": self._percentiles(all_lat),
                "fps": fps,
                "mpx_per_s": mpx,
            },
            "counters": self.counter_summary(),
        }
        if cache_stats is not None:
            out["cache"] = cache_stats
        return out

    def counter_summary(self) -> dict:
        """Every canonical counter (zeros included, so the schema is
        stable) plus any ad-hoc ones that were bumped."""
        out = {name: int(self.counters.get(name, 0)) for name in COUNTERS}
        for name in sorted(self.counters):
            out.setdefault(name, int(self.counters[name]))
        return out

    def counter_rows(self) -> list[dict]:
        """Lifecycle counters in the benchmarks row contract.  These
        rows carry *counts*, not times — ``us_per_call`` holds the raw
        count so the ``--json`` name → value schema can track them
        across PRs (documented in ``docs/BENCHMARKS.md``)."""
        return [
            {
                "name": f"serve/counters/{name}",
                "us_per_call": float(value),
                "derived": f"count={value}",
            }
            for name, value in self.counter_summary().items()
        ]

    def bench_rows(self, cache_stats: dict | None = None) -> list[dict]:
        """Rows in the ``benchmarks.common.emit`` contract."""
        rows = []
        for label, b in sorted(self._buckets.items()):
            if not b.requests:
                continue
            pct = self._percentiles(b.latencies_s)
            fps, mpx = self._rates(b.requests, b.pixels, b.span_s)
            derived = (
                f"p50={pct['p50_ms']:.1f}ms p99={pct['p99_ms']:.1f}ms "
                f"occ={b.occupancy:.2f} fps={fps:.1f} mpx/s={mpx:.1f}"
            )
            if b.errors:
                derived += f" errors={b.errors}"
            if b.degraded:
                derived += f" degraded={b.degraded}"
            if cache_stats is not None:
                derived += f" cache_hit={cache_stats['hit_rate']:.2f}"
            rows.append({
                "name": f"serve/{label}",
                "us_per_call": pct["mean_ms"] * 1e3,
                "derived": derived,
            })
        return rows

    def as_bench_json(self, cache_stats: dict | None = None) -> dict:
        """name → us_per_call, the ``benchmarks/run.py --json`` schema."""
        return {r["name"]: r["us_per_call"]
                for r in self.bench_rows(cache_stats)}
