"""Serving metrics: per-bucket latency percentiles, batch occupancy,
throughput (FPS / MPx-per-s) and cache statistics.

Throughput is measured over the *wall span* of each bucket (first
dispatch → last drain), not the sum of per-batch intervals — with the
double-buffered executor those intervals overlap, and summing them
would understate FPS exactly when the pipelining works.  Latency
percentiles are computed over a bounded window of the most recent
``LATENCY_WINDOW`` requests per bucket, so a long-running service keeps
O(1) memory per bucket while ``requests`` counts the full history.

``bench_rows()`` / ``as_bench_json()`` emit the same row contract as
``benchmarks/run.py`` (``name,us_per_call,derived`` rows and the
``--json`` name → us_per_call mapping), so serving throughput lands in
the same machine-readable perf trajectory as the kernel benchmarks.
Every emitted field is documented in ``docs/BENCHMARKS.md``.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

#: Most recent per-bucket request latencies retained for percentiles.
LATENCY_WINDOW = 4096

#: Lifecycle counters always present in ``summary()["counters"]`` (and
#: as ``serve/counters/*`` rows), so the benchmarks JSON schema is
#: stable whether or not faults occurred.  Semantics (full contract in
#: ``docs/ROBUSTNESS.md``):
#:   rejected    admission-time typed rejections (invalid/non-finite/
#:               unsupported dtype/unknown op)
#:   shed        requests load-shed because the bounded queue was full
#:   expired     requests whose deadline passed while queued (shed at
#:               launch with DeadlineExceededError)
#:   retried     whole-batch retry attempts after an executor failure
#:   poisoned    requests isolated by bisect-retry quarantine
#:   degraded    requests whose convergence watchdog tripped (partial
#:               result returned, Ticket.degraded = True)
#:   batch_failures    batches whose first execution failed
#:   quarantine_reruns successful sub-batch re-executions during bisect
#:   rewrites_applied  optimizer rule applications behind admitted
#:                     requests (``repro.opt``; 0 for already-canonical
#:                     graphs)
#:   programs_shared   times a distinct source graph joined an
#:                     already-compiled program identity (rewrite
#:                     canonicalization or run-signature co-batching)
#:   refills           requests admitted into a continuous-batching
#:                     slot freed mid-flight (other slots still
#:                     iterating) — the continuous-batching win counter
#:   backpressure_flushes  eager bucket launches forced by the
#:                     ``high_water`` backpressure watermark
#:   quantum_splits    adaptive pad_quantum decisions that *shrank* a
#:                     run signature's bucket quantum (splitting
#:                     buckets to cut pad waste)
#:   quantum_merges    adaptive pad_quantum decisions that *grew* it
#:                     (merging sparse buckets to recover co-batching)
COUNTERS = ("rejected", "shed", "expired", "retried", "poisoned",
            "degraded", "batch_failures", "quarantine_reruns",
            "rewrites_applied", "programs_shared", "refills",
            "backpressure_flushes", "quantum_splits", "quantum_merges")


#: Distinct request shapes tracked per run signature (oldest-seen kept:
#: deterministic, bounded).
TRAFFIC_SHAPES = 64


@dataclasses.dataclass
class TrafficStats:
    """Per-run-signature arrival histogram driving the adaptive
    ``pad_quantum``/bucket-split policy: how many requests arrived and
    with which raw (H, W) shapes.  Deliberately tiny and deterministic
    — a Counter over shapes, capped at :data:`TRAFFIC_SHAPES` distinct
    entries — so the policy replays identically under the virtual
    clock."""

    arrivals: int = 0
    shapes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)

    def record(self, shape) -> None:
        self.arrivals += 1
        key = (int(shape[0]), int(shape[1]))
        if key in self.shapes or len(self.shapes) < TRAFFIC_SHAPES:
            self.shapes[key] += 1


@dataclasses.dataclass
class _BucketStats:
    requests: int = 0
    batches: int = 0
    slots: int = 0
    pixels: int = 0
    errors: int = 0
    degraded: int = 0
    rounds: int = 0            # continuous-engine scheduler rounds
    slot_rounds: int = 0       # rounds × engine slots (capacity)
    busy_slot_rounds: int = 0  # slot-rounds spent on live requests
    busy_chunks: int = 0       # scheduler chunks spent on live images
    cap_chunks: int = 0        # chunks × slots the device was held for
    t_first: float | None = None   # earliest dispatch seen
    t_last: float = 0.0            # latest drain seen
    latencies_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW)
    )

    @property
    def occupancy(self) -> float:
        """Fraction of device capacity spent on real work.  Under the
        continuous engine this is busy slot-rounds over total
        slot-rounds (time-weighted, the honest number when slots refill
        mid-flight); the batch path keeps requests-over-slots."""
        if self.slot_rounds:
            return self.busy_slot_rounds / self.slot_rounds
        return self.requests / self.slots if self.slots else 0.0

    @property
    def work_occupancy(self) -> float:
        """Chunk-weighted utilization: scheduler chunks spent on live
        image work over the chunk-slots the device was held for.  The
        one occupancy number comparable across the batch path and the
        continuous engine — batch fill (``occupancy``) cannot see a
        converged slot parked behind a straggler, this can.  Falls back
        to :attr:`occupancy` when no chunk telemetry was recorded
        (custom ops, fixed-length chains)."""
        if self.cap_chunks:
            return self.busy_chunks / self.cap_chunks
        return self.occupancy

    @property
    def span_s(self) -> float:
        if self.t_first is None:
            return 0.0
        return max(0.0, self.t_last - self.t_first)


class ServeMetrics:
    def __init__(self):
        self._buckets: dict[str, _BucketStats] = {}
        self.counters = collections.Counter()
        self.traffic: dict[str, TrafficStats] = {}

    def count(self, name: str, n: int = 1) -> None:
        """Bump one lifecycle counter (see :data:`COUNTERS`)."""
        self.counters[name] += n

    def record_arrival(self, sig_label: str, shape) -> None:
        """Feed the per-run-signature traffic histogram (adaptive
        ``pad_quantum`` input; see :class:`TrafficStats`)."""
        self.traffic.setdefault(sig_label, TrafficStats()).record(shape)

    def record_round(self, label: str, *, n_busy: int, n_slots: int,
                     t: float, busy_chunks: int = 0,
                     cap_chunks: int = 0) -> None:
        """One continuous-engine scheduler round: ``n_busy`` of
        ``n_slots`` slots held live requests at time ``t``, consuming
        ``busy_chunks`` of ``cap_chunks`` chunk-slots.  Feeds the
        time-weighted occupancy, the chunk-weighted work occupancy and
        the bucket wall span."""
        b = self._buckets.setdefault(label, _BucketStats())
        b.rounds += 1
        b.slot_rounds += n_slots
        b.busy_slot_rounds += n_busy
        b.busy_chunks += busy_chunks
        b.cap_chunks += cap_chunks
        b.t_first = t if b.t_first is None else min(b.t_first, t)
        b.t_last = max(b.t_last, t)

    def record_batch(
        self,
        label: str,
        *,
        n_real: int,
        n_slots: int,
        pixels: int,
        t_dispatch: float,
        t_done: float,
        latencies_s,
        n_errors: int = 0,
        n_degraded: int = 0,
        busy_chunks: int = 0,
        cap_chunks: int = 0,
    ) -> None:
        b = self._buckets.setdefault(label, _BucketStats())
        b.requests += n_real
        b.batches += 1
        b.slots += n_slots
        b.pixels += pixels
        b.errors += n_errors
        b.degraded += n_degraded
        b.busy_chunks += busy_chunks
        b.cap_chunks += cap_chunks
        b.t_first = t_dispatch if b.t_first is None else min(b.t_first,
                                                             t_dispatch)
        b.t_last = max(b.t_last, t_done)
        b.latencies_s.extend(float(t) for t in latencies_s)

    @staticmethod
    def _percentiles(lat_s) -> dict:
        if not lat_s:
            return {"p50_ms": 0.0, "p90_ms": 0.0, "p99_ms": 0.0,
                    "mean_ms": 0.0}
        a = np.asarray(lat_s) * 1e3
        return {
            "p50_ms": float(np.percentile(a, 50)),
            "p90_ms": float(np.percentile(a, 90)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()),
        }

    @staticmethod
    def _rates(requests: int, pixels: int, span_s: float) -> tuple:
        if span_s <= 0.0:
            return 0.0, 0.0
        return requests / span_s, pixels / span_s / 1e6

    def summary(self, cache_stats: dict | None = None) -> dict:
        """Full metrics tree (buckets + totals + cache)."""
        buckets = {}
        tot = _BucketStats()
        all_lat: list = []
        for label, b in sorted(self._buckets.items()):
            fps, mpx = self._rates(b.requests, b.pixels, b.span_s)
            buckets[label] = {
                "requests": b.requests,
                "batches": b.batches,
                "errors": b.errors,
                "degraded": b.degraded,
                "batch_occupancy": b.occupancy,
                "work_occupancy": b.work_occupancy,
                "rounds": b.rounds,
                "latency": self._percentiles(b.latencies_s),
                "fps": fps,
                "mpx_per_s": mpx,
            }
            tot.requests += b.requests
            tot.batches += b.batches
            tot.slots += b.slots
            tot.pixels += b.pixels
            tot.errors += b.errors
            tot.degraded += b.degraded
            tot.rounds += b.rounds
            tot.slot_rounds += b.slot_rounds
            tot.busy_slot_rounds += b.busy_slot_rounds
            tot.busy_chunks += b.busy_chunks
            tot.cap_chunks += b.cap_chunks
            if b.t_first is not None:
                tot.t_first = (b.t_first if tot.t_first is None
                               else min(tot.t_first, b.t_first))
                tot.t_last = max(tot.t_last, b.t_last)
            all_lat.extend(b.latencies_s)
        fps, mpx = self._rates(tot.requests, tot.pixels, tot.span_s)
        out = {
            "buckets": buckets,
            "totals": {
                "requests": tot.requests,
                "batches": tot.batches,
                "errors": tot.errors,
                "degraded": tot.degraded,
                "batch_occupancy": tot.occupancy,
                "work_occupancy": tot.work_occupancy,
                "rounds": tot.rounds,
                "latency": self._percentiles(all_lat),
                "fps": fps,
                "mpx_per_s": mpx,
            },
            "counters": self.counter_summary(),
        }
        if cache_stats is not None:
            out["cache"] = cache_stats
        return out

    def counter_summary(self) -> dict:
        """Every canonical counter (zeros included, so the schema is
        stable) plus any ad-hoc ones that were bumped."""
        out = {name: int(self.counters.get(name, 0)) for name in COUNTERS}
        for name in sorted(self.counters):
            out.setdefault(name, int(self.counters[name]))
        return out

    def counter_rows(self) -> list[dict]:
        """Lifecycle counters in the benchmarks row contract.  These
        rows carry *counts*, not times — ``us_per_call`` holds the raw
        count so the ``--json`` name → value schema can track them
        across PRs (documented in ``docs/BENCHMARKS.md``)."""
        return [
            {
                "name": f"serve/counters/{name}",
                "us_per_call": float(value),
                "derived": f"count={value}",
            }
            for name, value in self.counter_summary().items()
        ]

    def bench_rows(self, cache_stats: dict | None = None) -> list[dict]:
        """Rows in the ``benchmarks.common.emit`` contract."""
        rows = []
        for label, b in sorted(self._buckets.items()):
            if not b.requests:
                continue
            pct = self._percentiles(b.latencies_s)
            fps, mpx = self._rates(b.requests, b.pixels, b.span_s)
            derived = (
                f"p50={pct['p50_ms']:.1f}ms p99={pct['p99_ms']:.1f}ms "
                f"occ={b.occupancy:.2f} fps={fps:.1f} mpx/s={mpx:.1f}"
            )
            if b.errors:
                derived += f" errors={b.errors}"
            if b.degraded:
                derived += f" degraded={b.degraded}"
            if cache_stats is not None:
                derived += f" cache_hit={cache_stats['hit_rate']:.2f}"
            rows.append({
                "name": f"serve/{label}",
                "us_per_call": pct["mean_ms"] * 1e3,
                "derived": derived,
            })
        return rows

    def as_bench_json(self, cache_stats: dict | None = None) -> dict:
        """name → us_per_call, the ``benchmarks/run.py --json`` schema."""
        return {r["name"]: r["us_per_call"]
                for r in self.bench_rows(cache_stats)}
