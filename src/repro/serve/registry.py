"""Operator registry: every servable op declared as data.

The implementations stay where they live — ``core.operators`` and
``kernels.ops`` each export a ``SERVE_OPS`` hook tuple (name + param
schema next to the code) and this module translates the hooks into
:class:`OpSpec` entries the service pipeline understands.  A service is
then *declared* as data: ``[("hmax", {"h": 40}), ("erode", {"s": 16})]``.

Each :class:`OpSpec` describes the three pipeline stages:

``prepare(images, params)``
    per-request, on the *unpadded* image — marker derivation happens
    here so per-image reductions (``hfill_marker``'s interior max, …)
    never see bucket padding.
``run(inputs, params, backend, plan)``
    the batched core compiled once per (bucket, params, backend) by the
    serve cache; kernel-backed ops receive an explicit
    :class:`~repro.core.chain.ChainPlan` so the compiled-plan cache can
    report the schedule it embeds.
``finalize(out, images, params)``
    per-request, after the demux crop (e.g. DOME's ``f - hmax``).

``pad_fills(params)`` names the absorbing fill ("hi"/"lo") used for
pad-to-bucket canonicalization of each canonical input; ops with
``pad_safe=False`` are bucketed by exact shape instead (see the hooks'
docstrings for the exactness argument, and ``docs/ARCHITECTURE.md``
for the repo-wide bit-exactness convention it instantiates).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

from repro.core import operators as OPS
from repro.core.chain import plan_chain
from repro.kernels import ops as K

_TYPES = {"int": int, "float": float, "str": str}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Schema for one operator parameter (declared as data in the hooks)."""

    type: str = "float"
    default: Any = None
    required: bool = False
    choices: tuple | None = None
    min: Any = None

    def coerce(self, op: str, name: str, value):
        try:
            value = _TYPES[self.type](value)
        except (TypeError, ValueError):
            raise ValueError(
                f"op {op!r}: param {name!r} expects {self.type}, got {value!r}"
            ) from None
        if self.choices is not None and value not in self.choices:
            raise ValueError(
                f"op {op!r}: param {name!r} must be one of {self.choices}, "
                f"got {value!r}"
            )
        if self.min is not None and value < self.min:
            raise ValueError(
                f"op {op!r}: param {name!r} must be >= {self.min}, got {value!r}"
            )
        return value


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """A servable operator: string name, param schema, pipeline stages."""

    name: str
    params: Mapping[str, ParamSpec]
    run: Callable
    arity: int = 1           # image inputs per request (user-facing)
    n_inputs: int | None = None  # canonical inputs after prepare (None=arity)
    n_outputs: int = 1
    pad_safe: bool = True
    pad_fills: Callable | None = None      # params dict -> ("hi"|"lo", ...)
    prepare: Callable | None = None        # None = identity
    finalize: Callable | None = None
    plan_builder: Callable | None = None   # (n, h, w, dtype, params) -> plan

    def canonical_params(self, params: Mapping | None) -> tuple:
        """Validate + normalize params into a sorted hashable tuple
        (the form bucket and cache keys embed)."""
        given = dict(params or {})
        out = []
        for name in sorted(self.params):
            spec = self.params[name]
            if name in given:
                val = spec.coerce(self.name, name, given.pop(name))
            elif spec.required:
                raise ValueError(
                    f"op {self.name!r}: missing required param {name!r}"
                )
            else:
                val = spec.default
            out.append((name, val))
        if given:
            raise ValueError(
                f"op {self.name!r}: unknown params {sorted(given)} "
                f"(schema: {sorted(self.params)})"
            )
        return tuple(out)

    def prepare_inputs(self, images: tuple, params: tuple) -> tuple:
        if self.prepare is None:
            return images
        return self.prepare(images, dict(params))


def _specs(op_name: str, schema: Mapping) -> dict[str, ParamSpec]:
    return {name: ParamSpec(**field) for name, field in schema.items()}


# ---------------------------------------------------------------------------
# hook translation (one builder per hook kind)
# ---------------------------------------------------------------------------


def _convergent_plan(resident):
    def build(n, h, w, dtype, params):
        return plan_chain(h, w, dtype, None, n_images_resident=resident,
                          n_images=n, convergent=True)
    return build


def _from_chain(hook) -> OpSpec:
    chain_op = hook["chain_op"]

    def run(inputs, params, backend, plan):
        return K.morph_chain(inputs[0], dict(params)["s"], chain_op, backend,
                             plan=plan)

    def plan_builder(n, h, w, dtype, params):
        return plan_chain(h, w, dtype, params["s"], n_images=n)

    return OpSpec(
        name=hook["name"], params=_specs(hook["name"], hook["params"]),
        run=run, pad_fills=lambda p: (hook["pad"],),
        plan_builder=plan_builder,
    )


def _from_unary_fn(hook) -> OpSpec:
    fn = hook["fn"]

    def run(inputs, params, backend, plan):
        return fn(inputs[0], dict(params)["s"], backend)

    return OpSpec(
        name=hook["name"], params=_specs(hook["name"], hook["params"]),
        run=run, pad_safe=hook.get("pad_safe", True),
    )


def _from_reconstruct(hook) -> OpSpec:
    def run(inputs, params, backend, plan):
        return K.reconstruct(inputs[0], inputs[1], dict(params)["op"],
                             backend, plan=plan)

    def pad_fills(params):
        which = "hi" if params["op"] == "erode" else "lo"
        return (which, which)

    return OpSpec(
        name=hook["name"], params=_specs(hook["name"], hook["params"]),
        run=run, arity=2, pad_fills=pad_fills,
        plan_builder=_convergent_plan(2),
    )


def _from_geodesic(hook) -> OpSpec:
    def run(inputs, params, backend, plan):
        p = dict(params)
        return K.geodesic_chain(inputs[0], inputs[1], p["n"], p["op"],
                                backend, plan=plan)

    def pad_fills(params):
        which = "hi" if params["op"] == "erode" else "lo"
        return (which, which)

    def plan_builder(n, h, w, dtype, params):
        return plan_chain(h, w, dtype, params["n"], n_images_resident=2,
                          n_images=n)

    return OpSpec(
        name=hook["name"], params=_specs(hook["name"], hook["params"]),
        run=run, arity=2, pad_fills=pad_fills, plan_builder=plan_builder,
    )


def _from_qdt(hook) -> OpSpec:
    def run(inputs, params, backend, plan):
        return K.qdt_planes(inputs[0], backend, plan=plan)

    return OpSpec(
        name=hook["name"], params=_specs(hook["name"], hook["params"]),
        run=run, n_outputs=2, pad_fills=lambda p: (hook["pad"],),
        plan_builder=_convergent_plan(3),
    )


def _from_marker_reconstruct(hook) -> OpSpec:
    direction = hook["direction"]
    marker = hook["marker"]
    residual = hook.get("residual", False)

    def prepare(images, params):
        return (marker(images[0], params), images[0])

    def run(inputs, params, backend, plan):
        return K.reconstruct(inputs[0], inputs[1], direction, backend,
                             plan=plan)

    finalize = None
    if residual:
        def finalize(out, images, params):
            return images[0] - out

    which = "hi" if direction == "erode" else "lo"
    return OpSpec(
        name=hook["name"], params=_specs(hook["name"], hook["params"]),
        run=run, prepare=prepare, finalize=finalize, n_inputs=2,
        pad_fills=lambda p, _w=which: (_w, _w),
        plan_builder=_convergent_plan(2),
    )


def _from_whole_image(hook) -> OpSpec:
    fn = hook["fn"]

    def run(inputs, params, backend, plan):
        return fn(inputs[0], dict(params))

    return OpSpec(
        name=hook["name"], params=_specs(hook["name"], hook["params"]),
        run=run, pad_safe=False,
    )


_BUILDERS = {
    "chain": _from_chain,
    "unary_fn": _from_unary_fn,
    "reconstruct": _from_reconstruct,
    "geodesic": _from_geodesic,
    "qdt": _from_qdt,
    "marker_reconstruct": _from_marker_reconstruct,
    "whole_image": _from_whole_image,
}

_REGISTRY: dict[str, OpSpec] = {}


def register(spec: OpSpec) -> OpSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"op {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> OpSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown op {name!r}; registered: {', '.join(names())}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _install_hooks():
    for hook in (*K.SERVE_OPS, *OPS.SERVE_OPS):
        register(_BUILDERS[hook["kind"]](hook))


_install_hooks()
