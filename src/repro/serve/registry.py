"""Operator registry: every servable op is an expression, and its
pipeline stages are *derived* from the lowered program.

The implementations stay where they live — ``core.operators`` and
``kernels.ops`` each export a ``SERVE_OPS`` hook tuple (name + param
schema + expression builder next to the code).  This module lowers the
expression (``repro.api.lower``) and reads the three pipeline stages
off the :class:`~repro.api.lower.Program` mechanically:

``prepare``
    the program's prepare exprs, evaluated per-request on the
    *unpadded* images — marker derivation (so per-image reductions like
    ``hfill_marker``'s interior max never see bucket padding);
``run``
    the program's run phase, compiled per bucket via
    ``repro.api.compile`` — the serve cache key **is**
    ``Executable.key`` (lowered run signature + bucket shape/dtype/
    backend + plan key), the same object the compile cache uses;
``finalize``
    the program's finalize region, evaluated per request on the cropped
    run outputs (DOME's ``f - hmax``, the QDT η-regularization).

Pad-to-bucket safety is derived too: single-kernel-segment programs are
pad-safe under their lowered fills; multi-phase programs (ASF,
opening-by-reconstruction) get exact-shape buckets (see
``docs/ARCHITECTURE.md`` for the exactness argument).

Because the bucket identity is the lowered *run signature* rather than
the op name, different operators whose run phases coincide — HMAX,
DOME and RAOBJ are all one dilate-reconstruction — co-batch into one
compiled bucket program (cross-op bucket packing).

Custom :class:`OpSpec` objects with a hand-written ``run`` callable are
still accepted by :func:`register` (tests and extensions use this);
they bucket by (name, params) as before.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax.numpy as jnp

from repro.api.expr import KERNEL_KINDS
from repro.api.lower import eval_pointwise, lower
from repro.opt import rewrite_traced

_TYPES = {"int": int, "float": float, "str": str}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Schema for one operator parameter (declared as data in the hooks)."""

    type: str = "float"
    default: Any = None
    required: bool = False
    choices: tuple | None = None
    min: Any = None

    def coerce(self, op: str, name: str, value):
        try:
            value = _TYPES[self.type](value)
        except (TypeError, ValueError):
            raise ValueError(
                f"op {op!r}: param {name!r} expects {self.type}, got {value!r}"
            ) from None
        if self.choices is not None and value not in self.choices:
            raise ValueError(
                f"op {op!r}: param {name!r} must be one of {self.choices}, "
                f"got {value!r}"
            )
        if self.min is not None and value < self.min:
            raise ValueError(
                f"op {op!r}: param {name!r} must be >= {self.min}, "
                f"got {value!r}"
            )
        return value

    def sample(self):
        """A representative value (used once at registration to derive
        arity/outputs from the lowered sample expression)."""
        if self.default is not None:
            return self.default
        if self.choices:
            return self.choices[0]
        if self.type == "int":
            return max(1, self.min or 1)
        if self.type == "float":
            return float(self.min) if self.min is not None else 1.0
        return ""


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """A servable operator: expression-derived or custom.

    For expression ops only ``name``/``params``/``expr_builder`` are
    declared; everything else is derived from the lowered program.  The
    remaining fields exist for custom (hand-written ``run``) specs.
    """

    name: str
    params: Mapping[str, ParamSpec]
    expr_builder: Callable | None = None   # params dict -> Expr
    run: Callable | None = None    # custom: (inputs, params, backend, plan)
    arity: int = 1           # image inputs per request (user-facing)
    n_inputs: int | None = None  # canonical inputs after prepare (None=arity)
    n_outputs: int = 1
    dtypes: str = "uif"      # supported NumPy dtype kinds
    pad_safe: bool = True
    pad_fills: Callable | None = None      # params dict -> ("hi"|"lo", ...)
    prepare: Callable | None = None        # custom per-request stage
    finalize: Callable | None = None       # custom: (out, images, params)
    plan_builder: Callable | None = None   # custom: (n, h, w, dtype, params)

    def canonical_params(self, params: Mapping | None) -> tuple:
        """Validate + normalize params into a sorted hashable tuple
        (the form bucket and cache keys embed)."""
        given = dict(params or {})
        out = []
        for name in sorted(self.params):
            spec = self.params[name]
            if name in given:
                val = spec.coerce(self.name, name, given.pop(name))
            elif spec.required:
                raise ValueError(
                    f"op {self.name!r}: missing required param {name!r}"
                )
            else:
                val = spec.default
            out.append((name, val))
        if given:
            raise ValueError(
                f"op {self.name!r}: unknown params {sorted(given)} "
                f"(schema: {sorted(self.params)})"
            )
        return tuple(out)

    def build_expr(self, canon: tuple):
        return self.expr_builder(dict(canon))

    def prepare_inputs(self, images: tuple, params: tuple) -> tuple:
        """Per-request prepare stage on the unpadded images."""
        if self.expr_builder is not None:
            info = request_info(self.name, params)
            env = dict(zip(info.program.input_names,
                           (jnp.asarray(im) for im in images)))
            memo: dict = {}
            return tuple(eval_pointwise(e, env, {}, memo)
                         for e in info.program.prepare)
        if self.prepare is None:
            return images
        return self.prepare(images, dict(params))


@dataclasses.dataclass(frozen=True)
class RunInfo:
    """Everything the service needs to bucket/stage one request."""

    expr: Any                # canonical (rewritten) Expr; None for custom
    program: Any             # lowered Program (None for custom)
    sig: tuple               # bucket identity of the run phase
    label: str               # human tag for metrics bucket labels
    n_inputs: int            # canonical run inputs to stage
    n_outputs: int
    fills: tuple             # "hi"/"lo" per canonical input
    pad_safe: bool
    source: Any = None       # pre-rewrite Expr (None for custom)
    n_rewrites: int = 0      # optimizer rules applied to reach ``expr``


@functools.lru_cache(maxsize=2048)
def request_info(op: str, canon: tuple) -> RunInfo:
    """Derive (and memoize) the staging/bucketing info for one
    (op, canonical params) pair."""
    spec = get(op)
    if spec.expr_builder is None:
        n_inputs = spec.n_inputs or spec.arity
        fills = (tuple(spec.pad_fills(dict(canon))) if spec.pad_fills
                 else ("hi",) * n_inputs)
        p = ",".join(f"{k}={v}" for k, v in canon if v is not None)
        return RunInfo(
            expr=None, program=None, sig=("custom", spec.name, canon),
            label=f"{spec.name}({p})" if p else spec.name,
            n_inputs=n_inputs, n_outputs=spec.n_outputs, fills=fills,
            pad_safe=spec.pad_safe,
        )
    source = spec.build_expr(canon)
    # canonicalize with the expression optimizer so staging, bucketing
    # and compilation all see one graph — ``api.compile`` re-derives
    # the same canonical form (memoized), so the compiled program's
    # prepare/fills match what is staged here
    rewritten = rewrite_traced(source)
    expr = rewritten.expr
    prog = lower(expr)
    return RunInfo(
        expr=expr, program=prog, sig=prog.run_sig, label=prog.sig_label(),
        n_inputs=len(prog.run_fills), n_outputs=prog.n_outputs,
        fills=prog.run_fills, pad_safe=prog.pad_safe,
        source=source, n_rewrites=rewritten.n_applied,
    )


@functools.lru_cache(maxsize=2048)
def request_finalize(op: str, canon: tuple) -> Callable | None:
    """Per-request finalize callable ``(outputs, images) -> outputs``,
    or None when the run outputs are the results (identity)."""
    spec = get(op)
    if spec.expr_builder is None:
        if spec.finalize is None:
            return None

        def legacy(outs, images, _spec=spec, _canon=canon):
            return tuple(_spec.finalize(o, images, dict(_canon))
                         for o in outs)

        return legacy
    prog = request_info(op, canon).program
    if prog.expr.kind in KERNEL_KINDS:
        return None  # root is the kernel output itself

    def finalize(outs, images, _prog=prog):
        kernel_vals = {
            (node, i): outs[j]
            for j, (node, i, _) in enumerate(_prog.kernel_outputs)
        }
        env = dict(zip(_prog.input_names, images))
        memo: dict = {}
        return tuple(eval_pointwise(e, env, kernel_vals, memo)
                     for e in _prog.result_exprs())

    return finalize


def _specs(op_name: str, schema: Mapping) -> dict[str, ParamSpec]:
    return {name: ParamSpec(**field) for name, field in schema.items()}


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, OpSpec] = {}


def register(spec: OpSpec) -> OpSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"op {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> OpSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown op {name!r}; registered: {', '.join(names())}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _from_hook(hook) -> OpSpec:
    """Build an OpSpec from a SERVE_OPS hook: lower a sample expression
    once to derive the shape of the op (arity, outputs, pad-safety)."""
    params = _specs(hook["name"], hook["params"])
    sample = {name: p.sample() for name, p in params.items()}
    prog = lower(hook["expr"](sample))
    # gdt iterates a float distance lattice — programs containing it
    # only compile for float dtypes (see api/compile.py's gate)
    dtypes = ("f" if any(s.kind == "gdt" for s in prog.segments)
              else "uif")
    return OpSpec(
        name=hook["name"], params=params, expr_builder=hook["expr"],
        arity=len(prog.input_names), n_inputs=len(prog.run_fills),
        n_outputs=prog.n_outputs, dtypes=dtypes, pad_safe=prog.pad_safe,
    )


def _install_hooks():
    from repro import gdt as G
    from repro.core import operators as OPS
    from repro.kernels import ops as K

    for hook in (*K.SERVE_OPS, *OPS.SERVE_OPS, *G.SERVE_OPS):
        register(_from_hook(hook))


_install_hooks()
