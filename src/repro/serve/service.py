"""The serving front-end: submit → bucket → compile-or-hit → execute.

``Service`` ties the pieces together: the :mod:`registry` validates ops
and params and lowers each request's expression, the :mod:`bucketer`
coalesces requests into *run-signature*/shape/dtype buckets (cross-op
packing: ops with identical compiled run phases co-batch), the
:mod:`cache` maps ``Executable.key`` — the same identity the
``repro.api`` compile cache uses — to compiled bucket programs + their
:class:`ChainPlan`, and the :mod:`executor` runs the double-buffered
pipeline and demuxes results, applying each request's own finalize
stage.

The service is single-threaded and cooperatively scheduled: ``submit``
launches a bucket the moment it fills, and every ``submit``/``poll``
also flushes buckets whose oldest request has waited ``max_delay_ms``.
Callers that want strict deadline behaviour between submissions pump
``poll()`` themselves (there is no background thread — see the ROADMAP
follow-up); ``flush()`` force-launches everything and drains the
pipeline, and ``Ticket.result()`` drives whatever its request still
needs.  The layer map this front-end sits on top of is documented in
``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.serve import registry
from repro.serve.bucketer import (BucketKey, BucketQueue, PendingRequest,
                                  Ticket, bucket_hw, canonical_batch,
                                  pad_fill)
from repro.serve.cache import CacheEntry, CompiledProgramCache
from repro.serve.executor import Executor
from repro.serve.metrics import ServeMetrics


class Service:
    def __init__(
        self,
        *,
        backend: str = "pallas",
        max_batch: int = 8,
        max_delay_ms: float = 5.0,
        pad_quantum: int = 64,
        cache_capacity: int = 64,
        pipeline_depth: int = 2,
        clock=time.monotonic,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.backend = backend
        self.max_batch = max_batch
        self.pad_quantum = pad_quantum
        self.clock = clock
        self.metrics = ServeMetrics()
        self.cache = CompiledProgramCache(cache_capacity)
        self.executor = Executor(self.metrics, depth=pipeline_depth,
                                 clock=clock)
        self._queue = BucketQueue(max_batch, max_delay_ms / 1e3)
        self._next_id = 0

    # -- request intake ----------------------------------------------------

    def submit(self, op: str, *images, params=None) -> Ticket:
        """Enqueue one request; returns a :class:`Ticket` whose
        ``result()`` drives the pipeline as needed."""
        spec = registry.get(op)
        if len(images) != spec.arity:
            raise ValueError(
                f"op {op!r} takes {spec.arity} image(s), got {len(images)}"
            )
        imgs = tuple(np.asarray(im) for im in images)
        for im in imgs:
            if im.ndim != 2:
                raise ValueError(
                    f"op {op!r}: expected 2-D images, got shape {im.shape}"
                )
            if im.shape != imgs[0].shape or im.dtype != imgs[0].dtype:
                raise ValueError(
                    f"op {op!r}: all inputs must share shape/dtype; got "
                    f"{[(i.shape, str(i.dtype)) for i in imgs]}"
                )
        canon = spec.canonical_params(params)
        info = registry.request_info(op, canon)

        ticket = Ticket(request_id=self._next_id, op=op,
                        t_enqueue=self.clock(), _service=self)
        self._next_id += 1
        req = PendingRequest(
            ticket=ticket, images=imgs,
            inputs=spec.prepare_inputs(imgs, canon), shape=imgs[0].shape,
            info=info, finalize=registry.request_finalize(op, canon),
        )
        key = self._bucket_for(info, imgs[0].shape, imgs[0].dtype)
        ticket._bucket_key = key
        ticket._queued = True
        if self._queue.add(key, req):
            self._launch(key)
        self.poll()
        return ticket

    def poll(self) -> None:
        """Launch buckets whose oldest request exceeded max_delay_ms."""
        for key in self._queue.due(self.clock()):
            self._launch(key)

    def flush(self) -> None:
        """Launch every queued bucket and drain the whole pipeline."""
        while True:
            keys = self._queue.keys()
            if not keys:
                break
            for key in keys:
                self._launch(key)
        self.executor.drain_all()

    def _complete(self, ticket: Ticket) -> None:
        """Drive the pipeline until ``ticket`` resolves (Ticket.result)."""
        if ticket._queued:
            self._launch(ticket._bucket_key)
        while not ticket.done and self.executor.drain_one():
            pass

    # -- bucket launch -----------------------------------------------------

    def _launch(self, key: BucketKey) -> None:
        requests = self._queue.pop(key)
        if not requests:
            return
        for req in requests:
            req.ticket._queued = False
        info = requests[0].info
        n_slots = canonical_batch(len(requests), self.max_batch)
        try:
            entry = self._entry_for(key, info, n_slots, warm=False)
            stacked = self._stage(info, key, requests, n_slots)
        except Exception as exc:
            # the requests are already out of the queue: resolve their
            # tickets with the error instead of stranding them (the
            # dispatch path inside the executor does the same).
            self.executor._fail_batch(requests, exc)
            raise
        self.executor.dispatch(entry, key, requests, n_slots, stacked)

    def _bucket_for(self, info, shape, dtype) -> BucketKey:
        """The one place (submit + warmup) bucket keys are derived."""
        h, w = shape
        return BucketKey(
            sig=info.sig,
            hw=bucket_hw(h, w, self.pad_quantum) if info.pad_safe else (h, w),
            dtype=str(np.dtype(dtype)),
            tag=info.label,
        )

    def _cache_identity(self, key: BucketKey, info, n_slots: int):
        """The cache key (and, for expression ops, the Executable —
        compiling is a cheap cached lookup)."""
        if info.expr is not None:
            exe = api.compile(info.expr, (n_slots, *key.hw),
                              np.dtype(key.dtype), self.backend)
            return exe.key, exe
        return (info.sig, (n_slots, *key.hw), key.dtype, self.backend), None

    def _entry_for(self, key: BucketKey, info, n_slots: int,
                   warm: bool) -> CacheEntry:
        """Compiled bucket program: the cache key *is* the compile key."""
        lookup = self.cache.warm if warm else self.cache.get
        cache_key, exe = self._cache_identity(key, info, n_slots)
        if exe is not None:
            return lookup(
                cache_key,
                lambda: CacheEntry(fn=exe.run_batch, plan=exe.plan,
                                   key=cache_key),
            )
        spec = registry.get(info.sig[1])  # ("custom", name, canon)
        return lookup(
            cache_key,
            functools.partial(self._build_custom, spec, info.sig[2], key,
                              n_slots, cache_key),
        )

    def _build_custom(self, spec, canon: tuple, key: BucketKey,
                      n_slots: int, cache_key: tuple) -> CacheEntry:
        h, w = key.hw
        plan = None
        if self.backend == "pallas" and spec.plan_builder is not None:
            plan = spec.plan_builder(n_slots, h, w, np.dtype(key.dtype),
                                     dict(canon))

        def call(*inputs):
            out = spec.run(inputs, canon, self.backend, plan)
            return out if isinstance(out, tuple) else (out,)

        return CacheEntry(fn=jax.jit(call), plan=plan, key=cache_key)

    def _stage(self, info, key: BucketKey, requests, n_slots: int) -> tuple:
        """Host staging: pad each canonical input to the bucket shape and
        stack; sentinel slots keep the absorbing fill (they converge in
        one chunk under the active-tile scheduler)."""
        h, w = key.hw
        dtype = np.dtype(key.dtype)
        stacked = []
        for j in range(info.n_inputs):
            buf = np.full((n_slots, h, w), pad_fill(dtype, info.fills[j]),
                          dtype)
            for i, req in enumerate(requests):
                rh, rw = req.shape
                buf[i, :rh, :rw] = np.asarray(req.inputs[j])
            stacked.append(jnp.asarray(buf))
        return tuple(stacked)

    # -- warm-up + introspection ------------------------------------------

    def warmup(self, entries) -> None:
        """Prefill the compiled-program cache.

        ``entries`` is an iterable of dicts with keys ``op``, ``shape``
        (H, W), ``dtype`` and optionally ``params`` / ``batch`` (defaults
        to ``max_batch``).  Each entry is compiled *and* executed once on
        a sentinel-only stack so first real traffic pays neither trace
        nor compile time; warm builds are excluded from hit/miss stats.
        """
        for e in entries:
            spec = registry.get(e["op"])
            canon = spec.canonical_params(e.get("params"))
            info = registry.request_info(e["op"], canon)
            key = self._bucket_for(info, e["shape"], e["dtype"])
            n_slots = canonical_batch(e.get("batch", self.max_batch),
                                      self.max_batch)
            cache_key, _ = self._cache_identity(key, info, n_slots)
            if cache_key in self.cache:
                continue  # already resident: don't re-execute the program
            entry = self._entry_for(key, info, n_slots, warm=True)
            stacked = self._stage(info, key, [], n_slots)
            jax.block_until_ready(entry.fn(*stacked))

    def stats(self) -> dict:
        """Metrics summary (buckets/totals/cache), JSON-serializable."""
        return self.metrics.summary(self.cache.stats())

    def bench_rows(self) -> list[dict]:
        """Rows in the benchmarks ``name,us_per_call,derived`` contract."""
        return self.metrics.bench_rows(self.cache.stats())

    def pending(self) -> int:
        return len(self._queue)


def serve_stream(service: Service, requests) -> list:
    """Convenience driver: submit ``(op, images, params)`` triples (or
    ``(op, image)`` pairs), flush, and return results in order."""
    tickets = []
    for r in requests:
        op, rest = r[0], r[1:]
        params = rest[-1] if rest and isinstance(rest[-1], dict) else None
        images = rest[:-1] if params is not None else rest
        images = images[0] if len(images) == 1 and isinstance(
            images[0], (tuple, list)) else images
        tickets.append(service.submit(op, *images, params=params))
    service.flush()
    return [t.result() for t in tickets]
