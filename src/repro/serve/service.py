"""The serving front-end: admit → bucket → compile-or-hit → execute,
as an event-driven engine with a fault-tolerant request lifecycle.

``Service`` ties the pieces together: the :mod:`registry` validates ops
and params and lowers each request's expression, the :mod:`bucketer`
coalesces requests into *run-signature*/shape/dtype buckets (cross-op
packing: ops with identical compiled run phases co-batch), the
:mod:`cache` maps ``Executable.key`` — the same identity the
``repro.api`` compile cache uses — to compiled bucket programs + their
:class:`ChainPlan`, and the :mod:`executor` runs the double-buffered
pipeline and demuxes results, applying each request's own finalize
stage.  With ``continuous=True``, refillable buckets (single
convergence-driven segment on the pallas backend) run on a resident
:class:`~repro.serve.continuous.SlotEngine` instead: converged slots
are harvested and refilled mid-flight while stragglers keep iterating.

Event-driven core: the service never sleeps and never spawns a thread —
every deferred action is a timer on a :class:`~repro.serve.loop
.EventLoop` sharing the service's injectable clock:

* a **flush timer** per non-empty bucket, armed for its oldest
  request's ``max_delay_ms`` deadline, launches the bucket with no
  caller involvement the next time the loop is pumped;
* an **expiry timer** per deadlined request sheds it the moment its
  deadline lapses while queued (and launch re-checks deadlines *after*
  compiling, closing the race where a request expiring during a long
  trace/compile was still dispatched — previously expiry was only
  evaluated inside ``poll()`` before staging began).

Cooperative callers pump the loop via ``submit``/``poll``/``pump``;
:class:`AsyncService` is the asyncio front-end that trampolines
``next_deadline()`` into real ``call_at`` wakeups so deadline flushes
fire with *no* caller, and resolves tickets into awaitable futures via
``Ticket.add_done_callback``.  Under a
:class:`~repro.serve.loop.VirtualClock` the same engine replays
deterministically (the stepped-loop driver in ``tests/serve_sim.py``).

Robustness contract (full version in ``docs/ROBUSTNESS.md``):

* **admission** rejects malformed requests *synchronously* with typed
  errors (:mod:`repro.serve.errors`) before they can poison a bucket:
  arity/shape/dtype validation, lattice-dtype and non-finite payload
  checks (``bucketer.check_payload``), load shedding when the bounded
  queue (``max_queue``) is full, and :class:`ServiceClosedError` after
  ``close()``;
* **deadlines**: each request may carry one (``deadline_ms`` per
  request, ``default_deadline_ms`` service-wide); expired requests are
  shed — by timer while queued, and again post-compile at launch —
  with :class:`DeadlineExceededError` instead of wasting device time;
* **backpressure**: with ``high_water`` set, admission that leaves the
  backlog at/above the watermark force-launches the fullest buckets
  (counted as ``backpressure_flushes``) instead of letting latency
  build behind the flush timers;
* **execution failures** never escape ``poll()``/``flush()``/
  ``submit()``: the executor retries the batch with backoff, then
  bisect-quarantines so only poisoned requests fail (typed) while
  healthy co-batched requests complete bit-exactly — the slot engine
  evicts its whole session into the same ladder;
* **partial convergence** (scheduler watchdog) is delivered as a
  degraded result (``Ticket.degraded``), counted per bucket and in the
  lifecycle counters.

Adaptive bucketing: with ``adaptive_quantum=True`` the per-run-
signature traffic histograms (``ServeMetrics.traffic``) periodically
re-evaluate ``pad_quantum`` — high pad waste halves the quantum
(``quantum_splits``, splitting buckets to cut wasted pixels), many
distinct bucket grids at negligible waste doubles it
(``quantum_merges``, merging sparse buckets to recover co-batching).

Deterministic fault injection (``serve/faults.py``, ``REPRO_FAULTS``)
enters at the named sites; a Service built without ``faults=`` picks up
the environment schedule.  The layer map this front-end sits on top of
is documented in ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.serve import faults as F
from repro.serve import registry
from repro.serve.bucketer import (BucketKey, BucketQueue, PendingRequest,
                                  Ticket, bucket_hw, canonical_batch,
                                  check_payload, pad_fill)
from repro.serve.cache import CacheEntry, CompiledProgramCache
from repro.serve.continuous import SlotEngine
from repro.serve.errors import (DeadlineExceededError, InvalidRequestError,
                                QueueFullError, ServiceClosedError,
                                UnsupportedDtypeError)
from repro.serve.executor import Executor
from repro.serve.loop import EventLoop
from repro.serve.metrics import ServeMetrics


class Service:
    def __init__(
        self,
        *,
        backend: str = "pallas",
        max_batch: int = 8,
        max_delay_ms: float = 5.0,
        pad_quantum: int = 64,
        cache_capacity: int = 64,
        pipeline_depth: int = 2,
        max_queue: int | None = None,
        default_deadline_ms: float | None = None,
        max_retries: int = 2,
        retry_backoff_ms: float = 0.0,
        continuous: bool = False,
        refill_quantum: int = 4,
        high_water: int | None = None,
        adaptive_quantum: bool = False,
        adapt_every: int = 16,
        clock=time.monotonic,
        sleep=time.sleep,
        loop: EventLoop | None = None,
        faults: F.FaultInjector | None = None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        if refill_quantum < 1:
            raise ValueError("refill_quantum must be >= 1")
        if high_water is not None and high_water < 1:
            raise ValueError("high_water must be >= 1 (or None to disable)")
        if adapt_every < 1:
            raise ValueError("adapt_every must be >= 1")
        self.backend = backend
        self.max_batch = max_batch
        self.pad_quantum = pad_quantum
        self.max_queue = max_queue
        self.default_deadline_ms = default_deadline_ms
        self.continuous = continuous
        self.refill_quantum = refill_quantum
        self.high_water = high_water
        self.adaptive_quantum = adaptive_quantum
        self.adapt_every = adapt_every
        self.loop = loop if loop is not None else EventLoop(clock)
        self.clock = self.loop.clock
        self.faults = faults if faults is not None else F.from_env()
        self.metrics = ServeMetrics()
        self.cache = CompiledProgramCache(cache_capacity)
        # source graphs seen per compiled-program identity, so the
        # ``programs_shared`` counter can spot distinct operators whose
        # (rewritten) run phases land on one compiled program
        self._program_sources: dict = {}
        self.executor = Executor(self.metrics, depth=pipeline_depth,
                                 clock=self.clock, faults=self.faults,
                                 max_retries=max_retries,
                                 backoff_s=retry_backoff_ms / 1e3,
                                 sleep=sleep)
        self._queue = BucketQueue(max_batch, max_delay_ms / 1e3)
        self._assets: dict[str, np.ndarray] = {}
        self._flush_timers: dict[BucketKey, object] = {}
        self._engines: dict[BucketKey, SlotEngine] = {}
        self._quantum: dict[str, int] = {}  # adaptive per-sig overrides
        self._closed = False
        self._next_id = 0

    # -- pinned assets -----------------------------------------------------

    def pin(self, name: str, image) -> None:
        """Pin a host image under ``name`` so later ``submit`` calls can
        pass the name in place of the array — the incremental-update
        pattern: pin the (large, unchanging) image once, then stream
        cheap marker/seed updates against it, e.g.
        ``service.pin("slice", ct); service.submit("gdt", "slice",
        scribbles)``.  Requests resolving a pinned asset count into the
        ``asset_hits`` metric.  Re-pinning a name replaces it (later
        submits see the new array; staged requests keep the old one)."""
        arr = np.asarray(image)
        if arr.ndim != 2:
            raise InvalidRequestError(
                f"pin({name!r}): expected a 2-D image, got shape "
                f"{arr.shape}")
        self._assets[str(name)] = arr

    def unpin(self, name: str) -> None:
        """Drop a pinned asset (KeyError when absent)."""
        del self._assets[name]

    # -- request intake ----------------------------------------------------

    def submit(self, op: str, *images, params=None,
               deadline_ms: float | None = None) -> Ticket:
        """Enqueue one request; returns a :class:`Ticket` whose
        ``result()`` drives the pipeline as needed.

        Admission is the only stage that raises: malformed requests get
        a typed :class:`~repro.serve.errors.RequestRejected` subclass,
        a full bounded queue gets :class:`QueueFullError`, a closed
        service :class:`ServiceClosedError`.  Once a ticket is
        returned, every later failure is recorded *on the ticket*
        (typed), never raised from ``poll``/``flush``.

        ``deadline_ms`` (or the service's ``default_deadline_ms``)
        bounds how long the request may sit queued: an expiry timer
        sheds it with :class:`DeadlineExceededError` the moment its
        deadline lapses (launch re-checks after compiling, too).
        """
        if self._closed:
            self.metrics.count("rejected")
            raise ServiceClosedError(
                f"op {op!r}: service is closed — no new requests admitted")
        try:
            spec, imgs, canon = self._admit(op, images, params)
        except Exception:
            self.metrics.count("rejected")
            raise
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue):
            self.metrics.count("shed")
            raise QueueFullError(
                f"op {op!r}: queue full ({self.max_queue} pending) — "
                "request load-shed; retry later or raise max_queue"
            )
        info = registry.request_info(op, canon)
        if info.n_rewrites:
            self.metrics.count("rewrites_applied", info.n_rewrites)
        self.metrics.record_arrival(info.label, imgs[0].shape)
        if self.adaptive_quantum and info.pad_safe:
            self._adapt_quantum(info)

        if self.faults.should_fire("deadline"):
            deadline_ms = self.faults.value("deadline", 0.0)
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms

        now = self.clock()
        ticket = Ticket(
            request_id=self._next_id, op=op, t_enqueue=now,
            deadline=None if deadline_ms is None else now + deadline_ms / 1e3,
            _service=self,
        )
        self._next_id += 1
        req = PendingRequest(
            ticket=ticket, images=imgs,
            inputs=spec.prepare_inputs(imgs, canon), shape=imgs[0].shape,
            info=info, finalize=registry.request_finalize(op, canon),
            poisoned=self.faults.should_fire("poison"),
        )
        key = self._bucket_for(info, imgs[0].shape, imgs[0].dtype)
        ticket._bucket_key = key
        ticket._queued = True
        if ticket.deadline is not None:
            # strict `now > deadline` shedding: fire just past the line
            req.timer = self.loop.call_at(
                ticket.deadline + 1e-9,
                functools.partial(self._expire, key, req))
        filled = self._queue.add(key, req)
        if filled:
            self._launch(key)
        elif self._queue.size(key) == 1:
            self._rearm_flush(key)
        if (self.high_water is not None
                and len(self._queue) >= self.high_water):
            self._backpressure()
        self.loop.run_due()
        return ticket

    def _admit(self, op: str, images, params):
        """Admission validation: typed rejections, nothing staged yet."""
        spec = registry.get(op)
        if len(images) != spec.arity:
            raise InvalidRequestError(
                f"op {op!r} takes {spec.arity} image(s), got {len(images)}"
            )
        resolved = []
        for im in images:
            if isinstance(im, str):
                try:
                    im = self._assets[im]
                except KeyError:
                    raise InvalidRequestError(
                        f"op {op!r}: unknown pinned asset {im!r} "
                        f"(pinned: {sorted(self._assets)})") from None
                self.metrics.count("asset_hits")
            resolved.append(im)
        imgs = tuple(np.asarray(im) for im in resolved)
        for im in imgs:
            if im.ndim != 2:
                raise InvalidRequestError(
                    f"op {op!r}: expected 2-D images, got shape {im.shape}"
                )
            if im.shape != imgs[0].shape or im.dtype != imgs[0].dtype:
                raise InvalidRequestError(
                    f"op {op!r}: all inputs must share shape/dtype; got "
                    f"{[(i.shape, str(i.dtype)) for i in imgs]}"
                )
        check_payload(op, imgs)  # lattice dtype + non-finite rejection
        if np.dtype(imgs[0].dtype).kind not in spec.dtypes:
            raise UnsupportedDtypeError(
                f"op {op!r} supports dtype kinds {spec.dtypes!r}, got "
                f"{imgs[0].dtype} (gdt-backed ops iterate a float "
                "distance lattice)"
            )
        return spec, imgs, spec.canonical_params(params)

    # -- engine pumping ----------------------------------------------------

    def poll(self) -> None:
        """Pump the engine once: fire due timers (bucket flushes,
        request expiries) and advance every slot engine one round.

        Part of the robustness contract: ``poll`` never raises — batch
        failures resolve into typed per-ticket errors via the
        executor's recovery ladder.
        """
        self.loop.run_due()
        self._step_engines()

    def pump(self) -> bool:
        """One cooperative engine turn: timers, one engine round each,
        one pipeline drain.  Returns True when any progress was made
        (the asyncio front-end's trampoline unit)."""
        progress = self.loop.run_due() > 0
        progress = self._step_engines() or progress
        if self.executor.inflight:
            progress = self.executor.drain_one() or progress
        return progress

    def flush(self) -> None:
        """Launch every queued bucket, run every slot engine to empty
        and drain the whole pipeline."""
        while True:
            for key in self._queue.keys():
                self._launch(key)
            if not self._step_engines() and not len(self._queue):
                break
        self.executor.drain_all()

    def close(self) -> None:
        """Drain everything, then refuse new work (idempotent).
        Requests admitted before close still reach terminal outcomes."""
        if not self._closed:
            self.flush()
            self._closed = True

    @property
    def closed(self) -> bool:
        return self._closed

    def work_pending(self) -> bool:
        """True while anything queued, resident in a slot engine, or in
        the executor pipeline still needs pumping."""
        return bool(len(self._queue) or self.executor.inflight
                    or any(e.occupied for e in self._engines.values()))

    def next_deadline(self) -> float | None:
        """Earliest armed timer (flush/expiry) on the service clock —
        what the asyncio front-end turns into a real wakeup."""
        return self.loop.next_deadline()

    def _step_engines(self) -> bool:
        progress = False
        for engine in list(self._engines.values()):
            progress = engine.step() or progress
        return progress

    def _complete(self, ticket: Ticket) -> None:
        """Drive the engine until ``ticket`` resolves (Ticket.result)."""
        while not ticket.done:
            progress = self.loop.run_due() > 0
            if ticket._queued:
                self._launch(ticket._bucket_key)
                progress = True
            progress = self._step_engines() or progress
            progress = self.executor.drain_one() or progress
            if not progress:
                break

    # -- bucket launch -----------------------------------------------------

    def _rearm_flush(self, key: BucketKey) -> None:
        """(Re-)arm the bucket's deadline-flush timer for its current
        oldest request; cancel it when the bucket is empty."""
        old = self._flush_timers.pop(key, None)
        if old is not None:
            old.cancel()
        oldest = self._queue.oldest(key)
        if oldest is not None:
            self._flush_timers[key] = self.loop.call_at(
                oldest.ticket.t_enqueue + self._queue.max_delay_s,
                functools.partial(self._launch, key))

    def _expire(self, key: BucketKey, req: PendingRequest) -> None:
        """Expiry-timer callback: shed ``req`` if it is still queued
        (deadlines only bound queue time; in-flight requests finish)."""
        req.timer = None
        t = req.ticket
        if t.done or not t._queued:
            return
        if not self._queue.discard(key, req):
            return
        t._queued = False
        now = self.clock()
        t.error = DeadlineExceededError(
            f"request {t.request_id} ({t.op}) waited "
            f"{(now - t.t_enqueue) * 1e3:.1f}ms, past its deadline"
        )
        t._fulfill(now)
        self.metrics.count("expired")
        self._rearm_flush(key)  # the bucket's oldest may have changed

    def _backpressure(self) -> None:
        """Watermark relief: force-launch the fullest buckets until the
        backlog drops below ``high_water`` (or nothing can launch)."""
        while self._queue.keys() and len(self._queue) >= self.high_water:
            key = max(self._queue.keys(), key=self._queue.size)
            before = len(self._queue)
            self.metrics.count("backpressure_flushes")
            self._launch(key)
            if len(self._queue) >= before:
                break  # engine full / everything shed: don't spin

    def _launch(self, key: BucketKey) -> None:
        """Launch one bucket: into its slot engine when continuous and
        refillable, else as one canonical batch.  Never raises."""
        timer = self._flush_timers.pop(key, None)
        if timer is not None:
            timer.cancel()
        engine = self._engines.get(key)
        if engine is None and self.continuous:
            engine = self._spawn_engine(key)
        if engine is not None:
            engine.pull()
            self._rearm_flush(key)
            return
        requests = self._queue.pop(key)
        for req in requests:
            req.ticket._queued = False
            if req.timer is not None:
                req.timer.cancel()
                req.timer = None
        self._rearm_flush(key)  # anything beyond max_batch stays queued
        requests = self._shed_expired(requests)
        if not requests:
            return
        info = requests[0].info
        runner = functools.partial(self._run_sync, key, info)
        n_slots = canonical_batch(len(requests), self.max_batch)
        try:
            entry = self._entry_for(key, info, n_slots, warm=False)
            # deadline re-check *after* compiling: a request whose
            # deadline lapsed during a long trace/compile must not be
            # dispatched (the old poll-time-only check raced here)
            live = self._shed_expired(requests)
            if not live:
                return
            if len(live) < len(requests):
                requests = live
                n_slots = canonical_batch(len(requests), self.max_batch)
                entry = self._entry_for(key, info, n_slots, warm=False)
            stacked = self._stage(info, key, requests, n_slots)
            self.faults.check("dispatch", key.label())
            self._check_poison(requests)
        except Exception as exc:
            # staging/compile/injected failure before dispatch: the
            # requests are already out of the queue — hand them to the
            # recovery ladder instead of stranding them (or raising out
            # of poll()).
            self.executor.recover(key, requests, runner, exc)
            return
        self.executor.dispatch(entry, key, requests, n_slots, stacked,
                               runner=runner)

    def _spawn_engine(self, key: BucketKey) -> SlotEngine | None:
        """Build the bucket's slot engine if its program is refillable
        (single convergent pallas segment); None routes to the batch
        path.  Compile failures fall through — the batch path's ladder
        reports them."""
        oldest = self._queue.oldest(key)
        if oldest is None or oldest.info.expr is None:
            return None
        try:
            entry = self._entry_for(key, oldest.info, self.max_batch,
                                    warm=False)
        except Exception:
            return None
        if entry.exe is None or not entry.exe.refillable:
            return None
        engine = SlotEngine(self, key, oldest.info, entry)
        self._engines[key] = engine
        return engine

    def _shed_expired(self, requests):
        """Deadline shedding at launch: typed errors, no device time."""
        now = self.clock()
        live = []
        for req in requests:
            t = req.ticket
            if t.done:
                continue  # expiry timer beat us to it
            if t.deadline is not None and now > t.deadline:
                if req.timer is not None:
                    req.timer.cancel()
                    req.timer = None
                t.error = DeadlineExceededError(
                    f"request {t.request_id} ({t.op}) waited "
                    f"{(now - t.t_enqueue) * 1e3:.1f}ms, past its deadline"
                )
                t._fulfill(now)
                self.metrics.count("expired")
            else:
                live.append(req)
        return live

    @staticmethod
    def _check_poison(requests) -> None:
        """Fault site: a poisoned request kills any batch containing it
        (deterministically — that is what bisect-retry needs)."""
        for req in requests:
            if req.poisoned:
                raise F.InjectedFault(
                    "poison", f"request {req.ticket.request_id}")

    def _run_sync(self, key: BucketKey, info, requests):
        """Synchronous (re-)execution for the executor's recovery
        ladder: restage the given subset, run, block.  Returns
        ``(outputs, n_slots, converged)``."""
        n_slots = canonical_batch(len(requests), self.max_batch)
        entry = self._entry_for(key, info, n_slots, warm=False)
        stacked = self._stage(info, key, requests, n_slots)
        self._check_poison(requests)
        outputs, conv, _ = Executor._call_entry(entry, stacked)
        jax.block_until_ready((outputs, conv))
        return outputs, n_slots, conv

    # -- bucketing policy --------------------------------------------------

    def _bucket_for(self, info, shape, dtype) -> BucketKey:
        """The one place (submit + warmup) bucket keys are derived."""
        h, w = shape
        quantum = self._quantum.get(info.label, self.pad_quantum)
        return BucketKey(
            sig=info.sig,
            hw=bucket_hw(h, w, quantum) if info.pad_safe else (h, w),
            dtype=str(np.dtype(dtype)),
            tag=info.label,
        )

    def _adapt_quantum(self, info) -> None:
        """Periodically re-fit the run signature's pad quantum to its
        observed traffic (every ``adapt_every`` arrivals): pad waste
        above 25% halves the quantum (``quantum_splits``), while many
        distinct bucket grids at under 5% waste doubles it
        (``quantum_merges``) to recover co-batching.  Pure function of
        the arrival history — deterministic under the virtual clock."""
        ts = self.metrics.traffic.get(info.label)
        if ts is None or ts.arrivals % self.adapt_every:
            return
        q = self._quantum.get(info.label, self.pad_quantum)
        raw = padded = 0
        grids = set()
        for (h, w), n in ts.shapes.items():
            hh, ww = bucket_hw(h, w, q)
            raw += n * h * w
            padded += n * hh * ww
            grids.add((hh, ww))
        if not padded:
            return
        waste = 1.0 - raw / padded
        if waste > 0.25 and q > 8:
            self._quantum[info.label] = q // 2
            self.metrics.count("quantum_splits")
        elif waste < 0.05 and len(grids) > 2 and q < 1024:
            self._quantum[info.label] = q * 2
            self.metrics.count("quantum_merges")

    # -- compile-or-hit ----------------------------------------------------

    def _cache_identity(self, key: BucketKey, info, n_slots: int):
        """The cache key (and, for expression ops, the Executable —
        compiling is a cheap cached lookup).  The ``budget`` fault site
        compiles with an injected ``max_chunks``; since ``max_chunks``
        is part of ``Executable.key``, injected and clean programs never
        share a cache entry."""
        if info.expr is not None:
            budget = self.faults.value("budget", None)
            exe = api.compile(
                info.expr, (n_slots, *key.hw), np.dtype(key.dtype),
                self.backend,
                max_chunks=None if budget is None else int(budget),
            )
            if info.source is not None:
                seen = self._program_sources.setdefault(exe.key, set())
                if info.source not in seen:
                    if seen:
                        self.metrics.count("programs_shared")
                    seen.add(info.source)
            return exe.key, exe
        return (info.sig, (n_slots, *key.hw), key.dtype, self.backend), None

    def _entry_for(self, key: BucketKey, info, n_slots: int,
                   warm: bool) -> CacheEntry:
        """Compiled bucket program: the cache key *is* the compile key."""
        lookup = self.cache.warm if warm else self.cache.get
        cache_key, exe = self._cache_identity(key, info, n_slots)
        if exe is not None:
            return lookup(
                cache_key,
                lambda: CacheEntry(fn=exe.run_batch, plan=exe.plan,
                                   key=cache_key,
                                   stats_fn=exe.run_batch_stats, exe=exe),
            )
        spec = registry.get(info.sig[1])  # ("custom", name, canon)
        return lookup(
            cache_key,
            functools.partial(self._build_custom, spec, info.sig[2], key,
                              n_slots, cache_key),
        )

    def _build_custom(self, spec, canon: tuple, key: BucketKey,
                      n_slots: int, cache_key: tuple) -> CacheEntry:
        h, w = key.hw
        plan = None
        if self.backend == "pallas" and spec.plan_builder is not None:
            plan = spec.plan_builder(n_slots, h, w, np.dtype(key.dtype),
                                     dict(canon))

        def call(*inputs):
            out = spec.run(inputs, canon, self.backend, plan)
            return out if isinstance(out, tuple) else (out,)

        return CacheEntry(fn=jax.jit(call), plan=plan, key=cache_key)

    def _stage(self, info, key: BucketKey, requests, n_slots: int) -> tuple:
        """Host staging: pad each canonical input to the bucket shape and
        stack; sentinel slots keep the absorbing fill (they converge in
        one chunk under the active-tile scheduler)."""
        h, w = key.hw
        dtype = np.dtype(key.dtype)
        stacked = []
        for j in range(info.n_inputs):
            buf = np.full((n_slots, h, w), pad_fill(dtype, info.fills[j]),
                          dtype)
            for i, req in enumerate(requests):
                rh, rw = req.shape
                buf[i, :rh, :rw] = np.asarray(req.inputs[j])
            stacked.append(jnp.asarray(buf))
        return tuple(stacked)

    # -- warm-up + introspection ------------------------------------------

    def warmup(self, entries) -> None:
        """Prefill the compiled-program cache.

        ``entries`` is an iterable of dicts with keys ``op``, ``shape``
        (H, W), ``dtype`` and optionally ``params`` / ``batch`` (defaults
        to ``max_batch``).  Each entry is compiled *and* executed once on
        a sentinel-only stack so first real traffic pays neither trace
        nor compile time; warm builds are excluded from hit/miss stats.
        With ``continuous=True`` the refillable session's entry points
        (init/admit/round/extract) are traced too.
        """
        for e in entries:
            spec = registry.get(e["op"])
            canon = spec.canonical_params(e.get("params"))
            info = registry.request_info(e["op"], canon)
            key = self._bucket_for(info, e["shape"], e["dtype"])
            n_slots = canonical_batch(e.get("batch", self.max_batch),
                                      self.max_batch)
            cache_key, _ = self._cache_identity(key, info, n_slots)
            if cache_key not in self.cache:
                entry = self._entry_for(key, info, n_slots, warm=True)
                stacked = self._stage(info, key, [], n_slots)
                # execute the callable dispatch will use (the stats
                # variant for expression programs): no trace on traffic
                jax.block_until_ready(entry.primary()(*stacked))
            if self.continuous and info.expr is not None:
                self._warm_session(key, info)

    def _warm_session(self, key: BucketKey, info) -> None:
        """Trace a refillable bucket's slot-session entry points on a
        sentinel slot so the first continuous round pays no trace."""
        entry = self._entry_for(key, info, self.max_batch, warm=True)
        if entry.exe is None or not entry.exe.refillable:
            return
        session = entry.exe.slot_session(self.refill_quantum)
        dtype = np.dtype(key.dtype)
        sentinels = tuple(
            jnp.full(key.hw, pad_fill(dtype, info.fills[j]), dtype)
            for j in range(info.n_inputs))
        state = session.admit(session.init(), 0, *sentinels)
        state, _, _ = session.round(state)
        jax.block_until_ready(session.extract(state))

    def stats(self) -> dict:
        """Metrics summary (buckets/totals/counters/cache/faults),
        JSON-serializable."""
        out = self.metrics.summary(self.cache.stats())
        out["faults"] = self.faults.snapshot()
        return out

    def bench_rows(self) -> list[dict]:
        """Rows in the benchmarks ``name,us_per_call,derived`` contract
        (per-bucket latency/throughput plus the lifecycle counters)."""
        return (self.metrics.bench_rows(self.cache.stats())
                + self.metrics.counter_rows())

    def pending(self) -> int:
        """Requests awaiting a result: queued plus resident in slot
        engines (in-flight executor batches are not counted — they are
        already past admission/launch)."""
        return len(self._queue) + sum(e.n_occupied
                                      for e in self._engines.values())


class AsyncService:
    """asyncio front-end: the same engine, with timers trampolined onto
    the running event loop so deadline flushes and expiries fire with
    **no caller**, and tickets awaitable as futures.

    Must be constructed inside a running asyncio event loop (the
    service clock defaults to ``loop.time`` so service timers and
    asyncio wakeups share one timebase).  ``submit`` is synchronous
    (admission raises immediately, as with :class:`Service`) and
    returns the plain :class:`Ticket`; ``await result(ticket)`` parks
    until the engine completes it.  Device rounds run *on* the loop
    thread — the engine is single-threaded by design — so concurrency
    here means overlapping request lifetimes, not parallel compute.
    """

    def __init__(self, *, loop=None, **kwargs):
        import asyncio
        self._aio = loop if loop is not None else asyncio.get_running_loop()
        kwargs.setdefault("clock", self._aio.time)
        self.service = Service(**kwargs)
        self._handle = None

    def submit(self, op: str, *images, params=None,
               deadline_ms: float | None = None) -> Ticket:
        ticket = self.service.submit(op, *images, params=params,
                                     deadline_ms=deadline_ms)
        self._schedule()
        return ticket

    async def result(self, ticket: Ticket):
        """Await the ticket's terminal outcome, then unwrap it (raises
        its typed error exactly like ``Ticket.result``)."""
        if not ticket.done:
            fut = self._aio.create_future()
            ticket.add_done_callback(
                lambda t: fut.done() or fut.set_result(None))
            self._schedule()
            await fut
        if ticket.error is not None:
            raise ticket.error
        return ticket.value

    async def run(self, op: str, *images, params=None,
                  deadline_ms: float | None = None):
        """submit + await result in one call."""
        return await self.result(self.submit(
            op, *images, params=params, deadline_ms=deadline_ms))

    async def close(self):
        """Drain all outstanding work (yielding between pump turns),
        then close the underlying service."""
        import asyncio
        while self.service.work_pending():
            self.service.pump()
            await asyncio.sleep(0)
        self.service.close()
        if self._handle is not None:
            self._handle.cancel()
            self._handle = None

    def stats(self) -> dict:
        return self.service.stats()

    # -- trampoline --------------------------------------------------------

    def _schedule(self) -> None:
        """Arm the next wakeup: immediately while work is in flight,
        else at the service's earliest timer deadline."""
        if self._handle is not None:
            self._handle.cancel()
            self._handle = None
        svc = self.service
        if svc.work_pending():
            self._handle = self._aio.call_soon(self._pump)
            return
        nxt = svc.next_deadline()
        if nxt is not None:
            self._handle = self._aio.call_later(
                max(0.0, nxt - svc.clock()), self._pump)

    def _pump(self) -> None:
        self._handle = None
        self.service.pump()
        self._schedule()


def serve_stream(service: Service, requests) -> list:
    """Convenience driver: submit ``(op, images, params)`` triples (or
    ``(op, image)`` pairs), flush, and return results in order."""
    tickets = []
    for r in requests:
        op, rest = r[0], r[1:]
        params = rest[-1] if rest and isinstance(rest[-1], dict) else None
        images = rest[:-1] if params is not None else rest
        images = images[0] if len(images) == 1 and isinstance(
            images[0], (tuple, list)) else images
        tickets.append(service.submit(op, *images, params=params))
    service.flush()
    return [t.result() for t in tickets]
