"""The serving front-end: admit → bucket → compile-or-hit → execute,
with a fault-tolerant request lifecycle.

``Service`` ties the pieces together: the :mod:`registry` validates ops
and params and lowers each request's expression, the :mod:`bucketer`
coalesces requests into *run-signature*/shape/dtype buckets (cross-op
packing: ops with identical compiled run phases co-batch), the
:mod:`cache` maps ``Executable.key`` — the same identity the
``repro.api`` compile cache uses — to compiled bucket programs + their
:class:`ChainPlan`, and the :mod:`executor` runs the double-buffered
pipeline and demuxes results, applying each request's own finalize
stage.

Robustness contract (full version in ``docs/ROBUSTNESS.md``):

* **admission** rejects malformed requests *synchronously* with typed
  errors (:mod:`repro.serve.errors`) before they can poison a bucket:
  arity/shape/dtype validation, lattice-dtype and non-finite payload
  checks (``bucketer.check_payload``), and load shedding when the
  bounded queue (``max_queue``) is full;
* **deadlines**: each request may carry one (``deadline_ms`` per
  request, ``default_deadline_ms`` service-wide); expired requests are
  shed at launch with :class:`DeadlineExceededError` instead of wasting
  device time;
* **execution failures** never escape ``poll()``/``flush()``/
  ``submit()``: the executor retries the batch with backoff, then
  bisect-quarantines so only poisoned requests fail (typed) while
  healthy co-batched requests complete bit-exactly;
* **partial convergence** (scheduler watchdog) is delivered as a
  degraded result (``Ticket.degraded``), counted per bucket and in the
  lifecycle counters.

Deterministic fault injection (``serve/faults.py``, ``REPRO_FAULTS``)
enters at the named sites; a Service built without ``faults=`` picks up
the environment schedule.

The service is single-threaded and cooperatively scheduled: ``submit``
launches a bucket the moment it fills, and every ``submit``/``poll``
also flushes buckets whose oldest request has waited ``max_delay_ms``.
Callers that want strict deadline behaviour between submissions pump
``poll()`` themselves (there is no background thread — see the ROADMAP
follow-up); ``flush()`` force-launches everything and drains the
pipeline, and ``Ticket.result()`` drives whatever its request still
needs.  The layer map this front-end sits on top of is documented in
``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.serve import faults as F
from repro.serve import registry
from repro.serve.bucketer import (BucketKey, BucketQueue, PendingRequest,
                                  Ticket, bucket_hw, canonical_batch,
                                  check_payload, pad_fill)
from repro.serve.cache import CacheEntry, CompiledProgramCache
from repro.serve.errors import (DeadlineExceededError, InvalidRequestError,
                                QueueFullError)
from repro.serve.executor import Executor
from repro.serve.metrics import ServeMetrics


class Service:
    def __init__(
        self,
        *,
        backend: str = "pallas",
        max_batch: int = 8,
        max_delay_ms: float = 5.0,
        pad_quantum: int = 64,
        cache_capacity: int = 64,
        pipeline_depth: int = 2,
        max_queue: int | None = None,
        default_deadline_ms: float | None = None,
        max_retries: int = 2,
        retry_backoff_ms: float = 0.0,
        clock=time.monotonic,
        sleep=time.sleep,
        faults: F.FaultInjector | None = None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        self.backend = backend
        self.max_batch = max_batch
        self.pad_quantum = pad_quantum
        self.max_queue = max_queue
        self.default_deadline_ms = default_deadline_ms
        self.clock = clock
        self.faults = faults if faults is not None else F.from_env()
        self.metrics = ServeMetrics()
        self.cache = CompiledProgramCache(cache_capacity)
        # source graphs seen per compiled-program identity, so the
        # ``programs_shared`` counter can spot distinct operators whose
        # (rewritten) run phases land on one compiled program
        self._program_sources: dict = {}
        self.executor = Executor(self.metrics, depth=pipeline_depth,
                                 clock=clock, faults=self.faults,
                                 max_retries=max_retries,
                                 backoff_s=retry_backoff_ms / 1e3,
                                 sleep=sleep)
        self._queue = BucketQueue(max_batch, max_delay_ms / 1e3)
        self._next_id = 0

    # -- request intake ----------------------------------------------------

    def submit(self, op: str, *images, params=None,
               deadline_ms: float | None = None) -> Ticket:
        """Enqueue one request; returns a :class:`Ticket` whose
        ``result()`` drives the pipeline as needed.

        Admission is the only stage that raises: malformed requests get
        a typed :class:`~repro.serve.errors.RequestRejected` subclass,
        a full bounded queue gets :class:`QueueFullError`.  Once a
        ticket is returned, every later failure is recorded *on the
        ticket* (typed), never raised from ``poll``/``flush``.

        ``deadline_ms`` (or the service's ``default_deadline_ms``)
        bounds how long the request may sit queued: expired requests
        are shed at launch with :class:`DeadlineExceededError`.
        """
        try:
            spec, imgs, canon = self._admit(op, images, params)
        except Exception:
            self.metrics.count("rejected")
            raise
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue):
            self.metrics.count("shed")
            raise QueueFullError(
                f"op {op!r}: queue full ({self.max_queue} pending) — "
                "request load-shed; retry later or raise max_queue"
            )
        info = registry.request_info(op, canon)
        if info.n_rewrites:
            self.metrics.count("rewrites_applied", info.n_rewrites)

        if self.faults.should_fire("deadline"):
            deadline_ms = self.faults.value("deadline", 0.0)
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms

        now = self.clock()
        ticket = Ticket(
            request_id=self._next_id, op=op, t_enqueue=now,
            deadline=None if deadline_ms is None else now + deadline_ms / 1e3,
            _service=self,
        )
        self._next_id += 1
        req = PendingRequest(
            ticket=ticket, images=imgs,
            inputs=spec.prepare_inputs(imgs, canon), shape=imgs[0].shape,
            info=info, finalize=registry.request_finalize(op, canon),
            poisoned=self.faults.should_fire("poison"),
        )
        key = self._bucket_for(info, imgs[0].shape, imgs[0].dtype)
        ticket._bucket_key = key
        ticket._queued = True
        if self._queue.add(key, req):
            self._launch(key)
        self.poll()
        return ticket

    def _admit(self, op: str, images, params):
        """Admission validation: typed rejections, nothing staged yet."""
        spec = registry.get(op)
        if len(images) != spec.arity:
            raise InvalidRequestError(
                f"op {op!r} takes {spec.arity} image(s), got {len(images)}"
            )
        imgs = tuple(np.asarray(im) for im in images)
        for im in imgs:
            if im.ndim != 2:
                raise InvalidRequestError(
                    f"op {op!r}: expected 2-D images, got shape {im.shape}"
                )
            if im.shape != imgs[0].shape or im.dtype != imgs[0].dtype:
                raise InvalidRequestError(
                    f"op {op!r}: all inputs must share shape/dtype; got "
                    f"{[(i.shape, str(i.dtype)) for i in imgs]}"
                )
        check_payload(op, imgs)  # lattice dtype + non-finite rejection
        return spec, imgs, spec.canonical_params(params)

    def poll(self) -> None:
        """Launch buckets whose oldest request exceeded max_delay_ms.

        Part of the robustness contract: ``poll`` never raises — batch
        failures resolve into typed per-ticket errors via the
        executor's recovery ladder.
        """
        for key in self._queue.due(self.clock()):
            self._launch(key)

    def flush(self) -> None:
        """Launch every queued bucket and drain the whole pipeline."""
        while True:
            keys = self._queue.keys()
            if not keys:
                break
            for key in keys:
                self._launch(key)
        self.executor.drain_all()

    def _complete(self, ticket: Ticket) -> None:
        """Drive the pipeline until ``ticket`` resolves (Ticket.result)."""
        if ticket._queued:
            self._launch(ticket._bucket_key)
        while not ticket.done and self.executor.drain_one():
            pass

    # -- bucket launch -----------------------------------------------------

    def _launch(self, key: BucketKey) -> None:
        requests = self._queue.pop(key)
        for req in requests:
            req.ticket._queued = False
        requests = self._shed_expired(requests)
        if not requests:
            return
        info = requests[0].info
        runner = functools.partial(self._run_sync, key, info)
        n_slots = canonical_batch(len(requests), self.max_batch)
        try:
            entry = self._entry_for(key, info, n_slots, warm=False)
            stacked = self._stage(info, key, requests, n_slots)
            self.faults.check("dispatch", key.label())
            self._check_poison(requests)
        except Exception as exc:
            # staging/compile/injected failure before dispatch: the
            # requests are already out of the queue — hand them to the
            # recovery ladder instead of stranding them (or raising out
            # of poll()).
            self.executor.recover(key, requests, runner, exc)
            return
        self.executor.dispatch(entry, key, requests, n_slots, stacked,
                               runner=runner)

    def _shed_expired(self, requests):
        """Deadline shedding at launch: typed errors, no device time."""
        now = self.clock()
        live = []
        for req in requests:
            t = req.ticket
            if t.deadline is not None and now > t.deadline:
                t.error = DeadlineExceededError(
                    f"request {t.request_id} ({t.op}) waited "
                    f"{(now - t.t_enqueue) * 1e3:.1f}ms, past its deadline"
                )
                t.done = True
                t.t_done = now
                self.metrics.count("expired")
            else:
                live.append(req)
        return live

    @staticmethod
    def _check_poison(requests) -> None:
        """Fault site: a poisoned request kills any batch containing it
        (deterministically — that is what bisect-retry needs)."""
        for req in requests:
            if req.poisoned:
                raise F.InjectedFault(
                    "poison", f"request {req.ticket.request_id}")

    def _run_sync(self, key: BucketKey, info, requests):
        """Synchronous (re-)execution for the executor's recovery
        ladder: restage the given subset, run, block.  Returns
        ``(outputs, n_slots, converged)``."""
        n_slots = canonical_batch(len(requests), self.max_batch)
        entry = self._entry_for(key, info, n_slots, warm=False)
        stacked = self._stage(info, key, requests, n_slots)
        self._check_poison(requests)
        outputs, conv = Executor._call_entry(entry, stacked)
        jax.block_until_ready((outputs, conv))
        return outputs, n_slots, conv

    def _bucket_for(self, info, shape, dtype) -> BucketKey:
        """The one place (submit + warmup) bucket keys are derived."""
        h, w = shape
        return BucketKey(
            sig=info.sig,
            hw=bucket_hw(h, w, self.pad_quantum) if info.pad_safe else (h, w),
            dtype=str(np.dtype(dtype)),
            tag=info.label,
        )

    def _cache_identity(self, key: BucketKey, info, n_slots: int):
        """The cache key (and, for expression ops, the Executable —
        compiling is a cheap cached lookup).  The ``budget`` fault site
        compiles with an injected ``max_chunks``; since ``max_chunks``
        is part of ``Executable.key``, injected and clean programs never
        share a cache entry."""
        if info.expr is not None:
            budget = self.faults.value("budget", None)
            exe = api.compile(
                info.expr, (n_slots, *key.hw), np.dtype(key.dtype),
                self.backend,
                max_chunks=None if budget is None else int(budget),
            )
            if info.source is not None:
                seen = self._program_sources.setdefault(exe.key, set())
                if info.source not in seen:
                    if seen:
                        self.metrics.count("programs_shared")
                    seen.add(info.source)
            return exe.key, exe
        return (info.sig, (n_slots, *key.hw), key.dtype, self.backend), None

    def _entry_for(self, key: BucketKey, info, n_slots: int,
                   warm: bool) -> CacheEntry:
        """Compiled bucket program: the cache key *is* the compile key."""
        lookup = self.cache.warm if warm else self.cache.get
        cache_key, exe = self._cache_identity(key, info, n_slots)
        if exe is not None:
            return lookup(
                cache_key,
                lambda: CacheEntry(fn=exe.run_batch, plan=exe.plan,
                                   key=cache_key,
                                   stats_fn=exe.run_batch_stats),
            )
        spec = registry.get(info.sig[1])  # ("custom", name, canon)
        return lookup(
            cache_key,
            functools.partial(self._build_custom, spec, info.sig[2], key,
                              n_slots, cache_key),
        )

    def _build_custom(self, spec, canon: tuple, key: BucketKey,
                      n_slots: int, cache_key: tuple) -> CacheEntry:
        h, w = key.hw
        plan = None
        if self.backend == "pallas" and spec.plan_builder is not None:
            plan = spec.plan_builder(n_slots, h, w, np.dtype(key.dtype),
                                     dict(canon))

        def call(*inputs):
            out = spec.run(inputs, canon, self.backend, plan)
            return out if isinstance(out, tuple) else (out,)

        return CacheEntry(fn=jax.jit(call), plan=plan, key=cache_key)

    def _stage(self, info, key: BucketKey, requests, n_slots: int) -> tuple:
        """Host staging: pad each canonical input to the bucket shape and
        stack; sentinel slots keep the absorbing fill (they converge in
        one chunk under the active-tile scheduler)."""
        h, w = key.hw
        dtype = np.dtype(key.dtype)
        stacked = []
        for j in range(info.n_inputs):
            buf = np.full((n_slots, h, w), pad_fill(dtype, info.fills[j]),
                          dtype)
            for i, req in enumerate(requests):
                rh, rw = req.shape
                buf[i, :rh, :rw] = np.asarray(req.inputs[j])
            stacked.append(jnp.asarray(buf))
        return tuple(stacked)

    # -- warm-up + introspection ------------------------------------------

    def warmup(self, entries) -> None:
        """Prefill the compiled-program cache.

        ``entries`` is an iterable of dicts with keys ``op``, ``shape``
        (H, W), ``dtype`` and optionally ``params`` / ``batch`` (defaults
        to ``max_batch``).  Each entry is compiled *and* executed once on
        a sentinel-only stack so first real traffic pays neither trace
        nor compile time; warm builds are excluded from hit/miss stats.
        """
        for e in entries:
            spec = registry.get(e["op"])
            canon = spec.canonical_params(e.get("params"))
            info = registry.request_info(e["op"], canon)
            key = self._bucket_for(info, e["shape"], e["dtype"])
            n_slots = canonical_batch(e.get("batch", self.max_batch),
                                      self.max_batch)
            cache_key, _ = self._cache_identity(key, info, n_slots)
            if cache_key in self.cache:
                continue  # already resident: don't re-execute the program
            entry = self._entry_for(key, info, n_slots, warm=True)
            stacked = self._stage(info, key, [], n_slots)
            # execute the callable dispatch will use (the stats variant
            # for expression programs), so first traffic pays no trace
            jax.block_until_ready(entry.primary()(*stacked))

    def stats(self) -> dict:
        """Metrics summary (buckets/totals/counters/cache/faults),
        JSON-serializable."""
        out = self.metrics.summary(self.cache.stats())
        out["faults"] = self.faults.snapshot()
        return out

    def bench_rows(self) -> list[dict]:
        """Rows in the benchmarks ``name,us_per_call,derived`` contract
        (per-bucket latency/throughput plus the lifecycle counters)."""
        return (self.metrics.bench_rows(self.cache.stats())
                + self.metrics.counter_rows())

    def pending(self) -> int:
        return len(self._queue)


def serve_stream(service: Service, requests) -> list:
    """Convenience driver: submit ``(op, images, params)`` triples (or
    ``(op, image)`` pairs), flush, and return results in order."""
    tickets = []
    for r in requests:
        op, rest = r[0], r[1:]
        params = rest[-1] if rest and isinstance(rest[-1], dict) else None
        images = rest[:-1] if params is not None else rest
        images = images[0] if len(images) == 1 and isinstance(
            images[0], (tuple, list)) else images
        tickets.append(service.submit(op, *images, params=params))
    service.flush()
    return [t.result() for t in tickets]
