"""Training loop with checkpoint/restart fault tolerance.

Fault model (DESIGN.md §6): a node failure kills the process; on
restart the loop restores the latest atomic checkpoint and replays the
deterministic data stream from the restored step — state after recovery
is bitwise identical to an uninterrupted run (tested by
tests/test_fault_tolerance.py with injected failures).

Straggler/elastic posture: batches are pure functions of (seed, step,
shard); re-sharding the data stream over a different worker count needs
no coordination, and checkpoints restore onto a different mesh via
logical shardings (checkpoint.manager).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.synthetic import EmbedPipeline, TokenPipeline
from repro.models import model as MDL
from repro.optim import adamw
from repro.train.steps import build_train_step


class FailureInjector:
    """Raises at a chosen step — simulates a node dying mid-run."""

    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int):
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.fired):
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    checkpoint_every: int = 20
    checkpoint_dir: str | None = None
    q_chunk: int = 128
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 opt_cfg: adamw.AdamWConfig | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            lr=1e-3, warmup_steps=10, total_steps=tcfg.steps)
        if cfg.frontend in ("audio", "vision") and not cfg.is_enc_dec:
            self.pipeline: Any = EmbedPipeline(
                cfg.d_model, tcfg.seq_len, tcfg.global_batch,
                cfg.vocab_size, tcfg.seed)
        else:
            self.pipeline = TokenPipeline(
                cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, tcfg.seed)
        self.step_fn = jax.jit(build_train_step(
            cfg, self.opt_cfg, q_chunk=tcfg.q_chunk))
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = MDL.init_params(self.cfg, jax.random.PRNGKey(seed))
        opt_state = adamw.init_state(self.opt_cfg, params)
        return {"params": params, "opt": opt_state}

    def _batch(self, step: int):
        b = self.pipeline.batch(step)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if self.cfg.is_enc_dec:
            rng = np.random.default_rng([self.tcfg.seed, step, 11])
            out["enc_embeds"] = jnp.asarray(rng.standard_normal(
                (self.tcfg.global_batch, self.tcfg.seq_len, self.cfg.d_model),
                dtype=np.float32))
        return out

    # ------------------------------------------------------------------
    def run(self, state=None, start_step: int = 0,
            injector: FailureInjector | None = None,
            restore: bool = False):
        """Run to tcfg.steps; returns (state, history).  With
        restore=True, resumes from the latest checkpoint if present."""
        if restore and self.ckpt and self.ckpt.latest_step() is not None:
            template = jax.tree.map(np.asarray, state or self.init_state())
            state, extra, start_step = self.ckpt.restore(template)
            state = jax.tree.map(jnp.asarray, state)
        elif state is None:
            state = self.init_state()

        history = []
        for step in range(start_step, self.tcfg.steps):
            if injector:
                injector.maybe_fail(step)
            batch = self._batch(step)
            params, opt, metrics = self.step_fn(
                state["params"], state["opt"], batch)
            state = {"params": params, "opt": opt}
            loss = float(metrics["loss"])
            history.append(loss)
            if self.ckpt and (step + 1) % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, state)
            if step % self.tcfg.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
        if self.ckpt:
            self.ckpt.save(self.tcfg.steps, state)
            self.ckpt.wait()
        return state, history
