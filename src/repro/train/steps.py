"""Step builders: train_step (loss + grad + AdamW, optional microbatch
accumulation and int8 gradient compression), prefill_step, serve_step.

These are the functions the launcher jits with in/out shardings; the
dry-run lowers exactly what trains.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode as DEC
from repro.models import model as MDL
from repro.optim import adamw
from repro.optim.compression import psum_compressed


def build_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    q_chunk: int = 1024,
    accum: int = 1,
    grad_shardings=None,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum`` > 1 splits the batch into microbatches and accumulates
    gradients in f32 — the per-step activation footprint divides by
    ``accum`` (a memory lever for the 480B cells).

    ``grad_shardings``: NamedSharding tree matching params.  Pins the
    f32 accumulation carry to the parameter sharding — without it XLA
    reshards the carry every microbatch, which on FSDP meshes shows up
    as a full-weight-set all-gather per microbatch (§Perf, arctic H1).
    """

    def loss(params, batch):
        return MDL.loss_fn(params, cfg, batch, q_chunk=q_chunk)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((accum, b // accum) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                (_, m), g = grad_fn(params, mb)
                carry = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), carry, g)
                return _pin(carry), m

            zeros = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, ms = jax.lax.scan(acc_step, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)

        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def build_compressed_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    mesh,
    data_axes,
    *,
    q_chunk: int = 1024,
) -> Callable:
    """Explicit-DP train step with int8 all-reduce gradient compression
    (error feedback carried in opt_state["err"]).  Params are replicated
    across ``data_axes`` in this mode (pure DP); used by the convergence
    test and as a §Perf lever for collective-bound cells."""
    from jax.sharding import PartitionSpec as P

    # single home for the shard_map version shim
    from repro.core.distributed import SHMAP_KW as shmap_kw
    from repro.core.distributed import shard_map

    def loss(params, batch):
        return MDL.loss_fn(params, cfg, batch, q_chunk=q_chunk)

    grad_fn = jax.grad(loss, has_aux=True)

    def local(params, opt_state, batch):
        grads, metrics = grad_fn(params, batch)
        grads, new_err = psum_compressed(grads, opt_state["err"], data_axes)
        metrics = jax.tree.map(
            lambda x: jax.lax.pmean(x, data_axes), metrics)
        params, inner, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, {k: opt_state[k] for k in
                                     ("m", "v", "step")})
        return params, {**inner, "err": new_err}, {**metrics, **opt_metrics}

    pspec = jax.tree.map(lambda _: P(), {"p": 0})["p"]
    batch_spec = P(data_axes)

    def train_step(params, opt_state, batch):
        in_specs = (
            jax.tree.map(lambda _: pspec, params),
            jax.tree.map(lambda _: pspec, opt_state),
            jax.tree.map(lambda _: batch_spec, batch),
        )
        out_specs = (
            jax.tree.map(lambda _: pspec, params),
            jax.tree.map(lambda _: pspec, opt_state),
            {"loss": pspec, "aux": pspec, "grad_norm": pspec, "lr": pspec},
        )
        return shard_map(local, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs,
                         **shmap_kw)(params, opt_state, batch)

    return train_step


def build_prefill_step(cfg: ModelConfig, *, q_chunk: int = 1024) -> Callable:
    def prefill_step(params, batch):
        return DEC.prefill(
            params, cfg,
            batch.get("tokens"),
            embeds=batch.get("embeds"),
            enc_embeds=batch.get("enc_embeds"),
            q_chunk=q_chunk,
        )

    return prefill_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens):
        return DEC.decode_step(params, cfg, cache, tokens)

    return serve_step
