import os

import numpy as np
import pytest

# Every executable compiled anywhere in the suite goes through the
# fast-level static verifier (repro.analysis) — an ERROR-severity
# finding fails the compiling test with a VerificationError.
os.environ.setdefault("REPRO_VERIFY", "1")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
