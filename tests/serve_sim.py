"""Stepped-loop driver: the event-driven serving engine under a
virtual clock.

``SimHarness`` owns a :class:`~repro.serve.loop.VirtualClock` and a
:class:`~repro.serve.Service` sharing it, and *steps* the pair: advance
the clock, pump the engine (timers → flush/expiry callbacks, engine
rounds, pipeline drains), repeat.  Nothing reads wall time, so a
scenario — arrival schedule, op mix, deadlines, fault schedule —
replays bit- and counter-identically on every run; that is what the
async test suite (``test_serve_async*.py``) and the CI flake detector
(run the ``__main__`` selftest twice, diff the JSON) lean on.

Run directly for the selftest::

    PYTHONPATH=src python tests/serve_sim.py

prints a canonical JSON summary (lifecycle counters + per-bucket
request/round counts) of a fixed mixed-traffic scenario under the
ambient ``REPRO_FAULTS`` schedule, with every timestamp taken from the
virtual clock.
"""
from __future__ import annotations

import json

import numpy as np

from repro.serve import Service, VirtualClock
from repro.serve.errors import ServeError


class SimHarness:
    """A Service on a virtual clock, with stepped-time drivers."""

    def __init__(self, **service_kwargs):
        self.clock = VirtualClock()
        service_kwargs.setdefault("clock", self.clock)
        self.service = Service(**service_kwargs)
        self.tickets: list = []
        self.rejections: list = []

    def submit(self, op, *images, params=None, deadline_ms=None):
        """Submit, recording typed admission rejections instead of
        raising (a simulated client just moves on)."""
        try:
            t = self.service.submit(op, *images, params=params,
                                    deadline_ms=deadline_ms)
        except ServeError as exc:
            self.rejections.append(exc)
            return None
        self.tickets.append(t)
        return t

    def play(self, schedule):
        """Drive an arrival schedule: an iterable of
        ``(t_arrival, op, images, params, deadline_ms)`` tuples
        (``images`` a tuple).  Arrivals are played in time order, the
        engine pumped through every intervening virtual instant.
        Returns the tickets (None for rejected arrivals)."""
        out = []
        for t_arr, op, images, params, deadline_ms in sorted(
                schedule, key=lambda s: s[0]):
            self.step_until(t_arr)
            out.append(self.submit(op, *images, params=params,
                                   deadline_ms=deadline_ms))
        return out

    def step_until(self, t: float, dt: float = 1e-3) -> None:
        """Advance virtual time to ``t`` in ``dt`` steps, pumping the
        engine at every step (so timers fire at their armed instants,
        not in one burst at ``t``)."""
        while self.clock() < t:
            self.clock.advance(min(dt, t - self.clock()))
            self.service.pump()

    def run_until_idle(self, dt: float = 1e-3,
                       max_steps: int = 100_000) -> None:
        """Pump (advancing virtual time when the engine is waiting on a
        timer) until no queued/resident/in-flight work remains."""
        for _ in range(max_steps):
            if not self.service.work_pending():
                return
            if self.service.pump():
                continue
            nxt = self.service.next_deadline()
            if nxt is not None and nxt > self.clock():
                self.clock.advance(nxt - self.clock() + 1e-9)
            else:
                self.clock.advance(dt)
        raise RuntimeError("sim failed to go idle (engine stuck?)")

    def summary(self) -> dict:
        """Canonical deterministic summary: lifecycle counters plus
        per-bucket request/batch/round counts and occupancy.  Every
        number derives from the virtual clock or integer counting, so
        two replays of one scenario must produce identical output."""
        s = self.service.stats()
        return {
            "counters": s["counters"],
            "buckets": {
                label: {
                    "requests": b["requests"],
                    "batches": b["batches"],
                    "rounds": b["rounds"],
                    "errors": b["errors"],
                    "degraded": b["degraded"],
                    "occupancy": round(b["batch_occupancy"], 6),
                }
                for label, b in s["buckets"].items()
            },
            "outcomes": sorted(t.outcome for t in self.tickets),
            "rejected": len(self.rejections),
        }


def selftest_scenario(harness: SimHarness) -> dict:
    """The fixed mixed-traffic scenario behind the CI flake detector:
    reconstructions with one slow straggler (forces refills under
    ``continuous=True``), QDTs, a tight deadline, and enough arrivals
    to exercise flush timers.  Deterministic by construction."""
    rng = np.random.default_rng(1702)

    def recon_pair(slow=False):
        f = rng.random((24, 32)).astype(np.float32)
        if slow:
            f[:] = 0.1
            f[0, :] = 0.9
            m = np.full((24, 32), 0.05, np.float32)
            m[0, 0] = 0.8
        else:
            m = (0.9 * f).astype(np.float32)
        return (np.minimum(m, f), f)

    schedule = []
    t = 0.0
    for i in range(10):
        t += 0.002
        if i % 3 == 2:
            img = (rng.random((24, 32)) > 0.5).astype(np.float32)
            schedule.append((t, "qdt", (img,), None, None))
        else:
            schedule.append((t, "reconstruct", recon_pair(slow=(i == 0)),
                             None, 50.0 if i != 4 else 0.001))
    harness.play(schedule)
    harness.run_until_idle()
    return harness.summary()


if __name__ == "__main__":
    harness = SimHarness(continuous=True, max_batch=4, max_delay_ms=4.0,
                         pad_quantum=32, refill_quantum=2)
    print(json.dumps(selftest_scenario(harness), sort_keys=True, indent=1))
