"""Mutation self-tests for the static verifier (``repro.analysis``).

Each test class seeds a violation of one of the five check classes —
halo/pad-state, dtype safety, plan constraints, cache-key
completeness, index-map bounds — and asserts the verifier reports it,
plus the corresponding clean-input case.  Mutants are forged past the
constructors' own validation (``object.__new__`` for frozen plans,
``dataclasses.replace`` for programs) so the checks are exercised
independently of ``__post_init__``.
"""
import dataclasses

import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro import analysis as A
from repro.analysis.findings import ERROR, WARN, VerificationError
from repro.api import E
from repro.api.compile import compile as compile_expr
from repro.api.executable import Executable
from repro.core.chain import ChainPlan, plan_chain


def exe_for(expr, shape3=(1, 40, 72), dtype="uint8", backend="pallas"):
    return compile_expr(expr, shape3, dtype, backend, verify=False)


def forge_plan(plan, **over):
    """Copy ``plan`` with fields overridden, bypassing __post_init__."""
    mutant = object.__new__(ChainPlan)
    for f in dataclasses.fields(ChainPlan):
        object.__setattr__(mutant, f.name,
                           over.get(f.name, getattr(plan, f.name)))
    return mutant


def errors_of(findings):
    return [f for f in findings if f.severity == ERROR]


# ---------------------------------------------------------------------------
# check class a: halo coverage / pad-state discipline
# ---------------------------------------------------------------------------

class TestHalo:
    def test_clean_multi_phase_program_passes(self):
        e = E.reconstruct(E.erode(4, E.input("f")), E.input("m"),
                          op="dilate")
        exe = exe_for(e)
        assert A.check_program(exe.program) == []
        assert errors_of(A.check_coverage(
            exe.program, exe.plan, (1, 40, 72))) == []

    def test_wrong_refill_identity_detected(self):
        """Flip one masked refill to the wrong lattice identity: the
        consumer kernel's operand pad is no longer absorbing."""
        e = E.reconstruct(E.erode(4, E.input("f")), E.input("m"),
                          op="dilate")
        prog = exe_for(e).program
        segs = list(prog.segments)
        idx = next(i for i, s in enumerate(segs) if s.kind == "refill")
        fill = segs[idx].param("fill")
        flipped = tuple(("fill", "hi" if fill == "lo" else "lo")
                        if n == "fill" else (n, v)
                        for n, v in segs[idx].params)
        segs[idx] = dataclasses.replace(segs[idx], params=flipped)
        bad = dataclasses.replace(prog, segments=tuple(segs))
        errs = errors_of(A.check_program(bad))
        assert errs and any("leak" in f.message for f in errs)

    def test_dropped_refill_detected(self):
        e = E.reconstruct(E.erode(4, E.input("f")), E.input("m"),
                          op="dilate")
        prog = exe_for(e).program
        assert any(s.kind == "refill" for s in prog.segments)
        bad = dataclasses.replace(prog, segments=tuple(
            s for s in prog.segments if s.kind != "refill"))
        assert errors_of(A.check_program(bad))

    def test_input_slot_misbinding_detected(self):
        """Binding canonical inputs by position instead of by the
        lowered ``run_input_slots`` (the historical executable bug)."""
        e = E.reconstruct(E.erode(1, E.input("a")), E.input("b"),
                          op="erode")
        prog = exe_for(e).program
        # the lowerer allocates the mask's slot after the chain's output
        assert prog.run_input_slots != tuple(
            range(len(prog.run_input_slots)))
        bad = dataclasses.replace(
            prog, run_input_slots=tuple(range(len(prog.run_input_slots))))
        errs = errors_of(A.check_program(bad))
        assert errs and any("before any definition" in f.message
                            for f in errs)

    def test_slot_binding_regression_bit_exact(self):
        """The non-contiguous-slot program itself runs bit-exact on both
        engines (regression for the enumerate-based binding)."""
        rng = np.random.default_rng(3)
        a = rng.integers(0, 255, (1, 40, 72), dtype=np.uint8)
        b = rng.integers(0, 255, (1, 40, 72), dtype=np.uint8)
        e = E.reconstruct(E.erode(1, E.input("a")), E.input("b"),
                          op="erode")
        outs = [np.asarray(exe_for(e, backend=bk)(a, b))
                for bk in ("pallas", "xla")]
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_plan_under_coverage_warned(self):
        exe = exe_for(E.erode(6, E.input("f")))
        # a stale schedule: 1 launch of 2 fused steps for a 6-chain
        short = forge_plan(exe.plan, fuse_k=2, band_h=16, n_chunks=1)
        finds = A.check_coverage(exe.program, short, (1, 40, 72))
        assert any(f.severity == WARN and "under-cover" in f.message
                   for f in finds)


# ---------------------------------------------------------------------------
# check class b: dtype safety
# ---------------------------------------------------------------------------

class TestDtypes:
    def test_bucketer_fills_clean(self):
        assert errors_of(A.check_bucketer_fills()) == []

    def test_non_identity_fill_detected(self):
        assert errors_of(A.check_fill_value("uint8", "hi", 254))
        assert errors_of(A.check_fill_value("float32", "lo", np.inf))
        assert A.check_fill_value("uint8", "hi", 255) == []

    def test_unrepresentable_fill_detected(self):
        assert errors_of(A.check_fill_value("uint8", "hi", 255.5))

    def test_qdt_accumulator_overflow(self):
        # provable: uint16 residuals overflow an int16 accumulator
        assert errors_of(A.check_qdt_accumulator("uint16", "int16"))
        # provable: fractional residuals truncate in an int accumulator
        assert errors_of(A.check_qdt_accumulator("float32", "int32"))
        # provable: int32 residuals exceed the float32 mantissa
        assert errors_of(A.check_qdt_accumulator("int32", "float32"))
        # production rule is safe for the narrow dtypes
        assert A.check_qdt_accumulator("uint8") == []
        assert A.check_qdt_accumulator("uint16") == []

    def test_qdt_accumulator_domain_conditional_warns(self):
        for img, acc in (("int32", "int32"), ("float64", "float32")):
            finds = A.check_qdt_accumulator(img, acc)
            assert finds and all(f.severity == WARN for f in finds)

    def test_distance_plane_overflow(self):
        assert errors_of(A.check_distance_plane(2 ** 28, 2 ** 8))
        assert A.check_distance_plane(1000, 16) == []


# ---------------------------------------------------------------------------
# check class c: plan constraints + Mosaic readiness
# ---------------------------------------------------------------------------

class TestPlans:
    def test_derived_plans_pass(self):
        for h, w in ((64, 64), (33, 70), (200, 128)):
            plan = plan_chain(h, w, "uint8", 8)
            assert errors_of(A.check_plan(plan, (1, h, w))) == []

    def test_band_fuse_violation_detected(self):
        plan = plan_chain(64, 64, "uint8", 8)
        bad = forge_plan(plan, band_h=plan.fuse_k * 2 + 1)
        assert errors_of(A.check_plan(bad))

    def test_ragged_tile_detected(self):
        plan = plan_chain(64, 64, "uint8", 8)
        bad = forge_plan(plan, tile_w=plan.fuse_k + 1)
        errs = errors_of(A.check_plan(bad))
        assert errs and any("tile_w" in f.message for f in errs)

    def test_requeue_exactness_detected(self):
        plan = plan_chain(64, 64, "uint8", 8)
        bad = forge_plan(plan, requeue_halo=0)
        assert errors_of(A.check_plan(bad))

    def test_shape_coverage_detected(self):
        plan = plan_chain(64, 64, "uint8", 8)
        assert errors_of(A.check_plan(plan, (1, plan.height_pad + 1,
                                             plan.width_pad)))
        assert errors_of(A.check_plan(plan, (2, 64, 64)))  # n_images=1

    def test_mosaic_readiness_warns(self):
        plan = ChainPlan(band_h=16, fuse_k=8, width_pad=256,
                         height_pad=64, n_bands=4, n_chunks=1, tile_w=64)
        finds = A.check_mosaic_readiness(plan, "uint8")
        assert finds and all(f.severity == WARN for f in finds)
        assert any("fuse_k" in f.message and "lanes wide" in f.message
                   for f in finds)  # the PR 4 on-TPU blocker

    def test_lane_aligned_plan_is_quiet_on_width(self):
        plan = plan_chain(64, 128, "uint8", 8)
        assert not any(f.subject == "mosaic/width"
                       for f in A.check_mosaic_readiness(plan, "uint8"))


# ---------------------------------------------------------------------------
# check class d: cache-key completeness
# ---------------------------------------------------------------------------

class TestCacheKeys:
    def test_plan_key_is_complete(self):
        plan = plan_chain(64, 96, "uint8", 8)
        assert A.check_plan_key(plan) == []

    def test_plan_key_gap_detected(self):
        plan = plan_chain(64, 96, "uint8", 8)
        # a key that forgets the schedule's tile/requeue fields
        broken = lambda p: (p.band_h, p.fuse_k, p.width_pad,  # noqa: E731
                            p.height_pad)
        finds = A.check_plan_key(plan, key_of=broken)
        assert finds and all(f.check == "cache-key" for f in finds)
        assert any("n_chunks" in f.message for f in finds)

    @pytest.mark.parametrize("backend", ["pallas", "xla"])
    def test_executable_key_is_complete(self, backend):
        e = E.reconstruct(E.erode(4, E.input("f")), E.input("m"),
                          op="dilate")
        exe = exe_for(e, backend=backend)
        assert A.check_executable_key(exe) == []

    def test_executable_key_gap_detected(self):
        exe = exe_for(E.erode(4, E.input("f")))
        # forget everything but the run signature and shape
        broken = lambda x: x.key[:2]  # noqa: E731
        finds = A.check_executable_key(exe, key_of=broken)
        insensitive = {f.message.split(" — ")[0] for f in finds}
        assert any("was_2d" in m for m in insensitive)
        assert any("max_chunks" in m for m in insensitive)


# ---------------------------------------------------------------------------
# check class e: index-map bounds
# ---------------------------------------------------------------------------

class TestIndexMaps:
    def test_real_specs_in_bounds(self):
        for kwargs in ({}, {"tile_w": 64}):
            plan = ChainPlan(band_h=16, fuse_k=8, width_pad=128,
                             height_pad=64, n_bands=4, n_chunks=2,
                             n_images=2, **kwargs)
            assert A.check_plan_index_maps(plan) == []

    def test_unclamped_top_halo_detected(self):
        # the real map is max(i*r - 1, 0); drop the clamp
        spec = pl.BlockSpec((8, 64), lambda i: (i * 2 - 1, 0))
        finds = A.check_block_specs([spec], (4,), (64, 64))
        assert any("negative block index" in f.message for f in finds)

    def test_unclamped_bottom_halo_detected(self):
        # the real map is min((i+1)*r, last); drop the clamp
        spec = pl.BlockSpec((8, 64), lambda i: (i * 2 + 2, 0))
        finds = A.check_block_specs([spec], (4,), (64, 64))
        assert any("past axis-0 extent" in f.message for f in finds)

    def test_non_dividing_block_detected(self):
        spec = pl.BlockSpec((10, 64), lambda i: (i, 0))
        finds = A.check_block_specs([spec], (4,), (64, 64))
        assert any("does not divide" in f.message for f in finds)

    def test_partition_violations_detected(self):
        overlap = pl.BlockSpec((16, 64), lambda i: (0, 0))
        finds = A.check_partition(overlap, (4,), (64, 64))
        assert any("both map to block" in f.message for f in finds)
        assert any("never visited" in f.message for f in finds)


# ---------------------------------------------------------------------------
# orchestration: verifier levels, compile hook, lint
# ---------------------------------------------------------------------------

class TestVerifier:
    def test_full_level_clean_on_registry_sample(self):
        from repro.analysis.lint import iter_registry_cases
        cases = list(iter_registry_cases(
            dtypes=("uint8",), shapes=((1, 48, 64),),
            backends=("pallas",)))
        assert cases
        for _label, expr, shape3, dtype, backend in cases:
            exe = compile_expr(expr, shape3, dtype, backend, verify=False)
            report = A.verify_executable(exe, level="full")
            assert report.ok, str(report)

    def test_hook_raises_on_seeded_violation(self):
        exe = exe_for(E.erode(4, E.input("f")))
        bad_prog = dataclasses.replace(
            exe.program,
            run_input_slots=tuple(s + 7 for s in
                                  exe.program.run_input_slots))
        bad = Executable(bad_prog, (1, 40, 72), "uint8", "pallas",
                         exe.plan, None, False)
        report = A.verify_executable(bad, level="fast")
        with pytest.raises(VerificationError) as ei:
            report.raise_if_errors()
        assert isinstance(ei.value, AssertionError)

    def test_hook_env_toggle(self, monkeypatch):
        from repro.analysis.verifier import verify_on_compile
        monkeypatch.setenv("REPRO_VERIFY", "0")
        assert not verify_on_compile()
        monkeypatch.setenv("REPRO_VERIFY", "1")
        assert verify_on_compile()

    def test_lint_cli_clean(self, capsys):
        from repro.analysis.lint import main
        rc = main(["--dtypes", "uint8", "--shapes", "1x48x64",
                   "--backends", "xla"])
        out = capsys.readouterr().out
        assert rc == 0 and "lint: ok" in out

    def test_lint_cli_rejects_bad_shape(self):
        from repro.analysis.lint import main
        with pytest.raises(SystemExit):
            main(["--shapes", "48x64"])
