"""Property test: for random expression graphs, the lowering + planner
always satisfy the static verifier's independently derived proofs —
pad-state discipline holds, the derived plan's pad/halo/launch budget
covers the verifier's computed Chebyshev reach, and the real BlockSpec
index maps stay in bounds over the full grid.

Gated on Hypothesis (not installed in every environment); the
deterministic mutation coverage lives in ``tests/test_analysis.py``.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis",
                                 reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro import analysis as A  # noqa: E402
from repro.analysis.halo import segment_reach  # noqa: E402
from repro.api import E  # noqa: E402
from repro.api.compile import compile as compile_expr  # noqa: E402

pytestmark = pytest.mark.pipeline


def _leaf(name):
    return E.input(name)


_leaves = st.sampled_from(["f", "g"]).map(_leaf)


def _extend(children):
    chains = st.tuples(st.sampled_from(["erode", "dilate"]),
                       st.integers(1, 9), children)
    recons = st.tuples(st.sampled_from(["erode", "dilate"]),
                       children, children)
    return st.one_of(
        chains.map(lambda t: getattr(E, t[0])(t[1], t[2])),
        recons.map(lambda t: E.reconstruct(t[1], t[2], op=t[0])),
    )


_exprs = st.recursive(_leaves, _extend, max_leaves=4)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(expr=_exprs, shape=st.sampled_from([(1, 40, 72), (2, 33, 70)]))
def test_lowering_satisfies_static_proofs(expr, shape):
    if expr.kind == "input":
        return  # nothing lowered: no run phase to verify
    exe = compile_expr(expr, shape, "uint8", "pallas", verify=False)

    # pad-state discipline: re-proved independently of the lowerer
    assert A.check_program(exe.program) == [], expr

    if exe.plan is None:
        return
    plan, shape3 = exe.plan, shape

    # plan constraints + shape coverage (pad >= image)
    assert [f for f in A.check_plan(plan, shape3)
            if f.severity == A.ERROR] == [], expr

    # the derived launch budget covers the verifier's computed reach:
    # check_coverage must not even warn for a freshly derived plan
    assert A.check_coverage(exe.program, plan, shape3) == [], expr
    reach = max((r for s in exe.program.segments
                 if (r := segment_reach(s)) is not None), default=0)
    if not exe.program.convergent:
        assert plan.n_chunks * plan.fuse_k >= reach, expr

    # the real index maps stay in bounds over the whole grid
    assert A.check_plan_index_maps(plan) == [], expr
