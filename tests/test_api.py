"""Expression API (repro.api): every operator built as a graph and
``compile``d must be bit-exact against independently composed dense
references, on both backends, 2-D and batched, across dtypes; fusion
must be *visible* in ``Executable.stats()`` (fewer pad/launch
round-trips than the legacy per-stage path); and the deprecation shims
on the legacy call surfaces must warn while staying bit-exact.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import api
from repro.api import E
from repro.api.lower import LoweringError, lower
from repro.core import morphology as M
from repro.core import operators as OPS
from repro.core.backend import BACKENDS, canonicalize_backend, default_backend
from repro.core.chain import plan_chain
from repro.kernels import ops as K

pytestmark = pytest.mark.pipeline

DTYPES = [np.uint8, np.float32, np.float64]


def _image(rng, shape, dtype):
    if np.issubdtype(np.dtype(dtype), np.integer):
        return rng.integers(0, 255, shape).astype(dtype)
    return rng.normal(size=shape).astype(dtype)


def _h(dtype):
    return 40 if np.issubdtype(np.dtype(dtype), np.integer) else 0.25


# Each case: name -> (expr builder (dtype -> Expr),
#                     per-image dense reference (f2d [, m2d] -> out),
#                     arity)
def _cases():
    def asf_ref(f):
        out = f
        for k in (1, 2):
            out = M.closing(M.opening(out, k), k)
        return out

    return {
        "erode5": (lambda dt: E.erode(5, E.input("f")),
                   lambda f: M.erode(f, 5), 1),
        "dilate5": (lambda dt: E.dilate(5, E.input("f")),
                    lambda f: M.dilate(f, 5), 1),
        "opening3": (lambda dt: E.opening(3, E.input("f")),
                     lambda f: M.opening(f, 3), 1),
        "closing3": (lambda dt: E.closing(3, E.input("f")),
                     lambda f: M.closing(f, 3), 1),
        "hmax": (lambda dt: api.hmax_expr(_h(dt)),
                 lambda f: M.dilate_reconstruct(
                     OPS.sat_sub(f, _h(f.dtype)), f), 1),
        "dome": (lambda dt: api.dome_expr(_h(dt)),
                 lambda f: f - M.dilate_reconstruct(
                     OPS.sat_sub(f, _h(f.dtype)), f), 1),
        "hfill": (lambda dt: api.hfill_expr(),
                  lambda f: M.erode_reconstruct(OPS.hfill_marker(f), f), 1),
        "raobj": (lambda dt: api.raobj_expr(),
                  lambda f: f - M.dilate_reconstruct(
                      OPS.raobj_marker(f), f), 1),
        "open_rec3": (lambda dt: api.opening_by_reconstruction_expr(3),
                      lambda f: M.dilate_reconstruct(M.erode(f, 3), f), 1),
        "asf2": (lambda dt: api.asf_expr(2), asf_ref, 1),
        "qdt_l1": (lambda dt: api.qdt_l1_expr(),
                   lambda f: OPS.qdt_regularize(OPS.qdt_raw(f)[0]), 1),
        "reconstruct": (
            lambda dt: E.reconstruct(E.input("marker"), E.input("mask"),
                                     op="dilate"),
            lambda mk, ms: M.dilate_reconstruct(mk, ms), 2),
        "geodesic4": (
            lambda dt: E.geodesic(E.input("marker"), E.input("mask"),
                                  4, "erode"),
            lambda mk, ms: M.geodesic_erode(mk, ms, 4), 2),
    }


def _inputs(rng, shape, dtype, arity):
    if arity == 1:
        return (jnp.asarray(_image(rng, shape, dtype)),)
    mask = _image(rng, shape, dtype)
    marker = np.minimum(_image(rng, shape, dtype), mask)  # marker <= mask
    return (jnp.asarray(marker), jnp.asarray(mask))


CASES = _cases()


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("batched", [False, True], ids=["2d", "batched"])
@pytest.mark.parametrize("name", sorted(CASES))
def test_expression_ops_bit_exact_xla(rng, name, dtype, batched):
    """Every expression-built operator vs its dense reference (xla)."""
    build, ref, arity = CASES[name]
    shape = (3, 26, 33) if batched else (26, 33)
    if name == "geodesic4":
        # geodesic erosion wants marker >= mask
        mask = jnp.asarray(_image(rng, shape, dtype))
        other = jnp.asarray(_image(rng, shape, dtype))
        inputs = (jnp.maximum(other, mask), mask)
    else:
        inputs = _inputs(rng, shape, dtype, arity)
    exe = api.compile(build(dtype), shape, inputs[0].dtype, "xla")
    out = exe(*inputs)
    if batched:
        for i in range(shape[0]):
            np.testing.assert_array_equal(
                np.asarray(out[i]),
                np.asarray(ref(*(x[i] for x in inputs))),
                err_msg=f"{name} {np.dtype(dtype)} image {i}")
    else:
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref(*inputs)),
            err_msg=f"{name} {np.dtype(dtype)}")


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
@pytest.mark.parametrize("batched", [False, True], ids=["2d", "batched"])
@pytest.mark.parametrize("name", ["erode5", "hmax", "open_rec3", "asf2"])
def test_expression_ops_bit_exact_pallas(rng, name, dtype, batched):
    """The padded fused programs (chains, refills, OBR's chain +
    reconstruction, the requeue scheduler) vs the same references."""
    build, ref, arity = CASES[name]
    shape = (2, 40, 52) if batched else (40, 52)
    inputs = _inputs(rng, shape, dtype, arity)
    exe = api.compile(build(dtype), shape, inputs[0].dtype, "pallas")
    out = exe(*inputs)
    outs = out if batched else out[None]
    for i in range(outs.shape[0]):
        np.testing.assert_array_equal(
            np.asarray(outs[i]),
            np.asarray(ref(*((x if not batched else x[i])
                             for x in inputs))),
            err_msg=f"{name} {np.dtype(dtype)} image {i}")


def test_qdt_expression_two_outputs(rng):
    f = jnp.asarray(_image(rng, (40, 52), np.uint8))
    for backend in ("xla", "pallas"):
        d, r = api.compile(E.qdt(E.input("f")), f.shape, f.dtype, backend)(f)
        dw, rw = OPS.qdt_raw(f)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(dw))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rw))


# ---------------------------------------------------------------------------
# fusion accounting: the point of the single padded program
# ---------------------------------------------------------------------------


def _stagewise_stats(stages, shape, dtype):
    """The legacy path: one compiled program (pad + launch + crop) per
    elementary stage, summed via Executable.stats()."""
    totals = {"pads": 0, "crops": 0, "launches": 0}
    for op, s in stages:
        builder = E.erode if op == "erode" else E.dilate
        st = api.compile(builder(s, E.input("f")), shape, dtype,
                         "pallas").stats()
        for k in totals:
            totals[k] += st[k]
    return totals


def test_asf_fuses_fewer_roundtrips():
    """ASF via compile: one pad/crop and 2s+1 fused launches vs the
    per-stage path's 4s pad/launch/crop round-trips."""
    s = 3
    shape, dtype = (64, 96), np.uint8
    fused = api.compile(api.asf_expr(s), shape, dtype, "pallas").stats()
    stages = []
    for k in range(1, s + 1):
        stages += [("erode", k), ("dilate", k),   # γ_k
                   ("dilate", k), ("erode", k)]   # φ_k
    legacy = _stagewise_stats(stages, shape, dtype)
    assert fused["pads"] == 1 and fused["crops"] == 1
    assert fused["launches"] == 2 * s + 1
    assert legacy["pads"] == 4 * s and legacy["launches"] == 4 * s
    assert fused["pads"] < legacy["pads"]
    assert fused["launches"] < legacy["launches"]
    assert fused["fused_chain_len"] == OPS.asf_chain_length(s)


def test_obr_specializes_per_segment_plans():
    """Opening-by-reconstruction mixes a fixed chain with a convergent
    reconstruction: by default compile specializes one plan per segment
    group (a re-band boundary between them); ``specialize=False``
    restores the single shared-plan program (one pad, one crop)."""
    expr = api.opening_by_reconstruction_expr(4)
    st = api.compile(expr, (64, 96), np.uint8, "pallas").stats()
    assert st["plans"] == 2 and st["rebands"] == 1
    assert st["launches"] == 2  # chain + reconstruct
    # re-band boundary: chain output crops, marker/mask re-pad (3 pads)
    assert st["pads"] == 3 and st["crops"] == 2
    single = api.compile(expr, (64, 96), np.uint8, "pallas",
                         specialize=False).stats()
    assert single["plans"] == 1 and single["rebands"] == 0
    assert single["pads"] == 1 and single["crops"] == 1
    prog = lower(expr)
    assert [s.kind for s in prog.kernel_segments] == ["chain", "reconstruct"]


def test_adjacent_chain_runs_merge():
    f = E.input("f")
    prog = lower(E.erode(3, E.erode(2, f)))
    (seg,) = prog.segments
    assert seg.kind == "chain" and seg.param("n") == 5
    # a shared intermediate must NOT fuse through
    mid = E.erode(2, f)
    prog2 = lower(E.sub(E.erode(3, mid), mid))
    assert [s.param("n") for s in prog2.kernel_segments] == [2, 3]


# ---------------------------------------------------------------------------
# compile cache + keys
# ---------------------------------------------------------------------------


def test_compile_cache_hits():
    expr = api.hmax_expr(17.0)
    before = api.cache_stats()
    a = api.compile(expr, (32, 32), np.uint8, "xla")
    b = api.compile(expr, (32, 32), np.uint8, "xla")
    assert a is b
    after = api.cache_stats()
    assert after["hits"] >= before["hits"] + 1


def test_run_signature_shared_across_prepare_only_differences():
    """HMAX/DOME/RAOBJ — and HMAX at different h — lower to the same
    run phase, hence the same bucket/compile identity."""
    sig = lower(api.hmax_expr(40.0)).run_sig
    assert lower(api.hmax_expr(12.5)).run_sig == sig
    assert lower(api.dome_expr(40.0)).run_sig == sig
    assert lower(api.raobj_expr()).run_sig == sig
    assert lower(api.hfill_expr()).run_sig != sig  # erode-reconstruction
    exe_h = api.compile(api.hmax_expr(40.0), (2, 32, 32), np.uint8, "pallas")
    exe_d = api.compile(api.dome_expr(12.5), (2, 32, 32), np.uint8, "pallas")
    assert exe_h.key == exe_d.key


def test_serve_cross_op_co_batching(rng):
    """hmax + dome + raobj requests land in ONE bucket and one batch."""
    from repro.serve import Service

    class FakeClock:
        t = 0.0

        def __call__(self):
            return self.t

    f1, f2, f3 = (_image(rng, (40, 56), np.uint8) for _ in range(3))
    svc = Service(backend="xla", max_batch=4, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    t1 = svc.submit("hmax", f1, params={"h": 40})
    t2 = svc.submit("dome", f2, params={"h": 25})
    t3 = svc.submit("raobj", f3)
    svc.flush()
    stats = svc.stats()
    assert stats["totals"]["batches"] == 1  # cross-op packed
    (bucket,) = stats["buckets"].values()
    assert bucket["requests"] == 3
    np.testing.assert_array_equal(
        np.asarray(t1.result()),
        np.asarray(M.dilate_reconstruct(OPS.sat_sub(jnp.asarray(f1), 40),
                                        jnp.asarray(f1))))
    np.testing.assert_array_equal(
        np.asarray(t2.result()),
        np.asarray(jnp.asarray(f2) - M.dilate_reconstruct(
            OPS.sat_sub(jnp.asarray(f2), 25), jnp.asarray(f2))))
    np.testing.assert_array_equal(
        np.asarray(t3.result()),
        np.asarray(jnp.asarray(f3) - M.dilate_reconstruct(
            OPS.raobj_marker(jnp.asarray(f3)), jnp.asarray(f3))))


# ---------------------------------------------------------------------------
# backend policy + deprecation shims
# ---------------------------------------------------------------------------


def test_backend_policy_single_source():
    assert default_backend() in BACKENDS
    assert canonicalize_backend(None) == default_backend()
    assert canonicalize_backend("xla") == "xla"
    with pytest.raises(ValueError, match="backend must be one of"):
        canonicalize_backend("cuda")
    with pytest.raises(ValueError, match="backend must be one of"):
        api.compile(E.erode(2, E.input("f")), (16, 16), np.uint8, "cuda")


def test_default_backends_agree(rng):
    """operators and kernels resolve the same policy default now."""
    f = jnp.asarray(_image(rng, (32, 40), np.uint8))
    np.testing.assert_array_equal(
        np.asarray(K.erode(f, 3)), np.asarray(M.erode(f, 3)))
    np.testing.assert_array_equal(
        np.asarray(OPS.hmax(f, 40)),
        np.asarray(M.dilate_reconstruct(OPS.sat_sub(f, 40), f)))


def test_deprecation_shims_warn_and_match(rng):
    f = jnp.asarray(_image(rng, (36, 44), np.uint8))
    mask = jnp.asarray(_image(rng, (36, 44), np.uint8))
    marker = jnp.minimum(f, mask)

    with pytest.warns(DeprecationWarning, match="backend"):
        legacy = OPS.hmax(f, 40, backend="xla")
    np.testing.assert_array_equal(np.asarray(legacy),
                                  np.asarray(OPS.hmax(f, 40)))

    with pytest.warns(DeprecationWarning, match="backend"):
        legacy = OPS.hmax(f, 40, backend="pallas")
    np.testing.assert_array_equal(np.asarray(legacy),
                                  np.asarray(OPS.hmax(f, 40)))

    with pytest.warns(DeprecationWarning, match="max_iters"):
        trunc = OPS.hfill(f, max_iters=f.shape[0] * f.shape[1])
    np.testing.assert_array_equal(np.asarray(trunc),
                                  np.asarray(OPS.hfill(f)))

    with pytest.warns(DeprecationWarning, match="backend"):
        legacy = K.reconstruct(marker, mask, "dilate", backend="xla")
    np.testing.assert_array_equal(
        np.asarray(legacy), np.asarray(M.dilate_reconstruct(marker, mask)))

    with pytest.warns(DeprecationWarning, match="max_chunks"):
        capped = K.reconstruct(marker, mask, "dilate",
                               max_chunks=f.shape[0] * f.shape[1])
    np.testing.assert_array_equal(
        np.asarray(capped), np.asarray(M.dilate_reconstruct(marker, mask)))

    with pytest.warns(DeprecationWarning, match="backend"):
        d, r = K.qdt_planes(f, backend="xla")
    dw, rw = OPS.qdt_raw(f)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dw))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rw))


# ---------------------------------------------------------------------------
# expression sugar + validation
# ---------------------------------------------------------------------------


def test_pipe_sugar_builds_the_same_graph():
    f = E.input("f")
    assert (f >> E.erode(2) >> E.dilate(3)) == E.dilate(3, E.erode(2, f))
    combo = E.erode(2) >> E.dilate(3)      # point-free composition
    assert combo(f) == E.dilate(3, E.erode(2, f))
    assert (f >> E.reconstruct(mask=f, op="dilate")
            == E.reconstruct(f, f, op="dilate"))
    assert f - E.erode(1, f) == E.sub(f, E.erode(1, f))


def test_compile_validation(rng):
    f = E.input("f")
    with pytest.raises(TypeError, match="unapplied pipe"):
        api.compile(E.erode(2), (16, 16), np.uint8)
    with pytest.raises(ValueError, match="shape must be"):
        api.compile(E.erode(2, f), (16,), np.uint8)
    exe = api.compile(E.erode(2, f), (16, 16), np.uint8, "xla")
    with pytest.raises(ValueError, match="does not match the compiled"):
        exe(jnp.zeros((8, 8), jnp.uint8))
    with pytest.raises(ValueError, match="dtype"):
        exe(jnp.zeros((16, 16), jnp.float32))
    with pytest.raises(TypeError, match="takes 1 input"):
        exe(jnp.zeros((16, 16), jnp.uint8), jnp.zeros((16, 16), jnp.uint8))
    bad_plan = plan_chain(64, 64, np.uint8, None)
    with pytest.raises(ValueError, match="smaller than"):
        api.compile(E.erode(2, f), (200, 200), np.uint8, "pallas",
                    plan=bad_plan)
    # per-image reductions between kernels are not lowerable (elementwise
    # maps now bridge as "point" segments — see test_point_segment_bridge)
    with pytest.raises(LoweringError, match="pointwise"):
        lower(E.erode(2, E.hfill_marker(E.erode(1, f))))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_point_segment_bridge(rng, backend):
    """Elementwise exprs between kernels lower as a ``point`` segment
    (top-hat fed back into an erosion) and stay bit-exact."""
    f = E.input("f")
    expr = E.erode(2, E.sub(f, E.erode(1, f)))
    prog = lower(expr)
    assert [s.kind for s in prog.segments] == [
        "refill", "chain", "point", "refill", "chain"]
    img = jnp.asarray(rng.integers(0, 255, (24, 30)).astype(np.uint8))
    out = api.compile(expr, img.shape, img.dtype, backend)(img)
    tophat = np.asarray(img) - np.asarray(M.erode(img, 1))
    ref = np.asarray(M.erode(jnp.asarray(tophat), 2))
    np.testing.assert_array_equal(np.asarray(out), ref)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_pick_fanout_edges(rng, backend):
    """E.pick edge cases: out-of-range index, pick-of-pick collapse,
    and one pick feeding two consumers."""
    f = E.input("f")
    q = E.qdt(f)
    with pytest.raises(ValueError, match="out of range"):
        E.pick(q, q.n_outputs)
    with pytest.raises(ValueError, match="out of range"):
        E.pick(q, -1)
    # pick of a single-output node is the node itself, so pick-of-pick
    # collapses to one pick
    d = E.pick(q, 0)
    assert E.pick(d, 0) is d
    # one pick fanning out into two consumers of the same kernel output
    expr = E.sub(E.sat_add(d, 1), d)
    img = jnp.asarray((rng.integers(0, 2, (24, 30)) * 255).astype(np.uint8))
    out = api.compile(expr, img.shape, img.dtype, backend)(img)
    d_ref = np.asarray(
        api.compile(d, img.shape, img.dtype, "xla")(img))
    ref = np.minimum(d_ref.astype(np.int64) + 1, 255).astype(np.uint8) - d_ref
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_operator_sugar_accepts_nd_batches(rng):
    """The (..., H, W) contract: leading batch dims beyond one fold
    into a stack and unfold after."""
    f = jnp.asarray(rng.integers(0, 255, (2, 3, 24, 30)).astype(np.uint8))
    out = OPS.hmax(f, 40)
    d = OPS.qdt(f)
    assert out.shape == f.shape and d.shape == f.shape
    for i in range(2):
        for j in range(3):
            np.testing.assert_array_equal(
                np.asarray(out[i, j]),
                np.asarray(M.dilate_reconstruct(
                    OPS.sat_sub(f[i, j], 40), f[i, j])))
            np.testing.assert_array_equal(
                np.asarray(d[i, j]),
                np.asarray(OPS.qdt_regularize(OPS.qdt_raw(f[i, j])[0])))


def test_explicit_plan_validated_on_every_backend():
    """A mismatched plan= is a caller bug even when the jnp engine
    would not use it."""
    bad = plan_chain(64, 64, np.uint8, None)
    for backend in ("xla", "pallas"):
        with pytest.raises(ValueError, match="smaller than"):
            api.compile(E.erode(2, E.input("f")), (200, 200), np.uint8,
                        backend, plan=bad)


def test_array_threshold_honors_backend(rng):
    """A non-scalar h cannot embed in the graph, but the reconstruction
    still compiles on the requested backend (and stays bit-exact)."""
    f = jnp.asarray(_image(rng, (40, 52), np.uint8))
    want = np.asarray(OPS.hmax(f, 40))
    with pytest.warns(DeprecationWarning, match="backend"):
        out = OPS.hmax(f, jnp.asarray(40, f.dtype), backend="pallas")
    np.testing.assert_array_equal(np.asarray(out), want)
    np.testing.assert_array_equal(          # and with the policy default
        np.asarray(OPS.dome(f, jnp.asarray(40, f.dtype))),
        np.asarray(OPS.dome(f, 40)))


def test_co_batched_ops_with_different_output_arity(rng):
    """Two ops sharing one run signature but fanning finalize into
    different output counts must each demux with their own arity."""
    from repro.serve import Service, registry

    register_spec = registry.OpSpec(
        name="_qdt_span_test", params={},
        expr_builder=lambda p: E.sub(E.pick(E.qdt(E.input("f")), 0),
                                     E.pick(E.qdt(E.input("f")), 1)),
    )
    registry.register(register_spec)
    try:
        f1 = _image(rng, (32, 40), np.uint8)
        f2 = _image(rng, (32, 40), np.uint8)
        svc = Service(backend="xla", max_batch=4, max_delay_ms=1e9,
                      pad_quantum=32)
        tq = svc.submit("qdt", f1)            # n_outputs == 2
        ts = svc.submit("_qdt_span_test", f2)  # n_outputs == 1
        svc.flush()
        assert svc.stats()["totals"]["batches"] == 1  # same run signature
        d, r = tq.result()                    # still a 2-tuple
        dw, rw = OPS.qdt_raw(jnp.asarray(f1))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(dw))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rw))
        span = ts.result()                    # a single array
        dw2, rw2 = OPS.qdt_raw(jnp.asarray(f2))
        np.testing.assert_array_equal(np.asarray(span),
                                      np.asarray(dw2 - rw2))
    finally:
        registry._REGISTRY.pop("_qdt_span_test", None)


def test_registry_derived_shapes():
    """Registry OpSpecs are derived from the lowered expressions."""
    from repro.serve import registry

    assert registry.get("reconstruct").arity == 2
    assert registry.get("geodesic").arity == 2
    assert registry.get("qdt").n_outputs == 2
    assert registry.get("hmax").n_inputs == 2      # (marker, mask)
    assert registry.get("asf").pad_safe is False   # exact-shape buckets
    assert registry.get("open_rec").pad_safe is False  # fused multi-phase
    assert registry.get("erode").pad_safe is True
    assert registry.get("qdt_l1").pad_safe is True  # η-step is finalize
