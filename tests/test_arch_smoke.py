"""Per-architecture smoke tests (assignment requirement): reduced config
of the same family, one forward/train step on CPU, output shapes +
finiteness asserted.  Also decode-vs-forward consistency where the arch
admits it."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.configs.shapes import cells_for
from repro.models import decode as DEC
from repro.models import model as MDL

B, S = 2, 32


def _batch(cfg, rng):
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    labels = jnp.roll(tok, -1, axis=1)
    batch = {"tokens": tok, "labels": labels}
    kw = {}
    if cfg.frontend == "vision":
        emb = jnp.asarray(rng.standard_normal((B, S, cfg.d_model),
                                              dtype=np.float32))
        batch = {"embeds": emb, "labels": labels}
        kw["embeds"] = emb
    if cfg.is_enc_dec:
        ee = jnp.asarray(rng.standard_normal((B, S, cfg.d_model),
                                             dtype=np.float32))
        batch["enc_embeds"] = ee
        kw["enc_embeds"] = ee
    return batch, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch, rng):
    cfg = get_reduced(arch)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    batch, _ = _batch(cfg, rng)
    loss, metrics = jax.jit(
        lambda p, b: MDL.loss_fn(p, cfg, b, q_chunk=16))(params, batch)
    assert np.isfinite(float(loss))
    # near ln(V) at init: sane logit scale
    assert float(loss) < np.log(cfg.vocab_size) + 3.0

    logits, aux = jax.jit(
        lambda p, b: MDL.forward(p, cfg, b.get("tokens"),
                                 embeds=b.get("embeds"),
                                 enc_embeds=b.get("enc_embeds"),
                                 q_chunk=16))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_descends(arch, rng):
    from repro.optim import adamw
    from repro.train.steps import build_train_step

    cfg = get_reduced(arch)
    params = MDL.init_params(cfg, jax.random.PRNGKey(1))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=10)
    opt = adamw.init_state(opt_cfg, params)
    step = jax.jit(build_train_step(cfg, opt_cfg, q_chunk=16))
    batch, _ = _batch(cfg, rng)
    losses = []
    for _ in range(4):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(v) for v in losses)
    assert losses[-1] < losses[0]     # same-batch loss must descend


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch, rng):
    cfg = get_reduced(arch)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    batch, kw = _batch(cfg, rng)
    tk = batch.get("tokens")
    logits, cache = jax.jit(
        lambda p: DEC.prefill(p, cfg, tk, smax=S + 4, q_chunk=16, **kw)
    )(params)
    assert logits.shape == (B, 1, cfg.vocab_size)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(
        lambda p, c, t: DEC.decode_step(p, cfg, c, t))(params, cache, nxt)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache["pos"]) == S + 1

    if cfg.frontend is None and not cfg.is_enc_dec:
        full = jnp.concatenate([tk, nxt], 1)
        fwd, _ = jax.jit(
            lambda p, t: MDL.forward(p, cfg, t, q_chunk=16))(params, full)
        a, b = np.asarray(fwd[:, -1]), np.asarray(logits2[:, 0])
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 2e-3, f"decode/fwd mismatch {rel}"


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch


def test_moe_configs():
    ds = get_config("deepseek-moe-16b").moe
    assert (ds.n_experts, ds.top_k, ds.n_shared) == (64, 6, 2)
    ar = get_config("arctic-480b").moe
    assert (ar.n_experts, ar.top_k) == (128, 2)
    assert ar.dense_residual_ff == 4864


def test_param_counts_plausible():
    """Total parameter counts land near the models' nameplates."""
    approx = {"gemma-7b": 8.5e9, "gemma-2b": 2.5e9, "qwen2.5-32b": 32e9,
              "arctic-480b": 480e9, "deepseek-moe-16b": 16e9,
              "chameleon-34b": 34e9, "xlstm-350m": 0.35e9}
    for arch, n in approx.items():
        got = get_config(arch).param_count()
        assert 0.55 * n < got < 1.75 * n, f"{arch}: {got/1e9:.2f}B vs {n/1e9}B"


def test_shape_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        cells = cells_for(cfg)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)
        if arch in ("zamba2-7b", "xlstm-350m", "gemma3-27b"):
            assert "long_500k" in cells
        if arch in ("gemma-7b", "qwen2.5-32b", "chameleon-34b"):
            assert "long_500k" not in cells


# ---------------------------------------------------------------------------
# repo-wide hygiene: every module imports, no bytecode in the tree
# ---------------------------------------------------------------------------


def test_every_repro_module_imports():
    """Walk the whole ``repro`` package and import every module — a
    syntax error, a broken import or an accidental import-time side
    effect anywhere in the tree fails here, not in whichever test
    happens to touch the module first."""
    import importlib
    import pkgutil

    import repro

    failures = []
    for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        try:
            importlib.import_module(mod.name)
        except Exception as e:  # noqa: BLE001 - report every breakage
            failures.append(f"{mod.name}: {type(e).__name__}: {e}")
    assert not failures, "\n".join(failures)


def test_no_bytecode_artifacts_tracked():
    """No __pycache__/.pyc files may be committed (they shadow source
    edits and churn diffs); only meaningful when running from a git
    checkout."""
    import pathlib
    import subprocess

    root = pathlib.Path(__file__).resolve().parent.parent
    if not (root / ".git").exists():
        pytest.skip("not a git checkout")
    tracked = subprocess.run(
        ["git", "ls-files"], cwd=root, capture_output=True, text=True,
        check=True).stdout.splitlines()
    bad = [p for p in tracked
           if p.endswith((".pyc", ".pyo")) or "__pycache__" in p]
    assert not bad, f"bytecode artifacts committed: {bad}"
