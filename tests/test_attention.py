"""Flash attention (custom VJP) vs dense reference: forward + gradients
across GQA/MQA, causal/cross, windowed, and ragged lengths."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import decode_attention, flash_attention


def ref_attn(q, k, v, causal=True, window=None):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / np.sqrt(hd)
    qpos, kpos = jnp.arange(sq), jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    p = jax.nn.softmax(jnp.where(mask, s, -1e30), -1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v)
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(b, sq, h, hd)


CASES = [
    (64, 64, 4, 2, 16, True, None, 16),     # GQA causal
    (64, 64, 4, 1, 16, True, 16, 16),       # MQA sliding window
    (48, 32, 4, 4, 8, False, None, 16),     # cross, ragged
    (100, 100, 8, 2, 32, True, None, 32),   # non-multiple length
]


@pytest.mark.parametrize("sq,sk,h,kv,hd,causal,window,qc", CASES)
def test_flash_forward(sq, sk, h, kv, hd, causal, window, qc):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, sq, h, hd))
    k = jax.random.normal(ks[1], (2, sk, kv, hd))
    v = jax.random.normal(ks[2], (2, sk, kv, hd))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=qc, kv_chunk=qc)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref_attn(q, k, v, causal, window)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("sq,sk,h,kv,hd,causal,window,qc", CASES)
def test_flash_gradients(sq, sk, h, kv, hd, causal, window, qc):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, sq, h, hd))
    k = jax.random.normal(ks[1], (2, sk, kv, hd))
    v = jax.random.normal(ks[2], (2, sk, kv, hd))

    def loss(fn):
        return lambda *a: jnp.sum(jnp.sin(
            fn(*a)))

    f = lambda q, k, v: jnp.sum(jnp.sin(flash_attention(      # noqa: E731
        q, k, v, causal=causal, window=window, q_chunk=qc, kv_chunk=qc)))
    r = lambda q, k, v: jnp.sum(  # noqa: E731
        jnp.sin(ref_attn(q, k, v, causal, window)))
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-5)


def test_decode_matches_flash_row():
    """Single-token decode equals the last row of a full flash pass."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, kv, hd = 2, 40, 8, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    full = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    # cache padded beyond pos: decode must mask it out
    kc = jnp.pad(k, ((0, 0), (0, 8), (0, 0), (0, 0)), constant_values=9.9)
    vc = jnp.pad(v, ((0, 0), (0, 8), (0, 0), (0, 0)), constant_values=9.9)
    dec = decode_attention(q[:, -1:], kc, vc, s - 1)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)


def test_decode_window():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, kv, hd, w = 1, 64, 4, 1, 8, 16
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    full = flash_attention(q, k, v, causal=True, window=w,
                           q_chunk=16, kv_chunk=16)
    dec = decode_attention(q[:, -1:], k, v, s - 1, window=w)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)
