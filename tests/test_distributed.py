"""Distributed morphology + sharding-policy tests.

Multi-device equivalence runs in a subprocess with 8 fake devices
(XLA_FLAGS must be set before jax initializes; the main test process
keeps its single-device view per the dry-run contract)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_distributed_chain_and_reconstruct_equivalence():
    out = run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import distributed as D, morphology as M

        mesh = jax.make_mesh((4, 2), ("r", "c"))
        rng = np.random.default_rng(3)
        f = jnp.asarray(rng.integers(0, 256, (96, 96), np.uint8))
        put = lambda x: jax.device_put(x, NamedSharding(mesh, P("r", "c")))

        fn = D.distributed_chain(mesh, "r", "c", n=9, op="erode",
                                 backend="xla", fuse_k=4)
        np.testing.assert_array_equal(
            np.asarray(fn(put(f))), np.asarray(M.erode(f, 9)))

        m = jnp.asarray(rng.integers(0, 256, (96, 96), np.uint8))
        marker = jnp.maximum(f, m)
        rec = D.distributed_reconstruct(mesh, "r", "c", op="erode",
                                        backend="xla", fuse_k=4)
        np.testing.assert_array_equal(
            np.asarray(rec(put(marker), put(m))),
            np.asarray(M.erode_reconstruct(marker, m)))
        print("EQUIV_OK")
    """)
    assert "EQUIV_OK" in out


@pytest.mark.slow
def test_compressed_grad_training_matches_uncompressed():
    """int8 grad compression with error feedback: loss still descends and
    tracks the uncompressed run closely on 8-way DP."""
    out = run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.registry import get_reduced
        from repro.models import model as MDL
        from repro.optim import adamw
        from repro.optim.compression import init_error
        from repro.train.steps import (build_compressed_train_step,
                                       build_train_step)

        cfg = get_reduced("gemma-2b")
        mesh = jax.make_mesh((8,), ("data",))
        opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=20)
        params = MDL.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(opt_cfg, params)
        opt_c = dict(opt, err=init_error(params))

        rng = np.random.default_rng(0)
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

        plain = jax.jit(build_train_step(cfg, opt_cfg, q_chunk=16))
        comp = jax.jit(build_compressed_train_step(cfg, opt_cfg, mesh,
                                                   "data", q_chunk=16))
        p1, o1, p2, o2 = params, opt, params, opt_c
        for _ in range(5):
            p1, o1, m1 = plain(p1, o1, batch)
            p2, o2, m2 = comp(p2, o2, batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert l2 < 6.3, l2                       # descends from ~ln(512)
        assert abs(l1 - l2) < 0.35, (l1, l2)      # tracks uncompressed
        print("COMPRESS_OK", l1, l2)
    """)
    assert "COMPRESS_OK" in out


def test_param_specs_cover_all_leaves():
    """Every param leaf gets a spec; dims divisible by their assigned
    axes; scanned stack dim never sharded."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs.registry import ARCH_IDS, get_config
    from repro.launch import sharding as SH

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    mesh = FakeMesh()
    from repro.models import model as MDL

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda c=cfg: MDL.init_params(c, jax.random.PRNGKey(0)))
        specs = SH.param_specs(cfg, shapes, mesh)
        leaves_shapes = jax.tree.leaves(shapes)
        leaves_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_shapes) == len(leaves_specs)
        for s, spec in zip(leaves_shapes, leaves_specs):
            spec = tuple(spec) + (None,) * (len(s.shape) - len(tuple(spec)))
            for dim, axes in zip(s.shape, spec):
                if axes is None:
                    continue
                axes = (axes,) if isinstance(axes, str) else axes
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert dim % n == 0, (arch, s.shape, spec)


def test_cache_specs_shard_big_dims():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs.registry import get_config
    from repro.launch import sharding as SH
    from repro.models import decode as DEC

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config("gemma3-27b")
    cache = jax.eval_shape(lambda: DEC.init_cache(cfg, 128, 1024))
    specs = SH.cache_specs(cfg, cache, FakeMesh())
    kspec = specs["blocks"][0]["k"]
    assert "model" in jax.tree.leaves(
        kspec, is_leaf=lambda x: x is not None) or tuple(kspec)
