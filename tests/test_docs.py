"""Docs smoke tests: the prose in ``docs/*.md`` (and the README) must
not rot.

Two classes of machine-checkable claims are extracted from the
markdown:

* backticked ``file.py:symbol`` references (the convention
  ``docs/ARCHITECTURE.md`` declares) — the file must exist and the
  symbol must be defined at its top level (one ``Class.member`` dot
  level is resolved into class bodies);
* commands inside fenced shell blocks — every ``python -m module`` /
  ``python path.py`` invocation must name a module/file that exists.

Marked ``docs`` so documentation checks can be run alone:
``pytest -m docs``.
"""
import ast
import pathlib
import re

import pytest

pytestmark = pytest.mark.docs

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(REPO.glob("docs/*.md")) + [REPO / "README.md"]

# a path-looking token ending in .py/.md, optionally with :symbol
_REF = re.compile(
    r"(?P<path>[A-Za-z0-9_\-./]+\.(?:py|md))(?::(?P<sym>[A-Za-z_][\w.]*))?"
)
_BACKTICK = re.compile(r"`([^`\n]+)`")
_FENCE = re.compile(r"^```(\w*)\s*$")
_CMD = re.compile(r"^(?:PYTHONPATH=\S+\s+)?python(?:3)?\s+(?P<rest>.+)$")
_SHELL_LANGS = {"", "bash", "sh", "shell", "console"}


def _doc_ids():
    return [p.relative_to(REPO).as_posix() for p in DOC_FILES]


def test_docs_exist():
    assert (REPO / "docs" / "ARCHITECTURE.md").is_file()
    assert (REPO / "docs" / "BENCHMARKS.md").is_file()
    assert (REPO / "docs" / "OPTIMIZER.md").is_file()
    assert (REPO / "README.md").is_file()


def _symbol_names(tree: ast.Module):
    """Top-level names and one dotted level into class bodies."""
    names = set()

    def targets(node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            yield node.name
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    yield t.id
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            yield node.target.id

    for node in tree.body:
        for name in targets(node):
            names.add(name)
        if isinstance(node, ast.ClassDef):
            for sub in node.body:
                for name in targets(sub):
                    names.add(f"{node.name}.{name}")
    return names


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids())
def test_docs_symbol_references_exist(doc):
    refs = []
    for span in _BACKTICK.findall(doc.read_text()):
        m = _REF.search(span)
        if m:
            refs.append((m.group("path"), m.group("sym")))
    assert refs, f"{doc.name}: no file references found (convention broken?)"
    missing = []
    for path, sym in refs:
        target = REPO / path
        if not target.is_file():
            missing.append(f"{path} (file missing)")
            continue
        if sym is None or target.suffix != ".py":
            continue
        tree = ast.parse(target.read_text())
        if sym not in _symbol_names(tree):
            missing.append(f"{path}:{sym} (symbol missing)")
    assert not missing, f"{doc.name}: stale references: {missing}"


def _fenced_commands(text: str):
    """Yield python invocations from shell-language fenced blocks."""
    lang = None
    for line in text.splitlines():
        fence = _FENCE.match(line.strip())
        if fence:
            lang = fence.group(1).lower() if lang is None else None
            continue
        if lang is None or lang not in _SHELL_LANGS:
            continue
        m = _CMD.match(line.strip())
        if m:
            yield m.group("rest")


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids())
def test_docs_fenced_commands_resolve(doc):
    checked = 0
    for rest in _fenced_commands(doc.read_text()):
        args = rest.split()
        if args[0] == "-m":
            mod = args[1]
            mod_path = REPO / (mod.replace(".", "/") + ".py")
            pkg_path = REPO / mod.replace(".", "/") / "__init__.py"
            if not (mod_path.is_file() or pkg_path.is_file()):
                # external module (e.g. pytest): must be importable
                import importlib.util
                top = mod.split(".")[0]
                assert importlib.util.find_spec(top) is not None, (
                    f"{doc.name}: `python -m {mod}` resolves nowhere")
        else:
            script = args[0]
            assert (REPO / script).is_file(), (
                f"{doc.name}: `python {script}` names a missing file")
        checked += 1
    if doc.name != "ARCHITECTURE.md":  # architecture has no run commands
        assert checked, f"{doc.name}: no fenced commands found"
