"""Fault tolerance: atomic checkpointing, failure injection + restore
resumes bitwise-identically, retention GC, async writer."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_reduced
from repro.train.loop import FailureInjector, Trainer, TrainerConfig


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
             "b": {"c": jnp.ones((5,), jnp.bfloat16),
                   "d": [jnp.zeros((2,)), jnp.full((3,), 7)]},
             "step": jnp.asarray(3, jnp.int32)}
    mgr.save(10, state, extra={"note": "hi"})
    got, extra, step = mgr.restore(jax.tree.map(np.asarray, state))
    assert step == 10 and extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        # bf16 has no numpy equality ufunc: compare exact bit patterns
        if a.dtype == jnp.bfloat16:
            a, b = a.view(np.uint16), b.view(np.uint16)
        np.testing.assert_array_equal(a, b)


def test_checkpoint_retention_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    # a stale tmp dir never shadows a good checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_00000099.tmp"))
    assert mgr.latest_step() == 4


def test_async_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, {"x": jnp.arange(3)})
    mgr.wait()
    assert mgr.latest_step() == 5


@pytest.mark.slow
def test_failure_injection_recovery_bitwise(tmp_path):
    """Run A: 8 uninterrupted steps.  Run B: dies at step 6, restarts
    with --restore from the step-4 checkpoint.  Final params must be
    bitwise identical (deterministic data + deterministic step)."""
    cfg = get_reduced("gemma-2b")
    tcfg = TrainerConfig(steps=8, seq_len=16, global_batch=2,
                         checkpoint_every=4, q_chunk=16,
                         checkpoint_dir=str(tmp_path / "b"), log_every=100)

    # run A: no checkpoint dir needed, pure run
    tA = Trainer(cfg, tcfg.__class__(**{**tcfg.__dict__,
                                        "checkpoint_dir": None}))
    stateA, histA = tA.run()

    # run B: crash at step 6, then resume
    tB = Trainer(cfg, tcfg)
    with pytest.raises(RuntimeError, match="injected node failure"):
        tB.run(injector=FailureInjector(fail_at_step=6))
    assert CheckpointManager(tcfg.checkpoint_dir).latest_step() == 4
    tB2 = Trainer(cfg, tcfg)
    stateB, histB = tB2.run(restore=True)

    for a, b in zip(jax.tree.leaves(stateA["params"]),
                    jax.tree.leaves(stateB["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # loss histories agree on the overlapping tail
    np.testing.assert_allclose(histA[-2:], histB[-2:], rtol=1e-6)


def test_deterministic_data_sharding():
    """A restarted/re-placed worker regenerates exactly its shard."""
    from repro.data.synthetic import TokenPipeline

    p = TokenPipeline(vocab_size=100, seq_len=8, global_batch=8, seed=1)
    full = p.batch(step=7)
    shard1 = p.batch(step=7, shard=1, n_shards=4)
    again = p.batch(step=7, shard=1, n_shards=4)
    np.testing.assert_array_equal(shard1["tokens"], again["tokens"])
    assert full["tokens"].shape == (8, 8)
    assert shard1["tokens"].shape == (2, 8)


def test_elastic_restore_different_shape_template(tmp_path):
    """Checkpoints restore by logical structure — a mesh change only
    changes device_put shardings, not the stored arrays."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(1, state)
    got, _, _ = mgr.restore(jax.tree.map(np.asarray, state))
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(state["w"]))
