"""Chaos suite: the fault-tolerant request lifecycle under injected
failures (PR 7 robustness contract, ``docs/ROBUSTNESS.md``).

The core invariants asserted here:

* typed admission — malformed/non-finite/over-capacity requests are
  rejected synchronously with :mod:`repro.serve.errors` classes;
* the chaos matrix — for every injection site and both backends,
  healthy requests co-batched with a poisoned/failing one complete
  **bit-exactly** (assert_array_equal vs the direct operator call)
  while only the poisoned request gets a typed error;
* no unstructured exception escapes ``Service.poll()``/``flush()``/
  ``submit()``-launch — every injected failure resolves into a ticket
  outcome;
* partial convergence (the ``budget`` site) is a *degraded result*,
  not an error.

The suite runs both with and without ``REPRO_FAULTS`` set: tests pin
their own injectors, and the env-driven test uses the ambient schedule
when present (the CI ``chaos`` job pins one).
"""
import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_array_equal

from repro.core import operators as OPS
from repro.kernels import ops as K
from repro.serve import Service, VirtualClock
from repro.serve import registry  # noqa: F401 (registry: op hooks)
from repro.serve import faults as F
from repro.serve.errors import (DeadlineExceededError, NonFiniteInputError,
                                PoisonedRequestError, QueueFullError,
                                RequestRejected, ServeError,
                                UnsupportedDtypeError)

pytestmark = pytest.mark.serve

BACKENDS = ("pallas", "xla")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture
def rng():
    return np.random.default_rng(1702)


def _image(rng, shape=(16, 16), dtype=np.uint8):
    return rng.integers(0, 255, shape).astype(dtype)


def _service(backend, spec="", **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay_ms", 1e9)
    kw.setdefault("pad_quantum", 16)
    kw.setdefault("max_retries", 1)
    kw.setdefault("sleep", lambda s: None)
    return Service(backend=backend, faults=F.parse(spec), **kw)


# ---------------------------------------------------------------------------
# spec grammar + injector determinism
# ---------------------------------------------------------------------------


def test_parse_grammar():
    inj = F.parse("seed=7; dispatch:p=0.5,n=2 ;budget:value=1;poison")
    assert inj.seed == 7
    assert inj.specs["dispatch"] == F.FaultSpec("dispatch", n=2, p=0.5)
    assert inj.specs["budget"].value == 1.0
    assert inj.specs["poison"] == F.FaultSpec("poison")
    assert not F.parse("").armed("dispatch")


@pytest.mark.parametrize("bad", [
    "unknown_site", "dispatch:q=1", "dispatch:p=x", "seed=x",
    "dispatch:p=2", "dispatch:n=-1", "poison;poison",
])
def test_parse_rejects_malformed(bad):
    with pytest.raises(F.FaultSpecError):
        F.parse(bad)


def test_injector_is_deterministic():
    spec = "seed=42;dispatch:p=0.3;poison:p=0.5,n=3"
    a, b = F.parse(spec), F.parse(spec)
    seq = lambda inj: [inj.should_fire(s)  # noqa: E731
                       for s in ("dispatch", "poison") * 50]
    assert seq(a) == seq(b)
    assert a.fired == b.fired
    assert a.specs["poison"].n == 3 and a.fired["poison"] <= 3


def test_from_env():
    inj = F.from_env({"REPRO_FAULTS": "seed=3;drain:n=1"})
    assert inj.seed == 3 and inj.armed("drain")
    assert F.from_env({}) is F.NULL
    assert F.from_env({"REPRO_FAULTS": "  "}) is F.NULL


# ---------------------------------------------------------------------------
# typed admission
# ---------------------------------------------------------------------------


def test_nonfinite_payload_rejected(rng):
    svc = _service("xla")
    f = rng.uniform(0.0, 1.0, (16, 16)).astype(np.float32)
    f[3, 4] = np.nan
    with pytest.raises(NonFiniteInputError, match="NaN/Inf"):
        svc.submit("hmax", f, params={"h": 0.1})
    f[3, 4] = np.inf
    with pytest.raises(NonFiniteInputError):
        svc.submit("hmax", f, params={"h": 0.1})
    # typed rejections are ValueErrors too (pre-robustness contract)
    with pytest.raises(ValueError):
        svc.submit("hmax", f, params={"h": 0.1})
    assert svc.stats()["counters"]["rejected"] == 3
    assert svc.pending() == 0  # nothing entered a bucket


def test_unsupported_dtype_rejected(rng):
    svc = _service("xla")
    f = np.zeros((8, 8), np.complex64)
    with pytest.raises(UnsupportedDtypeError, match="lattice"):
        svc.submit("hfill", f)
    with pytest.raises(RequestRejected):
        svc.submit("hfill", np.zeros((8, 8), bool))
    assert svc.stats()["counters"]["rejected"] == 2


def test_queue_full_sheds(rng):
    svc = _service("xla", max_batch=8, max_queue=2)
    svc.submit("hfill", _image(rng))
    svc.submit("hfill", _image(rng))
    with pytest.raises(QueueFullError, match="load-shed"):
        svc.submit("hfill", _image(rng))
    assert svc.stats()["counters"]["shed"] == 1
    svc.flush()  # the two admitted requests still complete
    assert svc.stats()["totals"]["requests"] == 2


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_expiry_sheds_at_launch(rng):
    clock = FakeClock()
    svc = _service("xla", clock=clock, default_deadline_ms=10.0)
    t_doomed = svc.submit("hfill", _image(rng))
    clock.advance(0.05)  # 50ms > 10ms deadline
    t_fresh = svc.submit("hfill", _image(rng), deadline_ms=1e6)
    svc.flush()
    assert t_doomed.outcome == "deadline"
    with pytest.raises(DeadlineExceededError):
        t_doomed.result()
    assert t_fresh.outcome == "ok"
    assert_array_equal(np.asarray(t_fresh.result()),
                       np.asarray(OPS.hfill(jnp.asarray(t_fresh.value))))
    assert svc.stats()["counters"]["expired"] == 1


def test_deadline_fault_site_forces_expiry(rng):
    clock = FakeClock()
    svc = _service("xla", spec="deadline:n=1;", clock=clock)
    svc.faults.specs["deadline"] = F.FaultSpec("deadline", n=1, value=1.0)
    t = svc.submit("hfill", _image(rng))  # injected 1ms deadline
    clock.advance(0.01)
    svc.flush()
    assert t.outcome == "deadline"
    assert svc.faults.fired["deadline"] == 1


# ---------------------------------------------------------------------------
# the chaos matrix: injection sites x backends, healthy slots bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("site", ["dispatch", "drain", "poison"])
def test_chaos_matrix_healthy_requests_bit_exact(rng, site, backend):
    """One injected failure per stream; every healthy request must
    complete bit-exactly vs the direct operator call, the poisoned one
    (poison site only) must get a typed PoisonedRequestError, and
    nothing may escape submit/flush."""
    svc = _service(backend, spec=f"{site}:n=1")
    images = [_image(rng) for _ in range(4)]
    tickets = [svc.submit("hmax", im, params={"h": 10}) for im in images]
    svc.flush()

    poisoned = [t for t in tickets if t.outcome == "poisoned"]
    healthy = [t for t in tickets if t.outcome == "ok"]
    if site == "poison":
        assert len(poisoned) == 1 and len(healthy) == 3
        with pytest.raises(PoisonedRequestError):
            poisoned[0].result()
        assert svc.stats()["counters"]["poisoned"] == 1
        assert svc.stats()["counters"]["quarantine_reruns"] >= 1
    else:
        # dispatch/drain faults are transient: retry clears them
        assert len(healthy) == 4 and not poisoned
        assert svc.stats()["counters"]["retried"] >= 1
    assert svc.stats()["counters"]["batch_failures"] >= 1

    for t in healthy:
        im = images[t.request_id]
        expect = OPS.hmax(jnp.asarray(im), 10)
        assert_array_equal(np.asarray(t.result()), np.asarray(expect))
    assert svc.faults.fired[site] == 1


# ---------------------------------------------------------------------------
# budget site: partial convergence is degraded, not an error
# ---------------------------------------------------------------------------


def test_budget_watchdog_degrades_pallas(rng):
    """A 1-chunk budget trips the scheduler watchdog on a propagation
    that needs several chunks: the ticket resolves with a value and
    ``degraded=True`` (the degraded-mode contract)."""
    svc = _service("pallas", spec="budget:value=1", max_batch=1)
    marker = np.zeros((64, 64), np.uint8)
    marker[0, 0] = 255
    mask = np.full((64, 64), 255, np.uint8)
    # the spike must flood the whole mask: ~(H+W)/fuse_k chunks of work
    t = svc.submit("reconstruct", marker, mask)
    svc.flush()
    assert t.error is None and t.done
    assert t.degraded and t.outcome == "degraded"
    assert t.result() is not None  # partial fixpoint, still delivered
    assert svc.stats()["counters"]["degraded"] == 1
    label = next(iter(svc.stats()["buckets"]))
    assert svc.stats()["buckets"][label]["degraded"] == 1


def test_budget_clean_run_not_degraded(rng):
    svc = _service("pallas", max_batch=1)
    t = svc.submit("hmax", _image(rng), params={"h": 10})
    svc.flush()
    assert t.outcome == "ok" and not t.degraded
    assert svc.stats()["counters"]["degraded"] == 0


# ---------------------------------------------------------------------------
# the umbrella invariant: nothing unstructured escapes poll()
# ---------------------------------------------------------------------------


def test_no_unstructured_exception_escapes_poll(rng):
    """Drive a request stream under an aggressive ambient fault
    schedule (REPRO_FAULTS when set — the CI chaos job pins one — else
    a local pinned spec): every ticket must end in a typed outcome."""
    import os
    spec = os.environ.get(
        "REPRO_FAULTS",
        "seed=1702;dispatch:p=0.3;drain:p=0.3;poison:p=0.2",
    )
    svc = _service("xla", spec=spec, max_batch=2, max_delay_ms=0.0)
    tickets = []
    for i in range(10):
        im = _image(rng, (16 + 16 * (i % 2), 16))
        try:
            tickets.append(svc.submit("hfill", im))
        except ServeError:
            pass  # typed admission rejection: allowed
        svc.poll()
    svc.flush()
    for t in tickets:
        assert t.done
        assert t.error is None or isinstance(t.error, ServeError)
        assert t.outcome != "pending"
    snap = svc.stats()["faults"]
    assert set(snap["fired"]) <= set(F.SITES)


# ---------------------------------------------------------------------------
# PR 9: the chaos matrix under the event-driven continuous engine
# ---------------------------------------------------------------------------


def _recon_pair(rng, shape=(24, 24), slow=False):
    h, w = shape
    if slow:
        f = np.full(shape, 0.1, np.float32)
        for r in range(0, h, 2):
            f[r, :] = 0.9
            if r + 1 < h:
                f[r + 1, -1 if (r // 2) % 2 == 0 else 0] = 0.9
        m = np.full(shape, 0.05, np.float32)
        m[0, 0] = 0.8
    else:
        f = rng.random(shape).astype(np.float32)
        m = (0.9 * f).astype(np.float32)
    return np.minimum(m, f), f


def _continuous_service(spec="", **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay_ms", 1e9)
    kw.setdefault("pad_quantum", 16)
    kw.setdefault("refill_quantum", 2)
    kw.setdefault("max_retries", 1)
    kw.setdefault("sleep", lambda s: None)
    kw.setdefault("clock", VirtualClock())
    return Service(backend="pallas", continuous=True,
                   faults=F.parse(spec), **kw)


def _drive(svc, tickets, clock, max_steps=2000):
    for _ in range(max_steps):
        if all(t.done for t in tickets):
            return
        clock.advance(1e-3)
        svc.poll()
        svc.executor.drain_all()
    raise AssertionError("continuous engine failed to complete tickets")


@pytest.mark.parametrize("site", ["dispatch", "drain", "poison"])
def test_chaos_matrix_continuous_engine(rng, site):
    """The PR 7 chaos matrix re-run on the stepped continuous engine:
    an injected failure at any site resolves through the recovery
    ladder with every healthy request completing bit-exactly and only
    a poisoned request getting a typed error — the session eviction
    path must not lose or corrupt occupants."""
    clock = VirtualClock()
    svc = _continuous_service(spec=f"{site}:n=1", clock=clock)
    cases = [_recon_pair(rng) for _ in range(4)]
    tickets = [svc.submit("reconstruct", m, f) for m, f in cases]
    svc.flush()
    _drive(svc, tickets, clock)

    poisoned = [t for t in tickets if t.outcome == "poisoned"]
    healthy = [t for t in tickets if t.outcome == "ok"]
    if site == "poison":
        assert len(poisoned) == 1 and len(healthy) == 3
        with pytest.raises(PoisonedRequestError):
            poisoned[0].result()
        assert svc.stats()["counters"]["poisoned"] == 1
    else:
        assert len(healthy) == 4 and not poisoned
        assert svc.stats()["counters"]["retried"] >= 1
    assert svc.stats()["counters"]["batch_failures"] >= 1
    for t in healthy:
        m, f = cases[t.request_id]
        ref = np.asarray(K.reconstruct(m, f, op="dilate"))
        assert_array_equal(np.asarray(t.result()), ref)
    assert svc.faults.fired[site] == 1


def test_poison_mid_refill_preserves_healthy_and_straggler(rng):
    """A poisoned request arriving in a *refill wave* (admitted while
    a straggler slot is still iterating) kills the session — eviction
    plus bisect-quarantine must isolate it while the straggler and
    every other occupant still complete bit-exactly."""
    clock = VirtualClock()
    svc = _continuous_service(clock=clock, refill_quantum=1)
    slow = _recon_pair(rng, slow=True)
    fast = [_recon_pair(rng) for _ in range(3)]
    cases = [slow] + fast
    tickets = [svc.submit("reconstruct", m, f) for m, f in cases]
    for key in list(svc._queue.keys()):
        svc._launch(key)  # engine spawned, first wave resident
    eng = next(iter(svc._engines.values()))
    assert eng.occupied
    for _ in range(3):
        svc.poll()  # free the fast slots while the straggler runs
    # next submission is poison, admitted into a freed slot mid-flight
    svc.faults.specs["poison"] = F.FaultSpec("poison", n=1)
    bad_pair = _recon_pair(rng)
    cases.append(bad_pair)
    tickets.append(svc.submit("reconstruct", *bad_pair))
    for key in list(svc._queue.keys()):
        svc._launch(key)
    _drive(svc, tickets, clock)

    assert tickets[-1].outcome == "poisoned"
    for t in tickets[:-1]:
        assert t.outcome == "ok"
        m, f = cases[t.request_id]
        ref = np.asarray(K.reconstruct(m, f, op="dilate"))
        assert_array_equal(np.asarray(t.result()), ref)
    assert svc.stats()["counters"]["refills"] >= 1


def test_budget_degrades_continuous_engine(rng):
    """The budget site under continuous batching: a 1-chunk budget
    truncates the slot, which is harvested as a degraded partial
    fixpoint (never an error) — same contract as the batch path."""
    clock = VirtualClock()
    svc = _continuous_service(spec="budget:value=1", clock=clock)
    marker = np.zeros((32, 32), np.float32)
    marker[0, 0] = 1.0
    mask = np.ones((32, 32), np.float32)
    t = svc.submit("reconstruct", marker, mask)
    svc.flush()
    _drive(svc, [t], clock)
    assert t.error is None and t.degraded and t.outcome == "degraded"
    assert t.result() is not None
    assert svc.stats()["counters"]["degraded"] == 1


def test_deadline_fault_expires_under_stepped_loop(rng):
    clock = VirtualClock()
    svc = _continuous_service(spec="deadline:n=1", clock=clock)
    svc.faults.specs["deadline"] = F.FaultSpec("deadline", n=1, value=1.0)
    t = svc.submit("reconstruct", *_recon_pair(rng))
    clock.advance(0.01)
    svc.poll()  # the expiry timer fires from the stepped loop
    assert t.outcome == "deadline"
    assert svc.stats()["counters"]["expired"] == 1


def test_no_unstructured_escape_continuous(rng):
    """The umbrella invariant on the async path: an aggressive ambient
    schedule (REPRO_FAULTS when set, as in CI) over the stepped
    continuous engine still resolves every ticket into a typed
    outcome, with no exception escaping submit/poll/flush."""
    import os
    spec = os.environ.get(
        "REPRO_FAULTS",
        "seed=1702;dispatch:p=0.3;drain:p=0.3;poison:p=0.2",
    )
    clock = VirtualClock()
    svc = _continuous_service(spec=spec, clock=clock, max_delay_ms=2.0)
    tickets = []
    for i in range(8):
        try:
            tickets.append(svc.submit("reconstruct",
                                      *_recon_pair(rng, slow=(i == 0))))
        except ServeError:
            pass
        clock.advance(1e-3)
        svc.poll()
    svc.flush()
    _drive(svc, tickets, clock)
    for t in tickets:
        assert t.done and t.outcome != "pending"
        assert t.error is None or isinstance(t.error, ServeError)
    assert set(svc.stats()["faults"]["fired"]) <= set(F.SITES)


def test_stats_surface_faults_and_counters(rng):
    svc = _service("xla", spec="seed=9;poison:n=1")
    t = svc.submit("hfill", _image(rng))
    svc.flush()
    assert t.outcome == "poisoned"
    s = svc.stats()
    assert s["faults"]["seed"] == 9
    assert s["faults"]["armed"] == ["poison"]
    assert s["counters"]["poisoned"] == 1
    rows = {r["name"]: r["us_per_call"] for r in svc.bench_rows()}
    assert rows["serve/counters/poisoned"] == 1.0
    assert rows["serve/counters/shed"] == 0.0  # schema stable at zero
