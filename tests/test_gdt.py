"""The generalised geodesic distance subsystem (``repro.gdt``).

The fixpoint is a min over paths of left-folded float sums, so it is
schedule-independent: every engine — the wavefront chunk scheduler,
the raster sweeps, the XLA Jacobi oracle — must reproduce the
pure-NumPy reference **bit-for-bit** (``np.array_equal``, never
tolerances), 2-D and batched, plus the λ=0 bridge to the binary QDT,
the segmentation composites, the serve pin/incremental-update path and
the static-verifier findings the subsystem added.
"""
import types

import numpy as np
import jax.numpy as jnp
import pytest

from repro import analysis as A
from repro import api
from repro.analysis.dtypes import check_executable_dtypes
from repro.analysis.findings import ERROR
from repro.analysis.halo import segment_reach
from repro.api import E
from repro.api.lower import RunSeg, lower
from repro.core import morphology as M
from repro.core import operators as OPS
from repro.core.chain import ChainPlan, plan_chain
from repro.gdt import gdt, gdt_reference, seg_hmin_expr, seg_scribble_expr
from repro.kernels import ops as K
from repro.serve import (InvalidRequestError, Service,
                         UnsupportedDtypeError)

pytestmark = pytest.mark.pipeline

DTYPES = [np.float32, np.float64]
LAMB, NU = 0.7, 50.0


def _case(rng, shape, dtype, density=0.05):
    """A smooth-ish float image in [0, 3] and a sparse soft seed plane
    (one guaranteed hard seed so the plateau is reachable)."""
    img = (rng.random(shape) * 3.0).astype(dtype)
    seeds = (rng.random(shape) < density).astype(dtype)
    seeds[tuple(d // 2 for d in shape)] = 1.0
    return img, seeds


def _expr():
    return E.gdt(E.input("image"), E.input("seeds"), lamb=LAMB, nu=NU)


def _ref(img, seeds):
    return gdt_reference(np.asarray(img), np.asarray(seeds),
                         lamb=LAMB, nu=NU)


# ---------------------------------------------------------------------------
# bit-exactness against the NumPy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gdt_bitexact_vs_reference(rng, backend, dtype):
    img, seeds = _case(rng, (29, 23), dtype)
    x, s = jnp.asarray(img), jnp.asarray(seeds)  # f64 downcasts (no x64)
    exe = api.compile(_expr(), x.shape, x.dtype, backend)
    out = exe(x, s)
    assert out.dtype == x.dtype and out.shape == x.shape
    np.testing.assert_array_equal(np.asarray(out), _ref(x, s))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_gdt_batched_stacks(rng, backend):
    img = np.stack([_case(rng, (24, 20), np.float32)[0] for _ in range(3)])
    seeds = np.stack([_case(rng, (24, 20), np.float32)[1]
                      for _ in range(3)])
    out = np.asarray(api.compile(_expr(), img.shape, img.dtype,
                                 backend)(jnp.asarray(img),
                                          jnp.asarray(seeds)))
    for i in range(3):
        np.testing.assert_array_equal(out[i], _ref(img[i], seeds[i]))


@pytest.mark.parametrize("dtype", DTYPES)
def test_gdt_raster_schedule_matches_wavefront(rng, dtype):
    """schedule="raster" (directional sweeps) and schedule="wavefront"
    (the chunk scheduler) land on the same bits."""
    img, seeds = _case(rng, (33, 27), dtype)
    x, s = jnp.asarray(img), jnp.asarray(seeds)
    wave = api.compile(_expr(), x.shape, x.dtype, "pallas")(x, s)
    plan = plan_chain(*x.shape, x.dtype, None, n_images_resident=3,
                      n_images=1, convergent=True, schedule="raster")
    raster = api.compile(_expr(), x.shape, x.dtype, "pallas",
                         plan=plan)(x, s)
    ref = _ref(x, s)
    np.testing.assert_array_equal(np.asarray(wave), ref)
    np.testing.assert_array_equal(np.asarray(raster), ref)


def test_gdt_lambda_zero_is_the_binary_qdt_bridge(rng):
    """λ=0 collapses the weight to exactly 1, so gdt from the
    background of a binary image is the Chebyshev distance — the same
    erosion counts the binary L1 QDT d-plane records."""
    binary = (rng.random((18, 14)) < 0.6).astype(np.uint8) * 255
    f = binary.astype(np.float32)
    seeds = (binary == 0).astype(np.float32)
    assert seeds.any() and (binary > 0).any()
    nu = float(sum(binary.shape))
    out = np.asarray(gdt(jnp.asarray(f), jnp.asarray(seeds),
                         lamb=0.0, nu=nu))
    # brute-force Chebyshev distance to the seed set
    ys, xs = np.nonzero(seeds)
    ii, jj = np.mgrid[:binary.shape[0], :binary.shape[1]]
    cheb = np.min(np.maximum(np.abs(ii[..., None] - ys),
                             np.abs(jj[..., None] - xs)), axis=-1)
    np.testing.assert_array_equal(out, cheb.astype(np.float32))
    # and the binary QDT's erosion-count plane agrees on the objects
    d = np.asarray(K.qdt_planes(jnp.asarray(binary))[0])
    np.testing.assert_array_equal(out.astype(np.int64), d.astype(np.int64))


# ---------------------------------------------------------------------------
# guards and plan validation
# ---------------------------------------------------------------------------


def test_gdt_parameter_and_dtype_guards(rng):
    f, s = E.input("f"), E.input("s")
    with pytest.raises(ValueError, match="lamb"):
        E.gdt(f, s, lamb=-1.0)
    with pytest.raises(ValueError, match="nu"):
        E.gdt(f, s, nu=0.0)
    with pytest.raises(TypeError, match="float dtype"):
        gdt(jnp.zeros((8, 8), jnp.uint8), jnp.zeros((8, 8), jnp.uint8))
    with pytest.raises(ValueError, match="shape"):
        gdt(jnp.zeros((8, 8), jnp.float32), jnp.zeros((8, 9), jnp.float32))
    with pytest.raises(TypeError, match="float dtype"):
        api.compile(E.gdt(f, s), (16, 16), np.uint8, "pallas")


def test_chainplan_schedule_validation():
    with pytest.raises(ValueError, match="schedule"):
        plan_chain(32, 32, np.float32, None, schedule="bogus")
    wave = plan_chain(32, 32, np.float32, None, convergent=True)
    rast = plan_chain(32, 32, np.float32, None, convergent=True,
                      schedule="raster")
    assert wave.key != rast.key  # the schedule is part of the cache key


def test_refillable_keys_on_schedule(rng):
    """Only the wavefront schedule exposes the per-slot activity grid
    the continuous engine needs; raster sweeps whole images."""
    wave = api.compile(_expr(), (2, 32, 32), np.float32, "pallas")
    assert wave.refillable
    plan = plan_chain(32, 32, np.float32, None, n_images_resident=3,
                      n_images=2, convergent=True, schedule="raster")
    rast = api.compile(_expr(), (2, 32, 32), np.float32, "pallas",
                       plan=plan)
    assert not rast.refillable


# ---------------------------------------------------------------------------
# segmentation composites
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_seg_scribble_composite(rng, backend):
    img, _ = _case(rng, (26, 22), np.float32)
    scrib = np.zeros(img.shape, np.float32)
    scrib[(rng.random(img.shape) < 0.03)] = 1.0
    scrib[(rng.random(img.shape) < 0.03) & (scrib == 0)] = 2.0
    scrib[3, 3], scrib[20, 18] = 1.0, 2.0
    exe = api.compile(seg_scribble_expr(lamb=LAMB, nu=NU), img.shape,
                      img.dtype, backend)
    out = np.asarray(exe(jnp.asarray(img), jnp.asarray(scrib)))
    d_fg = gdt_reference(img, (scrib == 1.0).astype(np.float32),
                         lamb=LAMB, nu=NU)
    d_bg = gdt_reference(img, (scrib == 2.0).astype(np.float32),
                         lamb=LAMB, nu=NU)
    np.testing.assert_array_equal(
        out, (d_bg - d_fg >= 0).astype(np.float32))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_seg_hmin_composite(rng, backend):
    """h-minima seeding crosses a reconstruction → point bridge → gdt
    chain inside one program."""
    h = 0.75
    img, _ = _case(rng, (24, 20), np.float32)
    expr = seg_hmin_expr(h, lamb=LAMB, nu=NU)
    kinds = [s.kind for s in lower(expr).segments]
    assert "point" in kinds and kinds[-1] == "gdt"
    out = np.asarray(api.compile(expr, img.shape, img.dtype,
                                 backend)(jnp.asarray(img)))
    marker = np.asarray(OPS.sat_add(jnp.asarray(img), h))
    hmin = np.asarray(M.erode_reconstruct(jnp.asarray(marker),
                                          jnp.asarray(img)))
    seeds = (hmin - img >= h).astype(np.float32)
    np.testing.assert_array_equal(
        out, gdt_reference(img, seeds, lamb=LAMB, nu=NU))


def test_seg_hmin_rejects_nonpositive_h():
    with pytest.raises(ValueError, match="h="):
        seg_hmin_expr(0.0)


# ---------------------------------------------------------------------------
# serving: pinned assets + incremental marker updates
# ---------------------------------------------------------------------------


def test_serve_pinned_incremental_updates(rng):
    """The interactive pattern: pin the image once, stream seed
    updates against the name — continuous engine, bit-exact, and every
    resolution counted in ``asset_hits``."""
    img, _ = _case(rng, (24, 24), np.float32)
    svc = Service(backend="pallas", max_batch=4, pad_quantum=8,
                  continuous=True)
    svc.pin("slice", img)
    params = {"lamb": LAMB, "nu": NU}
    tickets, refs = [], []
    for k in range(3):
        seeds = np.zeros(img.shape, np.float32)
        seeds[4 + 6 * k, 5 + 5 * k] = 1.0
        tickets.append(svc.submit("gdt", "slice", seeds, params=params))
        refs.append(gdt_reference(img, seeds, lamb=LAMB, nu=NU))
    svc.flush()
    for t, ref in zip(tickets, refs):
        np.testing.assert_array_equal(np.asarray(t.result()), ref)
    assert svc.stats()["counters"]["asset_hits"] == 3

    with pytest.raises(InvalidRequestError, match="unknown pinned"):
        svc.submit("gdt", "nosuch", np.zeros(img.shape, np.float32),
                   params=params)
    with pytest.raises(InvalidRequestError, match="2-D"):
        svc.pin("bad", np.zeros((2, 8, 8), np.float32))
    svc.unpin("slice")
    with pytest.raises(InvalidRequestError, match="unknown pinned"):
        svc.submit("gdt", "slice", np.zeros(img.shape, np.float32),
                   params=params)
    # gdt-backed ops are float-lattice only: integer payloads get the
    # typed admission rejection, not a compile error deep in the engine
    with pytest.raises(UnsupportedDtypeError, match="float"):
        svc.submit("gdt", np.zeros(img.shape, np.uint8),
                   np.zeros(img.shape, np.uint8), params=params)
    svc.close()


def test_serve_scribble_segmentation_op(rng):
    """The registered composite op end-to-end through the service."""
    img, _ = _case(rng, (20, 20), np.float32)
    scrib = np.zeros(img.shape, np.float32)
    scrib[2, 2], scrib[17, 15] = 1.0, 2.0
    svc = Service(backend="pallas", max_batch=2, pad_quantum=8)
    svc.pin("slice", img)
    out = np.asarray(svc.submit(
        "seg_scribble", "slice", scrib,
        params={"lamb": LAMB, "nu": NU}).result())
    d_fg = gdt_reference(img, (scrib == 1.0).astype(np.float32),
                         lamb=LAMB, nu=NU)
    d_bg = gdt_reference(img, (scrib == 2.0).astype(np.float32),
                         lamb=LAMB, nu=NU)
    np.testing.assert_array_equal(
        out, (d_bg - d_fg >= 0).astype(np.float32))
    svc.close()


# ---------------------------------------------------------------------------
# static verifier findings
# ---------------------------------------------------------------------------


def errors_of(findings):
    return [f for f in findings if f.severity == ERROR]


def test_segment_reach_rejects_unknown_kinds():
    with pytest.raises(ValueError, match="unknown segment kind"):
        segment_reach(RunSeg("mystery", (0,), (1,), ()))


def test_check_program_flags_unknown_kind_and_op():
    import dataclasses
    prog = lower(_expr())
    live = prog.segments[-1].dsts[0]
    bogus_kind = dataclasses.replace(
        prog, segments=prog.segments
        + (RunSeg("mystery", (live,), (live + 1,), ()),))
    errs = errors_of(A.check_program(bogus_kind))
    assert any("unknown segment kind" in f.message for f in errs)
    bogus_op = dataclasses.replace(
        prog, segments=prog.segments
        + (RunSeg("chain", (live,), (live + 1,),
                  (("n", 1), ("op", "mystery"))),))
    errs = errors_of(A.check_program(bogus_op))
    assert any("unknown op" in f.message for f in errs)


def test_check_plan_flags_unknown_schedule():
    import dataclasses
    plan = plan_chain(32, 32, np.float32, None, convergent=True)
    mutant = object.__new__(ChainPlan)  # forge past __post_init__
    for f in dataclasses.fields(ChainPlan):
        object.__setattr__(mutant, f.name, getattr(plan, f.name))
    object.__setattr__(mutant, "schedule", "zigzag")
    errs = errors_of(A.check_plan(mutant))
    assert any("schedule" in f.message for f in errs)


def test_dtype_check_flags_gdt_on_integers():
    exe = types.SimpleNamespace(
        dtype=np.dtype(np.uint8), plan=None,
        program=types.SimpleNamespace(
            segments=(RunSeg("gdt", (0, 1), (2,),
                             (("lamb", 1.0), ("nu", 1e6))),)))
    errs = errors_of(check_executable_dtypes(exe))
    assert any("gdt" in f.subject for f in errs)
    clean = api.compile(_expr(), (32, 32), np.float32, "pallas")
    assert errors_of(check_executable_dtypes(clean)) == []


def test_verifier_passes_gdt_programs(rng):
    """The full fast-level verifier proves every gdt program built in
    this file (conftest sets REPRO_VERIFY=1, so this is also implicit
    in every compile above — here we assert the explicit API)."""
    exe = api.compile(_expr(), (40, 36), np.float32, "pallas")
    A.verify_executable(exe)  # raises on ERROR findings
