"""End-to-end behaviour: training descends on structured data, serving
pipeline round-trips, MoE routing conserves mass, recurrent blocks are
chunk-invariant."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_reduced
from repro.models import model as MDL
from repro.train.loop import Trainer, TrainerConfig


@pytest.mark.slow
def test_training_loss_decreases_on_structured_data():
    from repro.optim import adamw

    cfg = get_reduced("gemma-2b")
    tcfg = TrainerConfig(steps=40, seq_len=32, global_batch=4, q_chunk=16,
                         log_every=1000)
    tr = Trainer(cfg, tcfg, adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                              total_steps=40))
    _, hist = tr.run()
    first = float(np.mean(hist[:5]))
    last = float(np.mean(hist[-5:]))
    assert last < first - 0.5, (first, last)


def test_moe_combine_conserves_probability(rng):
    """Top-k gate weights after renormalization sum to 1 per token;
    kept assignments route to exactly one slot."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_init, moe_apply

    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, router_chunk=8,
                    capacity_factor=2.0)
    p = moe_init(jax.random.PRNGKey(0), 32, cfg, "silu", jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    y, aux = moe_apply(p, x, cfg, "silu")
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.5  # load-balance loss near 1 for uniform router


def test_mamba_chunk_invariance(rng):
    """SSD output is independent of the chunk size (stream property)."""
    from repro.models.ssm import mamba2_apply, mamba2_init

    p = mamba2_init(jax.random.PRNGKey(0), 32, 8, 16, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 64, 32)), jnp.float32)
    y1, s1, _ = mamba2_apply(p, x, n_state=8, head_dim=16, chunk=16)
    y2, s2, _ = mamba2_apply(p, x, n_state=8, head_dim=16, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_mamba_prefill_equals_decode(rng):
    from repro.models.ssm import (mamba2_apply, mamba2_decode, mamba2_init,
                                  CONV_K)

    d, n, hd = 32, 8, 16
    p = mamba2_init(jax.random.PRNGKey(1), d, n, hd, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 24, d)), jnp.float32)
    y_all, state, conv = mamba2_apply(p, x, n_state=n, head_dim=hd, chunk=8)

    state_d = jnp.zeros_like(state)
    conv_d = jnp.zeros((1, CONV_K - 1, 2 * d + 2 * n), jnp.float32)
    ys = []
    for t in range(24):
        y, state_d, conv_d = mamba2_decode(p, x[:, t:t + 1], state_d, conv_d,
                                           n_state=n, head_dim=hd)
        ys.append(y)
    y_seq = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_all), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_d),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_invariance(rng):
    from repro.models.xlstm import mlstm_apply, mlstm_init

    p = mlstm_init(jax.random.PRNGKey(2), 32, 4, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 48, 32)), jnp.float32)
    y1, _ = mlstm_apply(p, x, n_heads=4, chunk=8)
    y2, _ = mlstm_apply(p, x, n_heads=4, chunk=48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)


def test_layer_plan_periods():
    from repro.configs.registry import get_config
    from repro.models.model import layer_plan

    period, groups, tail = layer_plan(get_config("gemma3-27b"))
    assert period == 6 and groups == 10 and len(tail) == 2
    period, groups, tail = layer_plan(get_config("zamba2-7b"))
    assert period == 6 and groups == 13 and len(tail) == 3
    period, groups, tail = layer_plan(get_config("xlstm-350m"))
    assert period == 2 and groups == 12 and not tail
    period, groups, tail = layer_plan(get_config("gemma-7b"))
    assert period == 1 and groups == 28 and not tail


def test_adamw_descends_quadratic():
    from repro.optim import adamw

    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=100)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(cfg, params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_compression_unbiased_over_time(rng):
    from repro.optim.compression import quantize

    g = jnp.asarray(rng.standard_normal(1000), jnp.float32) * 1e-3
    err = jnp.zeros_like(g)
    total_q = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, scale, err = quantize(g, err)
        total_q = total_q + q.astype(jnp.float32) * scale
    # error feedback: accumulated quantized sum converges to n*g
    np.testing.assert_allclose(np.asarray(total_q / n), np.asarray(g),
                               atol=5e-5)


def test_ring_cache_matches_full_cache(rng):
    """Sliding-window ring-buffer cache (§Perf G2) is numerically
    identical to the full-sequence cache across window wraparounds."""
    import jax

    from repro.configs.registry import get_reduced
    from repro.models import decode as DEC
    from repro.models import model as MDL

    cfg = get_reduced("gemma3-27b")      # window=16
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 40
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    _, c_full = jax.jit(
        lambda p: DEC.prefill(p, cfg, tok, smax=S + 40, q_chunk=16))(params)
    _, c_ring = jax.jit(
        lambda p: DEC.prefill(p, cfg, tok, smax=512, q_chunk=16))(params)
    assert c_ring["blocks"][0]["k"].shape[2] == cfg.sliding_window
    assert c_full["blocks"][0]["k"].shape[2] == S + 40
    step = jax.jit(lambda p, c, t: DEC.decode_step(p, cfg, c, t))
    stream = jnp.asarray(rng.integers(0, cfg.vocab_size, (24, B, 1)),
                         jnp.int32)
    for i in range(24):
        l1, c_full = step(params, c_full, stream[i])
        l2, c_ring = step(params, c_ring, stream[i])
        a, b = np.asarray(l1), np.asarray(l2)
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 1e-4, (i, rel)
