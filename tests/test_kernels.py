"""Per-kernel validation: shape/dtype sweeps, every Pallas kernel
(interpret=True) asserted exactly equal to its ref.py pure-jnp oracle.
Morphology on the integer lattice is exact — we use array_equal, not
allclose."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.chain import plan_chain
from repro.kernels import ops, ref

DTYPES = [np.uint8, np.uint16, np.float32, np.float64]
SHAPES = [(64, 64), (100, 130), (33, 257), (128, 96)]


def _image(rng, shape, dtype):
    if np.issubdtype(dtype, np.integer):
        return rng.integers(0, np.iinfo(dtype).max, shape).astype(dtype)
    return rng.normal(size=shape).astype(dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES[:2])
@pytest.mark.parametrize("n", [1, 5, 16, 33])
@pytest.mark.parametrize("op", ["erode", "dilate"])
def test_chain_kernel(rng, dtype, shape, n, op):
    f = jnp.asarray(_image(rng, shape, dtype))
    out = ops.morph_chain(f, n, op, "pallas")
    want = ref.chain(f, n, op)
    assert out.dtype == f.dtype and out.shape == f.shape
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES[2:])
def test_chain_kernel_odd_shapes(rng, shape):
    f = jnp.asarray(_image(rng, shape, np.uint8))
    out = ops.morph_chain(f, 17, "erode", "pallas")
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.chain(f, 17, "erode")))


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
@pytest.mark.parametrize("n", [1, 7, 32])
@pytest.mark.parametrize("op", ["erode", "dilate"])
def test_geodesic_kernel(rng, dtype, n, op):
    f = jnp.asarray(_image(rng, (96, 120), dtype))
    m = jnp.asarray(_image(rng, (96, 120), dtype))
    marker = jnp.maximum(f, m) if op == "erode" else jnp.minimum(f, m)
    out = ops.geodesic_chain(marker, m, n, op, "pallas")
    want = ref.geodesic_chain(marker, m, n, op)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.float32])
@pytest.mark.parametrize("op", ["erode", "dilate"])
def test_reconstruct_kernel(rng, dtype, op):
    f = jnp.asarray(_image(rng, (80, 100), dtype))
    m = jnp.asarray(_image(rng, (80, 100), dtype))
    marker = jnp.maximum(f, m) if op == "erode" else jnp.minimum(f, m)
    out = ops.reconstruct(marker, m, op, "pallas")
    want = (ref.erode_reconstruct(marker, m) if op == "erode"
            else ref.dilate_reconstruct(marker, m))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
def test_qdt_kernel(rng, dtype):
    f = jnp.asarray(_image(rng, (72, 96), dtype))
    d, r = ops.qdt_planes(f, backend="pallas")
    dw, rw = ref.qdt_raw(f)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dw))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rw))


def test_plan_chain_invariants():
    for dtype in DTYPES:
        for w in (128, 1024, 5000):
            p = plan_chain(777, w, dtype, 100)
            assert p.band_h % p.fuse_k == 0
            assert p.width_pad % 128 == 0 and p.width_pad >= w
            assert p.height_pad % p.band_h == 0 and p.height_pad >= 777
            assert 0 < p.redundant_compute_fraction < 1
