"""Operator-level correctness vs independent implementations (the
hierarchical-queue reconstruction shares no code with the jnp paths)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.baselines import pixel_pump, queue_reconstruction as qr, vhgw
from repro.core import morphology as M
from repro.core import operators as OPS
from repro.data.images import basins, blobs, border_objects


@pytest.fixture(scope="module")
def male():
    return blobs(48, 56, np.uint8)


def test_reconstruction_vs_queue(male, rng):
    m = rng.integers(0, 256, male.shape).astype(np.uint8)
    marker = np.minimum(male, m)
    ours = np.asarray(M.dilate_reconstruct(jnp.asarray(marker),
                                           jnp.asarray(m)))
    np.testing.assert_array_equal(ours, qr.dilate_reconstruct(marker, m))


def test_hmax_suppresses_small_maxima():
    img = np.full((32, 32), 50, np.uint8)
    img[8, 8] = 80      # contrast 30 bump
    img[24, 24] = 200   # contrast 150 bump
    out = np.asarray(OPS.hmax(jnp.asarray(img), 100))
    assert out[8, 8] == 50          # suppressed entirely
    assert out[24, 24] == 100       # clipped by h
    # dome extracts exactly the clipped contrast
    dome = np.asarray(OPS.dome(jnp.asarray(img), 100))
    assert dome[24, 24] == 100


def test_hfill_fills_interior_minima():
    img = np.full((32, 32), 100, np.uint8)
    img[10:14, 10:14] = 20           # interior hole
    out = np.asarray(OPS.hfill(jnp.asarray(img)))
    assert (out[10:14, 10:14] == 100).all()
    # border-connected basin is NOT filled
    img2 = np.full((32, 32), 100, np.uint8)
    img2[0:4, 0:4] = 20
    out2 = np.asarray(OPS.hfill(jnp.asarray(img2)))
    assert out2[0, 0] == 20


def test_raobj_removes_border_touching():
    img = np.zeros((32, 32), np.uint8)
    img[0:6, 0:6] = 200       # touches border
    img[15:20, 15:20] = 150   # interior object
    out = np.asarray(OPS.raobj(jnp.asarray(img)))
    assert (out[0:6, 0:6] == 0).all()
    assert (out[15:20, 15:20] == 150).all()


def test_opening_by_reconstruction_removes_small():
    img = np.zeros((48, 48), np.uint8)
    img[4:6, 4:6] = 200        # 2x2 object: removed by s=2
    img[20:34, 20:34] = 180    # 14x14 object: survives, shape restored
    out = np.asarray(OPS.opening_by_reconstruction(jnp.asarray(img), 2))
    assert (out[4:6, 4:6] == 0).all()
    assert (out[20:34, 20:34] == 180).all()


def test_qdt_on_flat_disk():
    """QDT of a flat bright square = L∞→η-corrected distance to edge."""
    img = np.zeros((33, 33), np.uint8)
    img[8:25, 8:25] = 100
    d = np.asarray(OPS.qdt(jnp.asarray(img)))
    assert d[16, 16] == d.max()     # centre is deepest
    assert d.max() >= 8             # half width of the square
    assert (np.abs(np.diff(d, axis=0)) <= 1).all()


def test_asf_bounded_and_ordered(male):
    f = jnp.asarray(male)
    a1 = OPS.asf(f, 1)
    a2 = OPS.asf(f, 2)
    assert a1.shape == f.shape and a1.dtype == f.dtype
    # ASF smooths: total variation decreases with scale
    tv = lambda x: np.abs(  # noqa: E731
        np.diff(np.asarray(x, np.int32), axis=0)).sum()
    assert tv(a2) <= tv(a1) <= tv(f)


def test_pixel_pump_large_window(male):
    want = np.asarray(M.erode(jnp.asarray(male), 7))
    np.testing.assert_array_equal(pixel_pump.erode(male, 7), want)
    np.testing.assert_array_equal(
        np.asarray(vhgw.erode(jnp.asarray(male), 7)), want)


def test_synthetic_images_have_required_statistics():
    b = blobs(64, 64, np.uint8)
    assert b.std() > 10                      # non-trivial content
    bo = border_objects(64, 64, np.uint8)
    edge = np.concatenate([bo[0], bo[-1], bo[:, 0], bo[:, -1]])
    assert edge.max() > 128                  # bright structure at border
    ba = basins(64, 64, np.uint8)
    assert ba.min() < 64                     # has deep minima
