"""Expression optimizer (repro.opt): every rewrite rule must be
bit-exact — ``execute(rewrite(g)) == execute(g)`` across dtypes,
shapes and backends — guards must block unsound applications, the
canonicalized compile cache must share programs across structurally
different sources, and per-segment plan specialization must stay
bit-exact against the single-plan path.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import api
from repro.api import E, Expr
from repro.opt import (DEFAULT_RULES, active_rules, register_rule,
                       rewrite, rewrite_traced, rule_names)
from repro.opt.rules import Rule

pytestmark = pytest.mark.pipeline

DTYPES = [np.uint8, np.float32, np.float64]
SHAPES = [(20, 27), (2, 16, 21)]


def _image(rng, shape, dtype):
    if np.issubdtype(np.dtype(dtype), np.integer):
        return rng.integers(0, 255, shape).astype(dtype)
    return rng.normal(size=shape).astype(dtype)


def _inputs(expr, rng, shape, dtype):
    from repro.api.lower import _input_names

    return [jnp.asarray(_image(rng, shape, dtype))
            for _ in _input_names(expr)]


def _assert_equivalent(expr, rng, backend, dtypes=DTYPES, shapes=SHAPES):
    """rewrite(expr) must execute bit-exactly like expr everywhere."""
    rewritten = rewrite(expr)
    for dtype in dtypes:
        for shape in shapes:
            imgs = _inputs(expr, rng, shape, dtype)
            a = api.compile(expr, shape, imgs[0].dtype, backend,
                            rewrite=False)(*imgs)
            b = api.compile(rewritten, shape, imgs[0].dtype, backend,
                            rewrite=False)(*imgs)
            a = a if isinstance(a, tuple) else (a,)
            b = b if isinstance(b, tuple) else (b,)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"{backend} {dtype} {shape}")


# ---------------------------------------------------------------------------
# per-rule bit-exactness (each case is built so exactly the named rule
# family fires; equivalence is checked numerically on both backends)
# ---------------------------------------------------------------------------

f = E.input("f")
g = E.input("g")

RULE_CASES = {
    # E.erode(0, x) folds at construction; the rule covers zero-length
    # chains entering via raw Expr construction (serializers, rewrites)
    "neutral-chain": E.sub(Expr("erode", (E.dilate(3, f),), (("s", 0),)),
                           E.dilate(3, f)),
    "neutral-sat": E.sat_sub(E.sat_add(f, 0), 0),
    "self-reconstruct": E.reconstruct(f, f, op="dilate"),
    "self-geodesic": E.geodesic(f, f, 3, op="dilate"),
    "double-reconstruct": E.reconstruct(
        E.reconstruct(E.sat_sub(f, 40), f, op="dilate"), f, op="dilate"),
    "geodesic-prefix": E.reconstruct(
        E.geodesic(E.sat_sub(f, 40), f, 4, op="dilate"), f, op="dilate"),
    "rec-opening-idem": E.reconstruct(
        E.erode(3, E.reconstruct(E.erode(3, f), f, op="dilate")),
        f, op="dilate"),
    "chain-merge": E.erode(2, E.erode(3, f)),
    "opening-absorb": E.opening(3, E.opening(1, f)),
    "closing-absorb": E.closing(1, E.closing(3, f)),
}


@pytest.mark.parametrize("rule", sorted(RULE_CASES))
def test_rule_fires(rule):
    """Each catalog rule fires on its canonical redundancy."""
    result = rewrite_traced(RULE_CASES[rule])
    assert result.changed
    assert rule in {a.rule for a in result.trace}


#: Rules whose witness graphs are pure erode/dilate chains — cheap on
#: pallas, so they get the full dtype/shape matrix there; the
#: convergent-kernel rules pay ~25 s of pallas tracing per fresh
#: (shape, dtype) and get a uint8 2-D spot-check instead.
CHAIN_RULES = ("neutral-sat", "chain-merge",
               "opening-absorb", "closing-absorb")

#: neutral-chain's witness embeds a raw zero-length segment the lowerer
#: (correctly) refuses, so it cannot execute *unrewritten* — its
#: soundness is structural: the rewrite must equal the graph the E
#: constructors fold to by definition (ε_0 = id).
EXEC_RULES = tuple(r for r in RULE_CASES if r != "neutral-chain")


def test_neutral_chain_matches_constructor_folding():
    out = rewrite(RULE_CASES["neutral-chain"])
    assert out == E.sub(E.dilate(3, f), E.dilate(3, f))


@pytest.mark.parametrize("rule", sorted(EXEC_RULES))
def test_rule_bit_exact_xla(rule, rng):
    _assert_equivalent(RULE_CASES[rule], rng, "xla")


@pytest.mark.parametrize("rule", sorted(CHAIN_RULES))
def test_chain_rule_bit_exact_pallas(rule, rng):
    _assert_equivalent(RULE_CASES[rule], rng, "pallas")


@pytest.mark.slow
@pytest.mark.parametrize("rule",
                         sorted(set(EXEC_RULES) - set(CHAIN_RULES)))
def test_convergent_rule_bit_exact_pallas(rule, rng):
    _assert_equivalent(RULE_CASES[rule], rng, "pallas",
                       dtypes=[np.uint8], shapes=[(20, 27)])


def test_catalog_is_stable():
    """The default catalog names are the documented contract."""
    assert rule_names() == tuple(r.name for r in DEFAULT_RULES)
    assert set(RULE_CASES) == set(r.name for r in DEFAULT_RULES)


# ---------------------------------------------------------------------------
# guards: shared intermediates and cost-increasing absorptions
# ---------------------------------------------------------------------------


def test_chain_merge_guard_shared_intermediate():
    """A chain over a multiply-consumed node must not merge through it
    (the fusion would duplicate the shared intermediate's work)."""
    mid = E.erode(2, f)
    expr = E.sub(E.erode(3, mid), mid)
    assert not rewrite_traced(expr).changed


def test_absorb_guard_shared_inner_opening():
    """γ_s over a *shared* γ_t absorbs only in the free direction:
    s <= t collapses to the existing inner node (no recompute), while
    s > t would build a fresh γ_s alongside the still-needed γ_t —
    guarded off."""
    inner = E.opening(1, f)
    expr = E.sub(E.opening(3, inner), inner)
    assert not rewrite_traced(expr).changed
    # the free direction rewrites even when the inner node is shared
    shared = E.opening(3, f)
    out = rewrite(E.sub(E.opening(1, shared), shared))
    assert out == E.sub(shared, shared)
    # ...and the private version absorbs to the larger radius
    assert rewrite(E.opening(1, E.opening(3, f))) == E.opening(3, f)
    assert rewrite(E.opening(3, E.opening(1, f))) == E.opening(3, f)


def test_rewrite_is_idempotent():
    for expr in RULE_CASES.values():
        once = rewrite(expr)
        assert rewrite(once) == once


def test_rewrite_off_escape_hatch(rng):
    """``rewrite=False`` compiles the graph as written (more launches),
    still bit-exact."""
    expr = RULE_CASES["double-reconstruct"]
    img = _image(rng, (24, 24), np.uint8)
    on = api.compile(expr, img.shape, img.dtype, "xla")
    off = api.compile(expr, img.shape, img.dtype, "xla", rewrite=False)
    assert on.stats()["launches"] < off.stats()["launches"]
    np.testing.assert_array_equal(np.asarray(on(img)),
                                  np.asarray(off(img)))


# ---------------------------------------------------------------------------
# canonicalization sharing in the compile cache
# ---------------------------------------------------------------------------


def test_cache_shares_canonical_programs():
    """Two structurally different graphs with one canonical form share
    a single cache entry; the hit taxonomy distinguishes the share."""
    api.clear_cache()
    a = api.compile(E.erode(2, E.erode(3, f)), (32, 32), np.uint8, "xla")
    b = api.compile(E.erode(5, f), (32, 32), np.uint8, "xla")
    assert a is b
    cs = api.cache_stats()
    assert cs["entries"] == 1
    assert cs["shared_hits"] == 1 and cs["structural_hits"] == 0
    # replaying either source is a structural hit
    api.compile(E.erode(5, f), (32, 32), np.uint8, "xla")
    assert api.cache_stats()["structural_hits"] == 1
    assert api.cache_stats()["hits"] == 2


def test_register_rule_rejects_duplicates():
    with pytest.raises(ValueError):
        register_rule(Rule("chain-merge", lambda node: None,
                           lambda b, ctx: True, lambda b: b))
    assert len(active_rules()) == len(DEFAULT_RULES)


# ---------------------------------------------------------------------------
# per-segment plan specialization
# ---------------------------------------------------------------------------


# two pallas reconstruct tracings per combo (~25 s each on a fresh
# shape): one 2-D integer + one batched float combo covers the
# specialization paths without another full matrix
@pytest.mark.parametrize("dtype,shape",
                         [(np.uint8, (20, 27)),
                          (np.float32, (2, 16, 21))])
def test_specialized_obr_bit_exact(dtype, shape, rng):
    """OBR (fixed chain + convergent reconstruction) under per-group
    plans matches the single-plan program bit-for-bit."""
    expr = api.opening_by_reconstruction_expr(3)
    img = _image(rng, shape, dtype)
    spec = api.compile(expr, shape, dtype, "pallas")
    mono = api.compile(expr, shape, dtype, "pallas", specialize=False)
    assert spec.stats()["plans"] == 2 and spec.stats()["rebands"] == 1
    assert mono.stats()["plans"] == 1 and mono.stats()["rebands"] == 0
    np.testing.assert_array_equal(np.asarray(spec(img)),
                                  np.asarray(mono(img)))


def test_specialization_key_distinct():
    """specialize on/off are distinct executables with distinct keys."""
    expr = api.opening_by_reconstruction_expr(3)
    spec = api.compile(expr, (32, 48), np.uint8, "pallas")
    mono = api.compile(expr, (32, 48), np.uint8, "pallas",
                       specialize=False)
    assert spec is not mono and spec.key != mono.key


def test_single_group_programs_unchanged():
    """A pure fixed-chain program (ASF) stays a single group — no
    re-band boundaries are introduced where none are needed."""
    st = api.compile(api.asf_expr(2), (64, 96), np.uint8,
                     "pallas").stats()
    assert st["plans"] == 1 and st["rebands"] == 0
    assert st["pads"] == 1 and st["crops"] == 1


# The Hypothesis property test ``execute(rewrite(g)) == execute(g)``
# over random redundancy-rich graphs lives in
# ``tests/test_opt_properties.py`` (repo convention: *_properties.py
# files importorskip hypothesis at module level).
