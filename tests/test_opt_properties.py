"""Hypothesis property test for the expression optimizer: for random
redundancy-rich graphs ``g``, ``execute(rewrite(g)) == execute(g)``
bit-for-bit — the whole-catalog soundness property every individual
rule test in ``tests/test_opt.py`` is a special case of.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

from hypothesis import given, settings, strategies as st
from hypothesis.extra.numpy import arrays

from repro import api
from repro.api import E
from repro.opt import rewrite

pytestmark = pytest.mark.pipeline

imgs = arrays(np.uint8, st.tuples(st.integers(6, 20), st.integers(6, 20)),
              elements=st.integers(0, 255))


@st.composite
def graphs(draw, depth=3):
    """Random expression graphs biased toward catalog redundancies
    (zero-length chains, stacked openings, re-reconstructions)."""
    node = E.input("f")
    for _ in range(draw(st.integers(1, depth))):
        choice = draw(st.integers(0, 5))
        s = draw(st.integers(0, 3))
        if choice == 0:
            node = E.erode(s, node)
        elif choice == 1:
            node = E.dilate(s, node)
        elif choice == 2:
            node = E.opening(max(1, s), node)
        elif choice == 3:
            node = E.closing(max(1, s), node)
        elif choice == 4:
            node = E.reconstruct(node, E.input("f"), op="dilate")
        else:
            node = E.sat_sub(node, draw(st.integers(0, 60)))
    return node


@settings(max_examples=20, deadline=None)
@given(graphs(), imgs)
def test_rewrite_preserves_semantics(expr, img):
    rewritten = rewrite(expr)
    a = api.compile(expr, img.shape, img.dtype, "xla",
                    rewrite=False)(jnp.asarray(img))
    b = api.compile(rewritten, img.shape, img.dtype, "xla",
                    rewrite=False)(jnp.asarray(img))
    a = a if isinstance(a, tuple) else (a,)
    b = b if isinstance(b, tuple) else (b,)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
