"""Hypothesis property tests on the system's morphological invariants
(paper §2 algebra)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

from hypothesis import given, settings, strategies as st
from hypothesis.extra.numpy import arrays

from repro.baselines import vhgw
from repro.core import morphology as M
from repro.core import operators as OPS

imgs = arrays(np.uint8, st.tuples(st.integers(4, 24), st.integers(4, 24)),
              elements=st.integers(0, 255))
small = st.integers(0, 4)


@settings(max_examples=25, deadline=None)
@given(imgs)
def test_duality(f):
    """ε(f) = 255 - δ(255 - f) on the inverted u8 lattice."""
    fj = jnp.asarray(f)
    lhs = M.erode3(fj)
    rhs = 255 - M.dilate3(255 - fj)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@settings(max_examples=25, deadline=None)
@given(imgs)
def test_decomposition(f):
    """Eq. 23: separable 1-D passes equal the direct 3×3 filter."""
    fj = jnp.asarray(f)
    np.testing.assert_array_equal(
        np.asarray(M.erode3(fj)), np.asarray(M.erode3_direct(fj)))
    np.testing.assert_array_equal(
        np.asarray(M.dilate3(fj)), np.asarray(M.dilate3_direct(fj)))


@settings(max_examples=20, deadline=None)
@given(imgs, small, small)
def test_chain_composition(f, s, t):
    """ε_s ∘ ε_t = ε_{s+t} (the chain identity the kernels exploit)."""
    fj = jnp.asarray(f)
    np.testing.assert_array_equal(
        np.asarray(M.erode(M.erode(fj, s), t)),
        np.asarray(M.erode(fj, s + t)))


@settings(max_examples=20, deadline=None)
@given(imgs, st.integers(1, 5))
def test_vhgw_equals_chain(f, s):
    """O(1)/px erosion equals the chained elementary erosion."""
    fj = jnp.asarray(f)
    np.testing.assert_array_equal(
        np.asarray(vhgw.erode(fj, s)), np.asarray(M.erode(fj, s)))


@settings(max_examples=20, deadline=None)
@given(imgs)
def test_extensivity_antiextensivity(f):
    fj = jnp.asarray(f)
    assert bool(jnp.all(M.erode3(fj) <= fj))
    assert bool(jnp.all(M.dilate3(fj) >= fj))
    assert bool(jnp.all(M.opening(fj, 2) <= fj))
    assert bool(jnp.all(M.closing(fj, 2) >= fj))


@settings(max_examples=15, deadline=None)
@given(imgs, st.integers(0, 2**31 - 1))
def test_reconstruction_fixpoint_and_bounds(f, seed):
    """ε_rec result lies in [mask, marker] and is a fixpoint of ε₁ᵐ."""
    m = np.random.default_rng(seed).integers(
        0, 256, f.shape).astype(np.uint8)
    marker = jnp.maximum(jnp.asarray(f), jnp.asarray(m))
    mask = jnp.asarray(m)
    rec = M.erode_reconstruct(marker, mask)
    assert bool(jnp.all(rec >= mask))
    assert bool(jnp.all(rec <= marker))
    again = M.geodesic_erode1(rec, mask)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(rec))


@settings(max_examples=15, deadline=None)
@given(imgs, st.integers(1, 60))
def test_hmax_properties(f, h):
    fj = jnp.asarray(f)
    out = OPS.hmax(fj, h)
    assert bool(jnp.all(out <= fj))
    # dome is what was removed
    np.testing.assert_array_equal(
        np.asarray(OPS.dome(fj, h)), np.asarray(fj - out))


@settings(max_examples=10, deadline=None)
@given(imgs)
def test_granulometry_monotone(f):
    """G_s is non-increasing in s (sieving axiom) ⇒ PS ≥ 0."""
    ps = np.asarray(OPS.pattern_spectrum(jnp.asarray(f), 4))
    assert (ps >= -1e-6).all()


@settings(max_examples=10, deadline=None)
@given(imgs)
def test_qdt_is_lipschitz(f):
    d = np.asarray(OPS.qdt(jnp.asarray(f)))
    dx = np.abs(np.diff(d, axis=0)).max(initial=0)
    dy = np.abs(np.diff(d, axis=1)).max(initial=0)
    assert max(dx, dy) <= 1
