"""Recompilation guard: steady-state serve traffic over a warm bucket
must never rebuild a program — neither in the service's compiled-program
cache nor in the expression compile cache underneath it.  A miss here
is how an incomplete ``Executable.key`` (the cache-key check class)
would first show up in production: as silent p99 spikes.
"""
import numpy as np
import pytest

from repro.api.compile import cache_stats
from repro.serve import Service

pytestmark = pytest.mark.serve


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_warm_bucket_serves_without_recompiles(backend, rng):
    svc = Service(backend=backend, max_batch=2, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    svc.warmup([
        {"op": "erode", "shape": (64, 96), "dtype": "uint8",
         "params": {"s": 4}},
        {"op": "hmax", "shape": (64, 96), "dtype": "uint8",
         "params": {"h": 40}},
    ])
    cache0 = svc.cache.stats()
    api0 = cache_stats()

    results = []
    for _round in range(2):
        # two requests per op fill the warmed batch=2 bucket exactly
        tickets = [
            svc.submit(op, rng.integers(0, 255, shape).astype(np.uint8),
                       params=params)
            for op, params in (("erode", {"s": 4}), ("hmax", {"h": 40}))
            for shape in ((60, 90), (64, 96))
        ]
        svc.flush()
        results.append([np.asarray(t.result()) for t in tickets])

    cache1 = svc.cache.stats()
    api1 = cache_stats()
    assert cache1["misses"] == cache0["misses"], \
        "serve compiled-program cache rebuilt a warm bucket"
    assert api1["misses"] == api0["misses"], \
        "expression compile cache rebuilt a warm program"
    # traffic did flow through the warm entries
    assert cache1["hits"] > cache0["hits"]
    # both rounds used the same shapes, so outputs must agree in shape
    for a, b in zip(*results):
        assert a.shape == b.shape
