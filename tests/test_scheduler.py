"""Active-band requeue scheduler: bit-exactness under band skipping and
compaction, stats accounting, and the batched (N, H, W) front-end.

The scheduler must be invisible in the outputs — every test here pins
the Pallas driver against the pure-jnp ``core.morphology`` references —
while the stats must show it actually skipped work on sparse markers.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import morphology as M
from repro.core import operators as OPS
from repro.core.chain import plan_chain
from repro.kernels import ops


def _sparse_marker(shape, dtype, seeds, value):
    m = np.zeros(shape, dtype)
    for (y, x) in seeds:
        m[y, x] = value
    return m


def _reference(marker, mask, op):
    if op == "erode":
        return M.erode_reconstruct(marker, mask)
    return M.dilate_reconstruct(marker, mask)


# ---------------------------------------------------------------------------
# bit-exactness on sparse single-seed markers (most bands converge early)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
@pytest.mark.parametrize("op", ["erode", "dilate"])
def test_reconstruct_sparse_seed_exact(rng, dtype, op):
    shape = (320, 130)
    hi = 200 if dtype == np.uint8 else 1.5
    mask = rng.integers(20, 180, shape).astype(dtype) if dtype == np.uint8 \
        else rng.uniform(0.1, 1.2, shape).astype(dtype)
    if op == "erode":
        # erosion reconstructs downwards: marker >= mask, sparse "hole"
        marker = np.full(shape, np.iinfo(dtype).max if dtype == np.uint8
                         else 2.0, dtype)
        marker[37, 61] = mask[37, 61]
    else:
        marker = _sparse_marker(shape, dtype, [(37, 61)], hi)
        marker = np.minimum(marker, mask)
    out = ops.reconstruct(jnp.asarray(marker), jnp.asarray(mask), op, "pallas")
    want = _reference(jnp.asarray(marker), jnp.asarray(mask), op)
    assert out.dtype == jnp.asarray(marker).dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("op", ["erode", "dilate"])
def test_reconstruct_compaction_branch_exact(op):
    """Tall image + single seed: the active fraction drops below the
    compaction threshold, so the compacted grid path must run and stay
    bit-exact."""
    H, W = 512, 96
    fill = 180
    mask = np.full((H, W), fill, np.uint8)
    if op == "erode":
        marker = np.full((H, W), 255, np.uint8)
        marker[500, 48] = fill
    else:
        marker = np.zeros((H, W), np.uint8)
        marker[4, 48] = fill
    plan = plan_chain(H, W, np.uint8, None, n_images_resident=2,
                      convergent=True)
    out, stats = ops.reconstruct_with_stats(
        jnp.asarray(marker), jnp.asarray(mask), op, "pallas", plan=plan)
    want = _reference(jnp.asarray(marker), jnp.asarray(mask), op)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    per_chunk = np.asarray(stats.active_per_chunk)[: int(stats.chunks)]
    # the wavefront localizes: compaction-eligible chunks must exist
    assert (per_chunk <= plan.compact_capacity).any()


def test_reconstruct_512_sparse_band_work():
    """Acceptance criterion: on a 512×512 sparse-marker image the summed
    active-band count stays below 50% of total_bands × chunks while the
    output matches the reference exactly.

    The mask holds one horizontally extended object; the rest of the
    image is background the reconstruction never touches, so most bands
    converge after the first chunk and must stop being requeued."""
    H = W = 512
    mask = np.zeros((H, W), np.uint8)
    mask[224:288, 40:472] = 200  # object spanning 2 of 16 bands
    marker = _sparse_marker((H, W), np.uint8, [(240, 48)], 200)
    marker = np.minimum(marker, mask)
    out, stats = ops.reconstruct_with_stats(
        jnp.asarray(marker), jnp.asarray(mask), "dilate", "pallas")
    want = M.dilate_reconstruct(jnp.asarray(marker), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    total = int(stats.total_bands) * int(stats.chunks)
    assert int(stats.active_band_sum) < 0.5 * total, (
        f"scheduler did not skip enough: {int(stats.active_band_sum)} of "
        f"{total} band-chunks ran")


def test_active_bands_monotone_after_wavefront():
    """Once the geodesic wavefront has passed (peak activity), the
    per-chunk active-band count must be non-increasing: converged bands
    are never requeued."""
    H, W = 512, 128
    mask = np.full((H, W), 200, np.uint8)
    marker = _sparse_marker((H, W), np.uint8, [(4, 64)], 200)
    _, stats = ops.reconstruct_with_stats(
        jnp.asarray(marker), jnp.asarray(mask), "dilate", "pallas")
    per_chunk = np.asarray(stats.active_per_chunk)[: int(stats.chunks)]
    assert per_chunk.sum() == int(stats.active_band_sum)
    # chunk 0 is the all-active warm-up; the wavefront has passed once
    # the steady-state activity peaks for the last time.  From there the
    # count must never regrow — converged bands are never requeued.
    steady = per_chunk[1:]
    last_peak = len(steady) - 1 - int(steady[::-1].argmax())
    tail = steady[last_peak:]
    assert (np.diff(tail) <= 0).all(), f"active counts regrew: {per_chunk}"


def test_qdt_scheduled_exact(rng):
    """QDT runs the same scheduler; sparse image converges bandwise."""
    f = np.zeros((320, 96), np.uint8)
    f[8:24, 8:24] = 255  # one object near the top: bottom bands idle early
    d, r = ops.qdt_planes(jnp.asarray(f), backend="pallas")
    dw, rw = OPS.qdt_raw(jnp.asarray(f))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dw))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rw))


# ---------------------------------------------------------------------------
# explicit plan= override (API consistency across all three chain drivers)
# ---------------------------------------------------------------------------


def test_plan_override_consistent(rng):
    f = jnp.asarray(rng.integers(0, 255, (96, 100)).astype(np.uint8))
    m = jnp.asarray(rng.integers(0, 255, (96, 100)).astype(np.uint8))
    marker = jnp.maximum(f, m)
    plan = plan_chain(96, 100, np.uint8, None, n_images_resident=2,
                      fuse_k=8, band_h=32, convergent=True)
    out_c = ops.morph_chain(f, 8, "erode", "pallas", plan=plan)
    np.testing.assert_array_equal(
        np.asarray(out_c),
        np.asarray(ops.morph_chain(f, 8, "erode", "pallas")))
    out_g = ops.geodesic_chain(marker, m, 8, "erode", "pallas", plan=plan)
    np.testing.assert_array_equal(
        np.asarray(out_g),
        np.asarray(ops.geodesic_chain(marker, m, 8, "erode", "pallas")))
    out_r = ops.reconstruct(marker, m, "erode", "pallas", plan=plan)
    np.testing.assert_array_equal(
        np.asarray(out_r), np.asarray(M.erode_reconstruct(marker, m)))


def test_plan_validation_single_place():
    with pytest.raises(ValueError, match="multiple of fuse_k"):
        plan_chain(128, 128, np.uint8, None, fuse_k=32, band_h=48)
    with pytest.raises(ValueError):
        bad = plan_chain(64, 64, np.uint8, None)
        ops.reconstruct(jnp.zeros((200, 200), jnp.uint8),
                        jnp.zeros((200, 200), jnp.uint8),
                        "erode", "pallas", plan=bad)


# ---------------------------------------------------------------------------
# batched (N, H, W) front-end vs the per-image path
# ---------------------------------------------------------------------------


def _batch(rng, n, shape, dtype=np.uint8):
    return rng.integers(0, 255, (n, *shape)).astype(dtype)


@pytest.mark.parametrize("fn,s", [(ops.erode, 5), (ops.dilate, 5),
                                  (ops.opening, 3), (ops.closing, 3)])
def test_batched_fixed_ops(rng, fn, s):
    fb = jnp.asarray(_batch(rng, 3, (70, 90)))
    out = fn(fb, s, backend="pallas")
    assert out.shape == fb.shape
    for i in range(fb.shape[0]):
        np.testing.assert_array_equal(
            np.asarray(out[i]), np.asarray(fn(fb[i], s, backend="pallas")))


def test_batched_geodesic_chain(rng):
    fb = jnp.asarray(_batch(rng, 3, (70, 90)))
    mb = jnp.asarray(_batch(rng, 3, (70, 90)))
    marker = jnp.maximum(fb, mb)
    out = ops.geodesic_chain(marker, mb, 7, "erode", "pallas")
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(out[i]),
            np.asarray(ops.geodesic_chain(marker[i], mb[i], 7, "erode",
                                          "pallas")))


@pytest.mark.parametrize("op", ["erode", "dilate"])
def test_batched_reconstruct(rng, op):
    fb = jnp.asarray(_batch(rng, 3, (64, 96)))
    mb = jnp.asarray(_batch(rng, 3, (64, 96)))
    marker = jnp.maximum(fb, mb) if op == "erode" else jnp.minimum(fb, mb)
    out = ops.reconstruct(marker, mb, op, "pallas")
    assert out.shape == fb.shape
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(out[i]),
            np.asarray(_reference(marker[i], mb[i], op)))


def test_batched_per_image_convergence(rng):
    """A converged image must stop contributing band work: stack a
    trivially-converged image with a slow one and compare the active-band
    total against running the slow image alone."""
    H, W = 256, 96
    mask = np.full((H, W), 200, np.uint8)
    slow = _sparse_marker((H, W), np.uint8, [(4, 48)], 200)
    done = mask.copy()  # marker == mask: converged after one pass
    stack_m = jnp.asarray(np.stack([done, slow]))
    stack_k = jnp.asarray(np.stack([mask, mask]))
    out, stats = ops.reconstruct_with_stats(stack_m, stack_k, "dilate",
                                            "pallas")
    _, solo = ops.reconstruct_with_stats(
        jnp.asarray(slow), jnp.asarray(mask), "dilate", "pallas")
    np.testing.assert_array_equal(np.asarray(out[0]), mask)
    np.testing.assert_array_equal(
        np.asarray(out[1]),
        np.asarray(M.dilate_reconstruct(jnp.asarray(slow), jnp.asarray(mask))))
    # batched total ≈ solo total + one all-active pass for the done image:
    # well under doubling the work.
    assert int(stats.active_band_sum) < 2 * int(solo.active_band_sum)


def test_batched_qdt(rng):
    fb = jnp.asarray(_batch(rng, 2, (72, 96)))
    d, r = ops.qdt_planes(fb, backend="pallas")
    for i in range(2):
        dw, rw = OPS.qdt_raw(fb[i])
        np.testing.assert_array_equal(np.asarray(d[i]), np.asarray(dw))
        np.testing.assert_array_equal(np.asarray(r[i]), np.asarray(rw))


def test_batched_qdt_ragged_convergence(rng):
    """Per-image distance offsets: a trivially-flat image (converged in
    one chunk), a deep-structure image (many chunks) and a busy one
    stacked together must each match their solo qdt_raw exactly — the
    d-plane index is per-image, not the global chunk counter."""
    H, W = 160, 96
    flat = np.zeros((H, W), np.uint8)
    deep = np.zeros((H, W), np.uint8)
    deep[8:152, 8:88] = 255  # large object: erosion iterates longest
    busy = rng.integers(0, 255, (H, W)).astype(np.uint8)
    fb = jnp.asarray(np.stack([flat, deep, busy]))
    d, r = ops.qdt_planes(fb, backend="pallas")
    for i in range(3):
        dw, rw = OPS.qdt_raw(fb[i])
        np.testing.assert_array_equal(np.asarray(d[i]), np.asarray(dw))
        np.testing.assert_array_equal(np.asarray(r[i]), np.asarray(rw))


def test_compaction_mask_cache_exact():
    """Wavefront confined to one band for many chunks: the compact
    workspace's mask gather is reused between chunks (the shared
    driver's gather_const cache hits while the active set is static);
    the output must stay bit-exact vs the oracle."""
    H, W = 128, 256
    mask = np.zeros((H, W), np.uint8)
    rows = list(range(2, 28, 4))
    for row in rows:  # serpentine corridor inside band 0 (rows 0..31)
        mask[row : row + 2, 2 : W - 2] = 200
    for j, row in enumerate(rows[:-1]):  # alternating end links
        col = W - 4 if j % 2 == 0 else 2
        mask[row : row + 6, col : col + 2] = 200
    marker = np.zeros((H, W), np.uint8)
    marker[2, 4] = 200
    marker = np.minimum(marker, mask)
    out, stats = ops.reconstruct_with_stats(
        jnp.asarray(marker), jnp.asarray(mask), "dilate", "pallas")
    want = M.dilate_reconstruct(jnp.asarray(marker), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    assert int(stats.chunks) > 8  # the in-band iteration actually ran long


def test_operators_pallas_backend(rng):
    f = jnp.asarray(rng.integers(0, 255, (96, 96)).astype(np.uint8))
    np.testing.assert_array_equal(
        np.asarray(OPS.hmax(f, 40, backend="pallas")),
        np.asarray(OPS.hmax(f, 40)))
    np.testing.assert_array_equal(
        np.asarray(OPS.hfill(f, backend="pallas")),
        np.asarray(OPS.hfill(f)))
