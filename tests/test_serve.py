"""repro.serve: bucketing/demux round-trips must be invisible in the
outputs (bit-exact vs direct operator calls, assert_array_equal), while
the metrics must show the machinery actually worked — batch occupancy,
deadline flushes, compiled-program cache hits and LRU eviction.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import morphology as M
from repro.core import operators as OPS
from repro.kernels import ops as K
from repro.serve import Service, registry
from repro.serve.bucketer import bucket_hw, canonical_batch, pad_fill

pytestmark = pytest.mark.serve


class FakeClock:
    """Deterministic time source for deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _image(rng, shape, dtype):
    if np.dtype(dtype).kind == "f":
        return rng.uniform(0.0, 1.0, shape).astype(dtype)
    return rng.integers(0, 255, shape).astype(dtype)


def _direct(op, images, params):
    """Reference: each operator called directly on the unpadded image."""
    f = jnp.asarray(images[0])
    if op == "hmax":
        return OPS.hmax(f, params["h"])
    if op == "dome":
        return OPS.dome(f, params["h"])
    if op == "hfill":
        return OPS.hfill(f)
    if op == "raobj":
        return OPS.raobj(f)
    if op == "open_rec":
        return OPS.opening_by_reconstruction(f, params["s"])
    if op == "erode":
        return K.erode(f, params["s"], backend="xla")
    if op == "dilate":
        return K.dilate(f, params["s"], backend="xla")
    if op == "asf":
        return OPS.asf(f, params["s"])
    if op == "qdt":
        return OPS.qdt_raw(f)  # (d, r)
    if op == "reconstruct":
        m = jnp.asarray(images[1])
        return M.dilate_reconstruct(f, m)
    raise AssertionError(op)


# ---------------------------------------------------------------------------
# acceptance: shuffled mixed-shape/dtype stream is bit-exact vs direct calls
# ---------------------------------------------------------------------------


def test_mixed_stream_bit_exact(rng):
    """A shuffled stream mixing shapes, dtypes, pad-safe and exact-shape
    ops must round-trip bit-exactly through bucketing, pad-to-bucket
    canonicalization, sentinel batch padding and the demux crop."""
    shapes = [(60, 90), (90, 60), (64, 96), (33, 47)]
    cases = []
    for i, shape in enumerate(shapes):
        for dtype in (np.uint8, np.float32):
            h = 40 if dtype == np.uint8 else 0.2
            f = _image(rng, shape, dtype)
            cases.append(("hmax", (f,), {"h": h}))
            cases.append(("hfill", (f,), {}))
            cases.append(("erode", (f,), {"s": 4}))
            cases.append(("asf", (f,), {"s": 2}))  # exact-shape bucket
    svc = Service(backend="xla", max_batch=4, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    # two rounds in different shuffled orders: round 2 replays every
    # bucket, so the compiled-program cache must serve it from hits
    for round_ in range(2):
        order = rng.permutation(len(cases))
        tickets = [
            (i, svc.submit(cases[i][0], *cases[i][1], params=cases[i][2]))
            for i in order
        ]
        svc.flush()
        for i, t in tickets:
            op, images, params = cases[i]
            np.testing.assert_array_equal(
                np.asarray(t.result()),
                np.asarray(_direct(op, images, params)),
                err_msg=f"{op} on {images[0].shape} {images[0].dtype}")
    stats = svc.stats()
    assert stats["totals"]["requests"] == 2 * len(cases)
    # mixed shapes that quantize to one bucket must actually co-batch
    assert any(b["batch_occupancy"] > 0 and b["requests"] > 1
               for b in stats["buckets"].values())
    assert stats["cache"]["hit_rate"] > 0  # round 2 reuses programs


def test_pallas_backend_stream_exact(rng):
    """Serving through the Pallas fast path (the shared active-band
    scheduler) with shapes that share one padded bucket."""
    f1 = _image(rng, (60, 90), np.uint8)
    f2 = _image(rng, (64, 96), np.uint8)
    svc = Service(backend="pallas", max_batch=2, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    t1 = svc.submit("hmax", f1, params={"h": 40})
    t2 = svc.submit("hmax", f2, params={"h": 40})
    svc.flush()
    assert svc.stats()["totals"]["batches"] == 1  # co-batched in one bucket
    np.testing.assert_array_equal(
        np.asarray(t1.result()), np.asarray(OPS.hmax(jnp.asarray(f1), 40)))
    np.testing.assert_array_equal(
        np.asarray(t2.result()), np.asarray(OPS.hmax(jnp.asarray(f2), 40)))


def test_arity2_and_multi_output(rng):
    """reconstruct (two inputs) and qdt (two outputs) round-trip."""
    mask = _image(rng, (48, 64), np.uint8)
    marker = np.minimum(_image(rng, (48, 64), np.uint8), mask)
    f = _image(rng, (40, 56), np.uint8)
    svc = Service(backend="xla", max_batch=2, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    tr = svc.submit("reconstruct", marker, mask, params={"op": "dilate"})
    tq = svc.submit("qdt", f)
    svc.flush()
    np.testing.assert_array_equal(
        np.asarray(tr.result()),
        np.asarray(M.dilate_reconstruct(jnp.asarray(marker),
                                        jnp.asarray(mask))))
    d, r = tq.result()
    dw, rw = OPS.qdt_raw(jnp.asarray(f))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dw))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rw))


# ---------------------------------------------------------------------------
# bucketer: deadline flush, occupancy, sentinel padding
# ---------------------------------------------------------------------------


def test_deadline_flush(rng):
    """A straggler request never waits more than max_delay_ms."""
    clock = FakeClock()
    svc = Service(backend="xla", max_batch=4, max_delay_ms=5.0,
                  pad_quantum=32, clock=clock)
    f = _image(rng, (32, 32), np.uint8)
    t = svc.submit("erode", f, params={"s": 3})
    assert svc.pending() == 1 and not t.done  # under deadline: queued
    clock.advance(0.004)
    svc.poll()
    assert svc.pending() == 1  # 4ms < 5ms: still queued
    clock.advance(0.002)
    svc.poll()  # 6ms: deadline exceeded -> launched
    assert svc.pending() == 0
    svc.flush()
    assert t.done
    np.testing.assert_array_equal(
        np.asarray(t.result()),
        np.asarray(K.erode(jnp.asarray(f), 3, backend="xla")))


def test_batch_occupancy_and_sentinels(rng):
    """3 requests into a max_batch=4 bucket: batch padded to the
    canonical size with sentinel slots, occupancy reported as 3/4."""
    clock = FakeClock()
    svc = Service(backend="xla", max_batch=4, max_delay_ms=1e9,
                  pad_quantum=32, clock=clock)
    frames = [_image(rng, (30, 40), np.uint8) for _ in range(3)]
    tickets = [svc.submit("dilate", f, params={"s": 3}) for f in frames]
    svc.flush()
    for f, t in zip(frames, tickets):
        np.testing.assert_array_equal(
            np.asarray(t.result()),
            np.asarray(K.dilate(jnp.asarray(f), 3, backend="xla")))
    (bucket,) = svc.stats()["buckets"].values()
    assert bucket["requests"] == 3
    assert bucket["batches"] == 1
    assert bucket["batch_occupancy"] == pytest.approx(0.75)


def test_full_bucket_launches_immediately(rng):
    clock = FakeClock()
    svc = Service(backend="xla", max_batch=2, max_delay_ms=1e9,
                  pad_quantum=32, clock=clock)
    f = _image(rng, (16, 16), np.uint8)
    svc.submit("erode", f, params={"s": 2})
    assert svc.pending() == 1
    svc.submit("erode", f, params={"s": 2})
    assert svc.pending() == 0  # bucket filled -> launched without a poll


def test_ticket_result_drives_pipeline(rng):
    """Ticket.result() on a queued request completes it without an
    explicit flush()."""
    svc = Service(backend="xla", max_batch=8, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    f = _image(rng, (24, 24), np.uint8)
    t = svc.submit("erode", f, params={"s": 2})
    np.testing.assert_array_equal(
        np.asarray(t.result()),
        np.asarray(K.erode(jnp.asarray(f), 2, backend="xla")))


def test_bucket_helpers():
    assert bucket_hw(60, 90, 32) == (64, 96)
    assert bucket_hw(64, 96, 32) == (64, 96)
    assert canonical_batch(1, 8) == 1
    assert canonical_batch(3, 8) == 4
    assert canonical_batch(5, 4) == 4
    assert canonical_batch(3, 3) == 3  # cap wins over power-of-two rounding
    assert pad_fill(np.uint8, "hi") == 255
    assert pad_fill(np.uint8, "lo") == 0
    assert np.isinf(pad_fill(np.float32, "hi"))


# ---------------------------------------------------------------------------
# compiled-program cache: hits, warm-up prefill, LRU eviction
# ---------------------------------------------------------------------------


def test_cache_hits_and_plan(rng):
    clock = FakeClock()
    svc = Service(backend="pallas", max_batch=1, max_delay_ms=1e9,
                  pad_quantum=32, clock=clock)
    f = _image(rng, (40, 60), np.uint8)
    for _ in range(3):
        svc.submit("erode", f, params={"s": 4})
    svc.flush()
    stats = svc.stats()["cache"]
    assert stats["misses"] == 1 and stats["hits"] == 2
    # the cached entry embeds the ChainPlan the program compiled against
    (entry,) = svc.cache.entries()
    assert entry.plan is not None and entry.plan.key[2] >= 64  # width_pad


def test_cache_warmup_prefill(rng):
    svc = Service(backend="xla", max_batch=2, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    svc.warmup([{"op": "erode", "params": {"s": 4}, "shape": (40, 60),
                 "dtype": np.uint8, "batch": 2}])
    assert svc.cache.stats()["warm_builds"] == 1
    f1, f2 = (_image(rng, (40, 60), np.uint8) for _ in range(2))
    t1 = svc.submit("erode", f1, params={"s": 4})
    t2 = svc.submit("erode", f2, params={"s": 4})
    svc.flush()
    t1.result(), t2.result()
    stats = svc.cache.stats()
    assert stats["misses"] == 0 and stats["hits"] == 1  # warm hit only


def test_cache_lru_eviction(rng):
    """Eviction follows recency of *use*, not insertion: touching A
    before inserting C must evict B, and A must stay resident."""
    clock = FakeClock()
    svc = Service(backend="xla", max_batch=1, max_delay_ms=1e9,
                  pad_quantum=16, cache_capacity=2, clock=clock)
    A, B, C = (16, 16), (32, 32), (48, 48)

    def hit(shape):
        svc.submit("erode", _image(rng, shape, np.uint8), params={"s": 2})

    hit(A)   # miss, insert A
    hit(B)   # miss, insert B
    hit(A)   # hit: A becomes most-recently-used
    hit(C)   # miss: evicts B (LRU), not A
    hit(A)   # hit: A survived the eviction
    svc.flush()
    stats = svc.cache.stats()
    assert stats["entries"] == 2
    assert stats["misses"] == 3
    assert stats["hits"] == 2
    assert stats["evictions"] == 1


def test_dispatch_failure_resolves_tickets(rng):
    """A program that fails at dispatch must resolve every co-batched
    ticket with a *typed* error instead of stranding them — and the
    failure must not propagate out of submit/poll (the PR 7 robustness
    contract; the recovery ladder itself is covered in
    tests/test_faults.py)."""
    from repro.serve.errors import PoisonedRequestError
    from repro.serve.registry import OpSpec, _REGISTRY, register

    def bad_run(inputs, params, backend, plan):
        raise RuntimeError("boom")

    register(OpSpec(name="_boom_test", params={}, run=bad_run))
    try:
        svc = Service(backend="xla", max_batch=2, max_delay_ms=1e9,
                      pad_quantum=16, clock=FakeClock(), max_retries=1)
        t1 = svc.submit("_boom_test", _image(rng, (8, 8), np.uint8))
        # fills the bucket -> launch -> trace raises inside dispatch;
        # the recovery ladder resolves both tickets, nothing escapes
        t2 = svc.submit("_boom_test", _image(rng, (8, 8), np.uint8))
        for t in (t1, t2):
            assert t.done and t.error is not None
            assert t.outcome == "poisoned"
            with pytest.raises(PoisonedRequestError, match="poisoned"):
                t.result()
            assert isinstance(t.error.cause, RuntimeError)  # boom preserved
        counters = svc.stats()["counters"]
        assert counters["batch_failures"] >= 1
        assert counters["retried"] >= 1
        assert counters["poisoned"] == 2
    finally:
        _REGISTRY.pop("_boom_test", None)


def test_optimizer_counters(rng):
    """The optimizer counters: ``rewrites_applied`` counts rule
    applications behind admitted requests (ASF's adjacent dilate
    chains merge), ``programs_shared`` fires when a distinct source
    graph joins an already-compiled program identity (HMAX and DOME
    are one dilate-reconstruction)."""
    svc = Service(backend="xla", max_batch=1, max_delay_ms=1e9,
                  pad_quantum=16, clock=FakeClock())
    f = _image(rng, (24, 24), np.uint8)
    t = svc.submit("asf", f, params={"s": 1})
    svc.flush()
    np.testing.assert_array_equal(
        np.asarray(t.result()),
        np.asarray(_direct("asf", (f,), {"s": 1})))
    counters = svc.stats()["counters"]
    assert counters["rewrites_applied"] >= 1
    assert counters["programs_shared"] == 0
    t1 = svc.submit("hmax", f, params={"h": 40})
    svc.flush()
    t2 = svc.submit("dome", f, params={"h": 40})
    svc.flush()
    assert t1.done and t2.done
    assert svc.stats()["counters"]["programs_shared"] == 1


# ---------------------------------------------------------------------------
# registry: schema-as-data validation
# ---------------------------------------------------------------------------


def test_registry_lists_hooked_ops():
    names = registry.names()
    for expected in ("hmax", "dome", "hfill", "raobj", "open_rec", "asf",
                     "erode", "dilate", "opening", "closing", "reconstruct",
                     "geodesic", "qdt", "qdt_l1"):
        assert expected in names


def test_registry_param_validation(rng):
    svc = Service(backend="xla", clock=FakeClock())
    f = _image(rng, (16, 16), np.uint8)
    with pytest.raises(KeyError, match="unknown op"):
        svc.submit("nope", f)
    with pytest.raises(ValueError, match="missing required param"):
        svc.submit("hmax", f)
    with pytest.raises(ValueError, match="unknown params"):
        svc.submit("hfill", f, params={"x": 1})
    with pytest.raises(ValueError, match="must be one of"):
        svc.submit("reconstruct", f, f, params={"op": "median"})
    with pytest.raises(ValueError, match="must be >="):
        svc.submit("erode", f, params={"s": 0})
    with pytest.raises(ValueError, match="takes 2 image"):
        svc.submit("reconstruct", f, params={"op": "dilate"})
    # params canonicalize to a stable hashable key (int h coerces float)
    spec = registry.get("hmax")
    assert spec.canonical_params({"h": 40}) == (("h", 40.0),)


# ---------------------------------------------------------------------------
# metrics: benchmarks JSON schema
# ---------------------------------------------------------------------------


def test_metrics_bench_json_schema(rng):
    svc = Service(backend="xla", max_batch=2, max_delay_ms=1e9,
                  pad_quantum=32, clock=FakeClock())
    for _ in range(2):
        svc.submit("erode", _image(rng, (24, 24), np.uint8),
                   params={"s": 2})
    svc.flush()
    payload = svc.metrics.as_bench_json(svc.cache.stats())
    assert payload  # same schema as benchmarks/run.py --json: name -> us
    for name, us in payload.items():
        assert name.startswith("serve/") and isinstance(us, float)
    rows = svc.bench_rows()
    assert all({"name", "us_per_call", "derived"} <= set(r) for r in rows)
    assert "occ=" in rows[0]["derived"] and "cache_hit=" in rows[0]["derived"]
