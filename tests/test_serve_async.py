"""The event-driven serving engine under the deterministic virtual
clock (PR 9): timer-driven flushes with no caller, deadline expiry as
timers (including the expiry-during-compile race), continuous slot
refill bit-exactness, time-weighted occupancy accounting, load
shedding, close semantics, adaptive pad-quantum — plus the in-process
flake detector (one scenario replayed twice must produce identical
counters).

Bit-exactness is the anchor invariant: a request served from a
refilled slot (admitted mid-flight while other slots iterate) must
produce *exactly* the bytes a solo execution produces.
"""
import asyncio

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_array_equal

from serve_sim import SimHarness, selftest_scenario
from repro.core import operators as OPS
from repro.kernels import ops as K
from repro.serve import (AsyncService, Service, ServiceClosedError,
                         VirtualClock)
from repro.serve.errors import DeadlineExceededError, QueueFullError
from repro.serve.loop import EventLoop
from repro.serve.metrics import ServeMetrics

pytestmark = pytest.mark.serve


@pytest.fixture
def rng():
    return np.random.default_rng(1702)


def _image(rng, shape=(16, 16), dtype=np.uint8):
    return rng.integers(0, 255, shape).astype(dtype)


def _recon_pair(rng, shape=(32, 32), slow=False):
    """(marker, mask) for ``reconstruct``; ``slow=True`` builds a
    serpentine mask so the propagation front must walk most of the
    image — many scheduler chunks, the straggler the continuous engine
    exists for."""
    h, w = shape
    if slow:
        f = np.full(shape, 0.1, np.float32)
        for r in range(0, h, 2):
            f[r, :] = 0.9
            if r + 1 < h:
                f[r + 1, -1 if (r // 2) % 2 == 0 else 0] = 0.9
        m = np.full(shape, 0.05, np.float32)
        m[0, 0] = 0.8
    else:
        f = rng.random(shape).astype(np.float32)
        m = (0.9 * f).astype(np.float32)
    return np.minimum(m, f), f


# ---------------------------------------------------------------------------
# the event loop itself
# ---------------------------------------------------------------------------


def test_event_loop_fires_in_when_seq_order():
    clk = VirtualClock()
    loop = EventLoop(clk)
    fired = []
    loop.call_at(2.0, lambda: fired.append("late"))
    loop.call_at(1.0, lambda: fired.append("a"))
    loop.call_at(1.0, lambda: fired.append("b"))  # same instant: arm order
    h = loop.call_at(1.5, lambda: fired.append("cancelled"))
    h.cancel()
    assert loop.run_due() == 0 and fired == []  # nothing due at t=0
    clk.advance(1.2)
    assert loop.run_due() == 2 and fired == ["a", "b"]
    assert loop.next_deadline() == 2.0
    clk.advance(1.0)
    loop.run_due()
    assert fired == ["a", "b", "late"] and loop.pending() == 0


def test_event_loop_cancel_mid_firing():
    """A due callback cancelling a later due timer suppresses it."""
    clk = VirtualClock()
    loop = EventLoop(clk)
    fired = []
    handles = {}
    handles["b"] = loop.call_at(1.0, lambda: fired.append("b"))

    def cancel_b():
        fired.append("a")
        handles["b"].cancel()

    loop.call_at(0.5, cancel_b)
    clk.advance(2.0)
    loop.run_due()
    assert fired == ["a"]


def test_virtual_clock_monotonic():
    clk = VirtualClock(5.0)
    assert clk() == 5.0
    clk.advance(1.5)
    assert clk() == 6.5
    with pytest.raises(ValueError):
        clk.advance(-0.1)


# ---------------------------------------------------------------------------
# timer-driven flush: the deadline flush fires from a timer, not a caller
# ---------------------------------------------------------------------------


def test_flush_timer_launches_without_flush_call(rng):
    clk = VirtualClock()
    svc = Service(backend="xla", max_batch=4, max_delay_ms=5.0,
                  pad_quantum=16, clock=clk)
    im = _image(rng)
    t = svc.submit("hfill", im)
    assert not t.done and svc.pending() == 1
    clk.advance(0.003)
    svc.pump()
    assert svc.pending() == 1  # 3ms < 5ms: timer not due yet
    clk.advance(0.003)
    svc.pump()                 # flush timer fires → bucket launches
    assert svc.pending() == 0
    while svc.work_pending():
        svc.pump()
    assert t.done and t.outcome == "ok"
    assert_array_equal(np.asarray(t.result()),
                       np.asarray(OPS.hfill(jnp.asarray(im))))


def test_asyncio_flush_fires_with_no_caller(rng):
    """The tentpole property: under AsyncService, a lone sub-batch
    request completes from the loop's own timer wakeups — no poll(),
    no flush(), no result() driving it."""
    im = _image(rng)

    async def main():
        svc = AsyncService(backend="xla", max_batch=8, max_delay_ms=5.0,
                           pad_quantum=16)
        t = svc.submit("hfill", im)
        deadline = asyncio.get_running_loop().time() + 30.0
        while not t.done:  # only sleeping — never pumping the service
            assert asyncio.get_running_loop().time() < deadline
            await asyncio.sleep(0.01)
        await svc.close()
        return t

    t = asyncio.run(main())
    assert t.outcome == "ok"
    assert_array_equal(np.asarray(t.value),
                       np.asarray(OPS.hfill(jnp.asarray(im))))


def test_async_result_and_close(rng):
    im = _image(rng)

    async def main():
        svc = AsyncService(backend="xla", max_batch=8, max_delay_ms=2.0,
                           pad_quantum=16)
        val = await svc.run("hfill", im)
        await svc.close()
        with pytest.raises(ServiceClosedError):
            svc.submit("hfill", im)
        return val

    val = asyncio.run(main())
    assert_array_equal(np.asarray(val),
                       np.asarray(OPS.hfill(jnp.asarray(im))))


# ---------------------------------------------------------------------------
# deadline expiry as timers
# ---------------------------------------------------------------------------


def test_deadline_expiry_ordering(rng):
    """Two queued deadlines expire in deadline order, each the moment
    its timer fires — not in a burst at the next poll."""
    clk = VirtualClock()
    svc = Service(backend="xla", max_batch=8, max_delay_ms=1e9,
                  pad_quantum=16, clock=clk)
    ta = svc.submit("hfill", _image(rng), deadline_ms=10.0)
    tb = svc.submit("hfill", _image(rng), deadline_ms=30.0)
    clk.advance(0.015)
    svc.pump()
    assert ta.done and ta.outcome == "deadline" and not tb.done
    clk.advance(0.025)
    svc.pump()
    assert tb.outcome == "deadline"
    assert ta.t_done < tb.t_done
    with pytest.raises(DeadlineExceededError):
        ta.result()
    assert svc.stats()["counters"]["expired"] == 2
    assert svc.pending() == 0 and not svc.work_pending()


def test_expiry_during_compile_not_dispatched(rng, monkeypatch):
    """Regression for the launch/deadline race: previously expiry was
    only checked in poll() *before* staging, so a request whose
    deadline lapsed during a long trace/compile was still dispatched.
    Now launch re-checks after compiling."""
    clk = VirtualClock()
    svc = Service(backend="xla", max_batch=1, max_delay_ms=1e9,
                  pad_quantum=16, clock=clk)
    real_entry_for = svc._entry_for

    def slow_entry_for(*a, **kw):
        clk.advance(0.05)  # "compile" takes 50ms
        return real_entry_for(*a, **kw)

    monkeypatch.setattr(svc, "_entry_for", slow_entry_for)
    t = svc.submit("hfill", _image(rng), deadline_ms=10.0)
    # max_batch=1 → submit launched inline; the deadline lapsed inside
    # the compile, and the post-compile re-check must have shed it
    assert t.done and t.outcome == "deadline"
    assert svc.stats()["counters"]["expired"] == 1
    assert svc.stats()["totals"]["requests"] == 0  # nothing dispatched


def test_expired_request_keeps_bucket_flush_armed(rng):
    """Expiry of the bucket's oldest re-arms the flush timer for the
    new oldest instead of dropping it."""
    clk = VirtualClock()
    svc = Service(backend="xla", max_batch=8, max_delay_ms=50.0,
                  pad_quantum=16, clock=clk)
    ta = svc.submit("hfill", _image(rng), deadline_ms=10.0)
    clk.advance(0.005)
    tb = svc.submit("hfill", _image(rng))  # no deadline
    clk.advance(0.010)
    svc.pump()  # ta expires; tb must still be flush-scheduled
    assert ta.outcome == "deadline" and not tb.done
    clk.advance(0.045)  # past tb's max_delay
    svc.pump()
    while svc.work_pending():
        svc.pump()
    assert tb.outcome == "ok"


# ---------------------------------------------------------------------------
# continuous batching: slot refill
# ---------------------------------------------------------------------------


def test_continuous_refill_bit_exact(rng):
    """The tentpole invariant: requests admitted into slots freed
    mid-flight (a serpentine straggler keeps the session alive)
    complete bit-exactly vs the direct operator call, and the refills
    counter proves mid-flight admission actually happened."""
    clk = VirtualClock()
    svc = Service(continuous=True, max_batch=4, refill_quantum=1,
                  max_delay_ms=1.0, pad_quantum=16, clock=clk)
    cases = [_recon_pair(rng, slow=True)] + [_recon_pair(rng)
                                             for _ in range(3)]
    tickets = [svc.submit("reconstruct", m, f) for m, f in cases]
    clk.advance(0.002)
    svc.poll()  # flush timer → engine spawned, first wave admitted
    eng = next(iter(svc._engines.values()))
    assert eng.occupied
    # second wave arrives while the straggler is resident
    for _ in range(6):
        m, f = _recon_pair(rng)
        cases.append((m, f))
        tickets.append(svc.submit("reconstruct", m, f))
        svc.poll()  # one engine round per arrival: fast slots free up
    for _ in range(2000):
        if all(t.done for t in tickets):
            break
        clk.advance(0.001)
        svc.poll()
    assert all(t.done for t in tickets)
    assert svc.stats()["counters"]["refills"] > 0
    for (m, f), t in zip(cases, tickets):
        assert t.outcome == "ok"
        ref = np.asarray(K.reconstruct(m, f, op="dilate"))
        assert_array_equal(np.asarray(t.result()), ref)


def test_continuous_matches_batch_path(rng):
    """continuous=True and the plain batch path must be value-identical
    on the same traffic (refill changes scheduling, never bytes)."""
    cases = [_recon_pair(rng) for _ in range(5)]
    results = {}
    for cont in (False, True):
        svc = Service(continuous=cont, max_batch=4, max_delay_ms=1e9,
                      pad_quantum=16, clock=VirtualClock())
        ts = [svc.submit("reconstruct", m, f) for m, f in cases]
        svc.flush()
        results[cont] = [np.asarray(t.result()) for t in ts]
    for a, b in zip(results[False], results[True]):
        assert_array_equal(a, b)


def test_occupancy_accounting():
    """Continuous occupancy is time-weighted: busy slot-rounds over
    total slot-rounds, not requests over slots."""
    m = ServeMetrics()
    m.record_round("b", n_busy=2, n_slots=4, t=0.0)
    m.record_round("b", n_busy=4, n_slots=4, t=1.0)
    m.record_round("b", n_busy=1, n_slots=4, t=2.0)
    s = m.summary()
    assert s["buckets"]["b"]["rounds"] == 3
    assert s["buckets"]["b"]["batch_occupancy"] == pytest.approx(7 / 12)
    # the batch-path formula still applies when no rounds were recorded
    m2 = ServeMetrics()
    m2.record_batch("c", n_real=3, n_slots=4, pixels=16, t_dispatch=0.0,
                    t_done=1.0, latencies_s=[0.1] * 3)
    assert m2.summary()["buckets"]["c"]["batch_occupancy"] == 0.75


def test_work_occupancy_chunk_weighted():
    """work_occupancy weighs by scheduler chunks, not slot fill: a
    full batch whose straggler holds the device while its mates idle
    scores low even though every slot carries a request."""
    m = ServeMetrics()
    # batch path: 4 real slots, but one ran 40 chunks while the other
    # three converged in 2 → busy 46 of a 160-chunk device reservation
    m.record_batch("b", n_real=4, n_slots=4, pixels=16, t_dispatch=0.0,
                   t_done=1.0, latencies_s=[0.1] * 4,
                   busy_chunks=46, cap_chunks=160)
    s = m.summary()["buckets"]["b"]
    assert s["batch_occupancy"] == 1.0           # fill metric saturates
    assert s["work_occupancy"] == pytest.approx(46 / 160)
    # engine rounds: refill keeps the chunk counters dense
    m2 = ServeMetrics()
    m2.record_round("c", n_busy=4, n_slots=4, t=0.0,
                    busy_chunks=8, cap_chunks=8)
    m2.record_round("c", n_busy=2, n_slots=4, t=1.0,
                    busy_chunks=4, cap_chunks=8)
    s2 = m2.summary()
    assert s2["buckets"]["c"]["work_occupancy"] == pytest.approx(12 / 16)
    assert s2["totals"]["work_occupancy"] == pytest.approx(12 / 16)
    # without chunk counters the field falls back to the fill metric
    m3 = ServeMetrics()
    m3.record_round("d", n_busy=1, n_slots=4, t=0.0)
    assert m3.summary()["buckets"]["d"]["work_occupancy"] == 0.25


def test_work_occupancy_straggler_batch_vs_engine(rng):
    """End to end: the same straggler-plus-fast traffic scores a lower
    work_occupancy on the poll batch path (the straggler's chunks
    reserve all four lanes) than fill occupancy suggests, and the
    continuous engine reports refills plus its own chunk accounting."""
    cases = [_recon_pair(rng, slow=True)] + [_recon_pair(rng)
                                             for _ in range(3)]
    svc = Service(continuous=False, max_batch=4, max_delay_ms=1e9,
                  pad_quantum=16, clock=VirtualClock())
    ts = [svc.submit("reconstruct", m, f) for m, f in cases]
    svc.flush()
    assert all(t.outcome == "ok" for t in ts)
    tot = svc.stats()["totals"]
    assert tot["batch_occupancy"] == 1.0  # all four slots held requests
    # the straggler ran ~35x its batch-mates' chunks: most of the
    # device reservation was spent on one image
    assert 0.0 < tot["work_occupancy"] < 0.5


def test_engine_occupancy_from_rounds(rng):
    """The served bucket's occupancy reflects the recorded rounds."""
    clk = VirtualClock()
    svc = Service(continuous=True, max_batch=4, refill_quantum=2,
                  max_delay_ms=1e9, pad_quantum=16, clock=clk)
    ts = [svc.submit("reconstruct", *_recon_pair(rng)) for _ in range(2)]
    svc.flush()
    assert all(t.outcome == "ok" for t in ts)
    label = next(iter(svc.stats()["buckets"]))
    b = svc.stats()["buckets"][label]
    assert b["rounds"] >= 1
    # 2 busy slots of 4 every round → exactly 0.5 while both run
    assert 0.0 < b["batch_occupancy"] <= 1.0


# ---------------------------------------------------------------------------
# shedding, close, adaptive quantum
# ---------------------------------------------------------------------------


def test_queue_full_sheds_under_virtual_clock(rng):
    clk = VirtualClock()
    svc = Service(backend="xla", max_batch=8, max_queue=2,
                  max_delay_ms=5.0, pad_quantum=16, clock=clk)
    t1 = svc.submit("hfill", _image(rng))
    t2 = svc.submit("hfill", _image(rng))
    with pytest.raises(QueueFullError):
        svc.submit("hfill", _image(rng))
    assert svc.stats()["counters"]["shed"] == 1
    clk.advance(0.01)
    svc.pump()
    while svc.work_pending():
        svc.pump()
    assert t1.outcome == "ok" and t2.outcome == "ok"
    assert svc.stats()["totals"]["requests"] == 2


def test_backpressure_watermark_launches_early(rng):
    """At the high-water mark admission force-launches the fullest
    bucket instead of waiting out max_delay."""
    clk = VirtualClock()
    svc = Service(backend="xla", max_batch=8, high_water=3,
                  max_delay_ms=1e9, pad_quantum=16, clock=clk)
    ts = [svc.submit("hfill", _image(rng)) for _ in range(3)]
    # third admission hit the watermark → bucket launched despite the
    # infinite flush delay
    assert svc.pending() == 0
    assert svc.stats()["counters"]["backpressure_flushes"] >= 1
    while svc.work_pending():
        svc.pump()
    assert all(t.outcome == "ok" for t in ts)


def test_closed_service_rejects(rng):
    svc = Service(backend="xla", max_batch=2, pad_quantum=16,
                  clock=VirtualClock())
    t = svc.submit("hfill", _image(rng))
    svc.close()
    assert svc.closed and t.done  # close drains admitted work
    with pytest.raises(ServiceClosedError):
        svc.submit("hfill", _image(rng))
    svc.close()  # idempotent


def test_adaptive_quantum_splits_on_pad_waste(rng):
    svc = Service(backend="xla", max_batch=8, max_delay_ms=1e9,
                  pad_quantum=64, adaptive_quantum=True, adapt_every=4,
                  clock=VirtualClock())
    for _ in range(4):
        svc.submit("hfill", _image(rng, (33, 33)))
    # 33x33 in 64x64 buckets: ~73% pad waste → quantum halves
    assert svc.stats()["counters"]["quantum_splits"] >= 1
    assert set(svc._quantum.values()) == {32}
    svc.flush()


def test_adaptive_quantum_merges_sparse_buckets(rng):
    svc = Service(backend="xla", max_batch=8, max_delay_ms=1e9,
                  pad_quantum=8, adaptive_quantum=True, adapt_every=4,
                  clock=VirtualClock())
    for shape in ((16, 16), (24, 24), (32, 32), (16, 16)):
        svc.submit("hfill", _image(rng, shape))
    # three quantum-aligned grids at zero pad waste → quantum doubles
    assert svc.stats()["counters"]["quantum_merges"] >= 1
    assert set(svc._quantum.values()) == {16}
    svc.flush()


# ---------------------------------------------------------------------------
# the flake detector, in process: one scenario, two replays, same counters
# ---------------------------------------------------------------------------


def test_selftest_scenario_deterministic():
    """The CI flake-detector contract: the canonical sim scenario
    replayed twice produces byte-identical summaries (counters, bucket
    rounds, outcomes) — no hidden wall-clock or ordering dependence."""
    kw = dict(continuous=True, max_batch=4, max_delay_ms=4.0,
              pad_quantum=32, refill_quantum=2)
    a = selftest_scenario(SimHarness(**kw))
    b = selftest_scenario(SimHarness(**kw))
    assert a == b
    assert sum(1 for o in a["outcomes"] if o != "pending") == len(
        a["outcomes"])
